"""End-to-end training driver: event-driven data shards → sharded train steps
→ async checkpoints → crash → elastic restore → continue.

    PYTHONPATH=src python examples/train_lm.py [--arch gemma-2b] [--steps 200]

Uses the reduced config of the chosen arch by default so a few hundred steps
run on one CPU in minutes (pass --full to use the published config on real
hardware). Demonstrates the full substrate: the pub/sub shard queue
(at-least-once data delivery), AdamW with grad accumulation, async
checkpointing, and a simulated mid-run crash + restore.
"""
import argparse
import sys
import tempfile
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import SimScheduler, Topic
from repro.data import ShardQueue, TokenDataset
from repro.train import TrainConfig, init_train_state, make_train_step
from repro.train.checkpoint import AsyncCheckpointer, restore_checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--full", action="store_true",
                    help="published config (needs real accelerators)")
    ap.add_argument("--compress", action="store_true",
                    help="int8 error-feedback gradient compression")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    tc = TrainConfig(lr=3e-3, warmup_steps=10, total_steps=args.steps,
                     compress="int8_ef" if args.compress else "none")
    print(f"arch={cfg.name} d_model={cfg.d_model} L={cfg.num_layers} "
          f"steps={args.steps} compress={tc.compress}")

    step_fn = jax.jit(make_train_step(cfg, tc))
    state = init_train_state(cfg, tc, jax.random.PRNGKey(0))
    ds = TokenDataset(cfg.vocab_size, args.seq, seed=0)

    # event-driven shard dispatch (the paper's pattern at the data layer)
    sched = SimScheduler()
    topic = Topic("train-shards", sched)
    queue = ShardQueue(topic)
    queue.publish_epoch(n_shards=args.steps)
    sched.run()

    ckpt_dir = Path(tempfile.mkdtemp(prefix="repro_ckpt_"))
    ck = AsyncCheckpointer(ckpt_dir, keep=2)
    t0 = time.time()
    crash_at = args.steps // 2
    i = 0
    while True:
        item = queue.poll()
        if item is None:
            sched.run()
            if queue.poll() is None:
                break
            continue
        shard, ack = item
        batch = {k: jnp.asarray(v)
                 for k, v in ds.shard_batch(shard["shard"], args.batch).items()}
        if cfg.family in ("vlm", "audio"):
            batch["cond"] = jnp.zeros(
                (args.batch, cfg.n_cross_tokens, cfg.d_model), cfg.dtype)
        state, m = step_fn(state, batch)
        ack()  # shard consumed — at-least-once bookkeeping
        i += 1
        if i % 20 == 0 or i == 1:
            print(f"step {i:4d} loss {float(m['loss']):.4f} "
                  f"lr {float(m['lr']):.2e} gnorm {float(m['grad_norm']):.2f} "
                  f"({(time.time()-t0)/i:.2f}s/step)")
        if i % 50 == 0:
            ck.save(i, state)
        if i == crash_at:
            ck.save(i, state)
            ck.wait()
            print(f"-- simulated crash at step {i}; elastic restore --")
            abstract = jax.tree_util.tree_map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
            state, restored_step = restore_checkpoint(ckpt_dir, abstract)
            assert restored_step == i
        if i >= args.steps:
            break
    ck.wait()
    print(f"done: {i} steps, final loss {float(m['loss']):.4f}, "
          f"checkpoints in {ckpt_dir}")


if __name__ == "__main__":
    main()
