"""Institutional-scale batch conversion — the paper's Figure 2/3 experiment.

    PYTHONPATH=src python examples/institutional_batch.py [--images 50]

Runs the three workflows (serial, 16-way parallel VM pool, event-driven
autoscaling) at the paper's scale in the discrete-event simulator, calibrated
by a real measured conversion, and prints the comparison plus the Figure-3
instance timeline.
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.fig2_workflows import (autoscaling_time, measure_service_time,
                                       parallel_time, serial_time)
from benchmarks.fig3_autoscaling import run as fig3_run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--images", type=int, default=50)
    ap.add_argument("--tau", type=float, default=90.0,
                    help="per-slide conversion seconds at paper scale")
    args = ap.parse_args()

    tau_m = measure_service_time()
    print(f"measured per-slide conversion (256² synthetic): {tau_m:.3f}s")
    print(f"simulating at paper scale with tau={args.tau}s\n")

    print(f"{'n':>4} {'serial':>10} {'parallel16':>11} {'autoscaling':>12}")
    for n in (1, 10, 25, args.images):
        s = serial_time(n, args.tau)
        p = parallel_time(n, args.tau)
        a = autoscaling_time(n, args.tau)
        print(f"{n:>4} {s:>9.0f}s {p:>10.0f}s {a:>11.0f}s")

    print("\nFigure 3 — avg instances per minute (50-slide burst):")
    minutes, pipe = fig3_run(n=args.images, tau=args.tau)
    for m, v in minutes:
        print(f"  {m:3d}m | {'#' * int(v)} {v:.0f}")
    print(f"\ncold starts: {pipe.service.cold_starts}, "
          f"conversions: {pipe.done_count()}")


if __name__ == "__main__":
    main()
