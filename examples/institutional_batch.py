"""Institutional-scale batch conversion — the paper's Figure 2/3 experiment,
with a real event-driven multi-slide batch up front.

    PYTHONPATH=src python examples/institutional_batch.py [--images 50]
        [--real-slides 4] [--real-size 1024] [--concurrency N]

What it demonstrates, and what to expect:

1. **Real mode** — ``--real-slides`` synthetic PSV slides are dropped into
   the landing bucket of a ``RealScheduler``-backed ``ConversionPipeline``;
   the event chain (object notification → pub/sub push → autoscaled
   wsi2dcm service) converts them with the pipelined JAX engine, up to
   ``--concurrency`` in parallel per instance (default: cores // 2).
   Prints the batch wall time vs the serial-sync equivalent and verifies
   every study landed in the DICOM store. ``auto_export=True`` closes the
   retrieval loop: every stored instance triggers the dicom2tiff export
   hop, and the final printout reports the ``pipeline.export.*`` counters
   plus the derived-bucket tiled TIFFs.
2. **Paper scale** — the three workflows (serial, 16-way parallel VM pool,
   event-driven autoscaling) simulated at the paper's scale in the
   discrete-event simulator, calibrated by the measured real conversion,
   and the Figure-3 instance timeline for a 50-slide burst. Expect
   autoscaling to lose at n=1 (cold start) and win clearly by n≥10.
"""
import argparse
import os
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.fig2_workflows import (autoscaling_time, measure_service_time,
                                       parallel_time, serial_time)
from benchmarks.fig3_autoscaling import run as fig3_run

from repro.core import ConversionPipeline, RealScheduler, tracing
from repro.core.dashboard import build_report
from repro.wsi import (ConvertOptions, SyntheticScanner, convert_wsi_to_dicom,
                       study_levels)


def run_real_batch(n: int, size: int, concurrency: int) -> None:
    """Push a real multi-slide batch through the event-driven wiring."""
    scanner = SyntheticScanner(seed=42)
    slides = {f"slides/batch{i:03d}.psv": scanner.scan(size, size, 256)
              for i in range(n)}

    def convert(data: bytes, meta: dict) -> bytes:
        return convert_wsi_to_dicom(data, meta,
                                    options=ConvertOptions(pipelined=True))

    # warm the jit caches so neither variant pays compile time
    first = next(iter(slides.values()))
    convert_wsi_to_dicom(first, options=ConvertOptions(pipelined=False))
    convert_wsi_to_dicom(first, options=ConvertOptions(pipelined=True))

    # serial-sync reference: the same slides, one at a time, no pipelining
    t0 = time.perf_counter()
    for key, psv in slides.items():
        convert_wsi_to_dicom(psv, {"slide_id": key},
                             options=ConvertOptions(pipelined=False))
    t_serial = time.perf_counter() - t0

    # one instance, `concurrency` parallel conversions: the per-instance
    # concurrency this PR adds (instance scale-out is what the paper-scale
    # simulation below demonstrates)
    sched = RealScheduler(workers=max(8, 4 * concurrency))
    with tracing.capture(now=sched.now) as tracer:
        pipe = ConversionPipeline(
            sched, convert=convert, max_instances=1,
            concurrency=concurrency, cold_start=0.0, scale_down_delay=5.0,
            auto_export=True,
        )
        t0 = time.perf_counter()
        pipe.run_batch(slides)
        t_batch = time.perf_counter() - t0
        sched.run(until=30.0)  # store ingest + subscribers + export drain

    print(f"real event-driven batch: {n} × {size}² slides, "
          f"concurrency={concurrency}")
    print(f"  serial sync loop : {t_serial:6.2f}s")
    print(f"  event-driven     : {t_batch:6.2f}s "
          f"({t_serial / t_batch:.2f}× vs serial sync)")
    for key in pipe.dicom.list():
        study = study_levels(pipe.dicom.get(key).data)
        n_dcm = sum(1 for k in study if k.endswith(".dcm"))
        print(f"  gs://dicom-store/{key}: {n_dcm} levels, "
              f"{len(pipe.dicom.get(key).data):,} bytes")
    studies = pipe.store_service.search_studies()
    print(f"  enterprise store: {len(studies)} studies, "
          f"{sum(pipe.store_service.study_summary(s)['n_instances'] for s in studies)} instances | "
          f"validated: {len(pipe.validator.checked)}, "
          f"ml-scored: {len(pipe.ml_subscriber.predictions)}")
    g = pipe.metrics.get
    print(f"  dicom2tiff export (auto, event-driven): "
          f"requests={g('pipeline.export.requests'):g}, "
          f"frames decoded={g('pipeline.export.frames_decoded'):g}, "
          f"bytes written={g('pipeline.export.bytes_written'):,.0f}, "
          f"dead-lettered={g('pipeline.export.dead_lettered'):g}")
    print(f"  gs://wsi-derived: {len(pipe.derived.list())} level TIFFs "
          f"across {len(studies)} studies")
    print(f"  cold starts: {pipe.service.cold_starts}, "
          f"acks: {g('sub.wsi2dcm-push.acks'):g}")
    # the dashboard's per-slide critical path: where each slide's
    # end-to-end time went (broker/queue vs conversion vs store I/O)
    report = build_report(pipe.metrics, tracer, title="real batch")
    lat = report["histograms"].get("sub.wsi2dcm-push.latency", {})
    if lat:
        print(f"  delivery latency: p50={lat['p50']:.2f}s "
              f"p95={lat['p95']:.2f}s p99={lat['p99']:.2f}s")
    for t in report["traces"]:
        a, dur = t["attribution"], max(t["duration"], 1e-9)
        print(f"  trace {t['slide']}: {t['duration']:.2f}s = "
              f"queue {100 * a['queue'] / dur:.0f}% + "
              f"compute {100 * a['compute'] / dur:.0f}% + "
              f"store {100 * a['store'] / dur:.0f}% "
              f"({t['n_spans']} spans, "
              f"{'OK' if not t['problems'] else t['problems']})")
    print()
    sched.shutdown()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--images", type=int, default=50,
                    help="batch size for the paper-scale simulation")
    ap.add_argument("--tau", type=float, default=90.0,
                    help="per-slide conversion seconds at paper scale")
    ap.add_argument("--real-slides", type=int, default=4,
                    help="slides in the real event-driven batch (0 skips)")
    ap.add_argument("--real-size", type=int, default=1024,
                    help="real slide edge length (pixels, multiple of 256)")
    ap.add_argument("--concurrency", type=int,
                    default=max(1, (os.cpu_count() or 2) // 2),
                    help="parallel conversions per instance in real mode")
    args = ap.parse_args()

    if args.real_slides > 0:
        run_real_batch(args.real_slides, args.real_size, args.concurrency)

    tau_m = measure_service_time()
    print(f"measured per-slide conversion (256² synthetic): {tau_m:.3f}s")
    print(f"simulating at paper scale with tau={args.tau}s\n")

    print(f"{'n':>4} {'serial':>10} {'parallel16':>11} {'autoscaling':>12}")
    for n in (1, 10, 25, args.images):
        s = serial_time(n, args.tau)
        p = parallel_time(n, args.tau)
        a = autoscaling_time(n, args.tau)
        print(f"{n:>4} {s:>9.0f}s {p:>10.0f}s {a:>11.0f}s")

    print("\nFigure 3 — avg instances per minute (50-slide burst):")
    minutes, pipe = fig3_run(n=args.images, tau=args.tau)
    for m, v in minutes:
        print(f"  {m:3d}m | {'#' * int(v)} {v:.0f}")
    print(f"\ncold starts: {pipe.service.cold_starts}, "
          f"conversions: {pipe.done_count()}")


if __name__ == "__main__":
    main()
