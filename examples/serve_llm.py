"""Event-driven LM serving: pub/sub request intake → continuous batching.

    PYTHONPATH=src python examples/serve_llm.py [--arch gemma-2b] [--requests 8]

The serving analogue of the paper's pipeline: requests land on a topic, the
engine (an autoscalable "container") consumes them with continuous batching
over a shared KV cache, and completions publish to a response topic.
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.core import SimScheduler, Subscription, Topic
from repro.models import model as M
from repro.serve.engine import ContinuousBatchingEngine, PubSubFrontend


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--kv8", action="store_true",
                    help="int8-quantized KV cache")
    args = ap.parse_args()

    arch = args.arch + ("-smoke+kv8" if args.kv8 else "-smoke")
    cfg = get_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    print(f"serving {cfg.name}: {args.slots} slots, kv={cfg.kv_cache_dtype}")

    sched = SimScheduler()
    req_topic = Topic("inference-requests", sched)
    resp_topic = Topic("inference-responses", sched)
    responses = []
    Subscription(resp_topic, "client",
                 lambda m, c: (responses.append(m.data), c.ack()))
    engine = ContinuousBatchingEngine(cfg, params, batch_size=args.slots,
                                      max_len=128)
    PubSubFrontend(engine, req_topic, resp_topic)

    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=4 + i % 5).tolist()
        req_topic.publish({"request_id": i, "prompt": prompt,
                           "max_new_tokens": args.max_new})
    sched.run(until=0.0)  # deliver requests into the engine
    engine.run_until_drained()
    sched.run()  # flush responses
    dt = time.time() - t0

    total_tokens = sum(len(r["tokens"]) for r in responses)
    for r in sorted(responses, key=lambda r: r["request_id"]):
        print(f"  req {r['request_id']}: {r['tokens']}")
    print(f"{len(responses)} responses, {total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens/dt:.1f} tok/s, {engine.steps} engine ticks — "
          f"{total_tokens/max(engine.steps,1):.2f} tokens/tick from batching)")
    assert len(responses) == args.requests


if __name__ == "__main__":
    main()
