"""Quickstart: a mixed-format batch through the event-driven pipeline.

    PYTHONPATH=src python examples/quickstart.py

Scans one synthetic slide and drops it in the landing bucket **twice** —
as the scanner's proprietary PSV container and as an SVS-shaped tiled
TIFF — then lets the event chain do the rest: object-creation
notification → pub/sub topic → push subscription → autoscaled converter
(which sniffs each container by magic bytes and runs the pipelined
JAX/Pallas transform + host Huffman engine) → DICOM-store bucket → store
ingest → enterprise DICOM store → validation + ML-inference subscribers.
Then reads the DICOM studies back, verifies them, and drives the export
hop: one study is re-materialized as a tiled-TIFF pyramid in the derived
bucket (batched inverse JPEG path) and reopened through the sniffer.

Expected output: both container byte counts, two converted studies in the
DICOM store (one .dcm per pyramid level — a 512² slide yields 2 levels),
each level's dimensions/frame count/transfer syntax, a level-0 PSNR in
the 30–40 dB range against the scanner's pixels, the enterprise store's
QIDO view of the studies with the validation verdicts and the mock ML
model's decoded per-frame pixel stats (fetched via indexed frame-level
WADO), the exported level TIFFs, and finally the **single dashboard**:
latency-histogram percentiles, each slide's end-to-end trace with its
queue/compute/store critical-path split, and the metric counters (note
``pipeline.format.psv``/``pipeline.format.tiff`` and the
``pipeline.export.*`` family) — then a final "quickstart OK".
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import ConversionPipeline, RealScheduler, tracing
from repro.core.dashboard import build_report, render_text
from repro.wsi import (PSVReader, SyntheticScanner, convert_wsi_to_dicom,
                       decode_tile, psnr, read_part10, study_levels)


def main():
    print("== scanner: one 512x512 slide (4 tiles), two containers ==")
    scanner = SyntheticScanner(seed=7)
    psv = scanner.scan(512, 512, 256)
    tif = scanner.scan_tiff(512, 512, 256)
    print(f"   PSV container:        {len(psv):,} bytes")
    print(f"   tiled-TIFF container: {len(tif):,} bytes")

    print("== pipeline: mixed landing bucket → pub/sub → sniffing converter ==")
    sched = RealScheduler(workers=2)
    # arm the distributed tracer: every hop below lands in one span tree
    # per slide, rendered by the dashboard at the end
    tracer = tracing.arm(now=sched.now)
    pipe = ConversionPipeline(
        sched, convert=lambda data, meta: convert_wsi_to_dicom(data, meta),
        max_instances=2, cold_start=0.0, scale_down_delay=2.0,
    )
    pipe.ingest("slides/quickstart.psv", psv, {"slide_id": "QS-1"})
    pipe.ingest("slides/quickstart-tiff.svs", tif, {"slide_id": "QS-2"})
    sched.run(until=300.0)
    assert pipe.done_count() == 2, "conversions did not finish"

    print("== DICOM store contents ==")
    for key in pipe.dicom.list():
        obj = pipe.dicom.get(key)
        print(f"   gs://dicom-store/{key}  {len(obj.data):,} bytes")

    study = study_levels(pipe.dicom.get("slides/quickstart.dcm").data)
    for name in sorted(study):
        if not name.endswith(".dcm"):
            continue
        ds, frames = read_part10(study[name])
        print(f"   {name}: {ds.get_int(0x0048, 0x0007)}x"
              f"{ds.get_int(0x0048, 0x0006)} total, "
              f"{ds.get_int(0x0028, 0x0008)} frames, "
              f"ts={ds.get_str(0x0002, 0x0010)}")

    ds, frames = read_part10(study["level_0.dcm"])
    tile0 = PSVReader(psv).read_tile(0, 0)
    rec = decode_tile(bytes(frames[0]).rstrip(b"\x00") or frames[0])
    print(f"== fidelity: level-0 frame-0 PSNR vs scanner output: "
          f"{psnr(tile0, rec):.1f} dB ==")

    print("== enterprise DICOM store (QIDO) + subscribers ==")
    svc = pipe.store_service
    for study_uid in svc.search_studies(modality="SM"):
        s = svc.study_summary(study_uid)
        print(f"   study {study_uid[:24]}…: {s['n_series']} series, "
              f"{s['n_instances']} instances, {s['total_frames']} frames")
    print(f"   validation: {len(pipe.validator.checked)} passed, "
          f"{len(pipe.validator.quarantined)} quarantined")
    for sop, pred in sorted(pipe.ml_subscriber.predictions.items()):
        feats = ", ".join(f"{st['mean']:.1f}±{st['std']:.0f}"
                          for st in pred["pixel_stats"])
        print(f"   ml-inference {sop[-12:]}: {pred['frames_scored']} "
              f"frames decoded via WADO, pixel mean±std [{feats}]")

    print("== export hop: study → derived tiled-TIFF pyramid ==")
    from repro.wsi import open_slide

    export_study = svc.search_studies()[0]
    pipe.request_export(export_study)
    sched.run(until=60.0)
    for key in pipe.derived.list():
        rd = open_slide(pipe.derived.get(key).data)
        print(f"   gs://wsi-derived/{key[-18:]}: {rd.H}x{rd.W} "
              f"{type(rd).__name__} (level {rd.metadata['level']}) — "
              "reopens via the sniffer")

    print("== the single dashboard: histograms, traces, counters ==")
    tracing.disarm()
    print(render_text(build_report(pipe.metrics, tracer,
                                   title="quickstart")))
    sched.shutdown()
    print("quickstart OK")


if __name__ == "__main__":
    main()
