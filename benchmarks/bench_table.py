"""Render the README benchmark tables from ``BENCH_convert.json`` (and,
when present, ``BENCH_store.json`` / ``BENCH_export.json`` /
``BENCH_kernels.json``).

    PYTHONPATH=src python -m benchmarks.bench_table [BENCH_convert.json]

Prints GitHub-flavored markdown. The tables embedded in README.md are the
output of this script over the checked-in ``BENCH_*.json``; re-run
``make bench`` followed by this module to refresh them after a change to
the conversion or store hot paths.
"""
from __future__ import annotations

import json
import os
import sys


def render(bench: dict) -> str:
    s = bench["slide"]
    te = bench["tile_encode_256"]
    e2e = bench["end_to_end"]
    ms = bench["multi_slide"]
    lines = [
        f"Single slide ({s['hw']}×{s['hw']}, {s['tiles']} tiles of "
        f"{s['tile']}²):",
        "",
        "| path | tile encode (µs/tile) | end-to-end (MPix/s) | vs sync |",
        "|---|---|---|---|",
        f"| per-tile (seed) | {te['per_tile_us']:,.0f} | "
        f"{e2e['per_tile_mpix_s']:.2f} | "
        f"{e2e['per_tile_mpix_s'] / e2e['sync_mpix_s']:.2f}× |",
        f"| batched sync | {te['batched_us']:,.0f} | "
        f"{e2e['sync_mpix_s']:.2f} | 1.00× |",
        f"| pipelined | {te['batched_us']:,.0f} | "
        f"{e2e['pipelined_mpix_s']:.2f} | "
        f"{e2e['pipelined_speedup_vs_sync']:.2f}× |",
        "",
        f"Multi-slide batch ({ms['n_slides']} × {ms['hw']}² slides, "
        f"{ms['max_instances']} instance × concurrency "
        f"{ms['concurrency']}):",
        "",
        "| path | batch wall (s) | MPix/s | vs sync |",
        "|---|---|---|---|",
        f"| sync serial | {ms['sync_s']:.3f} | {ms['sync_mpix_s']:.2f} | "
        "1.00× |",
        f"| pipelined serial | {ms['pipelined_s']:.3f} | "
        f"{ms['pipelined_mpix_s']:.2f} | {ms['pipelined_speedup']:.2f}× |",
        f"| pipelined + concurrent (event-driven) | {ms['concurrent_s']:.3f}"
        f" | {ms['concurrent_mpix_s']:.2f} | "
        f"{ms['concurrent_speedup']:.2f}× |",
        "",
        f"All paths emit byte-identical study tars "
        f"(asserted in the run: {ms['bytes_identical']}).",
    ]
    mx = bench.get("mixed_format")
    if mx:
        per_fmt = ", ".join(f"{n} {f}" for f, n in
                            sorted(mx["formats_converted"].items()))
        lines += [
            "",
            f"Mixed-format landing bucket ({mx['n_slides']} × {mx['hw']}² "
            f"slides: {per_fmt}; 1 instance × concurrency "
            f"{mx['concurrency']}):",
            "",
            "| metric | value |",
            "|---|---|",
            f"| batch wall (s) | {mx['batch_s']:.3f} |",
            f"| throughput (MPix/s) | {mx['mpix_s']:.2f} |",
            f"| PSV vs TIFF study tars byte-identical | "
            f"{mx['cross_format_bytes_identical']} |",
        ]
    return "\n".join(lines)


def render_store(bench: dict) -> str:
    w = bench["wado"]
    return "\n".join([
        f"Frame-level WADO ({w['n_frames']}-frame encapsulated instance, "
        f"{w['instance_bytes']:,} bytes):",
        "",
        "| path | µs/frame fetch | vs reparse |",
        "|---|---|---|",
        f"| reparse per fetch (seed) | {w['reparse_us_per_frame']:,.0f} | "
        "1× |",
        f"| `Part10Index` (cached) | {w['indexed_us_per_frame']:.2f} | "
        f"{w['indexed_speedup']:,.0f}× |",
        f"| store service (`retrieve_frame`) | "
        f"{w['store_us_per_frame']:.2f} | {w['store_speedup']:,.0f}× |",
        "",
        f"Frames byte-identical across all paths "
        f"(asserted in the run: {w['bytes_identical']}).",
    ])


def render_export(bench: dict) -> str:
    d = bench["decode"]
    e = bench["export"]
    lines = [
        f"Whole-level JPEG decode ({d['n_tiles']} tiles of {d['tile']}², "
        f"a {d['hw']}×{d['hw']} level):",
        "",
        "| path | decode (µs/tile) | vs per-tile |",
        "|---|---|---|",
        f"| per-tile loop (seed) | {d['per_tile_us']:,.0f} | 1.00× |",
        f"| batched (`decode_tiles_batch`) | {d['batched_us']:,.0f} | "
        f"{d['speedup']:.2f}× |",
        "",
        "Batch scaling (the lockstep entropy decoder amortizes across "
        "tiles): "
        + ", ".join(f"{s['speedup']:.2f}× at n={s['n_tiles']}"
                    for s in d["batch_scaling"])
        + f". Pixel-identical to the per-tile loop and coefficient-exact "
        f"round-trip asserted in the run: {d['pixel_identical']} / "
        f"{d['coef_roundtrip_exact']}.",
        "",
        f"Study export ({e['slide_hw']}² slide → "
        f"{e['levels_exported']}-level tiled-TIFF pyramid, "
        f"{e['frames_decoded']} frames over WADO):",
        "",
        "| metric | value |",
        "|---|---|",
        f"| export wall (s) | {e['export_s']:.3f} |",
        f"| throughput (MPix/s) | {e['mpix_s']:.2f} |",
        f"| repeated export byte-identical | {e['repeat_identical']} |",
        f"| export after crash + `rebuild_index()` byte-identical | "
        f"{e['rebuild_identical']} |",
        f"| exported TIFFs reopen via `open_slide` | "
        f"{e['reopens_via_sniffer']} |",
    ]
    return "\n".join(lines)


def render_kernels(bench: dict) -> str:
    rb = bench["roofline_batch"]
    lines = [
        f"Kernel roofline ({rb['n_tiles']}-tile level batch of "
        f"{rb['tile']}² tiles, {bench['hw']['name']} targets; terms from "
        f"the SPMD-partitioned HLO via `roofline.analyze_hlo` + "
        f"`derive_terms`):",
        "",
        "| kernel | devices | bound | compute µs | memory µs | "
        "collective µs | mfu bound |",
        "|---|---|---|---|---|---|---|",
    ]
    for kernel, per_d in bench["roofline"].items():
        for d, t in sorted(per_d.items(), key=lambda kv: int(kv[0])):
            lines.append(
                f"| `{kernel}` | {d} | "
                f"{t['dominant'].replace('_s', '')} | "
                f"{t['compute_s']*1e6:.1f} | {t['memory_s']*1e6:.1f} | "
                f"{t['collective_s']*1e6:.1f} | {t['mfu_bound']:.4f} |")
    scaling = bench.get("batch_scaling")
    if scaling:
        lines += [
            "",
            "Batch scaling (fused transform dispatch, µs/tile — flat "
            "across batch sizes, no small-batch recompile cliff; "
            "asserted in the run): "
            + ", ".join(f"{s['transform_us_per_tile']:,.0f} at "
                        f"n={s['n_tiles']}" for s in scaling) + ".",
        ]
    return "\n".join(lines)


def render_fleet(bench: dict) -> str:
    f2 = bench["fig2"]
    f3 = bench["fig3"]
    fi = bench["fault_injection"]
    bp = bench["backpressure"]
    by_n: dict[int, dict[str, float]] = {}
    for r in f2["rows"]:
        if r["workflow"] != "calibration":
            by_n.setdefault(r["n"], {})[r["workflow"]] = r["seconds"]
    lines = [
        f"Figure 2 — batch completion time (τ={f2['tau_s']:.0f} s/slide, "
        f"cold start {f2['cold_start_s']:.0f} s; simulated fleet with "
        "per-instance queues + controller scaling):",
        "",
        "| n slides | serial (s) | 16-way parallel (s) | "
        "event-driven fleet (s) |",
        "|---|---|---|---|",
    ]
    for n in sorted(by_n):
        t = by_n[n]
        lines.append(f"| {n} | {t['serial']:,.0f} | {t['parallel16']:,.0f} |"
                     f" {t['event_driven_fleet']:,.0f} |")
    lines += [
        "",
        "Cold start makes the fleet lose at n=1 and win at n≥10 "
        "(asserted in the run: "
        + ", ".join(f"{k}={v}" for k, v in f2["crossover"].items()) + ").",
        "",
        f"Figure 3 — avg container instances per minute, {f3['n_slides']}-"
        f"slide burst (peak {f3['peak_avg_instances']:.0f}, instantaneous "
        f"max {f3['peak_instantaneous']:.0f} ≤ max_instances="
        f"{f3['max_instances']}, decays to zero: {f3['decays_to_zero']}):",
        "",
        "| minute | " + " | ".join(str(m) for m, _ in f3["minutes"]) + " |",
        "|---|" + "---|" * len(f3["minutes"]),
        "| instances | "
        + " | ".join(f"{v:.0f}" for _, v in f3["minutes"]) + " |",
        "",
        f"Fault-injection gauntlet ({fi['n_slides']} real conversions under "
        f"`SimScheduler`, {fi['n_shards']}-shard store): "
        + "/".join(f"{v} {k}" for k, v in
                   sorted(fi["faults_injected"].items()))
        + " deliveries faulted, 1 instance kill, 1 shard crash → "
        f"{fi['dead_lettered']} dead-lettered, "
        f"{fi['study_tar_writes']} study-tar writes "
        f"(one per slide), byte-identical to a serial conversion: "
        f"{fi['byte_identical_to_serial']}; crash + `rebuild_index()` "
        f"QIDO/WADO identical: {fi['crash_rebuild_identical']}. "
        f"Backpressure: {bp['shed']} sheds → {bp['budget_exempt_requeues']} "
        f"budget-exempt requeues, {bp['completed']}/{bp['n_slides']} "
        f"completed, {bp['dead_lettered']} dead-lettered.",
    ]
    return "\n".join(lines)


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_convert.json"
    with open(path) as f:
        bench = json.load(f)
    print(render(bench))
    base = os.path.dirname(path) or "."
    for name, renderer in (("BENCH_store.json", render_store),
                           ("BENCH_export.json", render_export),
                           ("BENCH_kernels.json", render_kernels),
                           ("BENCH_fleet.json", render_fleet)):
        extra = os.path.join(base, name)
        if os.path.exists(extra):
            with open(extra) as f:
                print()
                print(renderer(json.load(f)))


if __name__ == "__main__":
    main()
