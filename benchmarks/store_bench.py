"""DICOM store benchmark: indexed WADO, STOW/QIDO, crash rebuild.

WADO section (the acceptance-gated one): a 256-frame encapsulated WSM
instance is served frame-by-frame two ways —

- **reparse baseline** — every fetch runs ``read_part10(blob)[1][i]``,
  i.e. a full Part-10 parse materializing all 256 frames to return one
  (what the seed ``DicomStoreService.retrieve_frame`` did);
- **indexed** — one :class:`~repro.wsi.dicom.Part10Index` scan, then each
  fetch is a single slice at the indexed offset; also measured through
  ``DicomStoreService.retrieve_frame`` (bucket read + LRU'd index).

Every frame is asserted byte-identical between the paths, and the indexed
path must be ≥ 10× faster per fetch (it is orders of magnitude faster —
O(frame) vs O(file)).

Store section: STOW throughput for converted study archives, re-STOW
idempotency (QIDO/WADO snapshots byte-identical), QIDO query latency, and
crash recovery — the index rebuilt from the bucket checkpoint + blob
rescan must serve a byte-identical snapshot.

Writes ``BENCH_store.json`` and prints a CSV summary. ``--fast`` shrinks
fetch counts/reps for the CI smoke; the byte-identity and ≥ 10× WADO
assertions are identical in both modes.
"""
from __future__ import annotations

import argparse
import json
import time

from repro.core import SimScheduler
from repro.core.storage import ObjectStore
from repro.wsi.convert import ConvertOptions, convert_wsi_to_dicom
from repro.wsi.dicom import Part10Index, read_part10, write_part10
from repro.wsi.jpeg import encode_tile
from repro.wsi.slide import PSVReader, SyntheticScanner
from repro.wsi.store_service import DicomStoreService

N_FRAMES = 256


def _time_per(fn, n: int, reps: int) -> float:
    """Average seconds per op over ``reps`` rounds of ``n`` calls."""
    fn(0)  # warm
    t0 = time.perf_counter()
    for _ in range(reps):
        for i in range(n):
            fn(i)
    return (time.perf_counter() - t0) / (reps * n)


def _make_instance(n_frames: int) -> bytes:
    """One encapsulated WSM instance with ``n_frames`` JPEG tile frames."""
    rd = PSVReader(SyntheticScanner(seed=11).scan(512, 512, 256))
    bh, bw = rd.grid
    jpgs = [encode_tile(rd.read_tile(r, c)[:64, :64])
            for r in range(bh) for c in range(bw)]
    frames = [jpgs[i % len(jpgs)] for i in range(n_frames)]
    side = int(n_frames ** 0.5) or 1
    return write_part10(frames=frames, rows=64, cols=64,
                        total_rows=side * 64, total_cols=side * 64)


def _wado_section(fetches: int, reps: int) -> dict:
    blob = _make_instance(N_FRAMES)

    # all three paths must serve byte-identical frames
    ref_frames = read_part10(blob)[1]
    idx = Part10Index(blob)
    assert [idx.read_frame(i) for i in range(N_FRAMES)] == ref_frames, \
        "indexed frames diverge from read_part10"

    sched = SimScheduler()
    svc = DicomStoreService(ObjectStore(sched).bucket("dicom"), sched)
    sop = svc.store_instance(blob)
    assert [svc.retrieve_frame(sop, i) for i in range(N_FRAMES)] \
        == ref_frames, "store-served frames diverge from read_part10"

    fetches = min(fetches, N_FRAMES)
    t_reparse = _time_per(lambda i: read_part10(blob)[1][i], fetches,
                          max(1, reps // 2))
    t_indexed = _time_per(idx.read_frame, fetches, reps)
    t_store = _time_per(lambda i: svc.retrieve_frame(sop, i), fetches, reps)
    speedup = t_reparse / t_indexed
    store_speedup = t_reparse / t_store
    assert speedup >= 10.0, \
        f"indexed WADO only {speedup:.1f}x over reparse-per-fetch (< 10x)"
    return {
        "n_frames": N_FRAMES,
        "instance_bytes": len(blob),
        "fetches": fetches,
        "reparse_us_per_frame": t_reparse * 1e6,
        "indexed_us_per_frame": t_indexed * 1e6,
        "store_us_per_frame": t_store * 1e6,
        "indexed_speedup": speedup,
        "store_speedup": store_speedup,
        "bytes_identical": True,
    }


def _qido_wado_snapshot(svc: DicomStoreService, *, frames_per: int = 1,
                        drop: tuple[str, ...] = ()) -> dict:
    """Everything QIDO/WADO serve, for byte-identity comparisons."""
    snap = {}
    for study in svc.search_studies():
        snap[study] = {
            "summary": svc.study_summary(study),
            "series": svc.search_series(study),
            "instances": [
                {**{k: v for k, v in m.items() if k not in drop},
                 "blob": svc.retrieve(m["sop_instance_uid"]),
                 "frames": [svc.retrieve_frame(m["sop_instance_uid"], i)
                            for i in range(min(m["frames"] or 0,
                                               frames_per))]}
                for m in svc.search_instances(study)],
        }
    return snap


def _store_section(n_studies: int, slide: int) -> dict:
    archives = {
        f"studies/s{i:02d}.tar":
            convert_wsi_to_dicom(
                SyntheticScanner(seed=40 + i).scan(slide, slide, 256),
                {"slide_id": f"S{i}"},
                options=ConvertOptions(min_level_size=slide // 2))
        for i in range(n_studies)}

    sched = SimScheduler()
    bucket = ObjectStore(sched).bucket("dicom")
    svc = DicomStoreService(bucket, sched)

    t0 = time.perf_counter()
    for key, archive in archives.items():
        svc.store_study_archive(key, archive)
    t_stow = time.perf_counter() - t0
    clean = _qido_wado_snapshot(svc)

    # re-STOW everything: idempotent, snapshot byte-identical
    t0 = time.perf_counter()
    for key, archive in archives.items():
        svc.store_study_archive(key, archive)
    t_restow = time.perf_counter() - t0
    assert _qido_wado_snapshot(svc) == clean, \
        "re-STOW changed QIDO/WADO results"

    # QIDO latency over the filled store
    t0 = time.perf_counter()
    n_hits = sum(len(svc.search_instances(s))
                 for s in svc.search_studies(modality="SM"))
    t_qido = time.perf_counter() - t0

    # crash: a fresh service over the same bucket rebuilds from the
    # checkpoint + blob rescan and serves a byte-identical snapshot
    svc2 = DicomStoreService(bucket, sched)
    t0 = time.perf_counter()
    reparsed = svc2.rebuild_index()
    t_rebuild = time.perf_counter() - t0
    assert _qido_wado_snapshot(svc2) == clean, \
        "crash rebuild changed QIDO/WADO results"

    n_instances = sum(len(s["instances"]) for s in clean.values())
    return {
        "n_studies": n_studies,
        "n_instances": n_instances,
        "stow_ms_per_study": t_stow / n_studies * 1e3,
        "restow_ms_per_study": t_restow / n_studies * 1e3,
        "qido_ms": t_qido * 1e3,
        "qido_instances_matched": n_hits,
        "rebuild_ms": t_rebuild * 1e3,
        "rebuild_reparsed": reparsed,
        "restow_identical": True,
        "rebuild_identical": True,
    }


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke: fewer fetches/studies, same assertions")
    args = ap.parse_args(argv)
    fetches = 64 if args.fast else N_FRAMES
    reps = 1 if args.fast else 3
    n_studies = 3 if args.fast else 8
    slide = 512

    wado = _wado_section(fetches, reps)
    store = _store_section(n_studies, slide)
    result = {"wado": wado, "store": store}
    with open("BENCH_store.json", "w") as f:
        json.dump(result, f, indent=2)

    print("name,value,derived")
    print(f"wado_reparse_us,{wado['reparse_us_per_frame']:.0f},"
          f"{wado['n_frames']}frames/{wado['instance_bytes']}B")
    print(f"wado_indexed_us,{wado['indexed_us_per_frame']:.2f},"
          f"speedup={wado['indexed_speedup']:.0f}x "
          f"identical={wado['bytes_identical']}")
    print(f"wado_store_us,{wado['store_us_per_frame']:.2f},"
          f"speedup={wado['store_speedup']:.0f}x")
    print(f"stow_ms_per_study,{store['stow_ms_per_study']:.1f},"
          f"{store['n_studies']}studies/{store['n_instances']}instances")
    print(f"restow_ms_per_study,{store['restow_ms_per_study']:.1f},"
          f"identical={store['restow_identical']}")
    print(f"qido_ms,{store['qido_ms']:.2f},"
          f"matched={store['qido_instances_matched']}")
    print(f"rebuild_ms,{store['rebuild_ms']:.1f},"
          f"reparsed={store['rebuild_reparsed']} "
          f"identical={store['rebuild_identical']}")
    print("wrote BENCH_store.json")


if __name__ == "__main__":
    main()
