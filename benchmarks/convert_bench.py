"""Conversion hot-path benchmark: whole-level batched vs per-tile encode.

Measures, on a synthetic 1024² slide (16 tiles of 256²):

- per-stage µs of the batched path — transform dispatch (one fused
  ``jpeg_transform`` per level), host entropy coding (vectorized symbol
  stream), DICOM Part-10 wrap;
- the same 256×256 tile encode through both paths (the A/B the tentpole
  targets: ≥3× on the batched path);
- end-to-end slide conversion MPix/s, batched vs per-tile.

On this CPU container the numbers are ref/interpret-mode numbers (the
Pallas kernels lower natively only with ``REPRO_PALLAS_COMPILE=1``); the
batched transform dispatches to the jnp oracle, the per-tile baseline runs
the seed path unchanged. Byte-identity of the two JPEG streams is asserted
as part of the run.

Writes ``BENCH_convert.json`` into the working directory and prints a CSV
summary (same format as the other benchmark modules).
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.kernels import jpeg_transform
from repro.wsi.convert import ConvertOptions, convert_wsi_to_dicom
from repro.wsi.dicom import TS_JPEG_BASELINE, new_uid, write_part10
from repro.wsi.jpeg import encode_coef_batch, encode_tile, encode_tiles_batch
from repro.wsi.slide import PSVReader, SyntheticScanner

SLIDE, TILE = 1024, 256


def _time(fn, reps=5) -> float:
    """Warm then average wall seconds per call."""
    fn()
    fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def main() -> None:
    psv = SyntheticScanner(seed=0).scan(SLIDE, SLIDE, TILE)
    rd = PSVReader(psv)
    bh, bw = rd.grid
    tiles = np.stack([rd.read_tile(r, c)
                      for r in range(bh) for c in range(bw)])
    n_tiles = tiles.shape[0]
    chw = np.transpose(tiles, (0, 3, 1, 2)).astype(np.float32)

    # --- stage timings (whole level = all 16 tiles) --------------------
    t_transform = _time(lambda: np.asarray(jpeg_transform(chw)))
    coef = np.asarray(jpeg_transform(chw))
    t_entropy = _time(lambda: encode_coef_batch(coef))
    frames = encode_coef_batch(coef)
    suid, seuid = new_uid(), new_uid()
    t_wrap = _time(lambda: write_part10(
        frames=frames, rows=TILE, cols=TILE, total_rows=SLIDE,
        total_cols=SLIDE, transfer_syntax=TS_JPEG_BASELINE,
        study_uid=suid, series_uid=seuid, instance_number=1,
        metadata={0: "bench", 1: "level=0"}))

    # --- the 256×256 tile encode A/B ----------------------------------
    t_per_tile = _time(lambda: [encode_tile(t) for t in tiles], reps=3)
    t_batched = _time(lambda: encode_tiles_batch(tiles), reps=3)
    per_frames = [encode_tile(t) for t in tiles]
    bat_frames = encode_tiles_batch(tiles)
    identical = all(a == b for a, b in zip(per_frames, bat_frames))
    assert identical, "batched JPEG bytes diverge from the per-tile path"
    speedup = t_per_tile / t_batched

    # --- end-to-end slide conversion ----------------------------------
    mpix = SLIDE * SLIDE / 1e6
    t_e2e_b = _time(lambda: convert_wsi_to_dicom(
        psv, options=ConvertOptions(batched=True)), reps=3)
    t_e2e_p = _time(lambda: convert_wsi_to_dicom(
        psv, options=ConvertOptions(batched=False)), reps=3)

    # dispatches per level: fused 1 vs 4 per tile (rgb2ycbcr + 3× dct)
    result = {
        "slide": {"hw": SLIDE, "tile": TILE, "tiles": n_tiles},
        "stage_us": {
            "transform_dispatch": t_transform * 1e6,
            "entropy": t_entropy * 1e6,
            "dicom_wrap": t_wrap * 1e6,
        },
        "tile_encode_256": {
            "per_tile_us": t_per_tile / n_tiles * 1e6,
            "batched_us": t_batched / n_tiles * 1e6,
            "speedup": speedup,
            "bytes_identical": identical,
        },
        "dispatches_per_level": {"per_tile": 4 * n_tiles, "batched": 1},
        "end_to_end": {
            "batched_s": t_e2e_b,
            "per_tile_s": t_e2e_p,
            "batched_mpix_s": mpix / t_e2e_b,
            "per_tile_mpix_s": mpix / t_e2e_p,
            "speedup": t_e2e_p / t_e2e_b,
        },
    }
    with open("BENCH_convert.json", "w") as f:
        json.dump(result, f, indent=2)

    print("name,value,derived")
    print(f"transform_dispatch_us,{t_transform*1e6:.0f},"
          f"{n_tiles}tiles/1dispatch")
    print(f"entropy_us,{t_entropy*1e6:.0f},vectorized")
    print(f"dicom_wrap_us,{t_wrap*1e6:.0f},part10")
    print(f"tile_encode_per_tile_us,{t_per_tile/n_tiles*1e6:.0f},baseline")
    print(f"tile_encode_batched_us,{t_batched/n_tiles*1e6:.0f},"
          f"speedup={speedup:.2f}x identical={identical}")
    print(f"e2e_batched_mpix_s,{mpix/t_e2e_b:.2f},"
          f"per_tile={mpix/t_e2e_p:.2f} speedup={t_e2e_p/t_e2e_b:.2f}x")
    print("wrote BENCH_convert.json")


if __name__ == "__main__":
    main()
