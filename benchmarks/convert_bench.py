"""Conversion hot-path benchmark: batched/pipelined/concurrent A/Bs.

Single-slide section (synthetic 1024² slide, 16 tiles of 256²):

- per-stage µs of the batched path — transform dispatch (one fused
  ``jpeg_transform`` per level), host entropy coding (vectorized symbol
  stream), DICOM Part-10 wrap;
- the same 256×256 tile encode through both paths (per-tile vs batched);
- end-to-end slide conversion MPix/s: per-tile vs batched-sync vs pipelined.

Multi-slide section (the paper's batch-conversion scenario):

- **sync** — slides converted one after another, ``pipelined=False``;
- **pipelined** — same serial order, the overlapping engine;
- **pipelined + concurrent** — the batch pushed through the real
  event-driven wiring (landing bucket → pub/sub → autoscaled service →
  DICOM store) with ``concurrency`` parallel real conversions per instance.

Mixed-format section (the paper's scanner-interoperability scenario):
every slide delivered twice — as PSV and as SVS-shaped tiled TIFF — into
one landing bucket served by one sniffing deployment; each pair's study
tars are asserted byte-identical.

Byte-identity is asserted across all three: every study tar (UIDs seeded
per slide) must be identical bit-for-bit, so the speedups cannot come from
computing something different.

On this CPU container the numbers are ref/interpret-mode numbers (the
Pallas kernels lower natively only with ``REPRO_PALLAS_COMPILE=1``); the
batched transform dispatches to the jnp oracle, the per-tile baseline runs
the seed path unchanged.

Writes ``BENCH_convert.json`` into the working directory and prints a CSV
summary (same format as the other benchmark modules). ``--fast`` shrinks
sizes/reps for the CI smoke (same assertions, looser timings).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.core import ConversionPipeline, RealScheduler
from repro.kernels import jpeg_transform
from repro.wsi.convert import (TRANSFER_STATS, ConvertOptions,
                               convert_wsi_to_dicom)
from repro.wsi.dicom import TS_JPEG_BASELINE, new_uid, write_part10
from repro.wsi.jpeg import encode_coef_batch, encode_tile, encode_tiles_batch
from repro.wsi.slide import PSVReader, SyntheticScanner

MIXED_FORMATS = ("psv", "tiff")

SLIDE, TILE = 1024, 256


def _time(fn, reps=5) -> float:
    """Warm then average wall seconds per call."""
    fn()
    fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def _single_slide(slide: int, reps: int) -> dict:
    psv = SyntheticScanner(seed=0).scan(slide, slide, TILE)
    rd = PSVReader(psv)
    bh, bw = rd.grid
    tiles = np.stack([rd.read_tile(r, c)
                      for r in range(bh) for c in range(bw)])
    n_tiles = tiles.shape[0]
    chw = np.transpose(tiles, (0, 3, 1, 2)).astype(np.float32)

    # --- stage timings (whole level = all tiles) -----------------------
    t_transform = _time(lambda: np.asarray(jpeg_transform(chw)))
    coef = np.asarray(jpeg_transform(chw))
    t_entropy = _time(lambda: encode_coef_batch(coef))
    frames = encode_coef_batch(coef)
    suid, seuid = new_uid(), new_uid()
    t_wrap = _time(lambda: write_part10(
        frames=frames, rows=TILE, cols=TILE, total_rows=slide,
        total_cols=slide, transfer_syntax=TS_JPEG_BASELINE,
        study_uid=suid, series_uid=seuid, instance_number=1,
        metadata={0: "bench", 1: "level=0"}))

    # --- the 256×256 tile encode A/B ----------------------------------
    t_per_tile = _time(lambda: [encode_tile(t) for t in tiles], reps=reps)
    t_batched = _time(lambda: encode_tiles_batch(tiles), reps=reps)
    per_frames = [encode_tile(t) for t in tiles]
    bat_frames = encode_tiles_batch(tiles)
    identical = all(a == b for a, b in zip(per_frames, bat_frames))
    assert identical, "batched JPEG bytes diverge from the per-tile path"
    speedup = t_per_tile / t_batched

    # --- end-to-end slide conversion: per-tile / sync / pipelined ------
    # (interleaved best-of rounds: container drift hits all variants alike)
    mpix = slide * slide / 1e6
    # fresh ConvertOptions per call: a reused one resumes from its manifest
    variants = {"sync": dict(pipelined=False),
                "pipe": dict(pipelined=True),
                "per_tile": dict(batched=False)}
    best = {k: float("inf") for k in variants}
    for k, kw in variants.items():  # warm jit caches
        convert_wsi_to_dicom(psv, options=ConvertOptions(**kw))
    for _ in range(max(2, reps)):
        for k, kw in variants.items():
            t0 = time.perf_counter()
            convert_wsi_to_dicom(psv, options=ConvertOptions(**kw))
            best[k] = min(best[k], time.perf_counter() - t0)
    t_e2e_sync, t_e2e_pipe, t_e2e_p = (best["sync"], best["pipe"],
                                       best["per_tile"])

    # e2e byte identity with shared UIDs: pipelined ≡ sync
    uids = json.dumps([new_uid(), new_uid()])
    e2e_sync = convert_wsi_to_dicom(psv, options=ConvertOptions(
        pipelined=False, manifest={"uids": uids}))
    e2e_pipe = convert_wsi_to_dicom(psv, options=ConvertOptions(
        pipelined=True, manifest={"uids": uids}))
    assert e2e_pipe == e2e_sync, "pipelined study tar diverges from sync"

    # the fused-pyramid round-trip gate: one streamed upload and one
    # jitted dispatch per slide — the whole pixel pyramid stays on device
    TRANSFER_STATS.reset()
    convert_wsi_to_dicom(psv, options=ConvertOptions(pipelined=True))
    transfers = {"uploads": TRANSFER_STATS.uploads,
                 "dispatches": TRANSFER_STATS.dispatches,
                 "coef_fetches": TRANSFER_STATS.fetches}
    assert TRANSFER_STATS.uploads == 1 and TRANSFER_STATS.dispatches == 1, \
        f"fused engine issued extra host↔device round trips: {transfers}"

    return {
        "slide": {"hw": slide, "tile": TILE, "tiles": n_tiles},
        "stage_us": {
            "transform_dispatch": t_transform * 1e6,
            "entropy": t_entropy * 1e6,
            "dicom_wrap": t_wrap * 1e6,
        },
        "tile_encode_256": {
            "per_tile_us": t_per_tile / n_tiles * 1e6,
            "batched_us": t_batched / n_tiles * 1e6,
            "speedup": speedup,
            "bytes_identical": identical,
        },
        "dispatches_per_level": {"per_tile": 4 * n_tiles, "batched": 1},
        "fused_transfers": transfers,
        "end_to_end": {
            "per_tile_s": t_e2e_p,
            "sync_s": t_e2e_sync,
            "pipelined_s": t_e2e_pipe,
            "per_tile_mpix_s": mpix / t_e2e_p,
            "sync_mpix_s": mpix / t_e2e_sync,
            "pipelined_mpix_s": mpix / t_e2e_pipe,
            "pipelined_speedup_vs_sync": t_e2e_sync / t_e2e_pipe,
            "sync_speedup_vs_per_tile": t_e2e_p / t_e2e_sync,
            "bytes_identical": True,
        },
    }


def _multi_slide(n_slides: int, slide: int, reps: int,
                 concurrency: int | None = None,
                 instances: int = 1) -> dict:
    """The batch A/B: serial sync vs serial pipelined vs event-driven
    concurrent, all byte-identical (per-slide seeded UIDs).

    ``concurrency`` defaults to ``cores // 2`` (min 1): each pipelined
    conversion already keeps ~2 threads busy (XLA pool + host entropy
    coder), so running more conversions than that in parallel just
    thrashes the cores and the GIL. The chosen value is recorded in the
    JSON so the A/B is interpretable across machines.
    """
    if concurrency is None:
        concurrency = max(1, (os.cpu_count() or 2) // 2)
    slides = {f"slides/s{i}.psv":
              SyntheticScanner(seed=100 + i).scan(slide, slide, TILE)
              for i in range(n_slides)}
    uids = {k: json.dumps([new_uid(), new_uid()]) for k in slides}

    def convert_one(key: str, data: bytes, pipelined: bool) -> bytes:
        opt = ConvertOptions(pipelined=pipelined,
                             manifest={"uids": uids[key]})
        return convert_wsi_to_dicom(data, {"slide_id": key}, options=opt)

    # warm the jit caches once so all variants time steady-state work
    k0, v0 = next(iter(slides.items()))
    convert_one(k0, v0, False)
    convert_one(k0, v0, True)

    def run_serial(pipelined: bool) -> tuple[float, dict]:
        t0 = time.perf_counter()
        outs = {k: convert_one(k, v, pipelined) for k, v in slides.items()}
        return time.perf_counter() - t0, outs

    def run_concurrent() -> tuple[float, dict]:
        sched = RealScheduler(workers=2 * instances * concurrency)
        # subscribers=False: this bench isolates the conversion wiring —
        # the store's validation/ML fan-out (which would compete for the
        # same cores mid-batch) is benchmarked by store_bench instead
        pipe = ConversionPipeline(
            sched,
            convert=lambda data, meta: convert_one(meta["slide_id"], data,
                                                   True),
            max_instances=instances, concurrency=concurrency,
            cold_start=0.0, scale_down_delay=5.0, subscribers=False,
        )
        # time until the last study is stored — not until the service has
        # also scaled back to zero (idle wind-down is not batch runtime)
        t0 = time.perf_counter()
        outs = pipe.run_batch(slides)
        dt = time.perf_counter() - t0
        sched.shutdown()
        return dt, outs

    # interleave the variants across rounds so drift on a shared container
    # hits all three equally; keep the best round of each (same number of
    # rounds per variant — an uneven best-of would bias the minima)
    t_sync = t_pipe = t_conc = float("inf")
    outs_sync = outs_pipe = outs_conc = None
    for _ in range(reps):
        dt, outs_sync = run_serial(False)
        t_sync = min(t_sync, dt)
        dt, outs_pipe = run_serial(True)
        t_pipe = min(t_pipe, dt)
        dt, outs_conc = run_concurrent()
        t_conc = min(t_conc, dt)
    assert outs_pipe == outs_sync, "pipelined batch diverges from sync"
    assert outs_conc == outs_sync, "concurrent batch diverges from sync"

    mpix = n_slides * slide * slide / 1e6
    return {
        "n_slides": n_slides,
        "hw": slide,
        "concurrency": concurrency,
        "max_instances": instances,
        "sync_s": t_sync,
        "pipelined_s": t_pipe,
        "concurrent_s": t_conc,
        "sync_mpix_s": mpix / t_sync,
        "pipelined_mpix_s": mpix / t_pipe,
        "concurrent_mpix_s": mpix / t_conc,
        "pipelined_speedup": t_sync / t_pipe,
        "concurrent_speedup": t_sync / t_conc,
        "bytes_identical": True,
    }


def _mixed_format(n_slides: int, slide: int,
                  concurrency: int | None = None) -> dict:
    """The mixed-format landing bucket: every slide rendered once, delivered
    twice — as PSV and as SVS-shaped tiled TIFF — through the real
    event-driven wiring. One deployment sniffs and serves both containers,
    and each PSV/TIFF pair (same pixels, seeded UIDs) must produce
    byte-identical study tars, so format support cannot come from a
    different compute path."""
    if concurrency is None:
        concurrency = max(1, (os.cpu_count() or 2) // 2)
    scanners = {f"s{i}": SyntheticScanner(seed=300 + i)
                for i in range(n_slides)}
    slides, metadata = {}, {}
    container_bytes = {f: 0 for f in MIXED_FORMATS}
    for sid, sc in scanners.items():
        for fmt in MIXED_FORMATS:
            blob = (sc.scan(slide, slide, TILE) if fmt == "psv"
                    else sc.scan_tiff(slide, slide, TILE))
            key = f"{fmt}/{sid}.{fmt}"
            slides[key] = blob
            metadata[key] = {"slide_id": sid}
            container_bytes[fmt] += len(blob)
    uids = {sid: json.dumps([new_uid(), new_uid()]) for sid in scanners}

    def convert(data, meta):
        opt = ConvertOptions(manifest={"uids": uids[meta["slide_id"]]})
        return convert_wsi_to_dicom(data, {"slide_id": meta["slide_id"]},
                                    options=opt)

    convert(next(iter(slides.values())), {"slide_id": "s0"})  # warm jit
    sched = RealScheduler(workers=2 * concurrency)
    pipe = ConversionPipeline(
        sched, convert=convert, max_instances=1, concurrency=concurrency,
        cold_start=0.0, scale_down_delay=5.0, subscribers=False,
    )
    t0 = time.perf_counter()
    outs = pipe.run_batch(slides, metadata)
    dt = time.perf_counter() - t0
    sched.shutdown()
    for sid in scanners:
        assert outs[f"psv/{sid}.psv"] == outs[f"tiff/{sid}.tiff"], \
            f"{sid}: TIFF study tar diverges from the PSV delivery"
    fmt_counts = {f: int(pipe.metrics.get(f"pipeline.format.{f}"))
                  for f in MIXED_FORMATS}
    assert fmt_counts == {f: n_slides for f in MIXED_FORMATS}
    mpix = len(slides) * slide * slide / 1e6
    return {
        "n_slides": len(slides),
        "hw": slide,
        "concurrency": concurrency,
        "formats_converted": fmt_counts,
        "container_bytes": container_bytes,
        "batch_s": dt,
        "mpix_s": mpix / dt,
        "cross_format_bytes_identical": True,
    }


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke: smaller slides, fewer reps, same "
                         "byte-identity assertions")
    args = ap.parse_args(argv)
    slide = 512 if args.fast else SLIDE
    reps = 1 if args.fast else 3
    n_slides = 3 if args.fast else 4

    single = _single_slide(slide, reps)
    multi = _multi_slide(n_slides, slide, reps)
    mixed = _mixed_format(2 if args.fast else 3, slide)
    result = {**single, "multi_slide": multi, "mixed_format": mixed}
    with open("BENCH_convert.json", "w") as f:
        json.dump(result, f, indent=2)

    st, te, e2e, ms = (result["stage_us"], result["tile_encode_256"],
                       result["end_to_end"], multi)
    n_tiles = result["slide"]["tiles"]
    print("name,value,derived")
    print(f"transform_dispatch_us,{st['transform_dispatch']:.0f},"
          f"{n_tiles}tiles/1dispatch")
    print(f"entropy_us,{st['entropy']:.0f},vectorized")
    print(f"dicom_wrap_us,{st['dicom_wrap']:.0f},part10")
    print(f"tile_encode_per_tile_us,{te['per_tile_us']:.0f},baseline")
    print(f"tile_encode_batched_us,{te['batched_us']:.0f},"
          f"speedup={te['speedup']:.2f}x identical={te['bytes_identical']}")
    print(f"e2e_sync_mpix_s,{e2e['sync_mpix_s']:.2f},"
          f"per_tile={e2e['per_tile_mpix_s']:.2f}")
    print(f"e2e_pipelined_mpix_s,{e2e['pipelined_mpix_s']:.2f},"
          f"speedup_vs_sync={e2e['pipelined_speedup_vs_sync']:.2f}x")
    tr = result["fused_transfers"]
    print(f"fused_transfers,ok,uploads={tr['uploads']} "
          f"dispatches={tr['dispatches']} "
          f"coef_fetches={tr['coef_fetches']}")
    print(f"batch_sync_s,{ms['sync_s']:.3f},{ms['n_slides']}x{ms['hw']}²")
    print(f"batch_pipelined_s,{ms['pipelined_s']:.3f},"
          f"speedup={ms['pipelined_speedup']:.2f}x")
    print(f"batch_concurrent_s,{ms['concurrent_s']:.3f},"
          f"speedup={ms['concurrent_speedup']:.2f}x "
          f"identical={ms['bytes_identical']}")
    mx = mixed
    print(f"mixed_format_batch_s,{mx['batch_s']:.3f},"
          f"{mx['n_slides']}slides:" +
          "+".join(f"{n}x{f}" for f, n in mx['formats_converted'].items()) +
          f" cross_format_identical={mx['cross_format_bytes_identical']}")
    print("wrote BENCH_convert.json")


if __name__ == "__main__":
    main()
