"""Institutional-scale converter fleet benchmark (paper Figures 2-3) plus a
fault-injection gauntlet that proves the fleet's delivery guarantees.

Four sections, all written into ``BENCH_fleet.json``:

* **fig2** — serial vs 16-way parallel vs the event-driven *fleet* (per-
  instance queues, controller scaling, ordered ingest) at batches of
  1/10/50 slides. Asserts the paper's crossover: cold start makes the
  fleet LOSE at n=1 and WIN against both baselines at n>=10.
* **fig3** — average container instances per minute during a 50-slide
  burst through the fleet: ramp to a plateau that never exceeds
  ``max_instances``, then decay back to zero.
* **sharded_store** — study-UID-hash routing balance across bucket
  partitions, plus crash-a-shard → ``rebuild_index()`` → byte-identical
  QIDO/WADO (measured on the gauntlet's real studies).
* **lockdep_overhead** — the disarmed-fast-path gate. Benchmarks run with
  lockdep *disarmed* (only the pytest plugin arms it), so the gate proves
  the production configuration costs nothing: the same fleet simulation
  timed with bare ``threading.Lock`` delegation vs disarmed
  ``TrackedLock`` (one module-global read per operation), min-of-N,
  asserted < 10% apart. The fully-armed detector's ratio is reported as a
  diagnostic alongside.
* **fault_injection** — the deterministic gauntlet: real JPEG/DICOM
  conversion under ``SimScheduler`` with pinned study UIDs, while the
  broker drops, delays, and duplicates deliveries, an instance is killed,
  and a store shard crashes. Asserts zero lost and zero double-converted
  slides, no dead-letters, and study tars byte-identical to a serial
  (no-infrastructure) conversion of the same slides. A backpressure
  sub-scenario overloads a 2-instance fleet past ``shed_backlog`` and
  asserts shed work is requeued budget-exempt — never dead-lettered —
  until it completes.

``--fast`` shrinks the gauntlet workload and skips wall-clock calibration;
every assertion is identical (the CI smoke runs this mode).
"""
from __future__ import annotations

import argparse
import hashlib
import json
import time

from benchmarks import fig2_workflows as fig2
from benchmarks import fig3_autoscaling as fig3
from repro.analysis import lockdep, racedep
from repro.core import (ConversionPipeline, DeliveryFaults, SimScheduler,
                        dashboard, tracing)

TAU = 90.0          # paper: ~90 s per gigapixel conversion on a 16-vCPU VM
COLD = 12.0         # paper: Cloud Run cold start
FLEET_BATCHES = (1, 10, 50)
FLEET_KW = dict(fleet={}, ordered_ingest=True)


def _uids_for(slide_id: str) -> list[str]:
    """Deterministic (study, series) UIDs so the fleet run and the serial
    baseline mint identical studies — byte-identity needs pinned UIDs."""
    h = hashlib.sha256(slide_id.encode()).hexdigest()
    return ["2.25." + str(int(h[:24], 16)),
            "2.25." + str(int(h[24:48], 16))]


def _pinned_convert():
    from repro.wsi.convert import ConvertOptions, convert_wsi_to_dicom

    def convert(data: bytes, meta: dict) -> bytes:
        opt = ConvertOptions(
            manifest={"uids": json.dumps(_uids_for(meta["slide_id"]))})
        return convert_wsi_to_dicom(data, meta, options=opt)

    return convert


# ---------------------------------------------------------------- fig 2
def _fig2_section(calibrate: bool) -> dict:
    rows = []
    if calibrate:
        tau_meas = fig2.measure_service_time()
        tau_scaled = tau_meas * (36_000 * 36_000) / (256 * 256)
        rows.append({"workflow": "calibration", "n": 1,
                     "seconds": round(tau_meas, 3),
                     "note": f"measured 256^2; "
                             f"gigapixel-scaled={tau_scaled:.0f}s"})
    for n in FLEET_BATCHES:
        rows.append({"workflow": "serial", "n": n,
                     "seconds": fig2.serial_time(n, TAU)})
        rows.append({"workflow": "parallel16", "n": n,
                     "seconds": fig2.parallel_time(n, TAU)})
        rows.append({"workflow": "event_driven_fleet", "n": n,
                     "seconds": round(fig2.autoscaling_time(
                         n, TAU, cold_start=COLD, **FLEET_KW), 1)})
    t = {(r["workflow"], r["n"]): r["seconds"] for r in rows
         if r["workflow"] != "calibration"}
    assert t[("event_driven_fleet", 1)] > t[("serial", 1)], \
        "cold start should make the fleet lose at n=1"
    for n in FLEET_BATCHES[1:]:
        assert t[("event_driven_fleet", n)] < t[("parallel16", n)] \
            < t[("serial", n)], f"fleet should win at n={n}"
    return {
        "tau_s": TAU, "cold_start_s": COLD, "rows": rows,
        "crossover": {
            "loses_at_1": t[("event_driven_fleet", 1)]
            > t[("serial", 1)],
            **{f"wins_at_{n}": t[("event_driven_fleet", n)]
               < t[("parallel16", n)] for n in FLEET_BATCHES[1:]},
        },
    }


# ---------------------------------------------------------------- fig 3
def _fig3_section() -> dict:
    max_instances = 100
    minutes, pipe = fig3.run(n=50, tau=TAU, cold_start=COLD,
                             max_instances=max_instances, **FLEET_KW)
    peak_avg = max(v for _, v in minutes)
    peak_inst = max(v for _, v in pipe.instance_series())
    assert peak_avg >= 45, f"should ramp to ~50 instances, got {peak_avg}"
    assert peak_inst <= max_instances, \
        f"instance count {peak_inst} exceeded max_instances"
    assert minutes[-1][1] == 0, "fleet should scale back to zero"
    return {
        "n_slides": 50, "max_instances": max_instances,
        "minutes": [[m, v] for m, v in minutes],
        "peak_avg_instances": peak_avg,
        "peak_instantaneous": peak_inst,
        "decays_to_zero": minutes[-1][1] == 0,
        "cold_starts": pipe.service.cold_starts,
    }


# ------------------------------------------------------- sharded store
def _hash_balance(n_shards: int = 4, n_uids: int = 2000) -> dict:
    from repro.wsi.store_service import ShardedDicomStore

    counts = [0] * n_shards
    for i in range(n_uids):
        uid = "2.25." + str(int(
            hashlib.sha256(f"study-{i}".encode()).hexdigest()[:24], 16))
        counts[ShardedDicomStore.shard_index_for_uid(uid, n_shards)] += 1
    lo, hi = min(counts), max(counts)
    assert hi <= 2 * lo, f"shard hash badly skewed: {counts}"
    return {"n_shards": n_shards, "n_uids": n_uids, "counts": counts,
            "max_over_min": round(hi / lo, 3)}


# -------------------------------------------------- fault-injection gauntlet
def _fault_gauntlet(n_slides: int, hw: int) -> dict:
    from repro.wsi import SyntheticScanner
    from repro.wsi.formats import sniff

    scanner = SyntheticScanner(seed=11)
    slides = {f"scans/s{i}.psv": scanner.scan(hw, hw, 256)
              for i in range(n_slides)}
    tenants = ("lab-a", "lab-b")
    meta = {k: {"slide_id": k, "tenant": tenants[i % 2]}
            for i, k in enumerate(slides)}
    convert = _pinned_convert()

    # serial baseline: plain function calls, no infrastructure, identical
    # metadata shape to what the pipeline's worker passes
    baseline = {}
    for k, d in slides.items():
        m = dict(meta[k])
        m.setdefault("format", sniff(d))
        baseline[k] = convert(d, m)

    faults = (DeliveryFaults()
              .drop("s0", attempts=(1,))          # lost push → redelivery
              .duplicate("s1", lag=1.0)           # double push → dedupe
              .delay("s2", by=200.0))             # arrives after deadline
    sched = SimScheduler()
    # traced on the sim clock: every slide's journey (faults, kill, shards
    # included) must land as one connected span tree
    with tracing.capture(now=sched.now) as tracer:
        pipe = ConversionPipeline(
            sched, convert=convert, cold_start=COLD, max_instances=4,
            ack_deadline=120.0, min_backoff=5.0,
            fleet=dict(instance_queue_depth=2), ordered_ingest=True,
            store_shards=4, delivery_faults=faults)
        for k, d in slides.items():
            pipe.ingest(k, d, meta[k])
        sched.schedule(5.0, pipe.service.kill_instance)  # churn mid-backlog
        sched.run()

    # --- zero lost, zero double-converted, nothing dead-lettered ---------
    assert pipe.dead_lettered == [], \
        f"work dead-lettered under faults: {pipe.dead_lettered}"
    out_keys = pipe.dicom.list()
    assert len(out_keys) == n_slides, \
        f"{len(out_keys)} studies for {n_slides} slides"
    writes = int(pipe.metrics.get("bucket.dicom-store.writes"))
    assert writes == n_slides, \
        f"{writes} study-tar writes for {n_slides} slides (double convert?)"

    # --- byte-identical to the serial baseline --------------------------
    from repro.core.pipeline import derive_out_key
    for k in slides:
        got = pipe.dicom.get(derive_out_key(k)).data
        assert got == baseline[k], f"fleet study tar differs for {k}"

    # --- the faults and the kill actually fired -------------------------
    assert faults.injected["drop"] >= 1 and faults.injected["duplicate"] >= 1 \
        and faults.injected["delay"] >= 1, dict(faults.injected)
    assert int(pipe.metrics.get("svc.wsi2dcm.killed")) == 1

    # --- one connected span tree per slide; attribution sums to the
    # --- trace window (the dashboard's 5% acceptance gate) --------------
    report = dashboard.build_report(pipe.metrics, tracer,
                                    title="fault gauntlet")
    assert len(report["traces"]) == n_slides, \
        f"{len(report['traces'])} traces for {n_slides} slides"
    for t in report["traces"]:
        assert not t["problems"], \
            f"trace {t['trace_id']} ({t['slide']}): {t['problems']}"
        total = sum(t["attribution"].values())
        assert abs(total - t["duration"]) <= 0.05 * max(t["duration"], 1e-9), \
            f"attribution {total} vs duration {t['duration']} for {t['slide']}"

    # --- crash a populated shard; rebuild serves identical QIDO/WADO ----
    ss = pipe.store_service
    studies = ss.search_studies()
    assert len(studies) == n_slides
    dist_before = ss.shard_distribution()
    uid = studies[0]
    shard_i = ss.shard_index_for(uid)
    qido_before = ss.search_instances(uid)
    wado_before = {m["sop_instance_uid"]: ss.retrieve(m["sop_instance_uid"])
                   for m in qido_before}
    ss.crash_shard(shard_i)
    assert ss.search_instances(uid) == [], \
        "crash_shard left index state behind"
    rebuilt = ss.rebuild_index()
    assert ss.search_instances(uid) == qido_before, \
        "post-rebuild QIDO differs"
    for sop, blob in wado_before.items():
        assert ss.retrieve(sop) == blob, f"post-rebuild WADO differs: {sop}"

    return {
        "n_slides": n_slides, "slide_hw": hw, "n_shards": 4,
        "faults_injected": dict(faults.injected),
        "instance_killed": True,
        "dead_lettered": 0,
        "study_tar_writes": writes,
        "byte_identical_to_serial": True,
        "shard_distribution": dist_before,
        "crashed_shard": shard_i,
        "rebuilt_instances": rebuilt,
        "crash_rebuild_identical": True,
        "deliveries": int(
            pipe.metrics.get("sub.wsi2dcm-push.deliveries")),
        "duplicates_deduped": int(
            pipe.metrics.get("svc.wsi2dcm.duplicates")),
        "completion_s": sched.now(),
        # the single dashboard, embedded: per-slide critical path + the
        # delivery-latency histogram percentiles
        "dashboard": {
            "traces": report["traces"],
            "histograms": report["histograms"],
        },
    }


# --------------------------------------------------------- lockdep overhead
def _lockdep_workload(n: int):
    """One lock-heavy fleet run: every ingest crosses the bucket, topic,
    subscription, fleet, and metrics locks several times."""
    sched = SimScheduler()
    pipe = ConversionPipeline(
        sched, service_time=TAU, cold_start=COLD, max_instances=8,
        min_backoff=5.0, fleet={}, ordered_ingest=True, subscribers=False)
    for i in range(n):
        pipe.ingest(f"bench/s{i:03d}.psv", bytes([i % 251]) * 32)
    sched.run()
    assert pipe.done_count() == n


def _lockdep_overhead_section(fast: bool) -> dict:
    import gc

    n, repeats = (120, 15) if fast else (200, 15)
    _lockdep_workload(n)  # warm-up: imports, bytecode, allocator

    def disarmed_run():
        _lockdep_workload(n)

    def bare_run():
        # bare baseline: every TrackedLock operation delegates straight to
        # the wrapped threading lock, skipping even the disarmed detector
        # check — what the tree would cost had the locks never been swapped
        TL = lockdep.TrackedLock
        orig = (TL.acquire, TL.release)
        TL.acquire = lambda self, blocking=True, timeout=-1: \
            self._lock.acquire(blocking, timeout)
        TL.release = lambda self: self._lock.release()
        try:
            _lockdep_workload(n)
        finally:
            TL.acquire, TL.release = orig

    def armed_run():
        with lockdep.capture(max_hold=30.0) as det:
            _lockdep_workload(n)
        assert det.violations == [], det.report()

    assert lockdep.current() is None, \
        "overhead baseline needs the disarmed fast path"
    # interleave the three variants so drift (thermal, scheduler, GC)
    # lands on all of them equally, then compare PAIRED per-round ratios:
    # each round times bare/disarmed/armed back-to-back, so slow spells
    # hit all three and cancel out of the ratio; the median round is the
    # gated statistic (robust to the odd descheduled round)
    times = {"bare": [], "disarmed": [], "armed": []}
    gc_was_enabled = gc.isenabled()
    gc.disable()
    try:
        for _ in range(repeats):
            for label, run in (("bare", bare_run),
                               ("disarmed", disarmed_run),
                               ("armed", armed_run)):
                gc.collect()
                t0 = time.perf_counter()
                run()
                times[label].append(time.perf_counter() - t0)
    finally:
        if gc_was_enabled:
            gc.enable()

    def median(xs):
        xs = sorted(xs)
        return xs[len(xs) // 2]

    bare = min(times["bare"])
    disarmed = min(times["disarmed"])
    armed = min(times["armed"])
    ratio = median(d / b for d, b in zip(times["disarmed"], times["bare"]))
    armed_ratio = median(a / b for a, b in zip(times["armed"],
                                               times["bare"]))
    assert ratio < 1.10, \
        f"disarmed lockdep overhead {ratio:.3f}x exceeds the 10% gate " \
        f"(bare {bare:.4f}s, disarmed {disarmed:.4f}s)"
    return {"n_slides": n, "repeats": repeats, "bare_s": round(bare, 4),
            "disarmed_s": round(disarmed, 4), "armed_s": round(armed, 4),
            "overhead_ratio": round(ratio, 4), "gate": 1.10,
            "armed_ratio": round(armed_ratio, 4)}


# --------------------------------------------------------- racedep overhead
def _racedep_overhead_section(fast: bool) -> dict:
    """Disarmed racedep instrumentation (Shared proxies on the spine's
    tracked structures, no detector armed) must cost <10% over an
    uninstrumented pipeline. Same paired-median methodology as the lockdep
    gate: bare (instrumentation kill-switch, raw containers), disarmed
    (proxies, one global read per access), armed (full vector-clock
    checking — diagnostic only)."""
    import gc

    n, repeats = (120, 15) if fast else (200, 15)
    _lockdep_workload(n)  # warm-up: imports, bytecode, allocator

    def bare_run():
        # uninstrumented baseline: objects constructed with instrumentation
        # off carry raw dicts/deques/lists — zero proxy indirection
        prev = racedep.set_instrumentation(False)
        try:
            _lockdep_workload(n)
        finally:
            racedep.set_instrumentation(prev)

    def disarmed_run():
        _lockdep_workload(n)

    def armed_run():
        with racedep.capture() as det:
            _lockdep_workload(n)
        assert det.violations == [], det.report()

    assert racedep.current() is None, \
        "overhead baseline needs the disarmed fast path"
    times = {"bare": [], "disarmed": [], "armed": []}
    gc_was_enabled = gc.isenabled()
    gc.disable()
    try:
        for _ in range(repeats):
            for label, run in (("bare", bare_run),
                               ("disarmed", disarmed_run),
                               ("armed", armed_run)):
                gc.collect()
                t0 = time.perf_counter()
                run()
                times[label].append(time.perf_counter() - t0)
    finally:
        if gc_was_enabled:
            gc.enable()

    def median(xs):
        xs = sorted(xs)
        return xs[len(xs) // 2]

    bare = min(times["bare"])
    disarmed = min(times["disarmed"])
    armed = min(times["armed"])
    ratio = median(d / b for d, b in zip(times["disarmed"], times["bare"]))
    armed_ratio = median(a / b for a, b in zip(times["armed"],
                                               times["bare"]))
    assert ratio < 1.10, \
        f"disarmed racedep overhead {ratio:.3f}x exceeds the 10% gate " \
        f"(bare {bare:.4f}s, disarmed {disarmed:.4f}s)"
    return {"n_slides": n, "repeats": repeats, "bare_s": round(bare, 4),
            "disarmed_s": round(disarmed, 4), "armed_s": round(armed, 4),
            "overhead_ratio": round(ratio, 4), "gate": 1.10,
            "armed_ratio": round(armed_ratio, 4)}


# --------------------------------------------------------- tracing overhead
def _tracing_overhead_section(fast: bool) -> dict:
    """Disarmed tracing (every instrumentation point bails on one
    module-global read) must cost <10% over a spine with the trace points
    compiled out. Same paired-median methodology as the lockdep/racedep
    gates: bare (tracing entry points monkeypatched to no-ops — what the
    spine would cost had it never been instrumented), disarmed (the
    shipped fast path), armed (full span capture — diagnostic only)."""
    import gc

    n, repeats = (120, 15) if fast else (200, 15)
    _lockdep_workload(n)  # warm-up: imports, bytecode, allocator

    def bare_run():
        t = tracing
        orig = (t.start_span, t.end_span, t.add_event, t.inject,
                t.extract, t.use_span, t.span, t.current_span)
        t.start_span = lambda name, **kw: None
        t.end_span = lambda sp, **kw: None
        t.add_event = lambda sp, name, **kw: None
        t.inject = lambda attributes, sp=None: None
        t.extract = lambda attributes: None
        t.use_span = lambda sp: t._NULL
        t.span = lambda name, **kw: t._NULL
        t.current_span = lambda: None
        try:
            _lockdep_workload(n)
        finally:
            (t.start_span, t.end_span, t.add_event, t.inject,
             t.extract, t.use_span, t.span, t.current_span) = orig

    def disarmed_run():
        _lockdep_workload(n)

    def armed_run():
        with tracing.capture() as tracer:
            _lockdep_workload(n)
        assert tracer.spans, "armed run recorded no spans"

    assert tracing.current() is None, \
        "overhead baseline needs the disarmed fast path"
    times = {"bare": [], "disarmed": [], "armed": []}
    gc_was_enabled = gc.isenabled()
    gc.disable()
    try:
        for _ in range(repeats):
            for label, run in (("bare", bare_run),
                               ("disarmed", disarmed_run),
                               ("armed", armed_run)):
                gc.collect()
                t0 = time.perf_counter()
                run()
                times[label].append(time.perf_counter() - t0)
    finally:
        if gc_was_enabled:
            gc.enable()

    def median(xs):
        xs = sorted(xs)
        return xs[len(xs) // 2]

    bare = min(times["bare"])
    disarmed = min(times["disarmed"])
    armed = min(times["armed"])
    ratio = median(d / b for d, b in zip(times["disarmed"], times["bare"]))
    armed_ratio = median(a / b for a, b in zip(times["armed"],
                                               times["bare"]))
    assert ratio < 1.10, \
        f"disarmed tracing overhead {ratio:.3f}x exceeds the 10% gate " \
        f"(bare {bare:.4f}s, disarmed {disarmed:.4f}s)"
    return {"n_slides": n, "repeats": repeats, "bare_s": round(bare, 4),
            "disarmed_s": round(disarmed, 4), "armed_s": round(armed, 4),
            "overhead_ratio": round(ratio, 4), "gate": 1.10,
            "armed_ratio": round(armed_ratio, 4)}


# ------------------------------------------------------------- backpressure
def _backpressure_section() -> dict:
    sched = SimScheduler()
    pipe = ConversionPipeline(
        sched, service_time=TAU, cold_start=COLD, max_instances=2,
        min_backoff=5.0, fleet=dict(shed_backlog=4), ordered_ingest=True,
        subscribers=False)
    n = 12
    for i in range(n):
        pipe.ingest(f"burst/s{i:02d}.psv", bytes([i]) * 32)
    sched.run()
    shed = int(pipe.metrics.get("svc.wsi2dcm.shed"))
    requeues = int(pipe.metrics.get("sub.wsi2dcm-push.requeues"))
    assert pipe.done_count() == n, \
        f"only {pipe.done_count()}/{n} completed under backpressure"
    assert shed > 0, "overload never shed"
    assert requeues >= shed, "sheds were not budget-exempt requeues"
    assert pipe.dead_lettered == [], "shed work dead-lettered"
    return {"n_slides": n, "max_instances": 2, "shed_backlog": 4,
            "shed": shed, "budget_exempt_requeues": requeues,
            "dead_lettered": 0, "completed": pipe.done_count(),
            "completion_s": sched.now()}


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke: smaller gauntlet, no wall-clock "
                         "calibration, same assertions")
    args = ap.parse_args(argv)

    result = {
        "config": {"tau_s": TAU, "cold_start_s": COLD,
                   "batches": list(FLEET_BATCHES), "fast": args.fast},
        "fig2": _fig2_section(calibrate=not args.fast),
        "fig3": _fig3_section(),
        "sharded_store": _hash_balance(),
        "lockdep_overhead": _lockdep_overhead_section(fast=args.fast),
        "racedep_overhead": _racedep_overhead_section(fast=args.fast),
        "tracing_overhead": _tracing_overhead_section(fast=args.fast),
        "fault_injection": _fault_gauntlet(
            n_slides=3 if args.fast else 6, hw=256),
        "backpressure": _backpressure_section(),
    }
    with open("BENCH_fleet.json", "w") as f:
        json.dump(result, f, indent=2)

    print("workflow,n_images,seconds")
    for r in result["fig2"]["rows"]:
        print(f"{r['workflow']},{r['n']},{r['seconds']}")
    print("# claims: fleet loses at n=1 (cold start), wins at n>=10 — OK")
    print("minute,avg_instances")
    for m, v in result["fig3"]["minutes"]:
        print(f"{m},{v}")
    fi = result["fault_injection"]
    print(f"faults,{sum(fi['faults_injected'].values())},"
          f"{fi['faults_injected']} + 1 instance kill + 1 shard crash")
    print(f"gauntlet,ok,{fi['n_slides']} slides byte-identical to serial, "
          f"0 lost, 0 double-converted, 0 dead-lettered")
    bp = result["backpressure"]
    print(f"backpressure,ok,{bp['shed']} sheds / "
          f"{bp['budget_exempt_requeues']} requeues, 0 dead-lettered, "
          f"{bp['completed']}/{bp['n_slides']} completed")
    lo = result["lockdep_overhead"]
    print(f"lockdep_overhead,ok,{lo['overhead_ratio']}x disarmed vs bare "
          f"(gate {lo['gate']}x; armed diagnostic {lo['armed_ratio']}x)")
    ro = result["racedep_overhead"]
    print(f"racedep_overhead,ok,{ro['overhead_ratio']}x disarmed vs bare "
          f"(gate {ro['gate']}x; armed diagnostic {ro['armed_ratio']}x)")
    to = result["tracing_overhead"]
    print(f"tracing_overhead,ok,{to['overhead_ratio']}x disarmed vs bare "
          f"(gate {to['gate']}x; armed diagnostic {to['armed_ratio']}x)")
    for t in fi["dashboard"]["traces"]:
        a = t["attribution"]
        print(f"trace,{t['slide']},total={t['duration']:.1f}s,"
              f"queue={a['queue']:.1f}s,compute={a['compute']:.1f}s,"
              f"store={a['store']:.1f}s,spans={t['n_spans']}")
    print("wrote BENCH_fleet.json")


if __name__ == "__main__":
    main()
