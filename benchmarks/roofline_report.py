"""Render the §Roofline table from the dry-run artifacts.

Reads every ``artifacts/dryrun/*.json`` cell, emits CSV + a markdown table
(written to ``artifacts/roofline.md``), flags HBM violations, and prints the
three hillclimb candidates (worst mfu-bound, most collective-bound, and the
paper-representative serving cell). When ``BENCH_kernels.json`` is present
(``make bench`` / ``kernels_bench.py``), a §WSI kernels section with the
conversion kernels' per-device-count roofline terms is appended.
"""
from __future__ import annotations

import glob
import json
from pathlib import Path

ART = Path(__file__).resolve().parents[1] / "artifacts"
REPO = Path(__file__).resolve().parents[1]


def load_cells(mesh: str = "single") -> list[dict]:
    cells = []
    for f in sorted(glob.glob(str(ART / "dryrun" / f"*__{mesh}.json"))):
        d = json.load(open(f))
        cells.append(d)
    return cells


def fmt_row(d: dict) -> str:
    if d.get("skipped"):
        return (f"| {d['arch']} | {d['shape']} | skip | — | — | — | — | — | — |"
                f" {d['reason'][:36]}… |")
    if not d.get("ok"):
        return f"| {d['arch']} | {d['shape']} | FAIL | | | | | | | |"
    return (
        f"| {d['arch']} | {d['shape']} | {d['dominant'].replace('_s','')} "
        f"| {d['compute_s']*1e3:.2f} | {d['memory_s']*1e3:.2f} "
        f"| {d['collective_s']*1e3:.2f} | {d['useful_flops_ratio']:.2f} "
        f"| {d['mfu_bound']:.4f} | {d['hbm_per_device']/1e9:.2f} "
        f"| {'OK' if d['fits_hbm'] else '** >16G **'} |"
    )


def main():
    lines = []
    for mesh in ("single", "multi"):
        cells = load_cells(mesh)
        if not cells:
            continue
        lines.append(f"\n### Mesh: {mesh} "
                     f"({'2×16×16=512' if mesh == 'multi' else '16×16=256'} chips)\n")
        lines.append("| arch | shape | dom | compute ms | memory ms "
                     "| collective ms | useful | mfu_bound | HBM GB/dev | fits |")
        lines.append("|---|---|---|---|---|---|---|---|---|---|")
        for d in sorted(cells, key=lambda x: (x["shape"], x["arch"])):
            lines.append(fmt_row(d))
        ok = [d for d in cells if d.get("ok")]
        n_skip = sum(1 for d in cells if d.get("skipped"))
        n_fail = sum(1 for d in cells if not d.get("ok") and not d.get("skipped"))
        lines.append(f"\ncells={len(cells)} ok={len(ok)} skip={n_skip} "
                     f"fail={n_fail}\n")

    kb = REPO / "BENCH_kernels.json"
    if kb.exists():
        bench = json.load(open(kb))
        rb = bench["roofline_batch"]
        lines.append(f"\n### WSI conversion kernels "
                     f"({rb['n_tiles']}×{rb['tile']}² level batch, "
                     f"{bench['hw']['name']} targets)\n")
        lines.append("| kernel | devices | dom | compute µs | memory µs "
                     "| collective µs | useful | mfu_bound |")
        lines.append("|---|---|---|---|---|---|---|---|")
        for kernel, per_d in bench["roofline"].items():
            for d, t in sorted(per_d.items(), key=lambda kv: int(kv[0])):
                lines.append(
                    f"| {kernel} | {d} | {t['dominant'].replace('_s','')} "
                    f"| {t['compute_s']*1e6:.1f} | {t['memory_s']*1e6:.1f} "
                    f"| {t['collective_s']*1e6:.1f} "
                    f"| {t['useful_flops_ratio']:.2f} "
                    f"| {t['mfu_bound']:.4f} |")
        lines.append("")
    report = "\n".join(lines)
    ART.mkdir(parents=True, exist_ok=True)
    (ART / "roofline.md").write_text(report)
    print(report)

    # hillclimb candidates (single-pod, base archs only)
    ok = [d for d in load_cells("single")
          if d.get("ok") and "+" not in d["arch"]]
    if not ok:
        print("# no dry-run artifacts; run the dry-run sweep first")
        return
    worst = min(ok, key=lambda d: d["mfu_bound"])
    coll = max(ok, key=lambda d: d["collective_s"] / max(d["bound_s"], 1e-12)
               * (d["dominant"] == "collective_s"))
    print(f"# worst mfu_bound: {worst['arch']} {worst['shape']} "
          f"({worst['mfu_bound']:.5f})")
    print(f"# most collective-bound: {coll['arch']} {coll['shape']}")


if __name__ == "__main__":
    main()
