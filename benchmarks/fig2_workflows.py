"""Figure 2 reproduction: serial vs 16-way parallel vs event-driven autoscaling
for batches of 1/10/25/50 slides.

Two modes:

* ``simulate(...)`` — discrete-event simulation at the paper's institutional
  scale (gigapixel slides, ~90 s/conversion on a 16-vCPU VM, one container
  per image). This reproduces the paper's qualitative claims exactly:
  cold start makes autoscaling LOSE at n=1 and WIN at n≥10.
* ``measure_service_time()`` — wall-clock per-slide conversion through the
  real JAX converter on synthetic slides; used to calibrate the simulation
  so its constants are grounded in measured compute, then scaled by the
  pixel-count ratio to the paper's gigapixel slides.
"""
from __future__ import annotations

import time

from repro.core import ConversionPipeline, SimScheduler

BATCHES = (1, 10, 25, 50)


def serial_time(n: int, tau: float) -> float:
    return n * tau


def parallel_time(n: int, tau: float, workers: int = 16,
                  threads_per_convert: int = 4, vcpus: int = 16) -> float:
    """multiprocessing.Pool on one VM. The C++ converter is internally
    multi-threaded (~``threads_per_convert`` vCPUs when run alone — the same
    assumption under τ), so k concurrent conversions on ``vcpus`` cores run at
    min(1, vcpus/(k·threads)) of solo speed. This contention is why the
    paper's Figure 2 shows autoscaling beating the 16-way pool already at
    n=10: the pool shares one VM, the containers don't."""
    total = 0.0
    remaining = n
    while remaining > 0:
        k = min(workers, remaining)
        slowdown = max(1.0, threads_per_convert * k / vcpus)
        total += tau * slowdown
        remaining -= k
    return total


def autoscaling_time(n: int, tau: float, *, cold_start: float = 12.0,
                     max_instances: int = 100, **pipe_kw) -> float:
    """Batch completion time through the simulated event-driven pipeline.

    Extra ``pipe_kw`` go to :class:`ConversionPipeline` — the fleet bench
    passes ``fleet={...}`` / ``ordered_ingest=True`` to run the same
    measurement against the multi-instance converter fleet.
    """
    sched = SimScheduler()
    pipe = ConversionPipeline(
        sched, service_time=tau, cold_start=cold_start,
        max_instances=max_instances, scale_down_delay=120.0, **pipe_kw,
    )
    t0 = sched.now()
    for i in range(n):
        pipe.ingest(f"slides/s{i}.psv", bytes([i % 251]) * 16)
    # run to quiescence; completion time = last conversion completion
    sched.run()
    assert pipe.done_count() == n
    lat = pipe.metrics.timeseries("svc.wsi2dcm.latency")
    return max(t for t, _ in lat) - t0


def measure_service_time(side: int = 256) -> float:
    """Real per-slide conversion wall time (small synthetic slide)."""
    from repro.wsi import SyntheticScanner, convert_wsi_to_dicom

    psv = SyntheticScanner(seed=0).scan(side, side, 256)
    convert_wsi_to_dicom(psv)  # warm the jits
    t0 = time.perf_counter()
    convert_wsi_to_dicom(psv)
    return time.perf_counter() - t0


def run(tau: float = 90.0, calibrate: bool = True) -> list[dict]:
    rows = []
    tau_meas = None
    if calibrate:
        tau_meas = measure_service_time()
        # scale measured 256² time to the paper's ~1.3 gigapixel slides
        tau_scaled = tau_meas * (36_000 * 36_000) / (256 * 256)
        rows.append({"workflow": "calibration", "n": 1,
                     "seconds": round(tau_meas, 3),
                     "note": f"measured 256^2; gigapixel-scaled={tau_scaled:.0f}s"})
    for n in BATCHES:
        rows.append({"workflow": "serial", "n": n,
                     "seconds": serial_time(n, tau)})
        rows.append({"workflow": "parallel16", "n": n,
                     "seconds": parallel_time(n, tau)})
        rows.append({"workflow": "autoscaling", "n": n,
                     "seconds": round(autoscaling_time(n, tau), 1)})
    return rows


def main():
    rows = run()
    print("workflow,n_images,seconds")
    for r in rows:
        print(f"{r['workflow']},{r['n']},{r['seconds']}")
    # the paper's two claims
    t = {(r["workflow"], r["n"]): r["seconds"] for r in rows
         if r["workflow"] != "calibration"}
    assert t[("autoscaling", 1)] > t[("serial", 1)], "cold start should lose at n=1"
    for n in (10, 25, 50):
        assert t[("autoscaling", n)] < t[("parallel16", n)] < t[("serial", n)]
    print("# claims: autoscaling loses at n=1 (cold start), wins at n>=10 — OK")


if __name__ == "__main__":
    main()
