"""Figure 3 reproduction: container instances per minute during a 50-slide
burst — ramp to plateau, then decay to zero after the backlog drains."""
from __future__ import annotations

from repro.core import ConversionPipeline, SimScheduler


def minute_averages(series: list[tuple[float, float]]) -> list[tuple[int, float]]:
    """Time-weighted per-minute averages of a step-function timeseries
    (the paper's Figure 3 axis: avg container instances per minute)."""
    end = max(t for t, _ in series)
    n_min = int(end // 60) + 2
    minutes = []
    for m in range(n_min):
        lo, hi = m * 60.0, (m + 1) * 60.0
        # value at lo = last change before lo
        cur = 0.0
        for t, v in series:
            if t <= lo:
                cur = v
            else:
                break
        acc, t_prev = 0.0, lo
        for t, v in series:
            if t <= lo or t >= hi:
                continue
            acc += cur * (t - t_prev)
            cur, t_prev = v, t
        acc += cur * (hi - t_prev)
        minutes.append((m, round(acc / 60.0, 1)))
    return minutes


def run(n: int = 50, tau: float = 90.0, cold_start: float = 12.0,
        scale_down_delay: float = 120.0, max_instances: int = 100,
        **pipe_kw):
    sched = SimScheduler()
    pipe = ConversionPipeline(sched, service_time=tau, cold_start=cold_start,
                              max_instances=max_instances,
                              scale_down_delay=scale_down_delay, **pipe_kw)
    for i in range(n):
        pipe.ingest(f"s{i}.psv", bytes([i % 251]) * 8)
    sched.run()
    return minute_averages(pipe.instance_series()), pipe


def main():
    minutes, pipe = run()
    print("minute,avg_instances")
    peak = 0.0
    for m, v in minutes:
        peak = max(peak, v)
        print(f"{m},{v}")
    assert peak >= 45, f"should ramp to ~50 instances, peaked at {peak}"
    assert minutes[-1][1] == 0, "should scale back to zero"
    bar = lambda v: "#" * int(v)
    print("# ascii:")
    for m, v in minutes:
        print(f"# {m:3d} | {bar(v)}")


if __name__ == "__main__":
    main()
