"""Kernel microbenchmarks: the conversion hot spots.

On this CPU container the Pallas kernels run in interpret mode (correctness
harness, not speed), so the numbers that matter here are (a) the jnp
reference path wall time — the real CPU compute the Figure-2 calibration
uses — and (b) derived per-tile conversion arithmetic (MPix/s, tiles/s).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.ops import dct8x8_quant, downsample2x2, rgb2ycbcr
from repro.wsi.jpeg import encode_tile
from repro.wsi.slide import SyntheticScanner, PSVReader


def _time(fn, *args, reps=5) -> float:
    fn(*args)  # warm/compile
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # µs


def main():
    rng = np.random.default_rng(0)
    tile = jnp.asarray(rng.integers(0, 255, size=(3, 256, 256)), jnp.float32)
    plane = jnp.asarray(rng.normal(0, 40, size=(256, 256)), jnp.float32)
    q = jnp.asarray(ref.JPEG_LUMA_Q)
    rows = []
    jit_ref = lambda f: jax.jit(f)
    rows.append(("rgb2ycbcr_ref_256", _time(jit_ref(ref.rgb2ycbcr_ref), tile),
                 "3x256x256"))
    rows.append(("downsample_ref_256", _time(jit_ref(ref.downsample2x2_ref),
                                             tile), "3x256x256"))
    rows.append(("dct_quant_ref_256",
                 _time(jit_ref(lambda p: ref.dct8x8_quant_ref(p, q)), plane),
                 "256x256"))
    rows.append(("rgb2ycbcr_pallas_interp",
                 _time(lambda x: rgb2ycbcr(x, impl="pallas"), tile),
                 "interpret-mode"))
    rows.append(("dct_quant_pallas_interp",
                 _time(lambda p: dct8x8_quant(p, q, impl="pallas"), plane),
                 "interpret-mode"))

    # fused rwkv6 wkv chunk kernel vs unfused chunked XLA path
    from repro.kernels.wkv_chunk import wkv_chunk_pallas
    from repro.models.rwkv6 import wkv_chunked
    B, S, H, K = 1, 256, 2, 64
    rr, kk, vv = (jnp.asarray(rng.normal(size=(B, S, H, K)), jnp.float32)
                  for _ in range(3))
    lw = -jnp.asarray(rng.uniform(0.01, 2.0, (B, S, H, K)), jnp.float32)
    uu = jnp.asarray(rng.normal(size=(H, K)), jnp.float32)
    st0 = jnp.zeros((B, H, K, K), jnp.float32)
    rows.append(("wkv_chunked_xla",
                 _time(jax.jit(lambda *a: wkv_chunked(*a)[0]),
                       rr, kk, vv, lw, uu, st0), f"B{B} S{S} H{H}"))
    rows.append(("wkv_chunk_pallas_interp",
                 _time(lambda *a: wkv_chunk_pallas(*a), rr, kk, vv, lw, uu),
                 "interpret-mode"))

    # end-to-end tile encode (transform + host entropy coder)
    psv = SyntheticScanner(seed=0).scan(256, 256, 256)
    t = PSVReader(psv).read_tile(0, 0)
    encode_tile(t)  # warm
    t0 = time.perf_counter()
    n = 4
    for _ in range(n):
        jpg = encode_tile(t)
    dt = (time.perf_counter() - t0) / n
    rows.append(("jpeg_encode_tile_256", dt * 1e6,
                 f"{0.256*0.256/dt:.2f}MPix/s ratio={len(jpg)/t.nbytes:.3f}"))

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.0f},{derived}")


if __name__ == "__main__":
    main()
