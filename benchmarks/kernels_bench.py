"""Kernel roofline benchmark: achieved-vs-peak terms per device count.

Three sections, written to ``BENCH_kernels.json``:

- **roofline** — for each batched conversion kernel (``jpeg_transform``,
  ``jpeg_inverse``, ``downsample2x2``) and each device count D, a fresh
  interpreter (``XLA_FLAGS=--xla_force_host_platform_device_count=D``)
  lowers the jitted kernel with its level batch laid out over a
  ``make_local_mesh()`` data axis, runs the loop-aware HLO analysis
  (``roofline.analyze_hlo``) on the SPMD-partitioned program, and the
  parent derives the three roofline terms against the TPU-v5e targets
  (``roofline.derive_terms``): compute vs memory vs collective bound,
  useful-FLOPs ratio (analytic kernel math ÷ compiled FLOPs), and the MFU
  bound. On this CPU container the HLO is the jnp oracle path — the same
  math the Pallas kernels implement — so the terms describe the *program*,
  not interpret-mode overhead. (``analyze_hlo`` counts dot FLOPs only, so
  ``useful_flops_ratio`` can exceed 1 on these elementwise-heavy kernels —
  the analytic model includes the color-transform and quant arithmetic the
  dot counter does not see.)
- **measured** — single-device wall time per kernel on the same batch, with
  achieved GFLOP/s (analytic FLOPs ÷ wall) and the achieved fraction of
  the memory-bound roofline time. CPU-proxy numbers; the gap to peak is
  the point of recording them.
- **batch_scaling** — per-tile µs of the fused transform/inverse dispatch
  at growing batch sizes. **Gates** (run in ``make smoke``): per-tile cost
  must stay flat across batch sizes (≤3× the cheapest point; a recompile
  cliff is ~100×), and odd batch sizes must ride already-compiled pow2
  buckets instead of tracing new kernel executables (asserted on the jit
  cache itself) — the size-bucketed jit means a 16-tile level never pays
  a compile a 256-tile level doesn't.
  (The decode-path twin — batched speedup >1x at every batch size — lives
  in ``export_bench.py``.)

The end-to-end tile-encode row opens the slide through the ``formats``
registry (``open_slide``), exercising the same container sniffing as the
pipeline.
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys
import textwrap
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import jpeg_inverse, jpeg_transform, ref
from repro.roofline import derive_terms
from repro.roofline.terms import HW
from repro.wsi.jpeg import encode_tile
from repro.wsi.slide import SyntheticScanner
from repro.wsi.formats import open_slide

SRC = str(Path(__file__).resolve().parents[1] / "src")

TILE = 256
ROOFLINE_N = 64  # tiles per level batch in the roofline lowering

KERNELS = ("jpeg_transform", "jpeg_inverse", "downsample2x2")


def model_flops(kernel: str, n: int, tile: int) -> float:
    """Analytic useful math per kernel call (the roofline numerator).

    Counts only the kernel's defining arithmetic, not compiled overhead:

    - color transform: 3 outputs × (3 mul + 3 add) per pixel;
    - 8×8 DCT (or iDCT): two 8×8×8 matmuls per block = 2·(2·8³) flops per
      64 pixels = 64 flops/pixel, plus ~2 flops/pixel (de)quant + round,
      per channel;
    - 2×2 box filter: 3 add + 1 mul per output pixel per channel.
    """
    px = n * tile * tile
    if kernel in ("jpeg_transform", "jpeg_inverse"):
        return px * (18 + 3 * (64 + 2))
    if kernel == "downsample2x2":
        return 3 * (px / 4) * 4
    raise ValueError(kernel)


def _roofline_prog(device_count: int, n: int, tile: int) -> str:
    """Subprocess: lower each sharded kernel, print analyze_hlo JSON."""
    return textwrap.dedent("""
        import os, json
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count=%d"
        import jax, jax.numpy as jnp, sys
        sys.path.insert(0, %r)
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.kernels import jpeg_transform, jpeg_inverse, downsample2x2
        from repro.launch.mesh import make_local_mesh
        from repro.roofline import analyze_hlo

        mesh = make_local_mesh()
        n, tile = %d, %d
        out = {}
        batch = jax.ShapeDtypeStruct((n, 3, tile, tile), jnp.float32)
        coef = jax.ShapeDtypeStruct((n, 3, tile, tile), jnp.int32)
        sh = NamedSharding(mesh, P("data"))
        for name, fn, spec in [
            ("jpeg_transform", lambda x: jpeg_transform(x), (batch, sh)),
            ("jpeg_inverse", lambda x: jpeg_inverse(x), (coef, sh)),
            # no batch axis: a level plane, rows over the data axis
            ("downsample2x2",
             lambda x: downsample2x2(x),
             (jax.ShapeDtypeStruct((3, n * tile // 8, tile * 8),
                                   jnp.float32),
              NamedSharding(mesh, P(None, "data", None)))),
        ]:
            arg, sharding = spec
            c = jax.jit(fn, in_shardings=sharding).lower(arg).compile()
            r = analyze_hlo(c.as_text())
            out[name] = {"flops": r["flops"], "bytes": r["bytes"],
                         "collective_bytes": r["collective_bytes"],
                         "by_kind": r["by_kind"]}
        print("ROOFLINE-JSON " + json.dumps(out))
    """) % (device_count, SRC, n, tile)


def _roofline_section(device_counts: list[int]) -> dict:
    """Per kernel per device count: HLO totals → three-term roofline."""
    hw = HW()
    out: dict[str, dict[str, dict]] = {k: {} for k in KERNELS}
    for d in device_counts:
        prog = _roofline_prog(d, ROOFLINE_N, TILE)
        res = subprocess.run([sys.executable, "-c", prog],
                             capture_output=True, text=True, timeout=600)
        line = next((ln for ln in res.stdout.splitlines()
                     if ln.startswith("ROOFLINE-JSON ")), None)
        assert line is not None, \
            f"roofline subprocess (D={d}) failed:\n{res.stderr[-2000:]}"
        analyzed = json.loads(line[len("ROOFLINE-JSON "):])
        for kernel in KERNELS:
            a = analyzed[kernel]
            terms = derive_terms(
                flops_per_device=a["flops"],
                bytes_per_device=a["bytes"],
                collective_bytes_per_device=a["collective_bytes"],
                chips=d,
                model_flops_total=model_flops(kernel, ROOFLINE_N, TILE),
                hw=hw)
            terms["collective_by_kind"] = a["by_kind"]
            out[kernel][str(d)] = terms
    return out


def _time(fn, *args, reps: int = 3) -> float:
    fn(*args)  # warm/compile
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def _measured_section(reps: int) -> dict:
    """Single-device wall time vs the analytic roofline (CPU proxy)."""
    rng = np.random.default_rng(0)
    batch = jnp.asarray(
        rng.integers(0, 255, size=(ROOFLINE_N, 3, TILE, TILE)), jnp.float32)
    coef = np.asarray(jpeg_transform(batch))
    hw = HW()
    out = {}
    for name, fn, arg in [("jpeg_transform", jpeg_transform, batch),
                          ("jpeg_inverse", jpeg_inverse,
                           jnp.asarray(coef))]:
        wall = _time(fn, arg, reps=reps)
        mf = model_flops(name, ROOFLINE_N, TILE)
        # the batch read + written once at f32/i32 = the memory floor
        floor_s = 2 * arg.nbytes / hw.hbm_bw
        out[name] = {
            "batch": list(arg.shape),
            "wall_s": wall,
            "achieved_gflops": mf / wall / 1e9,
            "peak_gflops": hw.peak_flops / 1e9,
            "achieved_vs_peak": mf / wall / hw.peak_flops,
            "memory_floor_s": floor_s,
            "achieved_vs_memory_bound": floor_s / wall,
        }
    return out


def _batch_scaling_section(ns: list[int], reps: int) -> list[dict]:
    """Per-tile dispatch cost vs batch size — the bucketed-jit gate."""
    rng = np.random.default_rng(1)
    full = jnp.asarray(rng.integers(0, 255, size=(max(ns), 3, TILE, TILE)),
                       jnp.float32)
    coef_full = jnp.asarray(np.asarray(jpeg_transform(full)))
    rows = []
    for n in ns:
        t_fwd = _time(jpeg_transform, full[:n], reps=reps)
        t_inv = _time(jpeg_inverse, coef_full[:n], reps=reps)
        rows.append({"n_tiles": n,
                     "transform_us_per_tile": t_fwd / n * 1e6,
                     "inverse_us_per_tile": t_inv / n * 1e6})
    for key in ("transform_us_per_tile", "inverse_us_per_tile"):
        floor = min(r[key] for r in rows)
        for r in rows:
            # the cliff gate: per-tile cost must stay flat across batch
            # sizes (≤3× the cheapest point — a recompile cliff is ~100×).
            # Host cache pressure on the largest batches costs ~2× on this
            # CPU proxy and stays inside the slack.
            assert r[key] <= floor * 3.0, (
                f"{key} cliff at n={r['n_tiles']}: {r[key]:.0f}us/tile vs "
                f"{floor:.0f}us/tile floor")

    # bucket-reuse gate: an odd batch size must ride an already-compiled
    # pow2 bucket, not trace a new kernel executable (the recompile cliff
    # the bucketed jit removes). Observed directly on the jit cache.
    from repro.kernels import ops
    jax.block_until_ready(jpeg_transform(full[:32]))  # warm the 32 bucket
    before = ops._jpeg_transform_core._cache_size()
    for n in (17, 19, 23, 32):
        jax.block_until_ready(jpeg_transform(full[:n]))
    after = ops._jpeg_transform_core._cache_size()
    assert after == before, (
        f"odd batch sizes traced new kernel executables: jit cache grew "
        f"{before}→{after}")
    return rows


def _micro_rows(reps: int) -> list[tuple[str, float, str]]:
    """The original per-kernel microbenchmark rows (CSV only)."""
    rng = np.random.default_rng(0)
    tile = jnp.asarray(rng.integers(0, 255, size=(3, TILE, TILE)),
                       jnp.float32)
    plane = jnp.asarray(rng.normal(0, 40, size=(TILE, TILE)), jnp.float32)
    q = jnp.asarray(ref.JPEG_LUMA_Q)
    rows = [
        ("rgb2ycbcr_ref_256",
         _time(jax.jit(ref.rgb2ycbcr_ref), tile, reps=reps) * 1e6,
         "3x256x256"),
        ("downsample_ref_256",
         _time(jax.jit(ref.downsample2x2_ref), tile, reps=reps) * 1e6,
         "3x256x256"),
        ("dct_quant_ref_256",
         _time(jax.jit(lambda p: ref.dct8x8_quant_ref(p, q)), plane,
               reps=reps) * 1e6,
         "256x256"),
    ]

    # fused rwkv6 wkv chunk kernel vs unfused chunked XLA path
    from repro.kernels.wkv_chunk import wkv_chunk_pallas
    from repro.models.rwkv6 import wkv_chunked
    B, S, H, K = 1, 256, 2, 64
    rr, kk, vv = (jnp.asarray(rng.normal(size=(B, S, H, K)), jnp.float32)
                  for _ in range(3))
    lw = -jnp.asarray(rng.uniform(0.01, 2.0, (B, S, H, K)), jnp.float32)
    uu = jnp.asarray(rng.normal(size=(H, K)), jnp.float32)
    st0 = jnp.zeros((B, H, K, K), jnp.float32)
    rows.append(("wkv_chunked_xla",
                 _time(jax.jit(lambda *a: wkv_chunked(*a)[0]),
                       rr, kk, vv, lw, uu, st0, reps=reps) * 1e6,
                 f"B{B} S{S} H{H}"))
    rows.append(("wkv_chunk_pallas_interp",
                 _time(lambda *a: wkv_chunk_pallas(*a), rr, kk, vv, lw, uu,
                       reps=1) * 1e6,
                 "interpret-mode"))

    # end-to-end tile encode, slide opened through the format sniffer
    psv = SyntheticScanner(seed=0).scan(TILE, TILE, TILE)
    t = open_slide(psv).read_tile(0, 0)
    encode_tile(t)  # warm
    t0 = time.perf_counter()
    n = 4
    for _ in range(n):
        jpg = encode_tile(t)
    dt = (time.perf_counter() - t0) / n
    rows.append(("jpeg_encode_tile_256", dt * 1e6,
                 f"{0.256*0.256/dt:.2f}MPix/s ratio={len(jpg)/t.nbytes:.3f}"))
    return rows


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke: fewer device counts / batch sizes, "
                         "same monotonicity gate")
    args = ap.parse_args(argv)
    device_counts = [1, 4] if args.fast else [1, 4, 8]
    scaling_ns = [16, 64] if args.fast else [16, 64, 256]
    reps = 2 if args.fast else 3

    roofline = _roofline_section(device_counts)
    measured = _measured_section(reps)
    scaling = _batch_scaling_section(scaling_ns, reps)
    result = {
        "hw": HW().__dict__,
        "roofline_batch": {"n_tiles": ROOFLINE_N, "tile": TILE},
        "roofline": roofline,
        "measured": measured,
        "batch_scaling": scaling,
    }
    with open("BENCH_kernels.json", "w") as f:
        json.dump(result, f, indent=2)

    print("name,value,derived")
    for kernel in KERNELS:
        for d, t in roofline[kernel].items():
            print(f"roofline_{kernel}_d{d},{t['bound_s']*1e6:.1f}us,"
                  f"bound={t['dominant'].removesuffix('_s')} "
                  f"useful={t['useful_flops_ratio']:.2f} "
                  f"mfu_bound={t['mfu_bound']:.3f}")
    for name, m in measured.items():
        print(f"measured_{name},{m['wall_s']*1e3:.1f}ms,"
              f"{m['achieved_gflops']:.2f}GFLOP/s "
              f"vs_peak={m['achieved_vs_peak']:.2e} "
              f"vs_membound={m['achieved_vs_memory_bound']:.2e}")
    for s in scaling:
        print(f"batch_scaling_n{s['n_tiles']},"
              f"{s['transform_us_per_tile']:.0f}us/tile,"
              f"inverse={s['inverse_us_per_tile']:.0f}us/tile")
    for name, us, derived in _micro_rows(reps):
        print(f"{name},{us:.0f},{derived}")
    print("wrote BENCH_kernels.json")


if __name__ == "__main__":
    main()
