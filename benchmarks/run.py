"""Benchmark entry point: one module per paper figure/table + kernel + roofline.

``python -m benchmarks.run`` prints CSV blocks per benchmark; the roofline
table is regenerated from the dry-run artifacts (run the dry-run sweep first
for a complete table).
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (fig2_workflows, fig3_autoscaling, fleet_bench,
                            kernels_bench, roofline_report)

    sections = [
        ("fig2_workflows (paper Figure 2)", fig2_workflows.main),
        ("fig3_autoscaling (paper Figure 3)", fig3_autoscaling.main),
        ("fleet (Figures 2-3 through the converter fleet + fault gauntlet)",
         lambda: fleet_bench.main([])),
        ("kernels (conversion hot spots)", kernels_bench.main),
        ("roofline (from dry-run artifacts)", roofline_report.main),
    ]
    failed = []
    for name, fn in sections:
        print(f"\n==== {name} ====")
        try:
            fn()
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"\nFAILED sections: {failed}", file=sys.stderr)
        sys.exit(1)
    print("\nall benchmark sections completed")


if __name__ == "__main__":
    main()
