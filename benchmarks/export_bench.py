"""Export subsystem benchmark: batched inverse JPEG path + dicom2tiff e2e.

Decode section (the acceptance-gated one): every tile of a pyramid level
is decoded two ways —

- **per-tile (seed)** — ``[decode_tile(j) for j in frames]``: a per-symbol
  Python Huffman loop plus one fused inverse dispatch per tile;
- **batched** — ``decode_tiles_batch(frames)``: the lockstep vectorized
  entropy decoder (one numpy step per symbol *position* across the whole
  level) plus a single fused ``jpeg_inverse`` dispatch.

Pixel identity between the two paths and coefficient-exact
``decode_coef_batch ∘ encode_coef_batch`` are asserted; the speedup is
recorded and must exceed 1x at **every** ``batch_scaling`` point, small
batches included (the jitted lockstep entropy engine keeps 16-tile levels
ahead of the per-tile loop — the old numpy lockstep lost there at 0.82x).
Bigger levels (and multi-frame WADO pulls) still win more.

Export section: a synthetic slide is converted, STOWed into a
``DicomStoreService``, and exported to a tiled-TIFF pyramid through
``ExportService`` (QIDO + frame-level WADO reads). Asserts, in both
modes: repeated export is byte-identical, export after a simulated crash
(fresh service + ``rebuild_index()``) is byte-identical, every exported
TIFF reopens through the ``open_slide`` sniffer, and the level-0 TIFF
survives a full-circle re-conversion into a new DICOM study.

Writes ``BENCH_export.json`` and prints a CSV summary. ``--fast`` shrinks
the decode workload for the CI smoke; every assertion is identical.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import SimScheduler
from repro.core.storage import ObjectStore
from repro.kernels import jpeg_transform
from repro.wsi.convert import ConvertOptions, convert_wsi_to_dicom
from repro.wsi.export import ExportService
from repro.wsi.formats import open_slide
from repro.wsi.jpeg import (decode_coef_batch, decode_tile,
                            decode_tiles_batch, encode_coef_batch)
from repro.wsi.slide import PSVReader, SyntheticScanner
from repro.wsi.store_service import DicomStoreService

TILE = 256


def _level_frames(hw: int, seed: int = 3) -> tuple[list[bytes], np.ndarray]:
    """One pyramid level's JPEG frames (+ their exact coefficients)."""
    rd = PSVReader(SyntheticScanner(seed=seed).scan(hw, hw, TILE))
    bh, bw = rd.grid
    tiles = np.stack([rd.read_tile(r, c)
                      for r in range(bh) for c in range(bw)])
    chw = np.transpose(tiles, (0, 3, 1, 2)).astype(np.float32)
    coef = np.asarray(jpeg_transform(chw))
    return encode_coef_batch(coef), coef


def _decode_section(hw: int, scaling_ns: list[int]) -> dict:
    frames, coef = _level_frames(hw)
    n = len(frames)

    # entropy decode∘encode must be coefficient-exact
    assert (decode_coef_batch(frames) == coef).all(), \
        "decode_coef_batch diverges from the encoded coefficients"

    # warm both paths (the fused inverse jits per batch shape)
    decode_tile(frames[0])
    decode_tiles_batch(frames)

    t0 = time.perf_counter()
    per = [decode_tile(j) for j in frames]
    t_per = time.perf_counter() - t0
    t0 = time.perf_counter()
    bat = decode_tiles_batch(frames)
    t_bat = time.perf_counter() - t0
    assert (np.stack(per) == bat).all(), \
        "batched decode diverges from the per-tile loop"
    speedup = t_per / t_bat
    assert speedup > 1.0, \
        f"batched decode only {speedup:.2f}x over per-tile (< 1x) at n={n}"

    scaling = []
    for sn in scaling_ns:
        if sn > n:
            continue
        sub = frames[:sn]
        decode_tiles_batch(sub)  # warm this batch shape's jit
        t0 = time.perf_counter()
        p = [decode_tile(j) for j in sub]
        tp = time.perf_counter() - t0
        t0 = time.perf_counter()
        b = decode_tiles_batch(sub)
        tb = time.perf_counter() - t0
        assert (np.stack(p) == b).all()
        # the small-batch cliff gate: the batched path must win at EVERY
        # batch size, not just whole-level batches (the jitted lockstep
        # entropy engine is what holds this at n=16 — see wsi/entropy_jax)
        assert tp / tb > 1.0, \
            f"batched decode only {tp / tb:.2f}x over per-tile at n={sn}"
        scaling.append({"n_tiles": sn, "per_tile_us": tp / sn * 1e6,
                        "batched_us": tb / sn * 1e6, "speedup": tp / tb})

    return {
        "hw": hw,
        "tile": TILE,
        "n_tiles": n,
        "per_tile_us": t_per / n * 1e6,
        "batched_us": t_bat / n * 1e6,
        "speedup": speedup,
        "pixel_identical": True,
        "coef_roundtrip_exact": True,
        "batch_scaling": scaling,
    }


def _snapshot(derived) -> dict:
    return {k: derived.get(k).data for k in derived.list()}


def _export_section(slide_hw: int) -> dict:
    psv = SyntheticScanner(seed=21).scan(slide_hw, slide_hw, TILE)
    archive = convert_wsi_to_dicom(
        psv, {"slide_id": "bench"}, options=ConvertOptions())

    sched = SimScheduler()
    store = ObjectStore(sched)
    svc = DicomStoreService(store.bucket("dicom"), sched)
    svc.store_study_archive("studies/bench.tar", archive)
    (study,) = svc.search_studies()
    exporter = ExportService(svc, store.bucket("derived"))

    t0 = time.perf_counter()
    keys = exporter.export_study(study)
    t_export = time.perf_counter() - t0
    clean = _snapshot(exporter.derived)
    frames_decoded = int(
        svc.metrics.get("pipeline.export.frames_decoded"))

    # repeated export, full re-derivation forced: byte-identical TIFFs
    # (idempotent bucket no-ops) — proves determinism, not just the
    # generation-skip shortcut
    t0 = time.perf_counter()
    exporter.export_study(study, skip_unchanged=False)
    t_re = time.perf_counter() - t0
    assert _snapshot(exporter.derived) == clean, \
        "repeated export changed derived TIFF bytes"

    # default path: unchanged levels are skipped without fetch/decode
    exporter.export_study(study)
    assert svc.metrics.get("pipeline.export.levels_unchanged") \
        == len(keys), "generation-skip did not engage on re-export"
    assert _snapshot(exporter.derived) == clean

    # simulated crash: a fresh service over the same bucket, index rebuilt
    # from the checkpoint + blob rescan, must export byte-identically
    svc2 = DicomStoreService(store.bucket("dicom"), sched)
    svc2.rebuild_index()
    exporter2 = ExportService(svc2, store.bucket("derived2"))
    exporter2.export_study(study)
    assert _snapshot(exporter2.derived) == \
        {k: v for k, v in clean.items()}, \
        "post-rebuild export changed derived TIFF bytes"

    # every exported level reopens through the format sniffer
    total_px = 0
    for key in keys:
        rd = open_slide(exporter.derived.get(key).data)
        total_px += rd.H * rd.W
        assert rd.tile == TILE and rd.metadata.get("study") == study

    # full circle: the exported level-0 TIFF re-converts into a new study
    tif0 = exporter.derived.get(keys[0]).data
    circle = convert_wsi_to_dicom(tif0, {"slide_id": "full-circle"})

    return {
        "slide_hw": slide_hw,
        "levels_exported": len(keys),
        "frames_decoded": frames_decoded,
        "export_s": t_export,
        "reexport_s": t_re,
        "mpix_s": total_px / 1e6 / t_export,
        "tiff_bytes": sum(len(v) for v in clean.values()),
        "repeat_identical": True,
        "rebuild_identical": True,
        "reopens_via_sniffer": True,
        "full_circle_bytes": len(circle),
    }


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke: smaller level/slide, same assertions")
    args = ap.parse_args(argv)
    decode_hw = 2048 if args.fast else 4096
    scaling_ns = [16, 64] if args.fast else [16, 64, 256]
    slide_hw = 512 if args.fast else 1024

    decode = _decode_section(decode_hw, scaling_ns)
    export = _export_section(slide_hw)
    result = {"decode": decode, "export": export}
    with open("BENCH_export.json", "w") as f:
        json.dump(result, f, indent=2)

    print("name,value,derived")
    print(f"decode_per_tile_us,{decode['per_tile_us']:.0f},"
          f"{decode['n_tiles']}tiles/{decode['hw']}^2")
    print(f"decode_batched_us,{decode['batched_us']:.0f},"
          f"speedup={decode['speedup']:.2f}x "
          f"pixel_identical={decode['pixel_identical']} "
          f"coef_exact={decode['coef_roundtrip_exact']}")
    for s in decode["batch_scaling"]:
        print(f"decode_scaling_n{s['n_tiles']},{s['speedup']:.2f}x,"
              f"{s['batched_us']:.0f}us/tile")
    print(f"export_s,{export['export_s']:.3f},"
          f"{export['levels_exported']}levels/{export['slide_hw']}^2 "
          f"{export['mpix_s']:.2f}MPix/s")
    print(f"reexport_s,{export['reexport_s']:.3f},"
          f"identical={export['repeat_identical']}")
    print(f"rebuild_export,ok,identical={export['rebuild_identical']}")
    print(f"full_circle,ok,{export['full_circle_bytes']}B study tar "
          f"from the exported TIFF")
    print("wrote BENCH_export.json")


if __name__ == "__main__":
    main()
