"""Racedep self-tests: the detector fires on planted data races — including
test doubles of the three historical interleaving bugs PRs 2/4 fixed by
hand — and stays silent on every synchronized pattern the tree uses
(lock-guarded access, condition handoff, scheduler fork/join, tracked
spawns).

Planted races run inside ``racedep.capture()`` so the suite-wide detector
armed by conftest never sees them. Note the vector-clock property that
makes these tests deterministic: two spawned threads are unordered by
happens-before even if the OS happens to run them back-to-back, so a
planted race is reported on every run, not just unlucky ones.
"""
import pytest

from repro.analysis import racedep
from repro.analysis.lockdep import TrackedLock
from repro.analysis.racedep import Shared, tracked_state
from repro.core import RealScheduler, SimScheduler
from repro.core.metrics import Metrics


def _race_vars(det):
    return [v.variable for v in det.violations]


def _spawn_join(*fns):
    threads = [racedep.spawn(fn, start=False) for fn in fns]
    for t in threads:
        t.start()
    for t in threads:
        t.join(10.0)


# ------------------------------------------------------------ planted races
def test_unsynchronized_writes_race():
    with racedep.capture() as det:
        d = Shared({}, "t.d")

        def w1():
            d["k"] = 1

        def w2():
            d["k"] = 2

        _spawn_join(w1, w2)
    assert "t.d" in _race_vars(det)
    v = det.violations[0]
    assert "t.d" in v.message and v.first_site != "<unknown>"
    assert v.first_site != v.second_site


def test_read_write_race_reports_both_sites():
    with racedep.capture() as det:
        items = Shared([], "t.items")

        def reader():
            len(items)

        def writer():
            items.append(1)

        _spawn_join(reader, writer)
    assert _race_vars(det) == ["t.items"]
    v = det.violations[0]
    assert "test_racedep.py" in v.first_site
    assert "test_racedep.py" in v.second_site


def test_disjoint_locksets_still_race():
    """Each thread holds *a* lock — just not the same one (the classic
    Eraser case a pure happens-before detector can miss and a pure
    lockset detector exists to catch)."""
    la, lb = TrackedLock("ra"), TrackedLock("rb")
    with racedep.capture() as det:
        d = Shared({}, "t.split")

        def w1():
            with la:
                d["k"] = 1

        def w2():
            with lb:
                d["k"] = 2

        _spawn_join(w1, w2)
    assert "t.split" in _race_vars(det)


def test_duplicate_race_reported_once():
    with racedep.capture() as det:
        d = Shared({}, "t.dup")

        def w1():
            for _ in range(50):
                d["k"] = 1

        def w2():
            for _ in range(50):
                d["k"] = 2

        _spawn_join(w1, w2)
    # many colliding accesses from one site pair: one report
    assert len([v for v in det.violations if v.variable == "t.dup"]) <= 2


# ------------------------------------------- historical bugs as test doubles
def test_double_hedge_settlement_detected():
    """PR 4's bug: the original delivery and its hedge both completed, and
    both settled — the check and the claim were not atomic. The double
    re-plants that access pattern: two completion paths read ``done`` then
    write it without the subscription lock's claim."""
    with racedep.capture() as det:
        outstanding = Shared({7: "ctx"}, "double.outstanding")
        converted = Shared([], "double.converted")

        def settle():
            # the pre-fix shape of Subscription._settle: check-then-act
            # with no lock — both the original and the hedge pass the
            # check and both convert
            if 7 in outstanding:
                converted.append("slide-7")

        _spawn_join(settle, settle)
    assert "double.converted" in _race_vars(det) or \
        "double.outstanding" in _race_vars(det)


def test_callback_order_race_detected():
    """PR 2's bug: the pump invoked the endpoint callback while another
    thread was still mutating the subscription's backlog — the callback
    observed (and mutated) the deque mid-update. The double re-plants the
    unguarded backlog handoff between pump and callback."""
    with racedep.capture() as det:
        backlog = Shared([], "double.backlog")

        def pump():
            backlog.append("msg-1")  # enqueue outside the lock

        def callback():
            if backlog:              # endpoint draining concurrently
                backlog.pop()

        _spawn_join(pump, callback)
    assert "double.backlog" in _race_vars(det)


def test_unguarded_metrics_inc_detected():
    """The Metrics variant PR 8's audit killed: ``counters[name] += v``
    without the lock loses increments when pool threads collide. The
    double bypasses ``Metrics.inc`` and hits the (tracked) dict raw."""
    with racedep.capture() as det:
        m = Metrics()

        def bump():
            # read-modify-write with no lock — the exact pre-audit shape
            m.counters["svc.conv.requests"] = \
                m.counters["svc.conv.requests"] + 1

        _spawn_join(bump, bump)
    assert "Metrics.counters" in _race_vars(det)


def test_guarded_metrics_inc_is_clean():
    """...and the shipped, locked ``inc`` on the same structure is clean."""
    with racedep.capture() as det:
        m = Metrics()
        _spawn_join(*[lambda: m.inc("svc.conv.requests")] * 4)
    assert det.violations == []
    assert m.get("svc.conv.requests") == 4.0


# ------------------------------------------------- synchronized negative space
def test_same_lock_orders_accesses():
    lk = TrackedLock("t.guard")
    with racedep.capture() as det:
        d = Shared({}, "t.guarded")

        def w(v):
            def go():
                with lk:
                    d["k"] = v
            return go

        _spawn_join(w(1), w(2))
    assert det.violations == []


def test_spawn_join_edge_orders_accesses():
    with racedep.capture() as det:
        d = Shared({}, "t.forkjoin")
        d["k"] = "parent"          # before fork: ordered by the spawn token

        def child():
            d["k"] = "child"

        t = racedep.spawn(child)
        t.join(10.0)
        assert d["k"] == "child"   # after join: ordered by the join edge
    assert det.violations == []


def test_sequential_spawns_are_ordered_through_parent():
    """T1 completes and is joined before T2 spawns: T2 inherits T1's
    history through the parent's clock — no race despite no common lock."""
    with racedep.capture() as det:
        d = Shared({}, "t.seq")

        def w1():
            d["a"] = 1

        def w2():
            d["a"] = 2

        t1 = racedep.spawn(w1)
        t1.join(10.0)
        t2 = racedep.spawn(w2)
        t2.join(10.0)
    assert det.violations == []


def test_lock_handoff_orders_across_threads():
    """A writes under L, B later takes L and writes: the release→acquire
    edge orders them even though the accesses themselves were seconds
    apart in different threads."""
    lk = TrackedLock("t.handoff")
    with racedep.capture() as det:
        d = Shared({}, "t.handoff_var")

        def first():
            with lk:
                d["k"] = 1

        t1 = racedep.spawn(first)
        t1.join(10.0)

        def second():
            with lk:
                assert d["k"] == 1
                d["k"] = 2

        t2 = racedep.spawn(second)
        t2.join(10.0)
    assert det.violations == []


def test_realscheduler_submit_edge_orders_accesses():
    """Main-thread state written before schedule() is visible to the pool
    thread, and main's post-run() read is ordered after the worker's
    write — the fork/join token plus the quiescence condition wait."""
    sched = RealScheduler(workers=2)
    try:
        with racedep.capture() as det:
            d = Shared({}, "t.sched")
            d["k"] = "main"

            def work():
                assert d["k"] == "main"
                d["k"] = "worker"

            sched.schedule(0.0, work)
            sched.run(until=10.0)
            assert d["k"] == "worker"
        assert det.violations == []
    finally:
        sched.shutdown()


def test_condition_wait_covered_by_lock_edges():
    """The producer/consumer condition handoff (RealScheduler.run's own
    pattern) generates no reports: wait's release/re-acquire go through
    TrackedLock's _release_save/_acquire_restore."""
    import threading

    lk = TrackedLock("t.cond")
    cond = threading.Condition(lk)
    with racedep.capture() as det:
        box = Shared([], "t.box")

        def producer():
            with cond:
                box.append("ready")
                cond.notify_all()

        t = racedep.spawn(producer, start=False)
        with cond:
            t.start()
            while not box:
                cond.wait(timeout=5.0)
            assert box[0] == "ready"
        t.join(5.0)
    assert det.violations == []


def test_single_thread_never_races():
    with racedep.capture() as det:
        d = Shared({}, "t.solo")
        for i in range(100):
            d[i] = i
            _ = d[i]
        assert len(d) == 100
    assert det.violations == []


def test_sim_scheduler_is_single_threaded_and_clean():
    sched = SimScheduler()
    with racedep.capture() as det:
        d = Shared({}, "t.sim")
        for i in range(20):
            sched.schedule(float(i % 3), d.__setitem__, i, i)
        sched.run()
        assert len(d) == 20
    assert det.violations == []


# -------------------------------------------------------- arming / instrument
def test_disarmed_records_nothing():
    prev = racedep._DETECTOR          # conftest armed the suite detector
    racedep._DETECTOR = None
    try:
        d = Shared({}, "t.off")
        d["k"] = 1
        assert d._race is None        # the disarmed fast path records nothing
    finally:
        racedep._DETECTOR = prev


def test_arm_rejects_nesting():
    # conftest already armed the suite detector
    with pytest.raises(RuntimeError, match="already armed"):
        racedep.arm()


def test_capture_scopes_and_restores():
    outer = racedep.current()
    with racedep.capture() as det:
        assert racedep.current() is det
        d = Shared({}, "t.scoped")

        def w1():
            d["k"] = 1

        def w2():
            d["k"] = 2

        _spawn_join(w1, w2)
    assert racedep.current() is outer
    assert det.violations  # stayed in the scoped detector
    assert all(v.variable != "t.scoped"
               for v in (outer.violations if outer else []))


def test_max_violations_bounds_reports():
    with racedep.capture(max_violations=1) as det:
        shared = [Shared({}, f"t.cap{i}") for i in range(5)]

        def w(v):
            def go():
                for s in shared:
                    s["k"] = v
            return go

        _spawn_join(w(1), w(2))
    assert len(det.violations) == 1


def test_instrumentation_kill_switch():
    """set_instrumentation(False): structures built while disabled carry
    raw containers (the overhead benchmark's uninstrumented baseline)."""
    prev = racedep.set_instrumentation(False)
    try:
        m = Metrics()
        assert not isinstance(m.counters, Shared)
    finally:
        racedep.set_instrumentation(prev)
    m2 = Metrics()
    assert isinstance(m2.counters, Shared)


# ------------------------------------------------------------- tracked_state
def test_tracked_state_wraps_init_and_rebinding():
    @tracked_state("items")
    class Box:
        def __init__(self):
            self.items = []
            self.plain = 0

    b = Box()
    assert isinstance(b.items, Shared)
    assert b.items.name == "Box.items"
    assert not isinstance(b.plain, Shared)
    b.items = ["rebound"]          # rebuild_index-style whole swap
    assert isinstance(b.items, Shared)
    assert list(b.items) == ["rebound"]


def test_shared_delegates_container_surface():
    d = Shared({"a": 1}, "t.surface")
    assert d == {"a": 1} and not d != {"a": 1}
    assert "a" in d and len(d) == 1 and list(d) == ["a"]
    assert d["a"] == 1 and d.get("b", 9) == 9
    assert dict(d) == {"a": 1}
    d["b"] = 2
    del d["b"]
    assert d.setdefault("c", 3) == 3
    assert d.pop("c") == 3
    assert sorted(d.items()) == [("a", 1)]
    lst = Shared([3, 1], "t.list")
    lst.sort()
    assert lst == [1, 3] and repr(lst).startswith("Shared(")
    assert bool(Shared([], "t.empty")) is False


def test_shared_eq_between_proxies():
    assert Shared([1], "x") == Shared([1], "y")
