import os
import sys
from pathlib import Path

# single-device CPU for tests (the dry-run manages its own device count)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

SRC = Path(__file__).resolve().parents[1] / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))
