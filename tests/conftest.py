import os
import sys
from pathlib import Path

import pytest

# single-device CPU for tests (the dry-run manages its own device count)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

SRC = Path(__file__).resolve().parents[1] / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))

from repro.analysis import lockdep, racedep  # noqa: E402


@pytest.fixture(autouse=True)
def _lockdep_armed(request):
    """Arm the lockdep detector for every test; fail on any violation.

    Every TrackedLock acquisition in the tree is observed while a test
    runs: lock-order inversions, callbacks invoked under a tracked lock,
    holds longer than ``max_hold`` and acquisitions inside a jit trace all
    fail the test that provoked them. Self-tests that *plant* violations
    run them inside ``lockdep.capture()``, which shadows this detector, so
    planted violations never leak here.
    """
    det = lockdep.arm(max_hold=30.0)
    try:
        yield det
    finally:
        violations = lockdep.disarm()
        if violations:
            lines = "\n".join(f"  [{v.kind}] {v.message}" for v in violations)
            pytest.fail(
                f"lockdep: {len(violations)} violation(s) during test:\n"
                f"{lines}", pytrace=False)


@pytest.fixture(autouse=True)
def _racedep_armed(request):
    """Arm the data-race detector for every test; fail on any report.

    Every read/write of the spine's ``@tracked_state`` structures is
    checked against the happens-before order (locks, condition waits,
    scheduler fork/join, tracked spawns) while a test runs. Self-tests
    that *plant* races scope them inside ``racedep.capture()``.
    """
    det = racedep.arm()
    try:
        yield det
    finally:
        violations = racedep.disarm()
        if violations:
            lines = "\n".join(f"  {v.message}\n    first:  {v.first_site}"
                              f"\n    second: {v.second_site}"
                              for v in violations)
            pytest.fail(
                f"racedep: {len(violations)} data race(s) during test:\n"
                f"{lines}", pytrace=False)
