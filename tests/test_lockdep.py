"""Lockdep self-tests: every detector fires on a planted violation, and the
legitimate concurrency patterns in the tree (consistent lock orders,
re-entrancy, condition waits, the fleet's budget-exempt shed/nack path) stay
violation-free.

Planted violations run inside ``lockdep.capture()`` so the suite-wide
detector armed by conftest never sees them."""
import random
import threading

import pytest

from _hypothesis_compat import given, settings, st
from repro.analysis import lockdep, racedep
from repro.analysis.lockdep import TrackedLock
from repro.core import ConversionPipeline, SimScheduler


def _kinds(det):
    return [v.kind for v in det.violations]


# ------------------------------------------------------- seeded violations
def test_inversion_detected_same_thread():
    a, b = TrackedLock("A"), TrackedLock("B")
    with lockdep.capture() as det:
        with a:
            with b:
                pass
        with b:
            with a:
                pass
    assert "inversion" in _kinds(det)
    msg = next(v for v in det.violations if v.kind == "inversion").message
    assert "A" in msg and "B" in msg


def test_inversion_detected_across_threads():
    # thread 1 takes A→B, thread 2 takes B→A — the classic ABBA deadlock
    # candidate, sequenced with events so the run itself never deadlocks
    a, b = TrackedLock("A"), TrackedLock("B")
    first_done = threading.Event()

    def t1():
        with a:
            with b:
                pass
        first_done.set()

    def t2():
        first_done.wait(5.0)
        with b:
            with a:
                pass

    with lockdep.capture() as det:
        threads = [racedep.spawn(t1, start=False),
                   racedep.spawn(t2, start=False)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(10.0)
    assert _kinds(det).count("inversion") == 1


def test_three_lock_cycle_detected():
    a, b, c = TrackedLock("A"), TrackedLock("B"), TrackedLock("C")
    with lockdep.capture() as det:
        with a, b:     # A→B
            pass
        with b, c:     # B→C
            pass
        with c, a:     # C→A closes the 3-cycle
            pass
    assert "inversion" in _kinds(det)


def test_callback_under_lock_detected():
    lk = TrackedLock("guard")
    with lockdep.capture() as det:
        with lk:
            lockdep.check_callback("planted.endpoint")
    vs = [v for v in det.violations if v.kind == "callback-under-lock"]
    assert len(vs) == 1
    assert "planted.endpoint" in vs[0].message
    assert "guard" in vs[0].message


def test_held_too_long_detected():
    lk = TrackedLock("slow")
    with lockdep.capture(max_hold=0.0) as det:
        with lk:
            sum(range(1000))  # any nonzero hold beats max_hold=0
    assert "held-too-long" in _kinds(det)


def test_acquired_in_jit_detected():
    jax = pytest.importorskip("jax")
    lk = TrackedLock("jit-victim")

    @jax.jit
    def f(x):
        with lk:  # runs at trace time only — the guard protects nothing
            pass
        return x + 1

    with lockdep.capture() as det:
        assert int(f(1)) == 2
    assert "acquired-in-jit" in _kinds(det)


def test_arm_rejects_nesting():
    # conftest already armed the global detector for this test
    assert lockdep.current() is not None
    with pytest.raises(RuntimeError):
        lockdep.arm()


# --------------------------------------------------------- negative space
def test_consistent_order_across_threads_is_clean():
    a, b = TrackedLock("A"), TrackedLock("B")

    def worker():
        for _ in range(50):
            with a:
                with b:
                    pass

    with lockdep.capture() as det:
        threads = [racedep.spawn(worker, start=False) for _ in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(10.0)
    assert det.violations == []
    assert det.edges_recorded == 1  # A→B once, deduplicated


def test_disjoint_orders_in_different_threads_are_clean():
    # t1 uses A→B, t2 uses C→D: no shared locks, no cycle, no violation
    a, b = TrackedLock("A"), TrackedLock("B")
    c, d = TrackedLock("C"), TrackedLock("D")

    def t1():
        with a, b:
            pass

    def t2():
        with c, d:
            pass

    with lockdep.capture() as det:
        threads = [racedep.spawn(t1, start=False),
                   racedep.spawn(t2, start=False)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(10.0)
    assert det.violations == []


def test_sequential_same_class_locks_never_alias():
    # N shard locks sharing a name, taken one at a time (the
    # ShardedDicomStore pattern): per-instance nodes, zero edges
    shards = [TrackedLock("Shard._lock") for _ in range(8)]
    with lockdep.capture() as det:
        for lk in shards:
            with lk:
                pass
    assert det.violations == []
    assert det.edges_recorded == 0


def test_reentrant_reacquisition_is_clean():
    lk = TrackedLock("R", reentrant=True)
    with lockdep.capture() as det:
        with lk:
            with lk:
                with lk:
                    pass
        assert det.held_locks() == []
    assert det.violations == []
    assert det.edges_recorded == 0  # re-entry records no self-edge


def test_condition_wait_is_clean():
    # Condition(TrackedLock): wait() fully releases (held-time stops) and
    # re-acquires (bookkeeping resumes); a slow consumer under a tiny
    # max_hold must not trip held-too-long while parked in wait()
    lk = TrackedLock("cond-lock", reentrant=True)
    cond = threading.Condition(lk)
    ready = []

    def producer():
        with cond:
            ready.append(True)
            cond.notify_all()

    with lockdep.capture(max_hold=0.5) as det:
        with cond:
            t = racedep.spawn(producer, start=False)
            t.start()
            while not ready:
                cond.wait(timeout=5.0)
            t.join(5.0)
        assert det.held_locks() == []
    assert det.violations == []


def test_check_callback_with_nothing_held_is_clean():
    with lockdep.capture() as det:
        lockdep.check_callback("free.endpoint")
    assert det.violations == []


def test_locked_probe():
    lk = TrackedLock("probe")
    rlk = TrackedLock("rprobe", reentrant=True)
    for target in (lk, rlk):
        assert not target.locked()
        with target:
            assert target.locked()
        assert not target.locked()


# ------------------------------------- fleet shed/nack path (satellite 3)
def _run_shed_heavy_trace(seed: int):
    """Burst arrivals into a tiny fleet with an aggressive shed threshold:
    most deliveries take the budget-exempt ``nack(consume_budget=False)``
    requeue path before eventually completing."""
    rng = random.Random(seed)
    sched = SimScheduler()
    pipe = ConversionPipeline(
        sched, service_time=30.0, cold_start=5.0, max_instances=2,
        min_backoff=5.0, max_backoff=40.0, ack_deadline=120.0,
        subscribers=False, fleet=dict(shed_backlog=2), ordered_ingest=True)
    n = rng.randint(6, 16)
    keys = [f"ok/s{i:03d}.psv" for i in range(n)]
    for i, key in enumerate(keys):
        # near-simultaneous burst → backlog spikes past shed_backlog
        sched.schedule(rng.uniform(0.0, 2.0), pipe.ingest, key,
                       bytes([i % 251]) * (i + 1), {"slide_id": key})
    sched.run()
    return pipe, keys


def _assert_shed_trace_clean(pipe, keys):
    det = lockdep.current()
    assert det is not None, "suite-wide lockdep must be armed"
    assert det.violations == [], det.report()
    # the scenario actually exercised the shed path, and still settled
    assert pipe.metrics.get("svc.wsi2dcm.shed") > 0
    assert pipe.subscription.stats()["acked"] == len(keys)
    assert pipe.subscription.stats()["outstanding"] == 0
    assert pipe.dead_lettered == []


def test_fleet_shed_nack_path_lockdep_clean_seeded_sweep():
    for seed in range(5):
        pipe, keys = _run_shed_heavy_trace(seed)
        _assert_shed_trace_clean(pipe, keys)


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=0, max_value=2**32 - 1))
def test_fleet_shed_nack_path_lockdep_clean_property(seed):
    pipe, keys = _run_shed_heavy_trace(seed)
    _assert_shed_trace_clean(pipe, keys)
