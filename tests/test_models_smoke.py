"""Per-arch smoke tests (reduced configs): forward/train step shapes + no NaNs,
prefill↔decode consistency, int8-KV accuracy."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, get_shape, list_archs
from repro.models import layers as lyr
from repro.models import model as M

ARCHS = list_archs()


def _batch(cfg, B=2, S=64, key=0):
    k = jax.random.PRNGKey(key)
    toks = jax.random.randint(k, (B, S), 0, cfg.vocab_size)
    b = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    if cfg.family in ("vlm", "audio"):
        b["cond"] = jax.random.normal(
            k, (B, cfg.n_cross_tokens, cfg.d_model), cfg.dtype)
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    x, aux, _ = M.forward(params, cfg, batch["tokens"],
                          cond=batch.get("cond"), mode="train")
    assert x.shape == (2, 64, cfg.d_model)
    assert not bool(jnp.isnan(x.astype(jnp.float32)).any())
    loss, grads = jax.value_and_grad(
        lambda p: M.lm_loss(p, cfg, batch))(params)
    assert np.isfinite(float(loss))
    gsum = jax.tree_util.tree_reduce(
        lambda a, g: a + float(jnp.abs(g).sum()), grads, 0.0)
    assert np.isfinite(gsum) and gsum > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_full_forward(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    b = _batch(cfg, B=2, S=33, key=2)
    toks, cond = b["tokens"], b.get("cond")
    x, _, _ = M.forward(params, cfg, toks, cond=cond, mode="train")
    ref = lyr.logits_apply(params["embed"], cfg, x[:, -1:])[:, 0]
    _, cache = M.prefill(params, cfg, toks[:, :32], cond=cond, max_len=64)
    got, _ = M.decode_step(params, cfg, cache, toks[:, 32:33],
                           jnp.full((2,), 32, jnp.int32))
    tol = 0.1 if cfg.num_experts else 5e-2  # MoE capacity drops differ
    assert float(jnp.abs(ref - got).max()) < tol


@pytest.mark.parametrize("arch", ["gemma-2b", "mixtral-8x7b", "musicgen-large",
                                  "llama-3.2-vision-11b"])
def test_int8_kv_cache_close_to_bf16(arch):
    cfg = dataclasses.replace(get_config(arch).reduced(),
                              kv_cache_dtype="int8")
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    b = _batch(cfg, B=2, S=17, key=3)
    toks, cond = b["tokens"], b.get("cond")
    x, _, _ = M.forward(params, cfg, toks, cond=cond, mode="train")
    ref = lyr.logits_apply(params["embed"], cfg, x[:, -1:])[:, 0]
    _, cache = M.prefill(params, cfg, toks[:, :16], cond=cond, max_len=32)
    assert cache["k"].dtype == jnp.int8
    got, _ = M.decode_step(params, cfg, cache, toks[:, 16:17],
                           jnp.full((2,), 16, jnp.int32))
    assert float(jnp.abs(ref - got).max()) < 0.25


@pytest.mark.parametrize("arch", ARCHS)
def test_multi_token_greedy_decode_consistency(arch):
    """Greedy decode token-by-token == argmax of the full forward pass."""
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(4))
    b = _batch(cfg, B=1, S=16, key=5)
    toks, cond = b["tokens"], b.get("cond")
    logits, cache = M.prefill(params, cfg, toks[:, :8], cond=cond, max_len=32)
    seq = list(np.asarray(toks)[0, :8])
    cur = int(np.argmax(np.asarray(logits)[0]))
    for step in range(3):
        seq.append(cur)
        full = jnp.asarray(np.asarray(seq)[None], jnp.int32)
        x, _, _ = M.forward(params, cfg, full, cond=cond, mode="train")
        want = int(jnp.argmax(
            lyr.logits_apply(params["embed"], cfg, x[:, -1:])[:, 0, :], -1)[0])
        got_logits, cache = M.decode_step(
            params, cfg, cache, jnp.asarray([[cur]], jnp.int32),
            jnp.asarray([len(seq) - 1], jnp.int32))
        got = int(jnp.argmax(got_logits[0]))
        if cfg.num_experts:  # capacity dispatch may flip rare near-ties
            continue
        assert got == want, f"step {step}: {got} != {want}"
        cur = got


def test_shape_grid_and_skips():
    """Every (arch × shape) cell is either supported or an explicit skip."""
    n_cells = 0
    n_skips = 0
    for arch in ARCHS:
        cfg = get_config(arch)
        for s in SHAPES.values():
            n_cells += 1
            if not cfg.supports_shape(s):
                n_skips += 1
                assert s.name == "long_500k" and not cfg.sub_quadratic
    assert n_cells == 40
    assert n_skips == 6  # the six pure full-attention archs


def test_param_counts_are_plausible():
    expect = {
        "gemma-2b": (2.0e9, 3.5e9),  # incl. 256k×2048 embeddings
        "minitron-8b": (7e9, 10e9),
        "phi4-mini-3.8b": (3.3e9, 4.6e9),
        "command-r-plus-104b": (95e9, 115e9),
        "mixtral-8x7b": (44e9, 49e9),
        "mixtral-8x22b": (135e9, 145e9),
        "rwkv6-3b": (2.6e9, 3.6e9),
        "zamba2-1.2b": (1.0e9, 1.6e9),
        "musicgen-large": (2.8e9, 3.6e9),
        "llama-3.2-vision-11b": (8.5e9, 11.5e9),  # text side + cross blocks
    }
    for arch, (lo, hi) in expect.items():
        n = M.param_count(get_config(arch))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9}, {hi/1e9}]"
