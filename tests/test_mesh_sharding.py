"""Mesh-sharded level batches + padded kernel dispatch.

Three layers:

- mesh context (``ops.default_mesh`` / ``use_mesh`` / ``data_sharding``)
  and the pow2 bucket padding of the batched kernel wrappers (byte-exact
  vs unpadded, N=0 passthrough);
- the explicit padded-alignment path: ``impl="pallas"`` on ragged
  (non-lane-aligned) shapes runs the kernel through pad-to-aligned +
  slice and must match the oracle exactly;
- multi-device subprocesses (``--xla_force_host_platform_device_count=4``,
  the pattern from test_sharding_roofline.py): sharded kernel dispatch is
  bit-exact vs a single-device mesh, and the full convert→store→export
  circle emits byte-identical artifacts under a 4-device data mesh —
  asserted both inside the subprocess (4-dev vs 1-dev mesh) and across
  processes (vs this interpreter's single-device run).
"""
import hashlib
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels import ops, ref
from repro.kernels.ops import (data_sharding, default_mesh, jpeg_inverse,
                               jpeg_transform, use_mesh)

SRC = str(Path(__file__).resolve().parents[1] / "src")
RNG = np.random.default_rng(7)

UIDS = json.dumps(["1.2.826.0.1.3680043.2.1", "1.2.826.0.1.3680043.2.2"])


# --------------------------------------------------------------------------
# mesh context + bucket padding (single device, in-process)
# --------------------------------------------------------------------------
def test_default_mesh_has_data_axis():
    mesh = default_mesh()
    assert mesh.axis_names == ("data",)
    assert mesh.devices.size == len(jax.devices())


def test_use_mesh_scopes_and_restores():
    outer = default_mesh()
    other = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    with use_mesh(other) as m:
        assert m is other
        assert default_mesh() is other
    assert default_mesh() is outer


def test_data_sharding_replicates_when_indivisible():
    mesh = default_mesh()
    ndev = mesh.devices.size
    # single device, zero batch, or a batch the mesh can't split evenly
    assert data_sharding(0).spec == P()
    if ndev == 1:
        assert data_sharding(8).spec == P()
    else:
        assert data_sharding(ndev).spec == P("data")
        assert data_sharding(ndev + 1).spec == P()


@pytest.mark.parametrize("n", [1, 3, 5, 7])
def test_bucket_padding_is_byte_exact(n):
    """Odd batch sizes ride a pow2 bucket; pad tiles must not leak."""
    tiles = jnp.asarray(RNG.integers(0, 256, size=(n, 3, 16, 128)),
                        jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(jpeg_transform(tiles)),
        np.asarray(ref.jpeg_transform_ref(
            tiles, jnp.asarray(ref.JPEG_LUMA_Q),
            jnp.asarray(ref.JPEG_CHROMA_Q))))
    coef = jpeg_transform(tiles)
    np.testing.assert_array_equal(
        np.asarray(jpeg_inverse(coef)),
        np.asarray(ref.jpeg_inverse_ref(coef)))


def test_zero_batch_passthrough():
    empty = jnp.zeros((0, 3, 256, 256), jnp.float32)
    assert jpeg_transform(empty).shape == (0, 3, 256, 256)
    assert jpeg_inverse(jnp.zeros((0, 3, 256, 256), jnp.int32)).shape \
        == (0, 3, 256, 256)


def test_bucket_reuses_jit_cache():
    """5 and 7 tiles both pad to the 8 bucket — no second trace."""
    x8 = jnp.asarray(RNG.integers(0, 256, size=(8, 3, 16, 128)), jnp.float32)
    jpeg_transform(x8)  # warm the 8 bucket
    before = ops._jpeg_transform_core._cache_size()
    jpeg_transform(x8[:5])
    jpeg_transform(x8[:7])
    assert ops._jpeg_transform_core._cache_size() == before


# --------------------------------------------------------------------------
# explicit padded-alignment path: pallas ≡ ref on ragged shapes
# --------------------------------------------------------------------------
def test_rgb2ycbcr_padded_pallas_matches_ref():
    img = jnp.asarray(RNG.integers(0, 256, size=(3, 20, 100)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(ops.rgb2ycbcr(img, impl="pallas")),
        np.asarray(ref.rgb2ycbcr_ref(img)), atol=1e-3, rtol=1e-5)


def test_downsample_padded_pallas_matches_ref():
    img = jnp.asarray(RNG.normal(0, 50, size=(3, 20, 100)), jnp.float32)
    out = ops.downsample2x2(img, impl="pallas")
    assert out.shape == (3, 10, 50)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.downsample2x2_ref(img)),
        atol=1e-4, rtol=1e-5)


def test_dct_quant_padded_pallas_matches_ref():
    q = jnp.asarray(ref.JPEG_LUMA_Q)
    plane = jnp.asarray(RNG.normal(0, 40, size=(24, 72)), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(ops.dct8x8_quant(plane, q, impl="pallas")),
        np.asarray(ref.dct8x8_quant_ref(plane, q)))


def test_jpeg_transform_padded_pallas_matches_ref():
    tiles = jnp.asarray(RNG.integers(0, 256, size=(2, 3, 24, 72)),
                        jnp.float32)
    ql = jnp.asarray(ref.JPEG_LUMA_Q)
    qc = jnp.asarray(ref.JPEG_CHROMA_Q)
    np.testing.assert_array_equal(
        np.asarray(jpeg_transform(tiles, impl="pallas")),
        np.asarray(ref.jpeg_transform_ref(tiles, ql, qc)))


def test_jpeg_inverse_padded_pallas_matches_ref():
    coef = jnp.asarray(RNG.integers(-64, 64, size=(2, 3, 24, 72)),
                       jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(jpeg_inverse(coef, impl="pallas")),
        np.asarray(ref.jpeg_inverse_ref(coef)))


# --------------------------------------------------------------------------
# multi-device subprocesses
# --------------------------------------------------------------------------
def _run(prog: str) -> subprocess.CompletedProcess:
    return subprocess.run([sys.executable, "-c", prog],
                          capture_output=True, text=True, timeout=600)


def test_sharded_kernels_bit_exact_multidevice_subprocess():
    """4-way data-sharded jpeg_transform/jpeg_inverse ≡ 1-device mesh."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np, sys
        sys.path.insert(0, %r)
        from repro.kernels.ops import (default_mesh, jpeg_inverse,
                                       jpeg_transform, use_mesh)
        assert default_mesh().devices.size == 4
        rng = np.random.default_rng(0)
        tiles = jnp.asarray(rng.integers(0, 256, size=(8, 3, 16, 128)),
                            jnp.float32)
        coef4 = jpeg_transform(tiles)          # 4-way data mesh
        rgb4 = jpeg_inverse(coef4)
        mesh1 = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
        with use_mesh(mesh1):                  # single-device mesh
            coef1 = jpeg_transform(tiles)
            rgb1 = jpeg_inverse(coef1)
        assert (np.asarray(coef4) == np.asarray(coef1)).all()
        assert (np.asarray(rgb4) == np.asarray(rgb1)).all()
        # odd batch: replicated (5 %% 4 != 0) but still exact
        coef_odd = jpeg_transform(tiles[:5])
        assert (np.asarray(coef_odd) == np.asarray(coef1)[:5]).all()
        print("SHARDED-KERNELS-OK")
    """) % SRC
    out = _run(prog)
    assert "SHARDED-KERNELS-OK" in out.stdout, out.stderr[-2000:]


def _single_device_circle() -> tuple[str, str]:
    """This interpreter's (1 CPU device) study tar + export digests."""
    from repro.core import SimScheduler
    from repro.core.storage import ObjectStore
    from repro.wsi.convert import ConvertOptions, convert_wsi_to_dicom
    from repro.wsi.export import ExportService
    from repro.wsi.slide import SyntheticScanner
    from repro.wsi.store_service import DicomStoreService

    psv = SyntheticScanner(seed=11).scan(512, 512, 256)
    tar = convert_wsi_to_dicom(psv, {"slide_id": "mesh"},
                               options=ConvertOptions(
                                   manifest={"uids": UIDS}))
    sched = SimScheduler()
    store = ObjectStore(sched)
    svc = DicomStoreService(store.bucket("dicom"), sched)
    svc.store_study_archive("studies/mesh.tar", tar)
    (study,) = svc.search_studies()
    exporter = ExportService(svc, store.bucket("derived"))
    keys = exporter.export_study(study)
    tifs = b"".join(exporter.derived.get(k).data for k in sorted(keys))
    return (hashlib.sha256(tar).hexdigest(),
            hashlib.sha256(tifs).hexdigest())


def test_convert_store_export_circle_multidevice_subprocess():
    """The full circle under a 4-device data mesh emits byte-identical
    artifacts — compared against a 1-device mesh in the same subprocess
    AND against this interpreter's single-device run."""
    tar_sha, tif_sha = _single_device_circle()
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import hashlib, json, sys
        import numpy as np
        sys.path.insert(0, %r)
        import jax
        from repro.core import SimScheduler
        from repro.core.storage import ObjectStore
        from repro.wsi.convert import ConvertOptions, convert_wsi_to_dicom
        from repro.wsi.export import ExportService
        from repro.wsi.slide import SyntheticScanner
        from repro.wsi.store_service import DicomStoreService
        from repro.kernels.ops import default_mesh

        UIDS = %r
        assert default_mesh().devices.size == 4
        psv = SyntheticScanner(seed=11).scan(512, 512, 256)

        def circle(mesh):
            tar = convert_wsi_to_dicom(
                psv, {"slide_id": "mesh"},
                options=ConvertOptions(manifest={"uids": UIDS}, mesh=mesh))
            sched = SimScheduler()
            store = ObjectStore(sched)
            svc = DicomStoreService(store.bucket("dicom"), sched)
            svc.store_study_archive("studies/mesh.tar", tar)
            (study,) = svc.search_studies()
            exporter = ExportService(svc, store.bucket("derived"),
                                     mesh=mesh)
            keys = exporter.export_study(study)
            tifs = b"".join(exporter.derived.get(k).data
                            for k in sorted(keys))
            return (hashlib.sha256(tar).hexdigest(),
                    hashlib.sha256(tifs).hexdigest())

        four = circle(None)   # ambient mesh: all 4 devices
        mesh1 = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
        one = circle(mesh1)
        assert four == one, (four, one)
        print("CIRCLE-SHA", four[0], four[1])
    """) % (SRC, UIDS)
    out = _run(prog)
    line = next((ln for ln in out.stdout.splitlines()
                 if ln.startswith("CIRCLE-SHA")), None)
    assert line is not None, out.stderr[-2000:]
    _, got_tar, got_tif = line.split()
    assert got_tar == tar_sha, "4-device study tar diverges from 1-device"
    assert got_tif == tif_sha, "4-device export TIFFs diverge from 1-device"
