"""Elastic trainer fleet + DICOM store service (Figure 1's last arrow)."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import SimScheduler, Subscription
from repro.data import TokenDataset
from repro.train import TrainConfig, init_train_state
from repro.train.elastic import ElasticTrainer
from repro.wsi import SyntheticScanner, convert_wsi_to_dicom
from repro.wsi.store_service import DicomStoreService


@pytest.fixture(scope="module")
def small():
    cfg = get_config("gemma-2b").reduced()
    tc = TrainConfig(lr=1e-3, warmup_steps=2, total_steps=50)
    return cfg, tc


def _trainer(cfg, tc, sched, n_workers=2):
    state = init_train_state(cfg, tc, jax.random.PRNGKey(0))
    ds = TokenDataset(cfg.vocab_size, 32, seed=0)
    t = ElasticTrainer(sched, cfg, tc, state,
                       lambda shard: ds.shard_batch(shard, 4))
    for i in range(n_workers):
        t.add_worker(f"w{i}")
    return t


def test_elastic_epoch_applies_every_shard_once(small):
    cfg, tc = small
    sched = SimScheduler()
    t = _trainer(cfg, tc, sched, n_workers=3)
    done = t.run_epoch(n_shards=12)
    assert done == list(range(12))
    assert len(t.losses) == 12  # effectively-once: no duplicate updates


def test_elastic_survives_worker_death(small):
    cfg, tc = small
    sched = SimScheduler()
    t = _trainer(cfg, tc, sched, n_workers=2)
    # kill one worker mid-epoch; its in-flight shard must redeliver
    sched.schedule(15.0, lambda: t.kill_worker("w0"))
    done = t.run_epoch(n_shards=10)
    assert done == list(range(10))
    assert len(t.losses) == 10


def test_elastic_scale_up_mid_epoch(small):
    cfg, tc = small
    sched = SimScheduler()
    t = _trainer(cfg, tc, sched, n_workers=1)
    sched.schedule(25.0, lambda: t.add_worker("late", speed=2.0))
    done = t.run_epoch(n_shards=8)
    assert done == list(range(8))


def test_elastic_loss_decreases(small):
    cfg, tc = small
    sched = SimScheduler()
    t = _trainer(cfg, tc, sched, n_workers=4)
    for epoch in range(3):
        t.run_epoch(n_shards=8, epoch=epoch)
    assert np.mean(t.losses[-6:]) < np.mean(t.losses[:6]) - 0.2


# --------------------------------------------------------------------------
# DICOM store service
# --------------------------------------------------------------------------
def test_store_stow_qido_wado_roundtrip():
    sched = SimScheduler()
    from repro.core.storage import ObjectStore

    store = ObjectStore(sched)
    svc = DicomStoreService(store.bucket("dicom"), sched)
    notified = []
    Subscription(svc.topic, "ml-consumer",
                 lambda m, c: (notified.append(m.data), c.ack()))

    psv = SyntheticScanner(seed=3).scan(512, 512, 256)
    archive = convert_wsi_to_dicom(psv, metadata={"slide_id": "X"})
    sops = svc.store_study_archive("studies/x", archive)
    sched.run()

    assert len(sops) == 2  # two pyramid levels
    studies = svc.search_studies(patient_id="ANON")
    assert len(studies) == 1
    instances = svc.search_instances(studies[0])
    assert {i["total_rows"] for i in instances} == {512, 256}
    # WADO retrieve + frame access
    blob = svc.retrieve(sops[0])
    assert blob[128:132] == b"DICM"
    frame = svc.retrieve_frame(sops[0], 0)
    assert len(frame) > 100
    # downstream consumer got one event per instance (extensibility claim)
    assert len(notified) == 2
    assert all(n["modality"] == "SM" for n in notified)
