"""End-to-end: scanner → landing bucket → event → autoscaled conversion →
DICOM store; plus crash/resume and effectively-once under redelivery."""
import numpy as np
import pytest

from repro.core import ConversionPipeline, RealScheduler, SimScheduler
from repro.wsi import (ConvertOptions, PSVReader, SyntheticScanner,
                       convert_wsi_to_dicom, read_part10, study_levels)


def test_simulated_batch_conversion_completes():
    sched = SimScheduler()
    pipe = ConversionPipeline(sched, service_time=60.0, cold_start=10.0,
                              max_instances=25)
    for i in range(25):
        pipe.ingest(f"slides/s{i}.psv", b"x" * (i + 1))
    sched.run()
    assert pipe.done_count() == 25
    assert pipe.service.instance_count() == 0  # back to zero


def test_real_mode_end_to_end_conversion():
    """RealScheduler + the actual JAX converter on small synthetic slides."""
    sched = RealScheduler(workers=4)
    pipe = ConversionPipeline(
        sched,
        convert=lambda data, meta: convert_wsi_to_dicom(data, meta),
        max_instances=2, cold_start=0.0, scale_down_delay=2.0,
    )
    scanner = SyntheticScanner(seed=5)
    for i in range(2):
        pipe.ingest(f"slides/s{i}.psv", scanner.scan(256, 256, 256),
                    {"slide_id": f"S{i}"})
    sched.run(until=240.0)
    assert pipe.done_count() == 2
    keys = pipe.dicom.list()
    assert sorted(keys) == ["slides/s0.dcm", "slides/s1.dcm"]
    study = study_levels(pipe.dicom.get("slides/s0.dcm").data)
    ds, frames = read_part10(study["level_0.dcm"])
    assert ds.get_int(0x0028, 0x0008) == 1  # 256² slide = 1 tile frame
    sched.shutdown()


def test_crash_resume_skips_finished_levels():
    psv = SyntheticScanner(seed=2).scan(512, 512, 256)
    opt = ConvertOptions()
    convert_wsi_to_dicom(psv, options=opt)  # "crashed after" full run
    done_levels = dict(opt.manifest)
    opt2 = ConvertOptions(manifest=done_levels)
    out2 = convert_wsi_to_dicom(psv, options=opt2)
    # resumed conversion reuses every finished level byte-for-byte
    lv = study_levels(out2)
    for k, blob in lv.items():
        if k.endswith(".dcm"):
            idx = k.split("_")[1].split(".")[0]
            assert blob == done_levels[idx]


def test_redelivered_conversion_is_effectively_once():
    """Kill the worker mid-conversion → redelivery converts exactly once."""
    sched = SimScheduler()
    pipe = ConversionPipeline(sched, service_time=100.0, cold_start=0.0,
                              ack_deadline=150.0, max_instances=4)
    pipe.ingest("slides/a.psv", b"payload")
    sched.run(until=50.0)  # conversion in flight
    pipe.service.kill_instance()
    sched.run()
    # redelivery happened and the slide was eventually converted exactly once
    assert pipe.done_count() == 1
    assert pipe.metrics.counters["sub.wsi2dcm-push.deadline_expired"] >= 1
