"""End-to-end: scanner → landing bucket → event → autoscaled conversion →
DICOM store → validation/ML subscribers; plus crash/resume, effectively-once
under redelivery, and collision-safe output keys."""

import numpy as np
import pytest

from repro.core import ConversionPipeline, RealScheduler, SimScheduler
from repro.core import clock
from repro.core.pipeline import derive_out_key
from repro.core.clock import wall_sleep
from repro.wsi import (ConvertOptions, PSVReader, SyntheticScanner,
                       convert_wsi_to_dicom, read_part10, study_levels)


def test_simulated_batch_conversion_completes():
    sched = SimScheduler()
    pipe = ConversionPipeline(sched, service_time=60.0, cold_start=10.0,
                              max_instances=25)
    for i in range(25):
        pipe.ingest(f"slides/s{i}.psv", b"x" * (i + 1))
    sched.run()
    assert pipe.done_count() == 25
    assert pipe.service.instance_count() == 0  # back to zero


def test_real_mode_end_to_end_conversion():
    """RealScheduler + the actual JAX converter on small synthetic slides."""
    sched = RealScheduler(workers=4)
    pipe = ConversionPipeline(
        sched,
        convert=lambda data, meta: convert_wsi_to_dicom(data, meta),
        max_instances=2, cold_start=0.0, scale_down_delay=2.0,
    )
    scanner = SyntheticScanner(seed=5)
    for i in range(2):
        pipe.ingest(f"slides/s{i}.psv", scanner.scan(256, 256, 256),
                    {"slide_id": f"S{i}"})
    sched.run(until=240.0)
    assert pipe.done_count() == 2
    keys = pipe.dicom.list()
    assert sorted(keys) == ["slides/s0.dcm", "slides/s1.dcm"]
    study = study_levels(pipe.dicom.get("slides/s0.dcm").data)
    ds, frames = read_part10(study["level_0.dcm"])
    assert ds.get_int(0x0028, 0x0008) == 1  # 256² slide = 1 tile frame
    sched.shutdown()


def test_crash_resume_skips_finished_levels():
    psv = SyntheticScanner(seed=2).scan(512, 512, 256)
    opt = ConvertOptions()
    convert_wsi_to_dicom(psv, options=opt)  # "crashed after" full run
    done_levels = dict(opt.manifest)
    opt2 = ConvertOptions(manifest=done_levels)
    out2 = convert_wsi_to_dicom(psv, options=opt2)
    # resumed conversion reuses every finished level byte-for-byte
    lv = study_levels(out2)
    for k, blob in lv.items():
        if k.endswith(".dcm"):
            idx = k.split("_")[1].split(".")[0]
            assert blob == done_levels[idx]


def test_derive_out_key_strips_only_trailing_basename_extension():
    # the seed used key.rsplit(".", 1), which mangled dotted directory
    # components and collapsed dotfiles
    assert derive_out_key("slides/a.svs") == "slides/a.dcm"
    assert derive_out_key("a.tiff") == "a.dcm"
    assert derive_out_key("scans.v1/slide") == "scans.v1/slide.dcm"
    assert derive_out_key("scans.v1/slide.svs") == "scans.v1/slide.dcm"
    assert derive_out_key("slide") == "slide.dcm"
    assert derive_out_key(".hidden") == ".hidden.dcm"
    assert derive_out_key("a/b.c/x.y.svs") == "a/b.c/x.y.dcm"


def test_colliding_sources_get_distinct_out_keys_and_reach_the_store():
    """a.svs and a.tiff no longer overwrite each other's study, a dotted
    directory survives, and every study flows on into the DICOM store
    subsystem with both subscribers running (the Figure-1 final arrow)."""
    sched = RealScheduler(workers=4)
    pipe = ConversionPipeline(
        sched, convert=lambda data, meta: convert_wsi_to_dicom(data, meta),
        max_instances=2, cold_start=0.0, scale_down_delay=2.0,
    )
    scanner = SyntheticScanner(seed=13)
    slides = {"slides/a.svs": scanner.scan(256, 256, 256),
              "slides/a.tiff": scanner.scan(512, 256, 256),
              "scans.v1/slide": scanner.scan(256, 256, 256)}
    # colliding keys arrive as separate uploads (run_batch would refuse the
    # pair up front), so ingest directly and wait for the conversions
    for key, data in slides.items():
        pipe.ingest(key, data, {"slide_id": key})
    deadline = clock.monotonic() + 240.0
    while clock.monotonic() < deadline:
        with pipe._converted_lock:
            done = dict(pipe._conversions)
        if len(done) == 3:
            break
        wall_sleep(0.01)
    outs = {k: pipe.dicom.get(v).data for k, v in done.items()}

    keys = pipe.dicom.list()
    assert "slides/a.dcm" in keys and "scans.v1/slide.dcm" in keys
    assert len(keys) == 3  # the second "a" got a suffixed key, not a merge
    # locked read: pool threads may still be inc'ing completion metrics
    assert pipe.metrics.get("pipeline.out_key_collisions") == 1
    # each source's study survives as its own conversion (distinct UIDs)
    assert study_levels(outs["slides/a.tiff"])["study.json"] \
        != study_levels(outs["slides/a.svs"])["study.json"]

    # the store subsystem ingested every study and fanned out to subscribers
    deadline = clock.monotonic() + 60.0
    while len(pipe.store_service.search_studies()) < 3 \
            and clock.monotonic() < deadline:
        wall_sleep(0.01)
    studies = pipe.store_service.search_studies()
    assert len(studies) == 3
    deadline = clock.monotonic() + 60.0
    while (len(pipe.validator.checked) < 3
           or len(pipe.ml_subscriber.predictions) < 3) \
            and clock.monotonic() < deadline:
        wall_sleep(0.01)
    assert len(pipe.validator.checked) == 3
    assert pipe.validator.quarantined == []
    assert len(pipe.ml_subscriber.predictions) == 3
    sched.shutdown()


def test_redelivered_source_reuses_its_out_key():
    """A redelivered/re-uploaded source maps back to its own key — the
    collision suffix never applies to the same landing key."""
    sched = RealScheduler(workers=4)
    pipe = ConversionPipeline(
        sched, convert=lambda data, meta: convert_wsi_to_dicom(data, meta),
        max_instances=2, cold_start=0.0, scale_down_delay=2.0,
    )
    psv = SyntheticScanner(seed=17).scan(256, 256, 256)
    pipe.run_batch({"slides/r.svs": psv}, timeout=240.0)
    # same key, new content (re-scan): replaces, no suffixed sibling
    psv2 = SyntheticScanner(seed=18).scan(256, 256, 256)
    pipe.run_batch({"slides/r.svs": psv2}, timeout=240.0)
    assert pipe.dicom.list() == ["slides/r.dcm"]
    # locked read: pool threads may still be inc'ing completion metrics
    assert pipe.metrics.get("pipeline.out_key_collisions", 0) == 0
    sched.shutdown()


def test_run_batch_fails_fast_on_poison_slide():
    """A slide that permanently fails conversion used to spin run_batch's
    full timeout in a 2 ms busy-poll; now the DLQ listener raises with the
    dlq_reason as soon as the retry budget is exhausted."""
    sched = RealScheduler(workers=4)

    def convert(data, meta):
        if "bad" in meta["slide_id"]:
            raise ValueError("unreadable slide: vendor firmware glitch")
        return convert_wsi_to_dicom(data, meta)

    pipe = ConversionPipeline(
        sched, convert=convert, max_instances=2, cold_start=0.0,
        scale_down_delay=2.0, max_delivery_attempts=2,
        min_backoff=0.05, max_backoff=0.05, subscribers=False,
    )
    scanner = SyntheticScanner(seed=3)
    slides = {"slides/ok.psv": scanner.scan(256, 256, 256),
              "slides/bad.psv": scanner.scan(256, 256, 256)}
    t0 = clock.monotonic()
    with pytest.raises(RuntimeError,
                       match="slides/bad.psv.*unreadable slide"):
        pipe.run_batch(slides, timeout=240.0)
    assert clock.monotonic() - t0 < 60.0  # failed fast, not at the timeout
    # the failure carries the converter's actual error, and the DLQ sink
    # recorded the poisoned event
    assert any("vendor firmware glitch" in reason
               for _, reason in pipe.dead_lettered)
    sched.shutdown()


def test_run_batch_raises_on_duplicate_out_keys():
    sched = RealScheduler(workers=2)
    pipe = ConversionPipeline(
        sched, convert=lambda data, meta: b"", max_instances=1,
        cold_start=0.0, scale_down_delay=2.0,
    )
    with pytest.raises(ValueError, match="collide.*a.dcm"):
        pipe.run_batch({"a.svs": b"x", "a.tiff": b"y"})
    assert pipe.landing.list() == []  # rejected before any ingest
    sched.shutdown()


def test_redelivered_conversion_is_effectively_once():
    """Kill the worker mid-conversion → redelivery converts exactly once."""
    sched = SimScheduler()
    pipe = ConversionPipeline(sched, service_time=100.0, cold_start=0.0,
                              ack_deadline=150.0, max_instances=4)
    pipe.ingest("slides/a.psv", b"payload")
    sched.run(until=50.0)  # conversion in flight
    pipe.service.kill_instance()
    sched.run()
    # redelivery happened and the slide was eventually converted exactly once
    assert pipe.done_count() == 1
    assert pipe.metrics.get("sub.wsi2dcm-push.deadline_expired") >= 1
