"""Cloud-Run-style autoscaler behaviour: cold starts, 0→N→0, fault injection."""
from repro.core import AutoscalingService, Metrics, SimScheduler


def make(n_requests=10, service_time=30.0, **kw):
    sched = SimScheduler()
    svc = AutoscalingService("conv", sched, lambda req: service_time, **kw)
    done = []
    for i in range(n_requests):
        svc.receive({"i": i}, lambda ok, i=i: done.append((i, ok)))
    return sched, svc, done


def test_scale_up_to_demand_and_back_to_zero():
    sched, svc, done = make(n_requests=20, service_time=60.0,
                            max_instances=10, cold_start=10.0,
                            scale_down_delay=30.0)
    sched.run(until=50.0)
    assert svc.instance_count() == 10  # burst scaled to the cap
    sched.run()
    assert len(done) == 20 and all(ok for _, ok in done)
    assert svc.instance_count() == 0  # scaled back to zero
    assert svc.cold_starts == 10


def test_cold_start_delays_first_completion():
    sched, svc, done = make(n_requests=1, service_time=60.0, cold_start=25.0)
    sched.run(until=84.0)
    assert not done  # 25 cold + 60 service > 84
    sched.run(until=86.0)
    assert len(done) == 1


def test_min_instances_serve_warm():
    sched = SimScheduler()
    svc = AutoscalingService("conv", sched, lambda r: 60.0,
                             min_instances=2, cold_start=25.0,
                             scale_down_delay=30.0)
    done = []
    svc.receive({"i": 0}, lambda ok: done.append(ok))
    sched.run(until=61.0)
    assert done  # no cold start paid
    assert svc.cold_starts == 0
    sched.run(until=500.0)
    assert svc.instance_count() == 2  # floor respected


def test_concurrency_packs_requests():
    sched = SimScheduler()
    svc = AutoscalingService("conv", sched, lambda r: 50.0,
                             concurrency=4, max_instances=2, cold_start=0.0)
    done = []
    for i in range(8):
        svc.receive({"i": i}, lambda ok: done.append(ok))
    sched.run(until=10.0)
    assert svc.instance_count() <= 2
    sched.run()
    assert len(done) == 8


def test_killed_instance_loses_work_but_counts_no_completion():
    sched = SimScheduler()
    svc = AutoscalingService("conv", sched, lambda r: 100.0, cold_start=0.0)
    done = []
    svc.receive({"i": 0}, lambda ok: done.append(ok))
    sched.run(until=10.0)
    killed = svc.kill_instance()
    assert killed is not None
    sched.run()
    assert not done  # the in-flight request produced no completion (no ack)


def test_instance_timeseries_ramps_and_decays():
    sched, svc, done = make(n_requests=50, service_time=90.0,
                            max_instances=100, cold_start=10.0,
                            scale_down_delay=60.0)
    sched.run()
    series = svc.metrics.timeseries("svc.conv.instances")
    counts = [v for _, v in series]
    assert max(counts) == 50  # Figure 3's plateau
    assert counts[-1] == 0  # and decay to zero
