"""Pub/sub delivery semantics — the fault-tolerance invariants, property-based."""
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import Metrics, SimScheduler, Subscription, Topic


def make(endpoint, **kw):
    sched = SimScheduler()
    topic = Topic("t", sched)
    dlq = Topic("dlq", sched)
    dead = []
    Subscription(dlq, "dlq-sink", lambda m, c: (dead.append(m.data), c.ack()))
    sub = Subscription(topic, "s", endpoint, dlq=dlq, **kw)
    return sched, topic, sub, dead


def test_happy_path_ack():
    got = []
    sched, topic, sub, _ = make(lambda m, c: (got.append(m.data["i"]), c.ack()))
    for i in range(5):
        topic.publish({"i": i})
    sched.run()
    assert sorted(got) == list(range(5))
    assert sub.stats()["acked"] == 5


def test_nack_redelivers_with_backoff():
    attempts = []

    def ep(m, c):
        attempts.append(sched.now())
        if len(attempts) < 3:
            c.nack("boom")
        else:
            c.ack()

    sched, topic, sub, dead = make(ep, min_backoff=10.0)
    topic.publish({"i": 0})
    sched.run()
    assert len(attempts) == 3
    # exponential backoff: gaps ~10 then ~20
    assert attempts[1] - attempts[0] >= 10.0
    assert attempts[2] - attempts[1] >= 20.0
    assert not dead


def test_max_attempts_dead_letters():
    sched, topic, sub, dead = make(lambda m, c: c.nack("always"),
                                   max_delivery_attempts=3, min_backoff=1.0)
    topic.publish({"i": 7})
    sched.run()
    assert len(dead) == 1 and dead[0]["i"] == 7
    assert sub.stats()["acked"] == 0


def test_ack_deadline_expiry_redelivers():
    """An endpoint that never responds (crashed worker) → redelivery."""
    calls = []

    def ep(m, c):
        calls.append(sched.now())
        if len(calls) == 1:
            return  # first delivery: worker dies, never acks
        c.ack()

    sched, topic, sub, _ = make(ep, ack_deadline=60.0, min_backoff=5.0)
    topic.publish({"i": 0})
    sched.run()
    assert len(calls) == 2
    assert calls[1] >= 60.0  # waited out the deadline
    assert sub.stats()["acked"] == 1


def test_ordering_key_serializes_delivery():
    order = []

    def ep(m, c):
        order.append(m.data["i"])
        # finish after a delay; next keyed message must wait for the ack
        sched.schedule(5.0, c.ack)

    sched, topic, sub, _ = make(ep)
    for i in range(4):
        topic.publish({"i": i}, ordering_key="slide-1")
    sched.run()
    assert order == [0, 1, 2, 3]


def test_flow_control_limits_outstanding():
    inflight = []
    peak = [0]

    def ep(m, c):
        inflight.append(c)
        peak[0] = max(peak[0], len(inflight))
        sched.schedule(10.0, lambda: (inflight.remove(c), c.ack()))

    sched, topic, sub, _ = make(ep, max_outstanding=3)
    for i in range(10):
        topic.publish({"i": i})
    sched.run()
    assert peak[0] <= 3
    assert sub.stats()["acked"] == 10


def test_ordered_nack_redelivers_before_later_keyed_messages():
    """Regression: a nacked ordered message used to re-enqueue into its own
    busy key's backlog and never redeliver. The retry must come back — and
    come back *before* later messages with the same key."""
    got = []

    def ep(m, c):
        got.append(m.data["i"])
        if m.data["i"] == 0 and got.count(0) == 1:
            c.nack("boom")
        else:
            c.ack()

    sched, topic, sub, dead = make(ep, min_backoff=5.0)
    for i in range(3):
        topic.publish({"i": i}, ordering_key="slide-1")
    sched.run()
    assert got == [0, 0, 1, 2]  # retried first; key order preserved
    assert sub.stats()["acked"] == 3
    assert sub.stats()["ordered_backlog"] == 0
    assert not dead


def test_ordered_deadline_expiry_redelivers_and_key_drains():
    """Regression: a deadline-expired ordered delivery wedged its key the
    same way a nack did."""
    calls = []

    def ep(m, c):
        calls.append(m.data["i"])
        if m.data["i"] == 0 and calls.count(0) == 1:
            return  # worker dies holding the keyed message
        c.ack()

    sched, topic, sub, dead = make(ep, ack_deadline=30.0, min_backoff=5.0)
    topic.publish({"i": 0}, ordering_key="k")
    topic.publish({"i": 1}, ordering_key="k")
    sched.run()
    assert calls == [0, 0, 1]
    assert sub.stats()["acked"] == 2
    assert not dead


def test_ordered_dead_letter_releases_key():
    """Regression: a dead-lettered ordered message left its key busy
    forever, stalling every later message with that key."""
    def ep(m, c):
        if m.data["i"] == 0:
            c.nack("poison")
        else:
            c.ack()

    sched, topic, sub, dead = make(ep, max_delivery_attempts=2,
                                   min_backoff=1.0)
    topic.publish({"i": 0}, ordering_key="k")
    topic.publish({"i": 1}, ordering_key="k")
    topic.publish({"i": 2}, ordering_key="k")
    sched.run()
    assert [d["i"] for d in dead] == [0]  # the poison message dead-letters
    assert sub.stats()["acked"] == 2  # …and the key's backlog drains
    assert sub.stats()["ordered_backlog"] == 0


@settings(max_examples=25, deadline=None)
@given(
    n_msgs=st.integers(1, 12),
    fail_pattern=st.lists(st.integers(0, 3), min_size=1, max_size=40),
    n_keys=st.integers(1, 3),
)
def test_ordered_at_least_once_invariant(n_msgs, fail_pattern, n_keys):
    """Property: ordered delivery under any failure pattern still settles
    every message (acked or dead-lettered), never wedges a key, and never
    lets a later message with a key overtake an earlier one's settlement."""
    state = {"calls": 0}
    settled: dict[str, list[int]] = {}

    def ep(m, c):
        k = state["calls"]
        state["calls"] += 1
        mode = fail_pattern[k % len(fail_pattern)]
        if mode == 0:
            settled.setdefault(m.ordering_key, []).append(m.data["i"])
            c.ack()
        elif mode == 1:
            c.nack("injected")
        elif mode == 2:
            raise RuntimeError("crash")
        else:
            pass  # hang → deadline expiry

    sched = SimScheduler()
    topic = Topic("t", sched)
    dlq = Topic("dlq", sched)
    dead = []
    Subscription(dlq, "sink", lambda m, c: (dead.append(m.data["i"]), c.ack()))
    sub = Subscription(topic, "s", ep, dlq=dlq, ack_deadline=30.0,
                       min_backoff=1.0, max_delivery_attempts=4)
    for i in range(n_msgs):
        topic.publish({"i": i}, ordering_key=f"k{i % n_keys}")
    sched.run(max_events=200_000)
    assert sched.idle(), "simulation did not quiesce"
    assert sub.stats()["acked"] + len(dead) == n_msgs
    assert sub.stats()["backlog"] == 0 and sub.stats()["outstanding"] == 0
    assert sub.stats()["ordered_backlog"] == 0, "wedged ordering key"
    for key, acked in settled.items():
        assert acked == sorted(acked), f"key {key} acked out of order"


def test_hedge_fires_duplicate_for_straggler():
    deliveries = []

    def ep(m, c):
        deliveries.append(sched.now())
        if len(deliveries) == 1:
            sched.schedule(500.0, c.ack)  # straggler
        else:
            c.ack()  # hedge finishes fast

    sched, topic, sub, _ = make(ep, hedge_after=50.0, ack_deadline=1000.0)
    topic.publish({"i": 0})
    sched.run()
    assert len(deliveries) == 2
    assert deliveries[1] >= 50.0


def test_hedge_nack_does_not_disturb_original_delivery():
    """Regression: a hedged duplicate shares the original's message_id, and
    its nack used to pop the *original's* outstanding entry and schedule a
    retry while the original was still in flight — double-delivering. A
    failed duplicate must settle itself only; the slow original's own ack
    is the message's one settlement."""
    deliveries = []

    def ep(m, c):
        deliveries.append(sched.now())
        if len(deliveries) == 1:
            sched.schedule(200.0, c.ack)  # slow original, eventually fine
        else:
            c.nack("hedge gave up")  # duplicate fails fast

    sched, topic, sub, dead = make(ep, hedge_after=50.0, ack_deadline=1000.0,
                                   min_backoff=10.0)
    topic.publish({"i": 0})
    sched.run()
    assert len(deliveries) == 2  # original + hedge, no phantom redelivery
    assert sub.stats()["acked"] == 1
    assert sub.stats()["outstanding"] == 0
    assert not dead
    # the duplicate's failure is accounted separately, not as a message nack
    assert sub.metrics.get("sub.s.nacks") == 0
    assert sub.metrics.get("sub.s.hedge_nacks") == 1
    assert "sub.s.deadline_expired" not in sub.metrics.counters


def test_hedge_ack_settles_original_and_cancels_its_timers():
    """When the duplicate wins, the original's deadline timer must die with
    it — no deadline_expired redelivery at t=ack_deadline."""
    deliveries = []
    def ep(m, c):
        deliveries.append(sched.now())
        if len(deliveries) == 1:
            return  # original hangs forever
        c.ack()  # duplicate finishes

    sched, topic, sub, dead = make(ep, hedge_after=20.0, ack_deadline=100.0,
                                   min_backoff=5.0)
    topic.publish({"i": 0})
    sched.run()
    assert len(deliveries) == 2
    assert sub.stats()["acked"] == 1
    assert sub.stats()["outstanding"] == 0
    assert sub.metrics.get("sub.s.hedge_acks") == 1
    assert "sub.s.deadline_expired" not in sub.metrics.counters
    assert not dead


@settings(max_examples=25, deadline=None)
@given(
    n_msgs=st.integers(1, 20),
    fail_pattern=st.lists(st.integers(0, 3), min_size=1, max_size=40),
)
def test_at_least_once_invariant(n_msgs, fail_pattern):
    """Property: whatever the failure pattern, every message is eventually
    acked or dead-lettered — none lost, none stuck."""
    state = {"calls": 0}

    def ep(m, c):
        k = state["calls"]
        state["calls"] += 1
        mode = fail_pattern[k % len(fail_pattern)]
        if mode == 0:
            c.ack()
        elif mode == 1:
            c.nack("injected")
        elif mode == 2:
            raise RuntimeError("crash")
        else:
            pass  # hang → deadline expiry

    sched = SimScheduler()
    topic = Topic("t", sched)
    dlq = Topic("dlq", sched)
    dead = []
    Subscription(dlq, "sink", lambda m, c: (dead.append(m.data["i"]), c.ack()))
    sub = Subscription(topic, "s", ep, dlq=dlq, ack_deadline=30.0,
                       min_backoff=1.0, max_delivery_attempts=4)
    for i in range(n_msgs):
        topic.publish({"i": i})
    sched.run(max_events=200_000)
    assert sched.idle(), "simulation did not quiesce"
    accounted = sub.stats()["acked"] + len(dead)
    assert accounted == n_msgs
    assert sub.stats()["backlog"] == 0 and sub.stats()["outstanding"] == 0
