"""Lint self-tests: every rule fires on a planted violation, the pragma
suppresses it, the path exemptions hold, and the shipped tree is clean."""
import textwrap
from pathlib import Path

from repro.analysis import lint

REPO = Path(__file__).resolve().parents[1]


def _findings(tmp_path: Path, source: str, *, rel: str = "mod.py"):
    p = tmp_path / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(source))
    return lint.lint_file(p, root=tmp_path)


def _rules(findings):
    return [f.rule for f in findings]


# ------------------------------------------------ each rule fires (seeded)
def test_bare_lock_fires(tmp_path):
    fs = _findings(tmp_path, """\
        import threading
        LOCK = threading.Lock()
        RLOCK = threading.RLock()
    """)
    assert _rules(fs) == ["bare-lock", "bare-lock"]
    assert "TrackedLock" in fs[0].message


def test_wall_clock_fires(tmp_path):
    fs = _findings(tmp_path, """\
        import time
        t0 = time.time()
        time.sleep(1.0)
        t1 = time.monotonic()
        t2 = time.perf_counter()
    """)
    assert _rules(fs) == ["wall-clock"] * 4
    assert "wall_time" in fs[0].message and "wall_sleep" in fs[1].message
    assert "monotonic" in fs[2].message and "monotonic" in fs[3].message


def test_bare_thread_fires(tmp_path):
    fs = _findings(tmp_path, """\
        import threading
        t = threading.Thread(target=work, daemon=True)
        timer = threading.Timer(5.0, fire)
    """)
    assert _rules(fs) == ["bare-thread", "bare-thread"]
    assert "racedep.spawn" in fs[0].message


def test_unseeded_random_fires(tmp_path):
    fs = _findings(tmp_path, """\
        import random
        import numpy as np
        r = random.Random()
        x = random.random()
        rng = np.random.default_rng()
        y = np.random.uniform(0, 1)
    """)
    assert _rules(fs) == ["unseeded-random"] * 4


def test_direct_pallas_fires(tmp_path):
    fs = _findings(tmp_path, """\
        from jax.experimental.pallas import pallas_call
        import jax.experimental.pallas as pl
        out = pallas_call(kernel, out_shape=shape)(x)
        out2 = pl.pallas_call(kernel, out_shape=shape)(x)
    """)
    assert "direct-pallas" in _rules(fs)
    # the import, the bare name, and the attribute access all flagged
    assert _rules(fs).count("direct-pallas") >= 3


def test_counter_name_fires(tmp_path):
    fs = _findings(tmp_path, """\
        metrics.inc("flat")
        metrics.inc("Bad.Case")
        metrics.record("spaced name.x", 1.0)
        metrics.inc(f"svc.{name}.requests")    # placeholder segment: fine
        metrics.inc("svc.conv.cold_starts")    # compliant: fine
    """)
    assert _rules(fs) == ["counter-name"] * 3


def test_counter_name_covers_observe(tmp_path):
    fs = _findings(tmp_path, """\
        metrics.observe("Bad Histogram", 1.0)
        metrics.observe("sub.push.latency", 1.0)   # compliant: fine
    """)
    assert _rules(fs) == ["counter-name"]


def test_span_name_fires(tmp_path):
    fs = _findings(tmp_path, """\
        from repro.core import tracing
        sp = tracing.start_span("FlatName")
        with tracing.span("Bad Span.x"):
            pass
        tracing.add_event(sp, "noDots")
        sp2 = tracing.start_span("sub.push.deliver")       # compliant
        tracing.add_event(sp2, f"fault.{kind}")            # placeholder
        with tracing.span("convert.slide"):                # compliant
            pass
    """)
    assert _rules(fs) == ["span-name"] * 3
    assert "segment.segment" in fs[0].message


def test_jit_global_mutation_fires(tmp_path):
    fs = _findings(tmp_path, """\
        import jax
        CACHE = {}
        COUNT = 0

        @jax.jit
        def f(x):
            global COUNT
            CACHE[1] = x
            CACHE.update({2: x})
            return x
    """)
    assert _rules(fs) == ["jit-global-mutation"] * 3


# ------------------------------------------------------ pragma suppression
def test_pragma_same_line_suppresses(tmp_path):
    fs = _findings(tmp_path, """\
        import threading
        LOCK = threading.Lock()  # detector guts  # lint: allow(bare-lock)
    """)
    assert fs == []


def test_pragma_line_above_suppresses(tmp_path):
    fs = _findings(tmp_path, """\
        import time
        # CLI stopwatch, never under SimScheduler  # lint: allow(wall-clock)
        t0 = time.time()
    """)
    assert fs == []


def test_pragma_is_rule_specific(tmp_path):
    fs = _findings(tmp_path, """\
        import time
        t0 = time.time()  # lint: allow(bare-lock)
    """)
    assert _rules(fs) == ["wall-clock"]


def test_pragma_multiple_rules(tmp_path):
    fs = _findings(tmp_path, """\
        import time
        t0 = time.time()  # lint: allow(bare-lock, wall-clock)
    """)
    assert fs == []


# -------------------------------------------------------- path exemptions
def test_analysis_dir_may_use_bare_locks(tmp_path):
    fs = _findings(tmp_path, """\
        import threading
        MU = threading.Lock()
    """, rel="analysis/guts.py")
    assert fs == []


def test_clock_module_may_use_wall_clock(tmp_path):
    fs = _findings(tmp_path, """\
        import time
        import threading
        def wall_time():
            return time.time()
        def monotonic():
            return time.monotonic()
        t = threading.Timer(1.0, fire)
    """, rel="core/clock.py")
    assert fs == []


def test_benchmarks_dir_may_use_monotonic(tmp_path):
    fs = _findings(tmp_path, """\
        import time
        t0 = time.perf_counter()
        t1 = time.monotonic()
    """, rel="benchmarks/some_bench.py")
    assert fs == []


def test_analysis_dir_may_spawn_threads(tmp_path):
    fs = _findings(tmp_path, """\
        import threading
        t = threading.Thread(target=work)
    """, rel="analysis/racedep.py")
    assert fs == []


def test_kernels_dir_may_use_pallas_call(tmp_path):
    fs = _findings(tmp_path, """\
        from jax.experimental.pallas import pallas_call
        out = pallas_call(kernel, out_shape=shape)(x)
    """, rel="kernels/impl.py")
    assert fs == []


# --------------------------------------------------- sanctioned idioms
def test_sanctioned_idioms_are_clean(tmp_path):
    fs = _findings(tmp_path, """\
        import random
        import time
        import numpy as np
        from repro.analysis.lockdep import TrackedLock
        from repro.core.clock import wall_time

        LOCK = TrackedLock("mod.LOCK")
        r = random.Random(7)
        rng = np.random.default_rng(7)
        t2 = wall_time()
        metrics.inc("svc.conv.requests")
    """)
    assert fs == []


def test_syntax_error_reported_not_raised(tmp_path):
    fs = _findings(tmp_path, "def broken(:\n")
    assert _rules(fs) == ["syntax"]


# ------------------------------------------------------ shipped tree + CLI
def test_shipped_tree_is_clean():
    findings = lint.lint_paths(
        [REPO / "src", REPO / "tests", REPO / "benchmarks"], root=REPO)
    assert findings == [], "\n".join(str(f) for f in findings)


def test_main_exit_codes(tmp_path, capsys):
    bad = tmp_path / "bad.py"
    bad.write_text("import time\nt = time.time()\n")
    good = tmp_path / "good.py"
    good.write_text("x = 1\n")
    assert lint.main([str(bad)]) == 1
    out = capsys.readouterr().out
    assert "[wall-clock]" in out and "1 finding(s)" in out
    assert lint.main([str(good)]) == 0
    assert "clean" in capsys.readouterr().out
    assert lint.main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rule in lint.RULES:
        assert rule in out
