"""Converter fleet: paper-claim assertions (Figures 2-3 through the
multi-instance fleet) plus fleet scheduling semantics — tenant fairness,
per-tenant quotas, backlog shedding, duplicate-delivery dedupe — and the
arrival-trace property (instance cap / quota cap / exactly-once-settled)
as both a Hypothesis property and an always-run seeded sweep."""
import random

from _hypothesis_compat import given, settings, st

from benchmarks.fig2_workflows import (autoscaling_time, parallel_time,
                                       serial_time)
from benchmarks.fig3_autoscaling import run as fig3_run
from repro.core import (ConversionPipeline, ConverterFleet, SimScheduler)

FLEET_KW = dict(fleet={}, ordered_ingest=True)
TAU = 90.0


# ---------------------------------------------------------------- paper claims
def test_fleet_loses_at_n1_under_cold_start():
    fleet_t = autoscaling_time(1, TAU, cold_start=12.0, **FLEET_KW)
    assert fleet_t > serial_time(1, TAU)


def test_fleet_beats_parallel_and_serial_at_scale():
    for n in (10, 50):
        fleet_t = autoscaling_time(n, TAU, cold_start=12.0, **FLEET_KW)
        assert fleet_t < parallel_time(n, TAU) < serial_time(n, TAU)


def test_fig3_fleet_ramps_to_plateau_and_decays():
    minutes, pipe = fig3_run(n=50, tau=TAU, cold_start=12.0,
                             max_instances=100, **FLEET_KW)
    peak = max(v for _, v in minutes)
    assert peak >= 45, f"should plateau near 50 instances, got {peak}"
    # the plateau never exceeds the configured ceiling, at ANY instant
    assert all(v <= 100 for _, v in pipe.instance_series())
    assert minutes[-1][1] == 0, "fleet should decay back to zero"
    assert pipe.done_count() == 50


# ------------------------------------------------------------ fleet scheduling
def _fleet(sched, handler, **kw):
    kw.setdefault("max_instances", 2)
    kw.setdefault("concurrency", 1)
    kw.setdefault("cold_start", 0.0)
    kw.setdefault("scale_down_delay", 5.0)
    return ConverterFleet("conv", sched, handler, **kw)


def test_tenant_fair_scheduling_interleaves_a_burst():
    sched = SimScheduler()
    order = []
    svc = _fleet(sched, lambda p: 10.0)
    done = []
    for i in range(8):
        svc.receive({"name": f"a{i}", "tenant": "lab-a"},
                    lambda ok, i=i: done.append(("lab-a", ok)),
                    key=("a", i))
    for i in range(2):
        svc.receive({"name": f"b{i}", "tenant": "lab-b"},
                    lambda ok, i=i: done.append(("lab-b", ok)),
                    key=("b", i))
    sched.run()
    assert len(done) == 10 and all(ok is True for _, ok in done)
    # round-robin dispatch: the small tenant's 2 jobs land inside the
    # first 4 completions instead of queueing behind lab-a's burst
    first4 = [t for t, _ in done[:4]]
    assert first4.count("lab-b") == 2, done


def test_tenant_quota_sheds_excess_and_caps_load_series():
    sched = SimScheduler()
    svc = _fleet(sched, lambda p: 10.0, tenant_quota=2, max_instances=4)
    verdicts = []
    for i in range(5):
        svc.receive({"name": f"a{i}"}, verdicts.append,
                    tenant="lab-a", key=("a", i))
    sched.run()
    assert verdicts.count("shed") == 3
    assert verdicts.count(True) == 2
    load = svc.metrics.timeseries("svc.conv.tenant.lab-a.load")
    assert max(v for _, v in load) <= 2


def test_backlog_shedding_then_admission():
    sched = SimScheduler()
    svc = _fleet(sched, lambda p: 10.0, shed_backlog=2, max_instances=1,
                 instance_queue_depth=0)
    verdicts = []
    for i in range(5):
        svc.receive({"name": f"s{i}"}, verdicts.append, key=("s", i))
    assert verdicts.count("shed") == 3  # backlog capped at 2 waiting
    sched.run()
    # shed work re-offered later (the broker's budget-exempt requeue in the
    # full pipeline) is admitted once the backlog drains
    svc.receive({"name": "late"}, verdicts.append, key=("late",))
    sched.run()
    assert verdicts.count(True) == 3


def test_duplicate_delivery_dedupes_in_flight_and_completed():
    sched = SimScheduler()
    runs = []
    svc = _fleet(sched, lambda p: runs.append(p["name"]) or 10.0)
    done = []
    svc.receive({"name": "s"}, done.append, key=("s", "g1"))
    # duplicate while in flight: attaches, does not run the handler twice
    svc.receive({"name": "s"}, done.append, key=("s", "g1"))
    sched.run()
    assert runs == ["s"]
    assert done == [True, True]
    # duplicate after completion: settled immediately from the completed set
    svc.receive({"name": "s"}, done.append, key=("s", "g1"))
    assert done == [True, True, True]
    assert runs == ["s"]
    assert svc.metrics.get("svc.conv.duplicates") == 2


def test_kill_mid_conversion_requeues_victims_work_exactly_once():
    sched = SimScheduler()
    svc = _fleet(sched, lambda p: 50.0, max_instances=1,
                 instance_queue_depth=2)
    done = []
    for i in range(3):
        svc.receive({"name": f"s{i}"}, done.append, key=("s", i))
    # t=10: s0 mid-conversion, s1/s2 queued on the doomed instance
    sched.schedule(10.0, svc.kill_instance)
    sched.run()
    assert done == [True, True, True]
    assert svc.metrics.get("svc.conv.requeued") == 3
    assert svc.metrics.get("svc.conv.completed") == 3
    assert svc.instance_count() == 0  # scaled back down afterwards


def test_work_stealing_balances_late_capacity():
    # 1 instance is ready first and buffers the burst in its local queue;
    # when the controller's extra instances come up they steal it instead
    # of idling — completion is width-limited, not head-of-line-limited
    sched = SimScheduler()
    svc = _fleet(sched, lambda p: 30.0, max_instances=6, cold_start=1.0)
    done = []
    for i in range(6):
        svc.receive({"name": f"s{i}"}, done.append, key=("s", i))
    sched.run()
    assert done == [True] * 6
    lat = svc.metrics.timeseries("svc.conv.latency")
    assert max(v for _, v in lat) < 60.0, "a slide waited behind another"


# -------------------------------------------------- arrival-trace property
MAX_INSTANCES = 6
QUOTA = 4


def _run_trace(seed: int):
    """Random arrival trace through the full pipeline; returns invariants."""
    rng = random.Random(seed)
    sched = SimScheduler()
    pipe = ConversionPipeline(
        sched, service_time=lambda ev: _service(ev), cold_start=5.0,
        max_instances=MAX_INSTANCES, min_backoff=5.0, max_backoff=40.0,
        ack_deadline=120.0, subscribers=False,
        fleet=dict(tenant_quota=QUOTA, shed_backlog=12), ordered_ingest=True)

    def _service(event):
        if event["name"].startswith("bad/"):
            raise RuntimeError("poison slide")
        return 20.0 + (event["generation"] and 0.0)

    n = rng.randint(4, 24)
    good, poison = [], []
    for i in range(n):
        bad = rng.random() < 0.2
        key = f"{'bad' if bad else 'ok'}/s{i:03d}.psv"
        (poison if bad else good).append(key)
        tenant = rng.choice(["lab-a", "lab-b", "lab-c"])
        delay = rng.uniform(0.0, 240.0)
        sched.schedule(delay, pipe.ingest, key, bytes([i % 251]) * (i + 1),
                       {"slide_id": key, "tenant": tenant})
    sched.run()
    return pipe, good, poison


def _assert_trace_invariants(pipe, good, poison):
    # 1) the instance cap holds at every step of the run
    series = pipe.instance_series()
    assert all(v <= MAX_INSTANCES for _, v in series), max(
        v for _, v in series)
    # 2) per-tenant admitted load never exceeds the quota
    for tenant in ("lab-a", "lab-b", "lab-c"):
        load = pipe.metrics.timeseries(f"svc.wsi2dcm.tenant.{tenant}.load")
        assert all(v <= QUOTA for _, v in load)
    # 3) every slide settles exactly once: good → acked conversion,
    #    poison → dead-lettered (and never both)
    dead = [ev["name"] for ev, _ in pipe.dead_lettered]
    assert sorted(dead) == sorted(poison)
    # acked == one settled delivery per good slide (the completed metric
    # also counts a poison slide's failed attempts, so it is no measure
    # of success); nothing left in flight
    assert pipe.subscription.stats()["acked"] == len(good)
    assert pipe.subscription.stats()["backlog"] == 0
    assert pipe.subscription.stats()["outstanding"] == 0


def test_random_arrival_traces_seeded_sweep():
    for seed in range(8):
        pipe, good, poison = _run_trace(seed)
        _assert_trace_invariants(pipe, good, poison)


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=0, max_value=2**32 - 1))
def test_random_arrival_traces_property(seed):
    pipe, good, poison = _run_trace(seed)
    _assert_trace_invariants(pipe, good, poison)
