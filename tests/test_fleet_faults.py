"""Fault-injection regression tier for the converter fleet.

Deterministic (``SimScheduler``) failure scenarios over the full pipeline:
an instance killed mid-conversion requeues its work exactly once with the
ordered key released and no DLQ entry; scripted broker faults (dropped,
delayed, duplicated deliveries) lose nothing and double-convert nothing;
backpressure sheds re-enter through budget-exempt requeues without ever
dead-lettering; and the real-bytes gauntlet — actual JPEG/DICOM conversion
with pinned UIDs through a sharded store under faults + instance kill +
shard crash — emits study tars byte-identical to a serial (no
infrastructure) conversion of the same slides."""
import hashlib
import json

import pytest

from repro.core import (ConversionPipeline, DeliveryFaults, SimScheduler)
from repro.core.pipeline import derive_out_key


# ------------------------------------------------------------ kill semantics
def test_kill_mid_conversion_requeues_once_releases_key_no_dlq():
    sched = SimScheduler()
    pipe = ConversionPipeline(
        sched, service_time=50.0, cold_start=5.0, max_instances=1,
        min_backoff=5.0, subscribers=False, fleet={}, ordered_ingest=True)
    pipe.ingest("scans/a.psv", b"aaaa")
    sched.schedule(20.0, pipe.service.kill_instance)  # mid-conversion
    sched.run()
    # requeued exactly once inside the fleet — the broker never saw a
    # failure, so there is no retry, no DLQ entry, and the ack settled
    # the delivery on the re-run
    assert pipe.metrics.get("svc.wsi2dcm.requeued") == 1
    assert pipe.metrics.get("svc.wsi2dcm.killed") == 1
    assert pipe.dead_lettered == []
    assert pipe.metrics.get("sub.wsi2dcm-push.acks") == 1
    assert pipe.metrics.get("sub.wsi2dcm-push.nacks") == 0
    # ordered key released on ack: a later event for the same object is
    # deliverable (nothing parked, nothing busy)
    assert pipe.subscription._ordered_busy == set()
    assert pipe.subscription.stats()["ordered_backlog"] == 0


def test_kill_during_cold_start_loses_nothing():
    sched = SimScheduler()
    pipe = ConversionPipeline(
        sched, service_time=30.0, cold_start=10.0, max_instances=2,
        subscribers=False, fleet={}, ordered_ingest=True)
    for i in range(4):
        pipe.ingest(f"scans/s{i}.psv", bytes([i + 1]) * 8)
    sched.schedule(5.0, pipe.service.kill_instance)  # still starting
    sched.run()
    assert pipe.metrics.get("sub.wsi2dcm-push.acks") == 4
    assert pipe.dead_lettered == []


# ----------------------------------------------------------- delivery faults
def test_scripted_faults_zero_lost_zero_double():
    runs = []
    faults = (DeliveryFaults()
              .drop("s0", attempts=(1,))
              .duplicate("s1", lag=1.0)
              .delay("s2", by=200.0))  # past the 120 s ack deadline
    sched = SimScheduler()
    pipe = ConversionPipeline(
        sched, service_time=lambda ev: runs.append(ev["name"]) or 20.0,
        cold_start=5.0, max_instances=4, ack_deadline=120.0, min_backoff=5.0,
        subscribers=False, fleet={}, ordered_ingest=True,
        delivery_faults=faults)
    for i in range(4):
        pipe.ingest(f"scans/s{i}.psv", bytes([i + 1]) * 8)
    sched.run()
    assert dict(faults.injected) == {"drop": 1, "duplicate": 1, "delay": 1}
    # zero lost: every slide converted and settled; zero double: the
    # duplicated and late deliveries deduped at fleet admission
    assert sorted(runs) == [f"scans/s{i}.psv" for i in range(4)]
    assert pipe.metrics.get("sub.wsi2dcm-push.acks") == 4
    assert pipe.metrics.get("svc.wsi2dcm.duplicates") >= 1
    assert pipe.dead_lettered == []
    assert pipe.subscription.stats()["outstanding"] == 0


def test_seeded_random_faults_converge():
    for seed in (3, 17):
        faults = DeliveryFaults.random(seed, p_drop=0.2, p_duplicate=0.2,
                                       p_delay=0.3, max_delay=150.0)
        sched = SimScheduler()
        pipe = ConversionPipeline(
            sched, service_time=15.0, cold_start=5.0, max_instances=4,
            ack_deadline=90.0, min_backoff=5.0, subscribers=False,
            fleet={}, ordered_ingest=True, delivery_faults=faults)
        n = 10
        for i in range(n):
            pipe.ingest(f"scans/s{i:02d}.psv", bytes([i + 1]) * 8)
        sched.run()
        assert pipe.metrics.get("sub.wsi2dcm-push.acks") == n
        assert pipe.dead_lettered == []
        assert pipe.subscription.stats()["outstanding"] == 0
        assert pipe.subscription.stats()["backlog"] == 0


# ------------------------------------------------------------- backpressure
def test_backpressure_sheds_without_dead_lettering():
    sched = SimScheduler()
    pipe = ConversionPipeline(
        sched, service_time=30.0, cold_start=5.0, max_instances=2,
        min_backoff=5.0, max_delivery_attempts=3, subscribers=False,
        fleet=dict(shed_backlog=3), ordered_ingest=True)
    n = 10
    for i in range(n):
        pipe.ingest(f"burst/s{i:02d}.psv", bytes([i + 1]) * 8)
    sched.run()
    shed = pipe.metrics.get("svc.wsi2dcm.shed")
    assert shed > 0, "overload never shed"
    # sheds came back as budget-exempt requeues (same attempt number), so
    # even with a 3-attempt budget nothing dead-letters and all complete
    assert pipe.metrics.get("sub.wsi2dcm-push.requeues") >= shed
    assert pipe.dead_lettered == []
    assert pipe.metrics.get("sub.wsi2dcm-push.acks") == n
    # in-flight work is never shed: admitted requests all completed
    assert pipe.metrics.get("svc.wsi2dcm.completed") == n


def test_dlq_depth_shedding_holds_new_work_back():
    # a poison slide exhausts its budget and dead-letters; with
    # shed_dlq_depth=1 the fleet then sheds new work (which retries
    # budget-exempt) instead of accepting it into a failing system
    def service(event):
        if event["name"].startswith("bad/"):
            raise RuntimeError("poison slide")
        return 10.0

    sched = SimScheduler()
    pipe = ConversionPipeline(
        sched, service_time=service, cold_start=2.0,
        max_instances=2, min_backoff=5.0, max_delivery_attempts=2,
        subscribers=False, ordered_ingest=True,
        fleet=dict(shed_dlq_depth=1))
    pipe.ingest("bad/p.psv", b"pp")
    sched.run()
    assert len(pipe.dead_lettered) == 1
    # the DLQ threshold is now tripped: a healthy slide sheds (retrying
    # budget-exempt on its 2-attempt budget) until the gate lifts, then
    # completes — it must never dead-letter while being held back
    pipe.ingest("ok/q.psv", b"qq")
    sched.schedule(12.0, lambda: setattr(pipe.service, "shed_dlq_depth", 10))
    sched.run()
    assert pipe.metrics.get("svc.wsi2dcm.shed") >= 2
    assert pipe.metrics.get("sub.wsi2dcm-push.acks") == 1
    assert [e["name"] for e, _ in pipe.dead_lettered] == ["bad/p.psv"]


# ------------------------------------------------- faults as span events
def test_fault_and_kill_span_events():
    """Every injected broker fault and the instance kill show up as
    structured span events in the delivery/request spans (PR 10): chaos is
    visible in the same trace tree the dashboard renders, not only as
    counters."""
    from repro.core import tracing

    faults = (DeliveryFaults()
              .drop("s0", attempts=(1,))
              .duplicate("s1", lag=1.0)
              .delay("s2", by=200.0))
    sched = SimScheduler()
    with tracing.capture(now=sched.now) as tracer:
        pipe = ConversionPipeline(
            sched, service_time=40.0, cold_start=5.0, max_instances=2,
            ack_deadline=120.0, min_backoff=5.0, subscribers=False,
            fleet={}, ordered_ingest=True, delivery_faults=faults)
        for i in range(3):
            pipe.ingest(f"scans/s{i}.psv", bytes([i + 1]) * 8)
        sched.schedule(20.0, pipe.service.kill_instance)  # mid-conversion
        sched.run()
    assert pipe.metrics.get("sub.wsi2dcm-push.acks") == 3
    assert pipe.metrics.get("svc.wsi2dcm.killed") >= 1

    events = {}  # event name -> list of (span name, attrs)
    for sp in tracer.spans:
        for _, name, attrs in sp.events:
            events.setdefault(name, []).append((sp.name, attrs))
    # each scripted fault annotated the delivery attempt it hit
    assert events["fault.drop"] == [("sub.wsi2dcm-push.deliver",
                                     {"attempt": 1})]
    assert events["fault.delay"] == [("sub.wsi2dcm-push.deliver",
                                      {"by": 200.0})]
    assert events["fault.duplicate"] == [("sub.wsi2dcm-push.deliver",
                                          {"lag": 1.0})]
    # the kill requeued its victims on their open request spans...
    assert {n for n, _ in events["fleet.kill_requeue"]} == \
        {"svc.wsi2dcm.request"}
    assert all(a["instance"] >= 0 for _, a in events["fleet.kill_requeue"])
    # ...and the dead serve attempts settled as killed handle spans
    killed = [sp for sp in tracer.spans if sp.status == "killed"]
    assert killed and {sp.name for sp in killed} == {"svc.wsi2dcm.handle"}
    assert len(killed) == len(events["fleet.kill_requeue"])


# ---------------------------------------------------- real-bytes gauntlet
def _uids_for(slide_id: str) -> list[str]:
    h = hashlib.sha256(slide_id.encode()).hexdigest()
    return ["2.25." + str(int(h[:24], 16)),
            "2.25." + str(int(h[24:48], 16))]


@pytest.fixture(scope="module")
def gauntlet():
    """Real conversions under SimScheduler with faults, a kill, and a
    4-shard store; plus the serial baseline of the same slides."""
    from repro.wsi import SyntheticScanner
    from repro.wsi.convert import ConvertOptions, convert_wsi_to_dicom
    from repro.wsi.formats import sniff

    def convert(data, meta):
        opt = ConvertOptions(
            manifest={"uids": json.dumps(_uids_for(meta["slide_id"]))})
        return convert_wsi_to_dicom(data, meta, options=opt)

    scanner = SyntheticScanner(seed=11)
    slides = {f"scans/s{i}.psv": scanner.scan(256, 256, 256)
              for i in range(3)}
    meta = {k: {"slide_id": k, "tenant": ("lab-a", "lab-b")[i % 2]}
            for i, k in enumerate(slides)}
    baseline = {}
    for k, d in slides.items():
        m = dict(meta[k])
        m.setdefault("format", sniff(d))
        baseline[k] = convert(d, m)

    faults = (DeliveryFaults()
              .drop("s0", attempts=(1,))
              .duplicate("s1", lag=1.0)
              .delay("s2", by=200.0))
    sched = SimScheduler()
    pipe = ConversionPipeline(
        sched, convert=convert, cold_start=12.0, max_instances=4,
        ack_deadline=120.0, min_backoff=5.0, fleet={}, ordered_ingest=True,
        store_shards=4, delivery_faults=faults)
    for k, d in slides.items():
        pipe.ingest(k, d, meta[k])
    sched.schedule(5.0, pipe.service.kill_instance)
    sched.run()
    return pipe, slides, baseline, faults


def test_gauntlet_zero_lost_zero_double_converted(gauntlet):
    pipe, slides, _, faults = gauntlet
    assert pipe.dead_lettered == []
    assert sum(faults.injected.values()) == 3
    assert pipe.metrics.get("svc.wsi2dcm.killed") == 1
    assert len(pipe.dicom.list()) == len(slides)
    # one study-tar write per slide: a re-converted duplicate would either
    # bump writes (different bytes) or idempotent_skips (same bytes) — the
    # former must not happen at all
    assert pipe.metrics.get("bucket.dicom-store.writes") == len(slides)


def test_gauntlet_study_tars_byte_identical_to_serial(gauntlet):
    pipe, slides, baseline, _ = gauntlet
    for k in slides:
        assert pipe.dicom.get(derive_out_key(k)).data == baseline[k], \
            f"fleet output differs from serial conversion for {k}"


def test_gauntlet_sharded_store_serves_all_studies(gauntlet):
    pipe, slides, _, _ = gauntlet
    ss = pipe.store_service
    studies = ss.search_studies()
    assert len(studies) == len(slides)
    assert sum(ss.shard_distribution()) == sum(
        len(ss.search_instances(u)) for u in studies)
    # downstream subscribers attached to the shared topic saw every store
    assert len(pipe.validator.checked) == sum(ss.shard_distribution())


def test_gauntlet_crashed_shard_rebuilds_byte_identical(gauntlet):
    pipe, _, _, _ = gauntlet
    ss = pipe.store_service
    uid = ss.search_studies()[0]
    shard_i = ss.shard_index_for(uid)
    qido = ss.search_instances(uid)
    wado = {m["sop_instance_uid"]: ss.retrieve(m["sop_instance_uid"])
            for m in qido}
    ss.crash_shard(shard_i)
    assert ss.search_instances(uid) == [], "crash left state behind"
    ss.rebuild_index()
    assert ss.search_instances(uid) == qido
    for sop, blob in wado.items():
        assert ss.retrieve(sop) == blob
