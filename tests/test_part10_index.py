"""Part10Index: O(1) frame seeks byte-identical to the full parser, plus
structural/BOT corruption rejection and deep verify() checks."""
import struct

import numpy as np
import pytest

from repro.wsi import (PSVReader, Part10Index, SyntheticScanner, encode_tile,
                       read_part10, write_part10)
from repro.wsi.dicom import TS_EXPLICIT_LE, TS_JPEG_BASELINE

_PIXEL_HDR = (struct.pack("<HH", 0x7FE0, 0x0010) + b"OB\x00\x00"
              + struct.pack("<I", 0xFFFFFFFF))


def _encapsulated(n_frames=4, seed=4):
    rd = PSVReader(SyntheticScanner(seed=seed).scan(512, 512, 256))
    bh, bw = rd.grid
    jpgs = [encode_tile(rd.read_tile(r, c)[:64, :64])
            for r in range(bh) for c in range(bw)]
    frames = [jpgs[i % len(jpgs)] for i in range(n_frames)]
    return write_part10(frames=frames, rows=64, cols=64, total_rows=256,
                        total_cols=256, transfer_syntax=TS_JPEG_BASELINE)


def _native(frame_hw=3, n_frames=3, seed=7):
    rng = np.random.default_rng(seed)
    frames = [rng.integers(0, 255, (frame_hw, frame_hw, 3),
                           dtype=np.uint8).tobytes() for _ in range(n_frames)]
    return write_part10(frames=frames, rows=frame_hw, cols=frame_hw,
                        total_rows=frame_hw * n_frames, total_cols=frame_hw,
                        transfer_syntax=TS_EXPLICIT_LE)


# --------------------------------------------------------------------------
# byte identity with read_part10
# --------------------------------------------------------------------------
@pytest.mark.parametrize("n_frames", [1, 4, 16])
def test_encapsulated_frames_byte_identical(n_frames):
    blob = _encapsulated(n_frames)
    idx = Part10Index(blob)
    _, frames = read_part10(blob)
    assert idx.encapsulated and idx.n_frames == n_frames == len(frames)
    assert [idx.read_frame(i) for i in range(n_frames)] == frames


def test_native_frames_byte_identical():
    blob = _native(frame_hw=64, n_frames=4)
    idx = Part10Index(blob)
    _, frames = read_part10(blob)
    assert not idx.encapsulated
    assert [idx.read_frame(i) for i in range(4)] \
        == [bytes(f) for f in frames]


def test_native_odd_length_padded_frames_byte_identical():
    """27-byte frames: blob is odd → even-padded; pad stays outside frames."""
    blob = _native(frame_hw=3, n_frames=3)
    assert len(blob) % 2 == 0
    idx = Part10Index(blob)
    _, frames = read_part10(blob)
    assert [idx.read_frame(i) for i in range(3)] \
        == [bytes(f) for f in frames]
    assert all(len(idx.read_frame(i)) == 27 for i in range(3))


def test_elements_match_full_parser():
    blob = _encapsulated(2)
    idx = Part10Index(blob)
    ds, _ = read_part10(blob)
    for (g, e), (vr, raw) in ds.elements.items():
        assert idx.read_element(g, e) == raw
        assert idx.get_str(g, e) == ds.get_str(g, e)
    assert idx.get_int(0x0028, 0x0008) == 2
    assert idx.get_int(0x0048, 0x0007) == 256
    assert idx.read_element(0x4242, 0x4242) is None


def test_read_frame_out_of_range():
    idx = Part10Index(_encapsulated(2))
    with pytest.raises(IndexError, match="out of range"):
        idx.read_frame(2)


# --------------------------------------------------------------------------
# corruption rejection
# --------------------------------------------------------------------------
@pytest.mark.parametrize("mangle", [
    lambda b: b"",                          # empty input
    lambda b: b[:100],                      # shorter than the preamble
    lambda b: b[:128] + b"DICX" + b[132:],  # wrong magic
    lambda b: b[: len(b) // 2],             # truncated mid-dataset
    lambda b: b[:-16],                      # truncated inside pixel data
])
def test_index_rejects_corrupt_streams(mangle):
    with pytest.raises(ValueError, match="corrupt Part-10"):
        Part10Index(mangle(_encapsulated(2)))


def _bot_offset(blob: bytes) -> int:
    """Offset of the basic-offset-table *item header* in ``blob``."""
    return blob.index(_PIXEL_HDR) + len(_PIXEL_HDR)


def test_index_rejects_bot_entry_mismatch():
    blob = bytearray(_encapsulated(2))
    struct.pack_into("<I", blob, _bot_offset(blob) + 8, 0xDEAD)  # entry 0
    with pytest.raises(ValueError, match="corrupt Part-10.*offset table"):
        Part10Index(bytes(blob))


def test_index_rejects_bot_length_not_multiple_of_4():
    blob = bytearray(_encapsulated(2))
    p = _bot_offset(blob)
    il = struct.unpack_from("<I", blob, p + 4)[0]
    struct.pack_into("<I", blob, p + 4, il + 2)
    with pytest.raises(ValueError, match="corrupt Part-10.*multiple of 4"):
        Part10Index(bytes(blob))


def test_index_rejects_bot_entry_count_mismatch():
    blob = bytearray(_encapsulated(2))
    p = _bot_offset(blob)
    struct.pack_into("<I", blob, p + 4, 4)  # claim 1 entry; 2 fragments
    with pytest.raises(ValueError, match="corrupt Part-10"):
        Part10Index(bytes(blob))


def test_index_rejects_native_pixel_data_shorter_than_frames():
    blob = bytearray(_native(frame_hw=4, n_frames=2))
    idx = Part10Index(bytes(blob))  # valid: locate NumberOfFrames
    vr, off, ln = idx.elements[(0x0028, 0x0008)]
    blob[off:off + ln] = b"9".ljust(ln)  # declare 9 frames, blob holds 2
    with pytest.raises(ValueError, match="corrupt Part-10.*shorter"):
        Part10Index(bytes(blob))


# --------------------------------------------------------------------------
# verify(): deep checks past the structural scan
# --------------------------------------------------------------------------
def test_verify_passes_on_clean_instances():
    Part10Index(_encapsulated(4)).verify()
    Part10Index(_native()).verify()


def test_verify_catches_rotted_jpeg_frame():
    blob = bytearray(_encapsulated(4))
    off, _ = Part10Index(bytes(blob)).frames[2]
    blob[off:off + 2] = b"\x00\x00"  # destroy the SOI marker
    with pytest.raises(ValueError, match="corrupt Part-10.*SOI"):
        Part10Index(bytes(blob)).verify()


def test_verify_catches_missing_sop_uid():
    blob = bytearray(_encapsulated(2))
    vr, off, ln = Part10Index(bytes(blob)).elements[(0x0008, 0x0018)]
    blob[off:off + ln] = b"\x00" * ln
    with pytest.raises(ValueError, match="corrupt Part-10.*SOP instance"):
        Part10Index(bytes(blob)).verify()


def test_verify_catches_frame_count_mismatch():
    blob = bytearray(_encapsulated(2))
    vr, off, ln = Part10Index(bytes(blob)).elements[(0x0028, 0x0008)]
    blob[off:off + ln] = b"3".ljust(ln)  # declares 3, stream holds 2
    with pytest.raises(ValueError, match="corrupt Part-10.*declared"):
        Part10Index(bytes(blob)).verify()
