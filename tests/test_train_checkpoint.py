"""Trainer: loss goes down, grad-accum equivalence, EF compression,
checkpoint save/restore/atomicity/elasticity."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comms.compress import ef_compress, ef_init, int8_dequantize, int8_quantize
from repro.configs import get_config
from repro.data import TokenDataset, make_lm_batch
from repro.models import model as M
from repro.train import TrainConfig, init_train_state, make_train_step
from repro.train.checkpoint import (AsyncCheckpointer, latest_step,
                                    restore_checkpoint, save_checkpoint)


def _cfg():
    return get_config("gemma-2b").reduced()


def _jb(b):
    return {k: jnp.asarray(v) for k, v in b.items()}


def test_loss_decreases_over_steps():
    cfg = _cfg()
    tc = TrainConfig(lr=3e-3, warmup_steps=2, total_steps=60, microbatches=1)
    step = jax.jit(make_train_step(cfg, tc))
    state = init_train_state(cfg, tc, jax.random.PRNGKey(0))
    ds = TokenDataset(cfg.vocab_size, 32, seed=0)
    losses = []
    for i in range(30):
        state, m = step(state, _jb(ds.shard_batch(i % 4, 8)))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses


def test_grad_accumulation_matches_full_batch():
    cfg = _cfg()
    key = jax.random.PRNGKey(1)
    b = _jb(TokenDataset(cfg.vocab_size, 32, seed=1).shard_batch(0, 8))
    tc1 = TrainConfig(microbatches=1)
    tc4 = TrainConfig(microbatches=4)
    s1 = init_train_state(cfg, tc1, key)
    s4 = jax.tree_util.tree_map(lambda x: x, s1)
    s1n, m1 = jax.jit(make_train_step(cfg, tc1))(s1, b)
    s4n, m4 = jax.jit(make_train_step(cfg, tc4))(s4, b)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 2e-2
    d = jax.tree_util.tree_map(
        lambda a, c: float(jnp.abs(a.astype(jnp.float32)
                                   - c.astype(jnp.float32)).max()),
        s1n["params"], s4n["params"])
    assert max(jax.tree_util.tree_leaves(d)) < 2e-2


def test_int8_quantize_roundtrip_error():
    x = jnp.asarray(np.random.default_rng(0).normal(0, 3, size=(64, 64)),
                    jnp.float32)
    q, s = int8_quantize(x)
    err = jnp.abs(int8_dequantize(q, s) - x)
    assert float(err.max()) <= float(s) * 0.5 + 1e-6


def test_error_feedback_compensates_bias():
    """Sum of EF-compressed grads tracks the sum of true grads."""
    rng = np.random.default_rng(3)
    g_true = [jnp.asarray(rng.normal(0, 1, size=(32,)), jnp.float32)
              for _ in range(50)]
    ef = {"g": jnp.zeros((32,), jnp.float32)}
    acc_c = jnp.zeros((32,))
    acc_t = jnp.zeros((32,))
    for g in g_true:
        (cg,), ef_tree = ef_compress((g,), (ef["g"],))
        ef["g"] = ef_tree[0]
        acc_c = acc_c + cg
        acc_t = acc_t + g
    # residual is bounded by one quantization step, not O(n) drift
    assert float(jnp.abs(acc_c - acc_t).max()) < 0.2


def test_compressed_training_still_learns():
    cfg = _cfg()
    tc = TrainConfig(lr=3e-3, warmup_steps=2, total_steps=60,
                     compress="int8_ef")
    step = jax.jit(make_train_step(cfg, tc))
    state = init_train_state(cfg, tc, jax.random.PRNGKey(0))
    assert "ef" in state
    ds = TokenDataset(cfg.vocab_size, 32, seed=0)
    losses = []
    for i in range(25):
        state, m = step(state, _jb(ds.shard_batch(i % 4, 8)))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.25


# --------------------------------------------------------------------------
# checkpointing
# --------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    cfg = _cfg()
    tc = TrainConfig()
    state = init_train_state(cfg, tc, jax.random.PRNGKey(0))
    save_checkpoint(tmp_path, 7, state)
    assert latest_step(tmp_path) == 7
    abstract = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    restored, step = restore_checkpoint(tmp_path, abstract)
    assert step == 7
    same = jax.tree_util.tree_map(
        lambda a, b: bool((np.asarray(a) == np.asarray(b)).all()),
        state, restored)
    assert all(jax.tree_util.tree_leaves(same))


def test_checkpoint_retention_and_latest(tmp_path):
    cfg = _cfg()
    tc = TrainConfig()
    state = init_train_state(cfg, tc, jax.random.PRNGKey(0))
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(tmp_path, s, state, keep=2)
    dirs = sorted(p.name for p in tmp_path.glob("step_*"))
    assert dirs == ["step_00000004", "step_00000005"]
    assert latest_step(tmp_path) == 5


def test_training_resumes_identically(tmp_path):
    cfg = _cfg()
    tc = TrainConfig(lr=1e-3, warmup_steps=2, total_steps=50)
    step = jax.jit(make_train_step(cfg, tc))
    ds = TokenDataset(cfg.vocab_size, 32, seed=0)
    state = init_train_state(cfg, tc, jax.random.PRNGKey(0))
    for i in range(4):
        state, _ = step(state, _jb(ds.shard_batch(i, 4)))
    save_checkpoint(tmp_path, 4, state)
    state_a = state
    for i in range(4, 8):
        state_a, ma = step(state_a, _jb(ds.shard_batch(i, 4)))
    # "crash" and restart from disk
    abstract = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    state_b, _ = restore_checkpoint(tmp_path, abstract)
    for i in range(4, 8):
        state_b, mb = step(state_b, _jb(ds.shard_batch(i, 4)))
    assert abs(float(ma["loss"]) - float(mb["loss"])) < 1e-5


def test_async_checkpointer(tmp_path):
    cfg = _cfg()
    tc = TrainConfig()
    state = init_train_state(cfg, tc, jax.random.PRNGKey(0))
    ck = AsyncCheckpointer(tmp_path)
    ck.save(11, state)
    ck.wait()
    assert latest_step(tmp_path) == 11
