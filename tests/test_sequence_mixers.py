"""Chunked sequence mixers vs their sequential oracles (rwkv6 / mamba2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.models.rwkv6 import wkv_chunked, wkv_decode, wkv_sequential


def _rand(shape, seed, scale=1.0):
    return jnp.asarray(
        np.random.default_rng(seed).normal(0, scale, size=shape), jnp.float32)


@settings(max_examples=15, deadline=None)
@given(
    B=st.integers(1, 3), S=st.sampled_from([17, 32, 64, 96]),
    H=st.integers(1, 4), K=st.sampled_from([4, 8, 16]),
    decay=st.floats(0.01, 20.0),
)
def test_wkv_chunked_matches_sequential(B, S, H, K, decay):
    seed = B * 1000 + S * 10 + H
    r, k, v = (_rand((B, S, H, K), seed + i) for i in range(3))
    logw = -jnp.asarray(
        np.random.default_rng(seed + 9).uniform(0.005, decay, (B, S, H, K)),
        jnp.float32)
    u = _rand((H, K), seed + 4)
    st0 = _rand((B, H, K, K), seed + 5, 0.2)
    o1, s1 = wkv_sequential(r, k, v, logw, u, st0)
    o2, s2 = wkv_chunked(r, k, v, logw, u, st0, chunk=32, sub=8)
    scale = float(jnp.abs(o1).max()) + 1.0
    assert float(jnp.abs(o1 - o2).max()) / scale < 2e-4
    assert float(jnp.abs(s1 - s2).max()) < 1e-3
    assert not bool(jnp.isnan(o2).any())


def test_wkv_decode_chain_matches_full():
    B, S, H, K = 2, 12, 2, 8
    r, k, v = (_rand((B, S, H, K), i) for i in range(3))
    logw = -jnp.asarray(
        np.random.default_rng(7).uniform(0.01, 2.0, (B, S, H, K)), jnp.float32)
    u = _rand((H, K), 11)
    st0 = jnp.zeros((B, H, K, K), jnp.float32)
    full, _ = wkv_sequential(r, k, v, logw, u, st0)
    s = st0
    outs = []
    for t in range(S):
        o, s = wkv_decode(r[:, t], k[:, t], v[:, t], logw[:, t], u, s)
        outs.append(o)
    step = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step),
                               rtol=1e-4, atol=1e-4)


def _mamba_sequential(p, cfg, x):
    """Naive per-step SSM recurrence oracle for mamba2_apply."""
    from repro.models import mamba2 as mb
    B = x.shape[0]
    state = {
        "conv_x": jnp.zeros((B, cfg.ssm_conv - 1, cfg.d_inner), x.dtype),
        "conv_B": jnp.zeros((B, cfg.ssm_conv - 1, cfg.ssm_state), x.dtype),
        "conv_C": jnp.zeros((B, cfg.ssm_conv - 1, cfg.ssm_state), x.dtype),
        "ssm": jnp.zeros((B, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                         jnp.float32),
    }
    outs = []
    for t in range(x.shape[1]):
        o, state = mb.mamba2_decode(p, cfg, x[:, t : t + 1], state)
        outs.append(o)
    return jnp.concatenate(outs, axis=1)


def test_mamba2_chunked_matches_recurrence():
    import dataclasses

    from repro.configs import get_config
    from repro.models import mamba2 as mb
    from repro.models.params import materialize

    cfg = get_config("zamba2-1.2b").reduced()
    p = materialize(mb.mamba2_defs(cfg), jax.random.PRNGKey(0),
                    dtype_override=jnp.float32)
    x = _rand((2, 48, cfg.d_model), 3, 0.5)
    full, _ = mb.mamba2_apply(p, cfg, x, chunk=16)
    step = _mamba_sequential(p, cfg, x)
    scale = float(jnp.abs(full).max()) + 1e-3
    assert float(jnp.abs(full - step).max()) / scale < 5e-3


def test_mamba2_final_state_matches_decode_state():
    import jax

    from repro.configs import get_config
    from repro.models import mamba2 as mb
    from repro.models.params import materialize

    cfg = get_config("zamba2-1.2b").reduced()
    p = materialize(mb.mamba2_defs(cfg), jax.random.PRNGKey(1),
                    dtype_override=jnp.float32)
    x = _rand((1, 32, cfg.d_model), 8, 0.5)
    _, st_full = mb.mamba2_apply(p, cfg, x, chunk=8, return_state=True)
    # replay the same tokens through decode; final ssm states must agree
    state = {
        "conv_x": jnp.zeros((1, cfg.ssm_conv - 1, cfg.d_inner), x.dtype),
        "conv_B": jnp.zeros((1, cfg.ssm_conv - 1, cfg.ssm_state), x.dtype),
        "conv_C": jnp.zeros((1, cfg.ssm_conv - 1, cfg.ssm_state), x.dtype),
        "ssm": jnp.zeros((1, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                         jnp.float32),
    }
    for t in range(32):
        _, state = mb.mamba2_decode(p, cfg, x[:, t : t + 1], state)
    assert float(jnp.abs(state["ssm"] - st_full["ssm"]).max()) < 5e-3


def test_blocked_attention_matches_naive():
    from repro.models.layers import blocked_attention
    B, Sq, H, KV, D = 2, 24, 4, 2, 8
    q = _rand((B, Sq, H, D), 0)
    k = _rand((B, Sq, KV, D), 1)
    v = _rand((B, Sq, KV, D), 2)
    pos = jnp.broadcast_to(jnp.arange(Sq), (B, Sq))
    out = blocked_attention(q, k, v, pos, pos, causal=True, chunk=8)
    # naive reference
    G = H // KV
    kx = jnp.repeat(k, G, axis=2)
    vx = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bqhd,bshd->bhqs", q, kx) * D**-0.5
    mask = jnp.tril(jnp.ones((Sq, Sq), bool))
    s = jnp.where(mask[None, None], s, -1e30)
    ref = jnp.einsum("bhqs,bshd->bqhd", jax.nn.softmax(s, -1), vx)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-2, atol=2e-3)


def test_blocked_attention_sliding_window():
    from repro.models.layers import blocked_attention
    B, S, H, D, W = 1, 32, 2, 8, 8
    q = _rand((B, S, H, D), 5)
    k = _rand((B, S, H, D), 6)
    v = _rand((B, S, H, D), 7)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    out = blocked_attention(q, k, v, pos, pos, causal=True, window=W, chunk=8)
    s = jnp.einsum("bqhd,bshd->bhqs", q, k) * D**-0.5
    i = jnp.arange(S)
    mask = (i[None, :] <= i[:, None]) & (i[:, None] - i[None, :] < W)
    s = jnp.where(mask[None, None], s, -1e30)
    ref = jnp.einsum("bhqs,bshd->bqhd", jax.nn.softmax(s, -1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-2, atol=2e-3)
