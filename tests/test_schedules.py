"""Schedule-exploration self-tests: seeded tie-breaking is reproducible
and actually permutes, the explore harness proves the real-bytes fleet
scenario invariant-clean across many schedules, and a broken invariant
dumps a replayable seed+trace artifact."""
import json

import pytest

from repro.analysis import racedep, schedules
from repro.core import SimScheduler


# --------------------------------------------------------- seeded scheduler
def _run_order(seed, n=8):
    sched = SimScheduler(seed=seed, record_trace=True)
    out = []
    for i in range(n):
        sched.schedule(0.0, out.append, i)
    sched.run()
    return out, sched.trace


def test_same_seed_same_schedule():
    o1, t1 = _run_order(42)
    o2, t2 = _run_order(42)
    assert o1 == o2 and t1 == t2


def test_seed_none_keeps_legacy_fifo_order():
    out, trace = _run_order(None)
    assert out == list(range(8))
    assert [seq for seq, _, _ in trace] == list(range(8))


def test_seeds_permute_equal_timestamp_events():
    fifo = list(range(8))
    orders = {tuple(_run_order(s)[0]) for s in range(10)}
    assert len(orders) > 1, "ten seeds never permuted the schedule"
    assert any(o != tuple(fifo) for o in orders)


def test_timestamp_order_still_dominates_ties():
    """Seeding only permutes *equal-timestamp* events — virtual time
    ordering is untouched."""
    sched = SimScheduler(seed=99)
    out = []
    for i, delay in enumerate([3.0, 1.0, 2.0]):
        sched.schedule(delay, out.append, i)
    sched.run()
    assert out == [1, 2, 0]


def test_trace_records_fired_events_only():
    sched = SimScheduler(seed=1, record_trace=True)
    h = sched.schedule(0.0, lambda: None)
    sched.schedule(0.0, lambda: None)
    h.cancel()
    sched.run()
    assert len(sched.trace) == 1


def test_trace_off_by_default():
    sched = SimScheduler(seed=1)
    assert sched.trace is None


# ------------------------------------------------------------- the harness
def test_explore_sim_scenario_clean(tmp_path):
    report = schedules.explore(schedules.sim_fleet_scenario, seeds=3,
                               artifacts_dir=str(tmp_path))
    assert len(report.seeds) == 4  # FIFO + 3 seeded permutations
    assert report.accesses > 0
    assert not list(tmp_path.iterdir()), "clean run wrote artifacts"


def test_explore_realbytes_fleet_20_seeds(tmp_path):
    """The acceptance tier: the real-bytes fleet scenario — synthetic
    slides through the real converter under drop/duplicate/delay faults
    and an instance kill — settles every slide exactly once, emits study
    tars byte-identical to the serial baseline AND across schedules, and
    reports zero data races, for 20 seeded schedules plus legacy FIFO."""
    report = schedules.explore(schedules.realbytes_fleet_scenario, seeds=20,
                               artifacts_dir=str(tmp_path))
    assert len(report.seeds) == 21
    assert not list(tmp_path.iterdir())


# --------------------------------------------- failure artifacts + replay
def order_dependent_scenario(sched):
    """Deliberately broken: returns bytes that depend on the schedule, so
    cross-seed identity fails (the artifact/replay path's test double)."""
    out = []
    for i in range(6):
        sched.schedule(0.0, out.append, i)
    sched.run()
    return {"order": repr(out).encode()}


def always_failing_scenario(sched):
    """Deliberately broken: violates its internal invariant on every
    schedule (the replay-reproduces-the-failure test double)."""
    sched.run()
    assert False, "planted invariant violation"


def racy_scenario(sched):
    """Deliberately racy: unsynchronized writes from spawned threads, so
    the zero-data-race invariant fails."""
    d = racedep.Shared({}, "racy.d")

    def w1():
        d["k"] = 1

    def w2():
        d["k"] = 2

    ts = [racedep.spawn(w1, start=False), racedep.spawn(w2, start=False)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(10.0)
    sched.run()
    return {}


def test_broken_invariant_dumps_artifact_and_replay_command(tmp_path,
                                                            capsys):
    with pytest.raises(schedules.ExplorationFailure) as ei:
        schedules.explore(order_dependent_scenario, seeds=10,
                          artifacts_dir=str(tmp_path))
    err = ei.value
    assert err.seed is not None and err.artifact is not None
    art = json.loads((tmp_path / err.artifact.rsplit("/", 1)[-1])
                     .read_text())
    assert art["seed"] == err.seed
    assert art["scenario"].endswith(":order_dependent_scenario")
    assert art["trace"], "artifact must carry the schedule trace"
    assert "diverged across schedules" in art["error"]
    out = capsys.readouterr().out
    assert "replay:" in out and "--replay" in out and err.artifact in out


def test_replay_reruns_the_recorded_schedule(tmp_path, capsys):
    with pytest.raises(schedules.ExplorationFailure) as ei:
        schedules.explore(always_failing_scenario, seeds=2,
                          artifacts_dir=str(tmp_path))
    artifact = ei.value.artifact
    # the replay command re-raises the original failure, reproducibly
    with pytest.raises(AssertionError, match="planted invariant violation"):
        schedules.replay(artifact)


def test_explore_fails_on_planted_data_race(tmp_path):
    with pytest.raises(schedules.ExplorationFailure, match="data race"):
        schedules.explore(racy_scenario, seeds=1,
                          artifacts_dir=str(tmp_path))
    arts = list(tmp_path.iterdir())
    assert len(arts) == 1
    assert "racy.d" in json.loads(arts[0].read_text())["error"]


def test_replay_result_matches_original_run(tmp_path):
    r1 = schedules._run_one(schedules.sim_fleet_scenario, 5)[0]
    r2 = schedules._run_one(schedules.sim_fleet_scenario, 5)[0]
    assert schedules._digest(r1) == schedules._digest(r2)


# ------------------------------------------------------------------- CLI
def test_cli_explore_and_replay(tmp_path, capsys, monkeypatch):
    monkeypatch.chdir(tmp_path)
    assert schedules.main(["--explore", "sim", "--seeds", "2",
                           "--artifacts", str(tmp_path / "arts")]) == 0
    assert "ExplorationReport" in capsys.readouterr().out
    with pytest.raises(SystemExit):
        schedules.main([])  # neither --explore nor --replay
