"""``hypothesis`` if available, else no-op stubs that skip property tests.

The seed container may not ship ``hypothesis``; the plain (non-property)
tests in the same modules must still collect and run. Usage:

    from _hypothesis_compat import given, settings, st

With hypothesis installed these are the real objects; without it, ``@given``
marks the test skipped and strategy constructors return placeholders.
"""
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:
    def settings(*args, **kwargs):
        return lambda f: f

    def given(*args, **kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    class _StrategyStub:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()
