"""Whole-level batched JPEG path: fused kernel differential, byte-exactness
of the batched entropy coder, and device-resident pyramid parity."""
import io
import tarfile

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import jpeg_transform
from repro.kernels import ref
from repro.wsi import (ConvertOptions, SyntheticScanner, convert_wsi_to_dicom,
                       decode_tile, encode_tile, read_part10, study_levels)
from repro.wsi.jpeg import encode_tiles_batch
from repro.wsi.slide import PSVReader

RNG = np.random.default_rng(7)


# --------------------------------------------------------------------------
# fused jpeg_transform kernel vs jnp oracle
# --------------------------------------------------------------------------
@pytest.mark.parametrize("n,h,w", [(1, 8, 128), (2, 64, 128), (3, 32, 256)])
@pytest.mark.parametrize("seed", [0, 1])
def test_jpeg_transform_pallas_matches_ref(n, h, w, seed):
    rng = np.random.default_rng(seed)
    tiles = jnp.asarray(rng.integers(0, 256, size=(n, 3, h, w))
                        .astype(np.float32))
    out = jpeg_transform(tiles, impl="pallas")
    expect = ref.jpeg_transform_ref(tiles)
    assert out.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


def test_jpeg_transform_matches_unfused_chain():
    """Fused kernel == rgb2ycbcr + per-channel dct8x8_quant, bit for bit."""
    from repro.kernels import dct8x8_quant, rgb2ycbcr

    tiles = RNG.integers(0, 256, size=(2, 3, 64, 128)).astype(np.float32)
    fused = np.asarray(jpeg_transform(jnp.asarray(tiles), impl="pallas"))
    qs = [ref.JPEG_LUMA_Q, ref.JPEG_CHROMA_Q, ref.JPEG_CHROMA_Q]
    for n in range(tiles.shape[0]):
        ycc = np.asarray(rgb2ycbcr(jnp.asarray(tiles[n])))
        for c in range(3):
            plane = np.asarray(dct8x8_quant(jnp.asarray(ycc[c]),
                                            jnp.asarray(qs[c])))
            np.testing.assert_array_equal(plane, fused[n, c])


def test_jpeg_transform_unaligned_falls_back_to_ref():
    tiles = jnp.asarray(RNG.integers(0, 256, size=(2, 3, 24, 72))
                        .astype(np.float32))
    np.testing.assert_array_equal(
        np.asarray(jpeg_transform(tiles)),
        np.asarray(ref.jpeg_transform_ref(tiles)))


# --------------------------------------------------------------------------
# batched entropy coder vs per-tile reference loop
# --------------------------------------------------------------------------
def test_batched_jpeg_bytes_identical_to_per_tile():
    psv = SyntheticScanner(seed=3).scan(512, 512, 256)
    rd = PSVReader(psv)
    tiles = np.stack([rd.read_tile(r, c) for r in range(2) for c in range(2)])
    per = [encode_tile(t) for t in tiles]
    bat = encode_tiles_batch(tiles)
    assert len(per) == len(bat)
    for a, b in zip(per, bat):
        assert a == b


@pytest.mark.parametrize("kind", ["noise", "flat", "gradient"])
def test_batched_bytes_identical_on_adversarial_content(kind):
    """Worst cases for the run-length vectorization: dense symbols (noise),
    long zero runs / EOB everywhere (flat), smooth DC drift (gradient)."""
    if kind == "noise":
        tiles = RNG.integers(0, 256, size=(2, 64, 128, 3)).astype(np.uint8)
    elif kind == "flat":
        tiles = np.full((2, 64, 128, 3), 200, np.uint8)
        tiles[0, 11, 13] = [0, 255, 7]  # one outlier block
    else:
        g = np.linspace(0, 255, 64 * 128).reshape(64, 128)
        one = np.stack([g, g[::-1], 255 - g], axis=-1).astype(np.uint8)
        tiles = np.stack([one, one[:, ::-1]])
    per = [encode_tile(t) for t in tiles]
    bat = encode_tiles_batch(tiles)
    for a, b in zip(per, bat):
        assert a == b


def test_out_of_range_coefficients_raise():
    """Categories beyond the baseline tables must raise, not alias/corrupt."""
    from repro.wsi.jpeg import encode_coef_batch

    coef = np.zeros((1, 3, 8, 8), np.int32)
    coef[0, 0, 0, 1] = 1 << 20  # AC category 21 would alias into the run nibble
    with pytest.raises(ValueError, match="AC coefficient"):
        encode_coef_batch(coef)

    coef = np.zeros((1, 3, 8, 8), np.int32)
    coef[0, 0, 0, 0] = 1 << 14  # DC diff category 15: no baseline code
    with pytest.raises(ValueError, match="DC difference"):
        encode_coef_batch(coef)


def test_unknown_impl_rejected():
    tiles = jnp.zeros((1, 3, 8, 128), jnp.float32)
    with pytest.raises(ValueError, match="impl"):
        jpeg_transform(tiles, impl="interpret")


def test_batched_roundtrip_decodes():
    psv = SyntheticScanner(seed=4).scan(256, 256, 256)
    tile = PSVReader(psv).read_tile(0, 0)
    jpg = encode_tiles_batch(tile[None])[0]
    rec = decode_tile(jpg)
    assert rec.shape == tile.shape
    err = np.abs(rec.astype(np.int32) - tile.astype(np.int32)).mean()
    assert err < 8.0  # q50 baseline quality


# --------------------------------------------------------------------------
# device-resident pyramid vs host pyramid
# --------------------------------------------------------------------------
def test_device_pyramid_matches_host_pyramid():
    psv = SyntheticScanner(seed=5).scan(1024, 1024, 256)
    tar_b = convert_wsi_to_dicom(psv, options=ConvertOptions(batched=True))
    tar_p = convert_wsi_to_dicom(psv, options=ConvertOptions(batched=False))
    lb, lp = study_levels(tar_b), study_levels(tar_p)
    names = sorted(k for k in lb if k.endswith(".dcm"))
    assert names == sorted(k for k in lp if k.endswith(".dcm"))
    assert len(names) == 3  # 1024 → 512 → 256
    for k in names:
        _, fb = read_part10(lb[k])
        _, fp = read_part10(lp[k])
        assert fb == fp  # per-level frames byte-identical


def test_batched_handles_levels_smaller_than_tile():
    """min_level_size below the tile size: the deepest levels hold no full
    frame; both paths must agree (and not crash) all the way down."""
    psv = SyntheticScanner(seed=9).scan(512, 512, 256)
    opts = dict(min_level_size=128)
    lb = study_levels(convert_wsi_to_dicom(
        psv, options=ConvertOptions(batched=True, **opts)))
    lp = study_levels(convert_wsi_to_dicom(
        psv, options=ConvertOptions(batched=False, **opts)))
    assert sorted(lb) == sorted(lp)
    assert "level_2.dcm" in lb  # the 128x128 sub-tile level exists
    for k in lb:
        if k.endswith(".dcm"):
            assert read_part10(lb[k])[1] == read_part10(lp[k])[1]


def test_raw_path_device_pyramid_matches_host():
    psv = SyntheticScanner(seed=6).scan(512, 512, 256)
    opts = dict(jpeg=False, min_level_size=256)
    lb = study_levels(convert_wsi_to_dicom(
        psv, options=ConvertOptions(batched=True, **opts)))
    lp = study_levels(convert_wsi_to_dicom(
        psv, options=ConvertOptions(batched=False, **opts)))
    for k in lb:
        if k.endswith(".dcm"):
            assert read_part10(lb[k])[1] == read_part10(lp[k])[1]


# --------------------------------------------------------------------------
# converter satellites: tar member guard, single-store manifest
# --------------------------------------------------------------------------
def test_study_levels_skips_non_file_members():
    buf = io.BytesIO()
    with tarfile.open(fileobj=buf, mode="w") as tar:
        d = tarfile.TarInfo("levels/")
        d.type = tarfile.DIRTYPE
        tar.addfile(d)
        info = tarfile.TarInfo("levels/level_0.dcm")
        payload = b"not-a-real-dcm"
        info.size = len(payload)
        tar.addfile(info, io.BytesIO(payload))
    out = study_levels(buf.getvalue())
    assert out == {"levels/level_0.dcm": payload}


def test_manifest_is_single_store_and_clearable():
    psv = SyntheticScanner(seed=8).scan(256, 256, 256)
    opt = ConvertOptions()
    tar_bytes = convert_wsi_to_dicom(psv, options=opt)
    # the manifest holds every finished level (plus the minted study/series
    # UIDs that make resume byte-exact); the tar is written from it
    assert set(opt.manifest) == {"0", "uids"}
    assert study_levels(tar_bytes)["level_0.dcm"] == opt.manifest["0"]
    opt.clear_manifest()
    assert opt.manifest == {}
