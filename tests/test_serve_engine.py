"""Continuous-batching engine + pub/sub frontend + data pipeline."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import SimScheduler, Subscription, Topic
from repro.data import ShardQueue, TokenDataset
from repro.models import model as M
from repro.serve.engine import ContinuousBatchingEngine, PubSubFrontend, Request


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("gemma-2b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _greedy_reference(cfg, params, prompt, n):
    """Token-by-token reference using prefill+decode directly."""
    import jax.numpy as jnp
    logits, cache = M.prefill(params, cfg, jnp.asarray(prompt)[None],
                              max_len=64)
    out = [int(np.argmax(np.asarray(logits)[0]))]
    for i in range(n - 1):
        pos = jnp.asarray([len(prompt) + i], jnp.int32)
        logits, cache = M.decode_step(
            params, cfg, cache, jnp.asarray([[out[-1]]], jnp.int32), pos)
        out.append(int(np.argmax(np.asarray(logits)[0])))
    return out


def test_engine_matches_reference_single(small_model):
    cfg, params = small_model
    eng = ContinuousBatchingEngine(cfg, params, batch_size=2, max_len=64)
    prompt = np.arange(5, dtype=np.int32) % cfg.vocab_size
    results = {}
    eng.submit(Request(prompt=prompt, max_new_tokens=5,
                       done=lambda t: results.update(out=t)))
    eng.run_until_drained()
    assert results["out"] == _greedy_reference(cfg, params, prompt, 5)


def test_engine_continuous_batching_drains_backlog(small_model):
    cfg, params = small_model
    eng = ContinuousBatchingEngine(cfg, params, batch_size=2, max_len=64)
    done = []
    for i in range(5):  # 5 requests > 2 slots
        prompt = (np.arange(3 + i) * 7 + i).astype(np.int32) % cfg.vocab_size
        eng.submit(Request(prompt=prompt, max_new_tokens=3 + i,
                           done=lambda t, i=i: done.append((i, len(t)))))
    eng.run_until_drained()
    assert sorted(i for i, _ in done) == [0, 1, 2, 3, 4]
    assert all(n == 3 + i for i, n in done)


def test_batched_results_match_isolated_runs(small_model):
    """Slot packing must not leak KV between concurrent requests."""
    cfg, params = small_model
    prompts = [(np.arange(4) + s).astype(np.int32) % cfg.vocab_size
               for s in (0, 11, 23)]
    solo = [_greedy_reference(cfg, params, p, 4) for p in prompts]
    eng = ContinuousBatchingEngine(cfg, params, batch_size=3, max_len=64)
    got = {}
    for i, p in enumerate(prompts):
        eng.submit(Request(prompt=p, max_new_tokens=4,
                           done=lambda t, i=i: got.update({i: t})))
    eng.run_until_drained()
    for i in range(3):
        assert got[i] == solo[i], f"request {i} diverged under batching"


def test_pubsub_frontend_round_trip(small_model):
    cfg, params = small_model
    sched = SimScheduler()
    req_topic = Topic("inference-requests", sched)
    resp_topic = Topic("inference-responses", sched)
    responses = []
    Subscription(resp_topic, "sink",
                 lambda m, c: (responses.append(m.data), c.ack()))
    eng = ContinuousBatchingEngine(cfg, params, batch_size=2, max_len=64)
    PubSubFrontend(eng, req_topic, resp_topic)
    for i in range(3):
        req_topic.publish({"request_id": i,
                           "prompt": [1 + i, 2, 3],
                           "max_new_tokens": 4})
    sched.run(until=0.0)  # immediate deliveries → engine.submit
    eng.run_until_drained()  # acks cancel the (virtual-time) deadline timers
    sched.run()  # response publishes
    assert sorted(r["request_id"] for r in responses) == [0, 1, 2]
    assert all(len(r["tokens"]) == 4 for r in responses)


# --------------------------------------------------------------------------
# data pipeline
# --------------------------------------------------------------------------
def test_dataset_shards_are_deterministic_and_distinct():
    ds = TokenDataset(1000, 32, seed=5)
    a1 = ds.shard_batch(3, 4)
    a2 = ds.shard_batch(3, 4)
    b = ds.shard_batch(4, 4)
    assert (a1["tokens"] == a2["tokens"]).all()
    assert not (a1["tokens"] == b["tokens"]).all()
    assert (a1["labels"][:, :-1] == a1["tokens"][:, 1:]).all()


def test_shard_queue_redelivers_on_worker_death():
    sched = SimScheduler()
    topic = Topic("shards", sched)
    q = ShardQueue(topic, ack_deadline=50.0)
    q.publish_epoch(5)
    sched.run()
    trained = []
    # worker processes two shards, dies holding the third (no ack)
    for _ in range(2):
        item, ack = q.poll()
        trained.append(item["shard"])
        ack()
    dead_item, _dead_ack = q.poll()  # never acked
    sched.run()  # deadline expires → redelivery
    while True:
        got = q.poll()
        if got is None:
            break
        item, ack = got
        trained.append(item["shard"])
        ack()
    sched.run()
    assert sorted(set(trained)) == [0, 1, 2, 3, 4]
    # the dead shard was re-trained exactly once after redelivery
    assert trained.count(dead_item["shard"]) >= 1
