"""DICOM store subsystem: idempotent STOW, persistent index + crash
rebuild, QIDO filtering/aggregation, indexed WADO, and the event-driven
validation / ML-inference subscribers."""
import pytest

from repro.core import SimScheduler, Subscription, Topic
from repro.core.storage import ObjectStore
from repro.wsi import (DicomStoreService, InferenceSubscriber, Part10Index,
                       SyntheticScanner, ValidationService,
                       convert_wsi_to_dicom, write_part10)


@pytest.fixture(scope="module")
def archive():
    psv = SyntheticScanner(seed=3).scan(512, 512, 256)
    return convert_wsi_to_dicom(psv, metadata={"slide_id": "X"})


def _svc(sched=None):
    sched = sched or SimScheduler()
    store = ObjectStore(sched)
    return DicomStoreService(store.bucket("dicom"), sched), store, sched


def _snapshot(svc, drop=()):
    """Everything QIDO/WADO serve, for byte-identity comparisons."""
    snap = {}
    for study in svc.search_studies():
        snap[study] = {
            "summary": svc.study_summary(study),
            "series": svc.search_series(study),
            "instances": [
                {**{k: v for k, v in m.items() if k not in drop},
                 "blob": svc.retrieve(m["sop_instance_uid"]),
                 "frame0": svc.retrieve_frame(m["sop_instance_uid"], 0)}
                for m in svc.search_instances(study)],
        }
    return snap


def _instance(study, series, sop, patient="ANON", **kw):
    return write_part10(frames=[b"\x00" * 48], rows=4, cols=4, total_rows=4,
                        total_cols=4, transfer_syntax="1.2.840.10008.1.2.1",
                        study_uid=study, series_uid=series,
                        sop_instance_uid=sop, patient_id=patient, **kw)


# --------------------------------------------------------------------------
# STOW idempotency
# --------------------------------------------------------------------------
def test_restow_is_idempotent_and_byte_identical(archive):
    svc, _, sched = _svc()
    sops = svc.store_study_archive("studies/x", archive)
    sched.run()
    clean = _snapshot(svc)
    assert len(sops) == 2  # two pyramid levels

    again = svc.store_study_archive("studies/x", archive)
    sched.run()
    assert again == sops
    assert _snapshot(svc) == clean
    (study,) = svc.search_studies()
    instances = svc.search_instances(study)
    assert len(instances) == 2  # no duplicate SOP UIDs
    assert svc.metrics.get("dicomstore.replaced") == 2


def test_identical_restow_does_not_republish(archive):
    svc, _, sched = _svc()
    events = []
    Subscription(svc.topic, "probe",
                 lambda m, c: (events.append(m.data["sop_instance_uid"]),
                               c.ack()))
    svc.store_study_archive("studies/x", archive)
    svc.store_study_archive("studies/x", archive)
    sched.run()
    assert sorted(events) == sorted(set(events))  # one event per instance


def test_redelivered_archive_through_real_subscription(archive):
    """At-least-once ingest: the first delivery stores but 'crashes' before
    acking; the redelivery stores again — QIDO must not see duplicates."""
    svc, store, sched = _svc()
    arrivals = Topic("study-arrivals", sched, store.metrics)
    attempts = []

    def ingest(msg, ctx):
        svc.store_study_archive(msg.data["key"], msg.data["archive"])
        attempts.append(ctx.attempt)
        if ctx.attempt >= 2:
            ctx.ack()

    Subscription(arrivals, "store-ingest", ingest, ack_deadline=30.0)
    arrivals.publish({"key": "studies/x", "archive": archive})
    sched.run()

    assert len(attempts) >= 2  # the redelivery actually happened
    (study,) = svc.search_studies()
    sops = [m["sop_instance_uid"] for m in svc.search_instances(study)]
    assert len(sops) == len(set(sops)) == 2


# --------------------------------------------------------------------------
# persistent index: crash + rebuild
# --------------------------------------------------------------------------
def test_crash_rebuild_from_checkpoint_is_byte_identical(archive):
    svc, store, sched = _svc()
    svc.store_study_archive("studies/x", archive)
    clean = _snapshot(svc)

    svc2 = DicomStoreService(store.bucket("dicom"), sched)  # fresh process
    assert svc2.search_studies() == []
    reparsed = svc2.rebuild_index()
    assert reparsed == 0  # checkpoint covered everything
    assert _snapshot(svc2) == clean


def test_crash_rebuild_without_checkpoint_rescans_blobs(archive):
    svc, store, sched = _svc()
    svc.store_study_archive("studies/x", archive)
    clean = _snapshot(svc, drop=("source",))

    bucket = store.bucket("dicom")
    bucket.delete(DicomStoreService.INDEX_KEY)  # checkpoint lost too
    svc2 = DicomStoreService(bucket, sched)
    reparsed = svc2.rebuild_index()
    assert reparsed == 2  # every blob re-indexed from its bytes
    # identical modulo provenance (the source label isn't in the blobs)
    assert _snapshot(svc2, drop=("source",)) == clean


def test_rebuild_drops_stale_checkpoint_entries(archive):
    svc, store, sched = _svc()
    sops = svc.store_study_archive("studies/x", archive)
    svc.delete_instance(sops[0])
    # checkpoint still lists the deleted instance; the blob is gone
    svc2 = DicomStoreService(store.bucket("dicom"), sched)
    svc2.rebuild_index()
    (study,) = svc2.search_studies()
    assert [m["sop_instance_uid"] for m in svc2.search_instances(study)] \
        == sops[1:]


# --------------------------------------------------------------------------
# QIDO: filters match any instance, stable order, aggregation
# --------------------------------------------------------------------------
def test_search_studies_matches_patient_on_any_instance():
    svc, _, _ = _svc()
    svc.store_instance(_instance("1.2.3", "1.2.3.1", "1.2.3.1.1", "ALICE"))
    svc.store_instance(_instance("1.2.3", "1.2.3.2", "1.2.3.2.1", "BOB"))
    svc.store_instance(_instance("1.2.9", "1.2.9.1", "1.2.9.1.1", "CAROL"))
    # the seed judged patient_id from the first stored instance only
    assert svc.search_studies(patient_id="BOB") == ["1.2.3"]
    assert svc.search_studies(patient_id="ALICE") == ["1.2.3"]
    assert svc.search_studies(patient_id="CAROL") == ["1.2.9"]
    assert svc.search_studies(patient_id="NOBODY") == []
    assert svc.search_studies() == ["1.2.3", "1.2.9"]


def test_qido_results_stable_under_arrival_order():
    orders = [(1, 2, 3), (3, 1, 2), (2, 3, 1)]
    snaps = []
    for order in orders:
        svc, _, _ = _svc()
        for i in order:
            svc.store_instance(_instance("1.2.3", f"1.2.3.{(i + 1) // 2}",
                                         f"1.2.3.0.{i}", "ANON",
                                         instance_number=i))
        snaps.append((svc.search_studies(),
                      [m["sop_instance_uid"]
                       for m in svc.search_instances("1.2.3")],
                      svc.search_series("1.2.3")))
    assert snaps[0] == snaps[1] == snaps[2]


def test_qido_filters_and_aggregation(archive):
    svc, _, sched = _svc()
    svc.store_study_archive("studies/x", archive)
    (study,) = svc.search_studies()
    assert svc.search_studies(modality="SM") == [study]
    assert svc.search_studies(modality="CT") == []
    assert svc.search_studies(study_date="20220101") == [study]
    assert svc.search_studies(study_date="19990101") == []
    assert svc.search_studies(modality="SM", patient_id="ANON") == [study]

    summary = svc.study_summary(study)
    assert summary["n_instances"] == 2 and summary["n_series"] == 1
    assert summary["modalities"] == ["SM"]
    assert summary["total_frames"] == sum(
        m["frames"] for m in svc.search_instances(study))
    (series,) = svc.search_series(study)
    assert series["n_instances"] == 2
    assert svc.search_series(study, modality="CT") == []


# --------------------------------------------------------------------------
# WADO: indexed frame retrieval
# --------------------------------------------------------------------------
def test_retrieve_frame_uses_cached_index(archive):
    svc, _, _ = _svc()
    sops = svc.store_study_archive("studies/x", archive)
    idx = Part10Index(svc.retrieve(sops[0]))
    for i in range(idx.n_frames):
        assert svc.retrieve_frame(sops[0], i) == idx.read_frame(i)
    assert svc.metrics.get("dicomstore.wado_index_misses") == 1
    assert svc.metrics.get("dicomstore.wado_index_hits") \
        == idx.n_frames - 1
    with pytest.raises(KeyError):
        svc.retrieve_frame("9.9.9", 0)


# --------------------------------------------------------------------------
# event-driven subscribers
# --------------------------------------------------------------------------
def test_validation_subscriber_quarantines_rotted_instance(archive):
    svc, store, sched = _svc()
    dlq = store.bucket("dicom-dlq")
    validator = ValidationService(svc, dlq)
    sops = svc.store_study_archive("studies/x", archive)
    sched.run()
    assert sorted(validator.checked) == sorted(sops)
    assert validator.quarantined == []

    # bit-rot: destroy the stored blob, then the event is redelivered
    meta = next(m for m in svc.search_instances(svc.search_studies()[0])
                if m["sop_instance_uid"] == sops[0])
    svc.bucket.put(meta["key"], b"\x00" * 200)
    svc.topic.publish(meta)
    sched.run()

    assert [s for s, _ in validator.quarantined] == [sops[0]]
    assert dlq.exists(f"quarantine/{sops[0]}.dcm")
    (study,) = svc.search_studies()
    remaining = [m["sop_instance_uid"] for m in svc.search_instances(study)]
    assert remaining == sops[1:]  # QIDO stops serving it
    with pytest.raises(KeyError):
        svc.retrieve(sops[0])


def test_validation_sweep_catches_rot_without_events(archive):
    svc, store, sched = _svc()
    validator = ValidationService(svc, store.bucket("dicom-dlq"))
    sops = svc.store_study_archive("studies/x", archive)
    sched.run()
    meta = next(m for m in svc.search_instances(svc.search_studies()[0])
                if m["sop_instance_uid"] == sops[1])
    svc.bucket.put(meta["key"], b"not dicom at all")
    assert validator.sweep() == 1
    assert [s for s, _ in validator.quarantined] == [sops[1]]
    assert validator.sweep() == 0  # stable after quarantine


def test_inference_subscriber_scores_decoded_frames_via_wado(archive):
    from repro.wsi import decode_tile

    svc, _, sched = _svc()
    ml = InferenceSubscriber(svc, max_frames=2)
    sops = svc.store_study_archive("studies/x", archive)
    sched.run()
    assert sorted(ml.predictions) == sorted(sops)
    for sop, pred in ml.predictions.items():
        n = next(m["frames"] for s in svc.search_studies()
                 for m in svc.search_instances(s)
                 if m["sop_instance_uid"] == sop)
        assert pred["frames_scored"] == min(n, 2)
        # the subscriber decodes with the batched path (>1 frame pulled);
        # per-tile decode of the same WADO bytes must yield the same stats
        assert pred["pixel_stats"] == [
            InferenceSubscriber.frame_stats(
                decode_tile(svc.retrieve_frame(sop, i)))
            for i in range(pred["frames_scored"])]
        for st in pred["pixel_stats"]:
            assert 0 <= st["min"] <= st["mean"] <= st["max"] <= 255


def test_identity_move_leaves_no_ghost_study():
    """Re-storing a SOP under a new study must fully relocate it — the old
    study disappears from QIDO instead of lingering empty."""
    svc, _, _ = _svc()
    svc.store_instance(_instance("1.2.3", "1.2.3.1", "1.2.3.1.1"))
    svc.store_instance(_instance("1.2.4", "1.2.4.1", "1.2.3.1.1"))
    assert svc.search_studies() == ["1.2.4"]
    for study in svc.search_studies():
        assert svc.study_summary(study)["n_instances"] == 1
    assert len(svc.bucket.list(svc.PREFIX)) == 1  # old blob deleted


def test_corrupt_archive_member_is_rejected(archive):
    svc, _, _ = _svc()
    with pytest.raises(ValueError, match="corrupt Part-10"):
        svc.store_instance(b"\x00" * 200)
