"""Distributed-tracing invariants + latency-histogram accuracy.

The tentpole observability contracts, as tests:

* **Propagation** — one slide's journey through the event spine (publish →
  every delivery attempt incl. retries, hedges, budget-exempt requeues →
  fleet admission → conversion → store) lands as ONE span tree: exactly
  one root per slide, no orphaned parent references, hedge duplicates
  linked to their primary attempt, and the tree survives scripted broker
  faults and a mid-flight instance kill.
* **Determinism** — a tracer clocked by ``SimScheduler`` exports
  bit-identical span lists across identical runs.
* **Cost** — conversion bytes are identical with tracing armed vs
  disarmed (the instrumentation observes, never participates), and the
  disarmed entry points are true no-ops.
* **Histograms** — the log-bucketed percentiles respect the documented
  ~19% bucket-width error bound, and ``Metrics._now()`` keeps real
  timestamps without a scheduler (the PR-10 regression fix).
"""
import hashlib
import json

from repro.core import (ConversionPipeline, DeliveryFaults, Metrics,
                        RealScheduler, SimScheduler, Subscription, Topic,
                        tracing)
from repro.core.dashboard import build_report, trace_problems
from repro.core.metrics import Histogram

ROOT = "topic.wsi-dicom-conversion.publish"


# ------------------------------------------------------- metrics regression
def test_metrics_now_without_scheduler_is_monotonic_not_zero():
    # regression: real-mode Metrics (no scheduler) stamped every sample 0.0
    m = Metrics()
    m.record("fig.t", 1.0)
    m.record("fig.t", 2.0)
    ts = [t for t, _ in m.timeseries("fig.t")]
    assert all(t > 0.0 for t in ts)
    assert ts == sorted(ts)
    m.log("boot")
    assert m.events[0][0] > 0.0


def test_metrics_now_prefers_scheduler_time():
    sched = SimScheduler()
    m = Metrics(sched)
    sched.schedule(7.0, lambda: m.record("fig.t", 1.0))
    sched.run()
    assert m.timeseries("fig.t") == [(7.0, 1.0)]


# ------------------------------------------------------- histogram accuracy
def test_histogram_percentiles_within_bucket_error_bound():
    h = Histogram()
    for v in range(1, 101):
        h.observe(float(v))
    s = h.snapshot()
    assert s["count"] == 100 and s["sum"] == 5050.0
    assert s["min"] == 1.0 and s["max"] == 100.0 and s["mean"] == 50.5
    # log2 buckets of width 0.25 → percentile is the bucket upper bound,
    # at most 2**0.25 (~19%) above the exact order statistic
    assert 50.0 <= s["p50"] <= 50.0 * 2 ** 0.25
    assert 95.0 <= s["p95"] <= 100.0  # clamped into [min, max]
    assert 99.0 <= s["p99"] <= 100.0


def test_histogram_zero_and_negative_values_bucket():
    h = Histogram()
    for v in (-1.0, 0.0, 4.0):  # sim queue waits are often exactly 0.0
        h.observe(v)
    assert h.zeros == 2
    assert h.percentile(0.50) == -1.0  # rank falls in the zeros bucket
    s = h.snapshot()
    assert s["min"] == -1.0 and s["max"] == 4.0 and s["count"] == 3


def test_metrics_observe_feeds_named_histogram():
    m = Metrics()
    for v in (1.0, 2.0, 4.0):
        m.observe("sub.x.latency", v)
    snap = m.histogram("sub.x.latency")
    assert snap["count"] == 3 and snap["sum"] == 7.0
    assert m.histogram("no.such")["count"] == 0
    assert "sub.x.latency" in m.summary()["histograms"]


# ---------------------------------------------------------- arming contract
def test_disarmed_entry_points_are_noops():
    assert tracing.current() is None
    assert tracing.start_span("a.b") is None
    tracing.end_span(None)  # must not raise
    tracing.add_event(None, "a.b")
    with tracing.span("a.b") as sp:
        assert sp is None
    attrs = {"k": "v"}
    tracing.inject(attrs)
    assert attrs == {"k": "v"}  # nothing written
    assert tracing.extract({"trace_id": "t", "span_id": "s"}) is None


def test_arm_twice_raises_and_capture_restores():
    tr = tracing.arm()
    try:
        try:
            tracing.arm()
            raise AssertionError("second arm() must raise")
        except RuntimeError:
            pass
        with tracing.capture() as shadow:
            assert tracing.current() is shadow
            with tracing.span("shadow.op"):
                pass
        assert tracing.current() is tr  # restored
        assert len(shadow.spans) == 1 and not tr.spans
    finally:
        assert tracing.disarm() is tr
    assert tracing.current() is None


# -------------------------------------------------- propagation invariants
def _assert_one_root_per_trace(tracer, n_expected, root_name=ROOT):
    traces = tracer.traces()
    assert len(traces) == n_expected
    for tid, spans in traces.items():
        roots = [sp for sp in spans if sp.parent_id is None]
        assert len(roots) == 1, f"{tid}: {len(roots)} roots"
        assert roots[0].name == root_name
        assert trace_problems(spans) == [], trace_problems(spans)
    return traces


def _scripted_fault_run(seed_spans=False):
    """The scripted drop/duplicate/delay scenario under a traced sim."""
    faults = (DeliveryFaults()
              .drop("s0", attempts=(1,))
              .duplicate("s1", lag=1.0)
              .delay("s2", by=200.0))  # past the 120 s ack deadline
    sched = SimScheduler()
    with tracing.capture(now=sched.now) as tracer:
        pipe = ConversionPipeline(
            sched, service_time=20.0, cold_start=5.0, max_instances=4,
            ack_deadline=120.0, min_backoff=5.0, subscribers=False,
            fleet={}, ordered_ingest=True, delivery_faults=faults)
        for i in range(4):
            pipe.ingest(f"scans/s{i}.psv", bytes([i + 1]) * 8)
        sched.run()
    return pipe, tracer


def _events(tracer, name):
    return [(sp, t, attrs) for sp in tracer.spans
            for t, n, attrs in sp.events if n == name]


def test_fault_gauntlet_one_connected_tree_per_slide():
    pipe, tracer = _scripted_fault_run()
    assert pipe.metrics.get("sub.wsi2dcm-push.acks") == 4
    traces = _assert_one_root_per_trace(tracer, 4)
    # faults are structured span events on the delivery they hit
    for ev in ("fault.drop", "fault.delay", "fault.duplicate"):
        hits = _events(tracer, ev)
        assert len(hits) == 1, f"{ev}: {hits}"
        assert hits[0][0].name == "sub.wsi2dcm-push.deliver"
    # the dropped delivery expired its deadline and retried IN THE SAME
    # trace: its span settles "deadline", the retry is a sibling attempt
    (drop_sp, _, _), = _events(tracer, "fault.drop")
    assert drop_sp.status == "deadline"
    assert any(n == "sub.retry" for _, n, _ in drop_sp.events)
    retried = [sp for sp in traces[drop_sp.trace_id]
               if sp.name == "sub.wsi2dcm-push.deliver"]
    assert len(retried) == 2  # dropped attempt + the redelivery
    assert {sp.parent_id for sp in retried} == {retried[0].parent_id}
    # the duplicated delivery deduped at fleet admission, visibly
    assert _events(tracer, "fleet.duplicate")


def test_trace_export_is_deterministic_across_runs():
    def normalized(tracer):
        # message/request ids come from process-global counters; the
        # determinism contract covers span ids, structure, and timings
        out = tracer.export()
        for sp in out:
            sp["attrs"].pop("message_id", None)
            sp["attrs"].pop("req_id", None)
            for ev in sp["events"]:
                ev["attrs"].pop("req_id", None)
        return out

    _, t1 = _scripted_fault_run()
    _, t2 = _scripted_fault_run()
    assert normalized(t1) == normalized(t2)


def test_hedge_span_links_primary_delivery():
    deliveries = []

    def ep(m, c):
        deliveries.append(c)
        if len(deliveries) == 1:
            return  # original hangs; the hedged duplicate wins
        c.ack()

    sched = SimScheduler()
    with tracing.capture(now=sched.now) as tracer:
        topic = Topic("t", sched)
        sub = Subscription(topic, "s", ep, hedge_after=20.0,
                           ack_deadline=1000.0, min_backoff=5.0)
        topic.publish({"i": 0})
        sched.run()
    assert sub.metrics.get("sub.s.hedge_acks") == 1
    (pub,) = tracer.spans_named("topic.t.publish")
    (orig,) = tracer.spans_named("sub.s.deliver")
    (hedge,) = tracer.spans_named("sub.s.hedge")
    # both race legs parent on the publish span, in one trace, and the
    # duplicate carries the hedge_of link back to the primary attempt
    assert orig.parent_id == pub.span_id
    assert hedge.parent_id == pub.span_id
    assert hedge.trace_id == orig.trace_id == pub.trace_id
    assert hedge.attrs["hedge_of"] == orig.span_id
    assert hedge.status == "acked" and orig.status == "acked"


def test_backpressure_requeues_stay_in_their_trace():
    sched = SimScheduler()
    n = 10
    with tracing.capture(now=sched.now) as tracer:
        pipe = ConversionPipeline(
            sched, service_time=30.0, cold_start=5.0, max_instances=2,
            min_backoff=5.0, max_delivery_attempts=3, subscribers=False,
            fleet=dict(shed_backlog=3), ordered_ingest=True)
        for i in range(n):
            pipe.ingest(f"burst/s{i:02d}.psv", bytes([i + 1]) * 8)
        sched.run()
    assert pipe.metrics.get("svc.wsi2dcm.shed") > 0
    traces = _assert_one_root_per_trace(tracer, n)
    shed = [sp for sp in tracer.spans if sp.status == "requeued"]
    assert shed, "overload never produced a requeued delivery span"
    for sp in shed:
        assert sp.name == "sub.wsi2dcm-push.deliver"
        assert any(n_ == "sub.requeue" for _, n_, _ in sp.events)
        # the budget-exempt redelivery landed in the SAME trace and
        # eventually acked — shed work is visible, never lost
        attempts = [s for s in traces[sp.trace_id]
                    if s.name == "sub.wsi2dcm-push.deliver"]
        assert len(attempts) >= 2
        assert attempts[-1].status == "acked"


def test_kill_mid_conversion_keeps_one_tree():
    sched = SimScheduler()
    with tracing.capture(now=sched.now) as tracer:
        pipe = ConversionPipeline(
            sched, service_time=50.0, cold_start=5.0, max_instances=1,
            min_backoff=5.0, subscribers=False, fleet={},
            ordered_ingest=True)
        pipe.ingest("scans/a.psv", b"aaaa")
        sched.schedule(20.0, pipe.service.kill_instance)  # mid-conversion
        sched.run()
    assert pipe.metrics.get("svc.wsi2dcm.killed") == 1
    traces = _assert_one_root_per_trace(tracer, 1)
    (spans,) = traces.values()
    handles = [sp for sp in spans if sp.name == "svc.wsi2dcm.handle"]
    # the serve attempt died with the instance; the requeued run finished.
    # Both live under ONE request span that records the kill_requeue hop
    assert sorted(sp.status for sp in handles) == ["killed", "ok"]
    (req,) = (sp for sp in spans if sp.name == "svc.wsi2dcm.request")
    assert req.status == "ok"
    assert any(n == "fleet.kill_requeue" for _, n, _ in req.events)
    assert {sp.parent_id for sp in handles} == {req.span_id}


# ------------------------------------------------- real-pipeline acceptance
def _pinned_convert(data, meta):
    from repro.wsi.convert import ConvertOptions, convert_wsi_to_dicom
    h = hashlib.sha256(meta["slide_id"].encode()).hexdigest()
    uids = ["2.25." + str(int(h[:24], 16)), "2.25." + str(int(h[24:48], 16))]
    return convert_wsi_to_dicom(
        data, meta, options=ConvertOptions(manifest={"uids": json.dumps(uids)}))


def test_real_single_slide_lands_as_one_span_tree():
    """ISSUE-10 acceptance: a single-slide real run (real scheduler, real
    converter, store + validation/inference subscribers + auto-export) is
    one connected trace covering every hop, and the dashboard's critical
    path accounts for its wall time within 5%."""
    from repro.wsi import SyntheticScanner

    scanner = SyntheticScanner(seed=3)
    slides = {"scans/acc.psv": scanner.scan(256, 256, 256)}
    meta = {"scans/acc.psv": {"slide_id": "scans/acc.psv"}}
    sched = RealScheduler(workers=4)
    try:
        with tracing.capture(now=sched.now) as tracer:
            pipe = ConversionPipeline(
                sched, convert=_pinned_convert, cold_start=0.0,
                max_instances=2, fleet={}, ordered_ingest=True,
                store_shards=2, auto_export=True)
            pipe.run_batch(slides, meta, timeout=180.0)
            sched.run(until=60.0)  # drain store ingest + fan-out + export
    finally:
        sched.shutdown()
    traces = _assert_one_root_per_trace(tracer, 1)
    ((tid, spans),) = traces.items()
    names = {sp.name for sp in spans}
    for hop in (ROOT, "sub.wsi2dcm-push.deliver", "svc.wsi2dcm.request",
                "svc.wsi2dcm.handle", "pipeline.fetch", "pipeline.convert",
                "pipeline.store", "convert.slide", "convert.entropy",
                "stow.archive", "export.study"):
        assert hop in names, f"missing hop {hop}: {sorted(names)}"
    events = {n for sp in spans for _, n, _ in sp.events}
    assert {"stow.instance", "validate.instance",
            "inference.instance"} <= events
    # critical-path attribution: queue + compute + store sums to the
    # trace's wall-clock window within the acceptance tolerance
    report = build_report(pipe.metrics, tracer, title="acceptance")
    (t,) = [x for x in report["traces"] if x["trace_id"] == tid]
    assert t["slide"] == "scans/acc.psv" and not t["problems"]
    covered = sum(t["attribution"].values())
    assert abs(covered - t["duration"]) <= 0.05 * max(t["duration"], 1e-9)
    assert t["attribution"]["compute"] > 0.0
    # the histogram migration: delivery latency lands in a bounded
    # histogram, not an unbounded series
    assert report["histograms"]["sub.wsi2dcm-push.latency"]["count"] >= 1


def test_conversion_bytes_identical_armed_vs_disarmed():
    from repro.wsi import SyntheticScanner

    psv = SyntheticScanner(seed=5).scan(256, 256, 256)
    meta = {"slide_id": "scans/id.psv"}
    assert tracing.current() is None
    plain = _pinned_convert(psv, meta)
    with tracing.capture() as tracer:
        traced = _pinned_convert(psv, meta)
    assert tracer.spans_named("convert.slide"), "tracer saw no conversion"
    assert traced == plain, "tracing changed the produced DICOM bytes"
