"""Sharding policy + HLO roofline analysis (single- and multi-device)."""
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import numpy as np
import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


# --------------------------------------------------------------------------
# spec_for policy (pure logic — fake mesh via a stub)
# --------------------------------------------------------------------------
class _FakeMesh:
    def __init__(self, sizes):
        self.axis_names = tuple(sizes)
        import numpy as _np
        self.devices = _np.empty(tuple(sizes.values()))


def _spec(shape, logical, sizes):
    from repro import sharding as shd
    return tuple(shd.spec_for(shape, logical, _FakeMesh(sizes)))


def test_batch_claims_pod_and_data():
    assert _spec((256, 4096), ("batch", "seq"),
                 {"pod": 2, "data": 16, "model": 16}) \
        == (("pod", "data"), "model")


def test_heads_fallback_when_indivisible():
    # gemma: 8 q heads on a 16-way model axis → seq takes the model axis
    spec = _spec((32, 4096, 8, 256), ("batch", "seq", "heads", "head_dim"),
                 {"data": 16, "model": 16})
    assert spec == ("data", "model")  # batch→data, seq→model, heads/dim open


def test_indivisible_batch_stays_replicated():
    spec = _spec((2, 4096, 8, 256), ("batch", "seq", "heads", "head_dim"),
                 {"data": 16, "model": 16})
    assert spec == (None, "model")


def test_heads_claim_model_when_divisible():
    spec = _spec((32, 4096, 32, 128), ("batch", "seq", "heads", "head_dim"),
                 {"data": 16, "model": 16})
    assert spec[2] == "model"


def test_weights_get_2d_fsdp_tp():
    spec = _spec((4096, 16384), ("embed", "mlp"), {"data": 16, "model": 16})
    assert spec == ("data", "model")


def test_each_mesh_axis_claimed_once():
    spec = _spec((4096, 4096), ("embed", "embed"), {"data": 16, "model": 16})
    assert tuple(spec) in ((("data",), ()), ("data",), ("data", None))


def test_constrain_rank_mismatch_raises():
    from repro import sharding as shd
    from repro.launch.mesh import make_local_mesh
    with shd.set_mesh(make_local_mesh()):
        with pytest.raises(ValueError):
            shd.constrain(np.zeros((2, 2)), "batch")


# --------------------------------------------------------------------------
# HLO analysis
# --------------------------------------------------------------------------
def test_shape_bytes_parsing():
    from repro.roofline import shape_bytes
    assert shape_bytes("bf16[16,128]{1,0}") == 16 * 128 * 2
    assert shape_bytes("(f32[8,8]{1,0}, s32[4]{0})") == 8 * 8 * 4 + 16
    assert shape_bytes("pred[]") == 1


def test_dot_flops_counted_loop_aware():
    """A scanned matmul must count trip × per-iteration flops."""
    import jax.numpy as jnp
    from repro.roofline import analyze_hlo

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=12)
        return out

    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    hlo = jax.jit(f).lower(x, w).compile().as_text()
    r = analyze_hlo(hlo)
    expect = 2 * 32 * 64 * 64 * 12
    assert r["flops"] >= expect * 0.99, (r["flops"], expect)
    assert r["flops"] <= expect * 1.5
    assert any(t == 12 for _, t in r["loops"])


def test_collectives_counted_in_multidevice_subprocess():
    """Spawn a fresh interpreter with 8 fake devices; verify all-reduce bytes."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        import sys
        sys.path.insert(0, %r)
        from repro.roofline import analyze_hlo
        from repro.launch.mesh import _axis_types_kwargs
        mesh = jax.make_mesh((8,), ("data",), **_axis_types_kwargs(1))
        xs = jax.ShapeDtypeStruct((64, 256), jnp.float32)
        ws = jax.ShapeDtypeStruct((256, 128), jnp.float32)
        f = lambda x, w: jnp.sum(x @ w)
        c = jax.jit(f, in_shardings=(NamedSharding(mesh, P("data", None)),
                                     NamedSharding(mesh, P(None, None)))
                    ).lower(xs, ws).compile()
        r = analyze_hlo(c.as_text())
        assert r["collective_bytes"] > 0, r
        assert "all-reduce" in r["by_kind"], r
        print("COLLECTIVES-OK", r["by_kind"])
    """) % SRC
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, timeout=300)
    assert "COLLECTIVES-OK" in out.stdout, out.stderr[-2000:]


def test_compressed_psum_multidevice_subprocess():
    """int8 psum under shard_map across 8 fake devices ≈ exact psum."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        import sys
        sys.path.insert(0, %r)
        from repro.comms.compress import compressed_psum
        from repro.launch.mesh import _axis_types_kwargs
        mesh = jax.make_mesh((8,), ("data",), **_axis_types_kwargs(1))
        x = jnp.asarray(np.random.default_rng(0).normal(0, 1, (8, 128)),
                        jnp.float32)
        from jax.experimental.shard_map import shard_map
        f = shard_map(lambda v: compressed_psum(v[0], "data"),
                      mesh=mesh, in_specs=P("data", None), out_specs=P())
        approx = f(x)
        exact = x.sum(0)
        err = float(jnp.abs(approx - exact).max())
        scale = float(jnp.abs(exact).max())
        assert err < 0.1 * scale + 0.2, (err, scale)
        print("PSUM-OK", err)
    """) % SRC
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, timeout=300)
    assert "PSUM-OK" in out.stdout, out.stderr[-2000:]


def test_derive_terms_dominance():
    from repro.roofline import derive_terms
    r = derive_terms(flops_per_device=197e12, bytes_per_device=1e9,
                     collective_bytes_per_device=0, chips=256,
                     model_flops_total=197e12 * 256 * 0.5)
    assert r["dominant"] == "compute_s"
    assert abs(r["mfu_bound"] - 0.5) < 1e-6
    r2 = derive_terms(flops_per_device=1e9, bytes_per_device=819e9,
                      collective_bytes_per_device=0, chips=256,
                      model_flops_total=1e9)
    assert r2["dominant"] == "memory_s"
