"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.kernels import dct8x8_quant, downsample2x2, idct8x8_dequant, rgb2ycbcr
from repro.kernels import ref

RNG = np.random.default_rng(42)


@pytest.mark.parametrize("h,w", [(8, 128), (16, 256), (64, 384), (256, 256)])
@pytest.mark.parametrize("dtype", [np.uint8, np.float32])
def test_rgb2ycbcr_matches_ref(h, w, dtype):
    img = jnp.asarray(RNG.integers(0, 256, size=(3, h, w)).astype(dtype))
    out = rgb2ycbcr(img, impl="pallas")
    expect = ref.rgb2ycbcr_ref(img)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-3, rtol=1e-5)
    assert out.dtype == jnp.float32


@pytest.mark.parametrize("c,h,w", [(3, 16, 256), (1, 32, 512), (4, 64, 256)])
def test_downsample_matches_ref(c, h, w):
    img = jnp.asarray(RNG.normal(0, 50, size=(c, h, w)).astype(np.float32))
    out = downsample2x2(img, impl="pallas")
    expect = ref.downsample2x2_ref(img)
    assert out.shape == (c, h // 2, w // 2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-4, rtol=1e-5)


@pytest.mark.parametrize("h,w", [(8, 128), (64, 256), (256, 384)])
@pytest.mark.parametrize("table", ["luma", "chroma"])
def test_dct_quant_matches_ref(h, w, table):
    q = jnp.asarray(ref.JPEG_LUMA_Q if table == "luma" else ref.JPEG_CHROMA_Q)
    plane = jnp.asarray(RNG.normal(0, 40, size=(h, w)).astype(np.float32))
    out = dct8x8_quant(plane, q, impl="pallas")
    expect = ref.dct8x8_quant_ref(plane, q)
    assert out.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


def test_unaligned_shapes_fall_back_to_ref():
    img = jnp.asarray(RNG.integers(0, 255, size=(3, 20, 100)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(rgb2ycbcr(img)), np.asarray(ref.rgb2ycbcr_ref(img)),
        atol=1e-3,
    )
    np.testing.assert_allclose(
        np.asarray(downsample2x2(img)),
        np.asarray(ref.downsample2x2_ref(img)), atol=1e-4,
    )


@settings(max_examples=20, deadline=None)
@given(
    bh=st.integers(1, 4), bw=st.integers(1, 3),
    scale=st.floats(1.0, 200.0),
)
def test_dct_idct_roundtrip_error_bounded(bh, bw, scale):
    """Property: quantize→dequantize error is bounded by the quant step."""
    h, w = 8 * bh, 128 * bw
    plane = jnp.asarray(
        np.random.default_rng(bh * 7 + bw).normal(0, scale, size=(h, w))
        .astype(np.float32)
    )
    q = jnp.asarray(ref.JPEG_LUMA_Q)
    coef = dct8x8_quant(plane, q)
    rec = idct8x8_dequant(coef, q)
    # max reconstruction error per coefficient is q/2; after orthonormal IDCT
    # the per-pixel error is bounded by ||q||/2 (loose bound: max q × 4)
    err = float(jnp.max(jnp.abs(rec - plane)))
    assert err <= float(jnp.max(q)) * 4.0


def test_dct_energy_preservation():
    """Orthonormal DCT preserves energy (Parseval) before quantization."""
    plane = jnp.asarray(RNG.normal(0, 30, size=(32, 128)).astype(np.float32))
    ones = jnp.ones((8, 8), jnp.float32)  # quant table of 1s ≈ pure DCT
    coef = dct8x8_quant(plane, ones).astype(jnp.float32)
    e_sp = float(jnp.sum(plane**2))
    e_fr = float(jnp.sum(coef**2))
    assert abs(e_sp - e_fr) / e_sp < 0.01  # rounding-only deviation


# --------------------------------------------------------------------------
# fused RWKV6 wkv chunk kernel
# --------------------------------------------------------------------------
@pytest.mark.parametrize("S,chunk,sub", [(64, 32, 8), (128, 64, 16),
                                         (256, 64, 16)])
@pytest.mark.parametrize("decay_max", [2.0, 25.0])
def test_wkv_chunk_kernel_matches_sequential(S, chunk, sub, decay_max):
    from repro.kernels.wkv_chunk import wkv_chunk_pallas
    from repro.models.rwkv6 import wkv_sequential

    rng = np.random.default_rng(S + int(decay_max))
    B, H, K = 2, 2, 64
    r, k, v = (jnp.asarray(rng.normal(size=(B, S, H, K)), jnp.float32)
               for _ in range(3))
    logw = -jnp.asarray(rng.uniform(0.005, decay_max, (B, S, H, K)),
                        jnp.float32)
    u = jnp.asarray(rng.normal(size=(H, K)), jnp.float32)
    ref, _ = wkv_sequential(r, k, v, logw, u,
                            jnp.zeros((B, H, K, K), jnp.float32))
    out = wkv_chunk_pallas(r, k, v, logw, u, chunk=chunk, sub=sub)
    scale = float(jnp.abs(ref).max()) + 1.0
    assert float(jnp.abs(ref - out).max()) / scale < 5e-4
    assert not bool(jnp.isnan(out).any())
