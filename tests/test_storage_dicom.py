"""Object store semantics + DICOM Part-10 round trips + JPEG codec."""
import numpy as np
import pytest

from repro.core import LifecycleRule, Metrics, SimScheduler, Subscription, Topic
from repro.core.storage import ObjectStore
from repro.wsi import (PSVReader, SyntheticScanner, decode_tile, encode_tile,
                       psnr, read_part10, write_part10)
from repro.wsi.dicom import TS_EXPLICIT_LE, TS_JPEG_BASELINE


# --------------------------------------------------------------------------
# storage
# --------------------------------------------------------------------------
def test_put_emits_creation_notification():
    sched = SimScheduler()
    store = ObjectStore(sched)
    bucket = store.bucket("landing")
    topic = Topic("t", sched, store.metrics)
    got = []
    Subscription(topic, "s", lambda m, c: (got.append(m.data), c.ack()))
    bucket.add_notification(topic)
    bucket.put("slides/a.psv", b"hello", {"slide_id": "A"})
    sched.run()
    assert len(got) == 1
    evt = got[0]
    assert evt["bucket"] == "landing" and evt["name"] == "slides/a.psv"
    assert evt["eventType"] == "OBJECT_FINALIZE"
    assert evt["metadata"]["slide_id"] == "A"


def test_identical_content_write_is_idempotent():
    sched = SimScheduler()
    store = ObjectStore(sched)
    bucket = store.bucket("b")
    topic = Topic("t", sched, store.metrics)
    got = []
    Subscription(topic, "s", lambda m, c: (got.append(1), c.ack()))
    bucket.add_notification(topic)
    bucket.put("x", b"same")
    bucket.put("x", b"same")  # retried/hedged conversion output
    bucket.put("x", b"different")
    sched.run()
    assert len(got) == 2  # second identical write did not re-notify
    assert store.metrics.get("bucket.b.idempotent_skips") == 1


def test_lifecycle_tiers_by_age():
    sched = SimScheduler()
    store = ObjectStore(sched)
    b = store.bucket("b")
    b.add_lifecycle_rule(LifecycleRule(100.0, "COLDLINE"))
    b.add_lifecycle_rule(LifecycleRule(1000.0, "ARCHIVE"))
    b.put("old", b"1")
    sched.run(until=150.0)
    b.put("new", b"2")
    b.apply_lifecycle()
    assert b.get("old").storage_class == "COLDLINE"
    assert b.get("new").storage_class == "STANDARD"
    sched.run(until=2000.0)
    b.apply_lifecycle()
    assert b.get("old").storage_class == "ARCHIVE"


# --------------------------------------------------------------------------
# DICOM
# --------------------------------------------------------------------------
def _frames(n, size=64):
    rng = np.random.default_rng(0)
    return [rng.integers(0, 255, size=(size, size, 3), dtype=np.uint8)
            for _ in range(n)]


def test_part10_native_roundtrip():
    frames = [f.tobytes() for f in _frames(4)]
    blob = write_part10(frames=frames, rows=64, cols=64, total_rows=128,
                        total_cols=128, transfer_syntax=TS_EXPLICIT_LE)
    assert blob[128:132] == b"DICM"
    ds, out = read_part10(blob)
    assert ds.get_str(0x0008, 0x0016) == "1.2.840.10008.5.1.4.1.1.77.1.6"
    assert ds.get_str(0x0002, 0x0010) == TS_EXPLICIT_LE
    assert ds.get_int(0x0028, 0x0008) == 4
    assert ds.get_int(0x0048, 0x0007) == 128
    assert ds.get_str(0x0020, 0x9311) == "TILED_FULL"
    assert len(out) == 4 and out[0] == frames[0]


def test_part10_encapsulated_jpeg_roundtrip():
    # realistic (compressible) tissue tiles — JPEG on white noise is ~17 dB
    rd = PSVReader(SyntheticScanner(seed=4).scan(512, 256, 256))
    tiles = [rd.read_tile(0, 0)[:64, :64], rd.read_tile(1, 0)[:64, :64]]
    jpgs = [encode_tile(t) for t in tiles]
    blob = write_part10(frames=jpgs, rows=64, cols=64, total_rows=64,
                        total_cols=128, transfer_syntax=TS_JPEG_BASELINE)
    ds, out = read_part10(blob)
    assert ds.get_str(0x0002, 0x0010) == TS_JPEG_BASELINE
    assert len(out) == 2
    for orig, frag in zip(tiles, out):
        rec = decode_tile(frag.rstrip(b"\x00") if frag[-1:] == b"\x00"
                          and frag[-2:-1] != b"\xd9" else frag)
        assert psnr(orig, rec) > 25.0


def test_jpeg_psnr_and_compression_on_realistic_tissue():
    psv = SyntheticScanner(seed=9).scan(256, 256, 256)
    tile = PSVReader(psv).read_tile(0, 0)
    jpg = encode_tile(tile)
    rec = decode_tile(jpg)
    assert psnr(tile, rec) > 30.0
    assert len(jpg) < 0.25 * tile.nbytes  # ≥4× compression on tissue


def test_jpeg_gray_and_extreme_tiles():
    for fill in (0, 127, 255):
        tile = np.full((64, 64, 3), fill, np.uint8)
        rec = decode_tile(encode_tile(tile))
        assert psnr(tile, rec) > 40.0


def test_part10_native_odd_length_padded_pixeldata_roundtrip():
    """27-byte RGB frames (3×3) make an odd PixelData blob → even-padded."""
    rng = np.random.default_rng(7)
    frames = [rng.integers(0, 255, size=(3, 3, 3), dtype=np.uint8).tobytes()
              for _ in range(3)]
    assert len(b"".join(frames)) % 2 == 1
    blob = write_part10(frames=frames, rows=3, cols=3, total_rows=9,
                        total_cols=3, transfer_syntax=TS_EXPLICIT_LE)
    assert len(blob) % 2 == 0
    ds, out = read_part10(blob)
    assert ds.get_str(0x0002, 0x0010) == TS_EXPLICIT_LE
    assert len(out) == 3
    assert [bytes(f) for f in out] == frames  # pad byte stays outside frames


# --------------------------------------------------------------------------
# corrupt Part-10 input is rejected with a clear error
# --------------------------------------------------------------------------
def _valid_blob(ts=TS_EXPLICIT_LE):
    frames = [f.tobytes() for f in _frames(2)]
    return write_part10(frames=frames, rows=64, cols=64, total_rows=128,
                        total_cols=64, transfer_syntax=ts)


@pytest.mark.parametrize("mangle", [
    lambda b: b"",                          # empty input
    lambda b: b[:100],                      # shorter than the preamble
    lambda b: b[:128] + b"DICX" + b[132:],  # wrong magic
    lambda b: b[: len(b) // 2],             # truncated mid-dataset
    lambda b: b[:-40],                      # truncated inside pixel data
])
def test_read_part10_rejects_corrupt_native(mangle):
    blob = mangle(_valid_blob())
    with pytest.raises(ValueError, match="corrupt Part-10"):
        read_part10(blob)


def test_read_part10_rejects_corrupt_vr_bytes():
    blob = bytearray(_valid_blob())
    # overwrite the first element's VR (2 bytes after its tag) with garbage
    blob[132 + 4 : 132 + 6] = b"\xff\xfe"
    with pytest.raises(ValueError, match="corrupt Part-10"):
        read_part10(bytes(blob))


def test_read_part10_rejects_truncated_encapsulated_stream():
    rd = PSVReader(SyntheticScanner(seed=4).scan(256, 256, 256))
    jpg = encode_tile(rd.read_tile(0, 0)[:64, :64])
    blob = write_part10(frames=[jpg], rows=64, cols=64, total_rows=64,
                        total_cols=64, transfer_syntax=TS_JPEG_BASELINE)
    with pytest.raises(ValueError, match="corrupt Part-10"):
        read_part10(blob[:-16])  # sequence-delimiter item cut off
