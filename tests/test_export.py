"""Export subsystem: event-driven DICOM → tiled-TIFF retrieval — QIDO/WADO
reads, deterministic TIFF output (repeat + crash-rebuild byte identity),
actionable DLQ reasons for corrupt frames, auto-export fan-out, and the
full-circle re-ingestion of an exported TIFF."""
import numpy as np
import pytest

from repro.core import ConversionPipeline, RealScheduler, SimScheduler
from repro.core.storage import ObjectStore
from repro.wsi import (ConvertOptions, DicomStoreService, ExportService,
                       SyntheticScanner, convert_wsi_to_dicom, decode_tile,
                       open_slide, study_levels, write_part10)
from repro.wsi.dicom import TS_JPEG_BASELINE
from repro.wsi.formats import TiffSlideReader


def _stored_study(hw=512, seed=3, **convert_kw):
    psv = SyntheticScanner(seed=seed).scan(hw, hw, 256)
    archive = convert_wsi_to_dicom(psv, {"slide_id": "exp"},
                                   options=ConvertOptions(**convert_kw))
    sched = SimScheduler()
    store = ObjectStore(sched)
    svc = DicomStoreService(store.bucket("dicom"), sched)
    svc.store_study_archive("studies/exp.tar", archive)
    (study,) = svc.search_studies()
    return psv, svc, store, study


def _derived_bytes(derived):
    return {k: derived.get(k).data for k in derived.list()}


# --------------------------------------------------------------------------
# the export itself
# --------------------------------------------------------------------------
def test_export_study_writes_reopenable_level_tiffs():
    _, svc, store, study = _stored_study()
    exporter = ExportService(svc, store.bucket("derived"))
    keys = exporter.export_study(study)
    assert keys == [f"{study}/level_0.tiff", f"{study}/level_1.tiff"]
    for li, key in enumerate(keys):
        rd = open_slide(store.bucket("derived").get(key).data)
        assert isinstance(rd, TiffSlideReader)
        assert (rd.H, rd.W, rd.tile) == (512 >> li, 512 >> li, 256)
        # provenance rides in the Aperio-style ImageDescription
        assert rd.metadata["vendor"] == "repro-dicom2tiff"
        assert rd.metadata["study"] == study
        assert rd.metadata["level"] == str(li)
    assert exporter.exported == [(study, tuple(keys))]


def test_exported_pixels_match_wado_frame_decode():
    """The TIFF tiles are exactly the decoded WADO frames, row-major."""
    _, svc, store, study = _stored_study()
    exporter = ExportService(svc, store.bucket("derived"))
    (key0, _) = exporter.export_study(study)
    rd = open_slide(store.bucket("derived").get(key0).data)
    meta = svc.search_instances(study)[0]
    sop = meta["sop_instance_uid"]
    bh, bw = rd.grid
    for r in range(bh):
        for c in range(bw):
            frame = svc.retrieve_frame(sop, r * bw + c)
            np.testing.assert_array_equal(rd.read_tile(r, c),
                                          decode_tile(frame))


def test_native_study_exports_lossless_pixels():
    """jpeg=False studies export through the native path — the TIFF pixels
    equal the original scan exactly (no transform loss anywhere)."""
    psv, svc, store, study = _stored_study(jpeg=False)
    exporter = ExportService(svc, store.bucket("derived"))
    keys = exporter.export_study(study)
    rd = open_slide(store.bucket("derived").get(keys[0]).data)
    src = open_slide(psv)
    for (rc, tile) in src.tiles():
        np.testing.assert_array_equal(rd.read_tile(*rc), tile)


def test_repeated_and_post_rebuild_exports_are_byte_identical():
    _, svc, store, study = _stored_study()
    exporter = ExportService(svc, store.bucket("derived"))
    exporter.export_study(study)
    clean = _derived_bytes(exporter.derived)

    # full re-derivation forced: the decode + write_tiff pipeline itself
    # must be deterministic (content-addressed no-op, no re-notify)
    exporter.export_study(study, skip_unchanged=False)
    assert _derived_bytes(exporter.derived) == clean
    assert store.metrics.get("bucket.derived.idempotent_skips") >= 2

    # default path short-circuits on the recorded content generation —
    # no WADO fetch, no decode (frames_decoded unchanged)
    before = svc.metrics.get("pipeline.export.frames_decoded")
    keys = exporter.export_study(study)
    assert svc.metrics.get("pipeline.export.levels_unchanged") == 2
    assert svc.metrics.get("pipeline.export.frames_decoded") == before
    assert keys == sorted(clean)  # skipped levels still report their keys

    # simulated crash: fresh service over the same bucket + rebuilt index
    svc2 = DicomStoreService(store.bucket("dicom"), svc.scheduler)
    svc2.rebuild_index()
    exporter2 = ExportService(svc2, store.bucket("derived2"))
    exporter2.export_study(study)
    assert _derived_bytes(exporter2.derived) == {
        k: v for k, v in clean.items()}


def test_sub_tile_levels_are_skipped_not_fatal():
    """A level smaller than one tile stores zero frames — export skips it
    (there are no pixels) and records the skip."""
    _, svc, store, study = _stored_study(min_level_size=128)
    exporter = ExportService(svc, store.bucket("derived"))
    keys = exporter.export_study(study)
    assert [k.rsplit("/", 1)[1] for k in keys] == \
        ["level_0.tiff", "level_1.tiff"]  # level_2 (128² < tile) skipped
    assert svc.metrics.get("pipeline.export.levels_skipped") == 1


def test_unknown_study_raises_key_error():
    _, svc, store, _ = _stored_study()
    exporter = ExportService(svc, store.bucket("derived"))
    with pytest.raises(KeyError, match="unknown study"):
        exporter.export_study("2.25.404")


# --------------------------------------------------------------------------
# the event-driven hop (pipeline wiring)
# --------------------------------------------------------------------------
def test_request_export_through_pipeline_topic():
    sched = SimScheduler()
    pipe = ConversionPipeline(sched)
    archive = convert_wsi_to_dicom(
        SyntheticScanner(seed=4).scan(512, 512, 256), {"slide_id": "s"})
    pipe.dicom.put("studies/s.dcm", archive)  # → store-ingest hop
    sched.run()
    (study,) = pipe.store_service.search_studies()
    assert pipe.derived.list() == []  # no auto-export by default

    pipe.request_export(study)
    sched.run()
    assert pipe.derived.list() == [f"{study}/level_0.tiff",
                                   f"{study}/level_1.tiff"]
    g = pipe.metrics.get
    assert g("pipeline.export.requests") == 1
    assert g("pipeline.export.frames_decoded") == 5  # 4 + 1 frames
    assert g("pipeline.export.bytes_written") > 0
    assert g("topic.export-request.published") == 1


def test_auto_export_triggers_on_instance_stored():
    sched = SimScheduler()
    pipe = ConversionPipeline(sched, auto_export=True)
    archive = convert_wsi_to_dicom(
        SyntheticScanner(seed=6).scan(512, 512, 256), {"slide_id": "s"})
    pipe.dicom.put("studies/s.dcm", archive)
    sched.run()
    (study,) = pipe.store_service.search_studies()
    # every stored instance republished the request; the repeats skip on
    # the recorded content generation instead of re-decoding every level
    assert pipe.derived.list() == [f"{study}/level_0.tiff",
                                   f"{study}/level_1.tiff"]
    assert pipe.metrics.get("pipeline.export.requests") == 2
    assert pipe.metrics.get("pipeline.export.frames_decoded") == 5
    assert pipe.metrics.get("pipeline.export.levels_unchanged") == 2


def test_corrupt_frame_dead_letters_with_actionable_reason():
    """A stored instance whose frame bytes rot into undecodable JPEG must
    exhaust export retries and land in the export DLQ carrying the
    decoder's corrupt-JPEG reason."""
    sched = SimScheduler()
    pipe = ConversionPipeline(sched, max_delivery_attempts=2,
                              min_backoff=0.1, max_backoff=0.1,
                              subscribers=False)
    # SOI marker present (so the deep-verify path keeps it) but garbage after
    bad = b"\xff\xd8" + b"\x99" * 40
    blob = write_part10(frames=[bad], rows=8, cols=8, total_rows=8,
                        total_cols=8, transfer_syntax=TS_JPEG_BASELINE,
                        study_uid="1.2.9", series_uid="1.2.9.1",
                        sop_instance_uid="1.2.9.1.1")
    pipe.store_service.store_instance(blob)
    pipe.request_export("1.2.9")
    sched.run()
    assert pipe.derived.list() == []
    assert pipe.metrics.get("pipeline.export.dead_lettered") == 1
    ((event, reason),) = pipe.export_dead_lettered
    assert event == {"study_uid": "1.2.9"}
    assert "corrupt JPEG" in reason


# --------------------------------------------------------------------------
# full circle: scan → convert → store → export → re-ingest
# --------------------------------------------------------------------------
def test_full_circle_export_reingests_through_sniffing_pipeline():
    sched = RealScheduler(workers=4)
    pipe = ConversionPipeline(
        sched, convert=lambda data, meta: convert_wsi_to_dicom(data, meta),
        max_instances=2, cold_start=0.0, scale_down_delay=2.0,
    )
    psv = SyntheticScanner(seed=11).scan(512, 512, 256)
    pipe.run_batch({"slides/circle.psv": psv}, timeout=240.0)
    sched.run(until=30.0)  # store ingest + subscriber fan-out
    (study,) = pipe.store_service.search_studies()
    pipe.request_export(study)
    sched.run(until=30.0)
    keys = pipe.derived.list()
    assert keys == [f"{study}/level_0.tiff", f"{study}/level_1.tiff"]

    # the exported level-0 TIFF goes back through the same sniffing
    # pipeline as any scanner upload and lands as a new study
    tif = pipe.derived.get(keys[0]).data
    out = pipe.run_batch({"slides/rescan.tiff": tif}, timeout=240.0)
    assert pipe.metrics.get("pipeline.format.tiff") >= 1
    levels = study_levels(out["slides/rescan.tiff"])
    assert sorted(k for k in levels if k.endswith(".dcm")) == \
        ["level_0.dcm", "level_1.dcm"]
    sched.run(until=30.0)
    assert len(pipe.store_service.search_studies()) == 2
    assert pipe.validator.quarantined == []
    sched.shutdown()
