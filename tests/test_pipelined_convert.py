"""Pipelined conversion engine: byte-identity A/B vs the sync batched path,
manifest-resume determinism, and real-mode multi-slide concurrency."""
import json

import pytest

from repro.core import ConversionPipeline, RealScheduler
from repro.core import clock
from repro.core.clock import wall_sleep
from repro.wsi import (ConvertOptions, SyntheticScanner,
                       convert_wsi_to_dicom, read_part10, study_levels)
from repro.wsi.dicom import new_uid


def _uids():
    return json.dumps([new_uid(), new_uid()])


def _convert(psv, *, uids, **kw):
    opt = ConvertOptions(manifest={"uids": uids}, **kw)
    return convert_wsi_to_dicom(psv, {"slide_id": "AB"}, options=opt), opt


# --------------------------------------------------------------------------
# byte identity: pipelined vs sync batched, whole study tars
# --------------------------------------------------------------------------
@pytest.mark.parametrize("hw,min_level", [
    ((512, 512), 256),
    ((1024, 512), 256),   # non-square, multi-level
    ((512, 512), 64),     # runs into sub-tile levels (0 full frames)
])
def test_pipelined_tar_identical_to_sync(hw, min_level):
    psv = SyntheticScanner(seed=11).scan(*hw, 256)
    uids = _uids()
    sync_tar, _ = _convert(psv, uids=uids, pipelined=False,
                           min_level_size=min_level)
    pipe_tar, _ = _convert(psv, uids=uids, pipelined=True,
                           min_level_size=min_level)
    assert pipe_tar == sync_tar


def test_pipelined_levels_decode_and_cover_pyramid():
    psv = SyntheticScanner(seed=12).scan(1024, 1024, 256)
    tar, _ = _convert(psv, uids=_uids())
    lv = study_levels(tar)
    meta = json.loads(lv["study.json"])
    assert meta["levels"] == 3  # 1024 → 512 → 256
    for li, (total, frames) in enumerate([(1024, 16), (512, 4), (256, 1)]):
        ds, fr = read_part10(lv[f"level_{li}.dcm"])
        assert ds.get_int(0x0048, 0x0007) == total
        assert ds.get_int(0x0028, 0x0008) == frames
        assert len(fr) == frames


# --------------------------------------------------------------------------
# manifest resume reproduces a fresh conversion byte-for-byte
# --------------------------------------------------------------------------
def test_full_manifest_resume_tar_identical():
    psv = SyntheticScanner(seed=13).scan(512, 512, 256)
    tar1, opt1 = _convert(psv, uids=_uids())
    opt2 = ConvertOptions(manifest=dict(opt1.manifest))
    tar2 = convert_wsi_to_dicom(psv, {"slide_id": "AB"}, options=opt2)
    assert tar2 == tar1


def test_partial_manifest_resume_tar_identical():
    psv = SyntheticScanner(seed=13).scan(1024, 1024, 256)
    tar1, opt1 = _convert(psv, uids=_uids())
    # crashed after level 0: only level 0's bytes + the minted UIDs survive
    partial = {"uids": opt1.manifest["uids"], "0": opt1.manifest["0"]}
    opt2 = ConvertOptions(manifest=partial)
    tar2 = convert_wsi_to_dicom(psv, {"slide_id": "AB"}, options=opt2)
    assert tar2 == tar1
    # the sync engine resumes to the same bytes as the pipelined one
    opt3 = ConvertOptions(pipelined=False, manifest={
        "uids": opt1.manifest["uids"], "0": opt1.manifest["0"]})
    tar3 = convert_wsi_to_dicom(psv, {"slide_id": "AB"}, options=opt3)
    assert tar3 == tar1


def test_pipelined_crash_mid_pyramid_checkpoints_finished_levels(monkeypatch):
    """A level is checkpointed into the manifest as soon as its last chunk
    is entropy-coded, so a crash mid-conversion resumes past it."""
    import repro.wsi.convert as cv

    psv = SyntheticScanner(seed=15).scan(512, 512, 256)  # 2 chunks + 1 chunk
    calls = []
    real = cv.encode_coef_batch

    def flaky(coef):
        calls.append(1)
        if len(calls) == 3:  # die on level 1's (only) chunk
            raise RuntimeError("killed")
        return real(coef)

    monkeypatch.setattr(cv, "encode_coef_batch", flaky)
    opt = ConvertOptions(manifest={"uids": _uids()})
    with pytest.raises(RuntimeError):
        convert_wsi_to_dicom(psv, {"slide_id": "AB"}, options=opt)
    assert "0" in opt.manifest and "1" not in opt.manifest

    monkeypatch.setattr(cv, "encode_coef_batch", real)
    level0 = opt.manifest["0"]
    tar = convert_wsi_to_dicom(psv, {"slide_id": "AB"}, options=opt)
    lv = study_levels(tar)
    assert lv["level_0.dcm"] == level0  # resumed, not recomputed
    # and the resumed tar matches an uninterrupted conversion bit-for-bit
    fresh = convert_wsi_to_dicom(
        psv, {"slide_id": "AB"},
        options=ConvertOptions(manifest={"uids": opt.manifest["uids"]}))
    assert tar == fresh


def test_clear_manifest_mints_fresh_uids():
    psv = SyntheticScanner(seed=14).scan(256, 256, 256)
    tar1, opt = _convert(psv, uids=_uids())
    opt.clear_manifest()
    assert opt.manifest == {}
    tar2 = convert_wsi_to_dicom(psv, {"slide_id": "AB"}, options=opt)
    ds1, _ = read_part10(study_levels(tar1)["level_0.dcm"])
    ds2, _ = read_part10(study_levels(tar2)["level_0.dcm"])
    assert ds1.get_str(0x0020, 0x000D) != ds2.get_str(0x0020, 0x000D)


# --------------------------------------------------------------------------
# real-mode concurrency: a multi-slide batch through the event-driven wiring
# --------------------------------------------------------------------------
def test_concurrent_real_mode_batch_matches_sequential():
    n = 4
    scanner = SyntheticScanner(seed=21)
    slides = {f"slides/s{i}.psv": scanner.scan(512, 512, 256)
              for i in range(n)}
    uids = {k: _uids() for k in slides}

    def convert(data, meta):
        opt = ConvertOptions(manifest={"uids": uids[meta["slide_id"]]})
        return convert_wsi_to_dicom(data, meta, options=opt)

    reference = {k: convert(v, {"slide_id": k}) for k, v in slides.items()}

    sched = RealScheduler(workers=8)
    pipe = ConversionPipeline(
        sched, convert=convert, max_instances=2, concurrency=2,
        cold_start=0.0, scale_down_delay=2.0,
    )
    outs = pipe.run_batch(slides, timeout=240.0)
    assert outs == reference
    # run_batch returns once the studies are stored (inside the handler);
    # the completion metric ticks in _finish after the handler returns
    deadline = clock.monotonic() + 30.0
    while pipe.done_count() < n and clock.monotonic() < deadline:
        wall_sleep(0.01)
    assert pipe.done_count() == n
    assert sorted(pipe.converted) == sorted(
        k.rsplit(".", 1)[0] + ".dcm" for k in slides)
    sched.shutdown()
