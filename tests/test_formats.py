"""Multi-format ingestion: SlideReader protocol, registry/sniff, tiled TIFF,
and cross-format conversion byte-identity (direct + through the event-driven
pipeline)."""
import json
import struct

import numpy as np
import pytest

from repro.core import ConversionPipeline, RealScheduler
from repro.core import clock
from repro.wsi import (ConvertOptions, PSVReader, SyntheticScanner,
                       convert_wsi_to_dicom, open_slide, sniff, study_levels)
from repro.wsi.dicom import new_uid
from repro.wsi.formats import (SlideReader, TiffSlideReader, formats,
                               write_tiff)


def _tiles(seed=3, H=512, W=512, tile=256):
    return SyntheticScanner(seed=seed)._render_tiles(H, W, tile)


# ---------------------------------------------------------------------------
# registry / sniff
# ---------------------------------------------------------------------------
def test_sniff_matrix():
    sc = SyntheticScanner(seed=1)
    assert sniff(sc.scan(256, 256, 256)) == "psv"
    assert sniff(sc.scan_tiff(256, 256, 256)) == "tiff"
    be = write_tiff(_tiles(1, 256, 256), 256, 256, 256, byteorder=">")
    assert sniff(be) == "tiff"  # big-endian (MM) classic TIFF


@pytest.mark.parametrize("blob", [b"", b"garbage!", b"\x00" * 64])
def test_sniff_unknown_container_is_actionable(blob):
    with pytest.raises(ValueError, match="supported formats are.*psv.*tiff"):
        sniff(blob)


def test_registry_lists_both_formats():
    fmts = formats()
    assert set(fmts) >= {"psv", "tiff"}
    assert ".svs" in fmts["tiff"].extensions


def test_readers_satisfy_protocol():
    sc = SyntheticScanner(seed=2)
    for blob in (sc.scan(256, 256, 256), sc.scan_tiff(256, 256, 256)):
        rd = open_slide(blob)
        assert isinstance(rd, SlideReader)
        assert rd.grid == (1, 1)
        assert rd.read_tile(0, 0).shape == (256, 256, 3)
        assert isinstance(rd.metadata, dict)


# ---------------------------------------------------------------------------
# tiled TIFF reader/writer
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("byteorder", ["<", ">"])
def test_tiff_round_trip_both_byteorders(byteorder):
    tiles = _tiles(7, 512, 768, 256)
    blob = write_tiff(tiles, 512, 768, 256, byteorder=byteorder,
                      description="repro test|AppMag = 40|MPP = 0.25")
    rd = TiffSlideReader(blob)
    assert (rd.H, rd.W, rd.tile) == (512, 768, 256)
    assert rd.grid == (2, 3)
    for (r, c), t in rd.tiles():
        assert np.array_equal(t, tiles[(r, c)])
    assert rd.metadata["AppMag"] == "40"
    assert rd.metadata["MPP"] == "0.25"
    assert rd.metadata["vendor"] == "repro test"


def test_tiff_matches_psv_pixels_exactly():
    sc = SyntheticScanner(seed=11)
    rp = PSVReader(sc.scan(512, 512, 256))
    rt = TiffSlideReader(sc.scan_tiff(512, 512, 256))
    assert rp.grid == rt.grid
    for (k1, t1), (k2, t2) in zip(rp.tiles(), rt.tiles()):
        assert k1 == k2
        assert np.array_equal(t1, t2)


def test_tiff_writer_is_deterministic():
    tiles = _tiles(4, 256, 256)
    assert write_tiff(tiles, 256, 256, 256) == write_tiff(tiles, 256, 256, 256)


def test_truncated_tiff_raises_at_open():
    blob = SyntheticScanner(seed=5).scan_tiff(512, 512, 256)
    for cut in (4, 100, len(blob) // 2, len(blob) - 10):
        with pytest.raises(ValueError, match="TIFF"):
            TiffSlideReader(blob[:cut])


def test_corrupt_tiff_tile_raises_on_read():
    blob = bytearray(SyntheticScanner(seed=5).scan_tiff(512, 512, 256))
    rd = TiffSlideReader(bytes(blob))
    off = rd._offsets[0]
    blob[off:off + 8] = b"\xff" * 8  # smash the first tile's zlib stream
    with pytest.raises(ValueError, match="corrupt TIFF tile"):
        TiffSlideReader(bytes(blob)).read_tile(0, 0)


def test_unsupported_tiff_layouts_are_actionable():
    # striped TIFF (StripOffsets instead of TileOffsets)
    def ifd(entries):
        body = struct.pack("<H", len(entries))
        for tag, typ, count, value in entries:
            body += struct.pack("<HHII", tag, typ, count, value)
        return body + struct.pack("<I", 0)

    header = b"II" + struct.pack("<HI", 42, 8)
    striped = header + ifd([(256, 4, 1, 64), (257, 4, 1, 64),
                            (273, 4, 1, 8), (278, 4, 1, 64)])
    with pytest.raises(ValueError, match="striped layout"):
        open_slide(striped)

    # JPEG-compressed tiled TIFF
    jpeg = header + ifd([(256, 4, 1, 64), (257, 4, 1, 64), (259, 3, 1, 7),
                         (322, 4, 1, 64), (323, 4, 1, 64),
                         (324, 4, 1, 8), (325, 4, 1, 0)])
    with pytest.raises(ValueError, match="(?i)jpeg"):
        open_slide(jpeg)

    # BigTIFF magic
    with pytest.raises(ValueError, match="BigTIFF"):
        open_slide(b"II" + struct.pack("<HI", 43, 8) + b"\x00" * 16)


def test_zero_tile_containers_raise_cleanly():
    # crafted headers declaring tile=0 must be a clear ValueError, never a
    # ZeroDivisionError surfacing as the dlq_reason
    psv0 = b"PSV1" + struct.pack("<IIII", 512, 512, 0, 0)
    with pytest.raises(ValueError, match="corrupt PSV"):
        open_slide(psv0)
    header = b"II" + struct.pack("<HI", 42, 8)
    body = struct.pack("<H", 5)
    for tag, typ, count, value in [(256, 4, 1, 64), (257, 4, 1, 64),
                                   (322, 4, 1, 0), (323, 4, 1, 0),
                                   (324, 4, 1, 8)]:
        body += struct.pack("<HHII", tag, typ, count, value)
    tif0 = header + body + struct.pack("<I", 0)
    with pytest.raises(ValueError, match="corrupt TIFF"):
        open_slide(tif0)


def test_core_simulation_import_stays_light():
    """repro.core is the discrete-event simulation substrate; importing it
    must not drag in the jax converter stack (format sniffing is lazy)."""
    import os
    import subprocess
    import sys

    import repro
    src = os.path.dirname(list(repro.__path__)[0])
    env = {**os.environ,
           "PYTHONPATH": src + os.pathsep + os.environ.get("PYTHONPATH", "")}
    code = ("import sys, repro.core; "
            "sys.exit(1 if 'jax' in sys.modules else 0)")
    assert subprocess.run([sys.executable, "-c", code],
                          env=env).returncode == 0


def test_truncated_psv_raises_at_open():
    blob = SyntheticScanner(seed=5).scan(512, 512, 256)
    for cut in (30, len(blob) // 2):
        with pytest.raises(ValueError, match="truncated PSV"):
            PSVReader(blob[:cut])


def test_misaligned_slide_dims_raise():
    tiles = _tiles(1, 256, 256)
    blob = write_tiff(tiles, 200, 256, 256)  # H not a tile multiple
    with pytest.raises(ValueError, match="tile-aligned"):
        convert_wsi_to_dicom(blob)


# ---------------------------------------------------------------------------
# cross-format conversion byte-identity
# ---------------------------------------------------------------------------
def test_cross_format_study_tars_are_byte_identical():
    """Same pixels as PSV and as tiled TIFF, same manifest UIDs → identical
    study tar, on every compute path."""
    sc = SyntheticScanner(seed=21)
    psv = sc.scan(512, 512, 256)
    tif = sc.scan_tiff(512, 512, 256)
    uids = json.dumps([new_uid(), new_uid()])
    outs = {}
    for name, blob in (("psv", psv), ("tiff", tif)):
        for path, kw in (("pipe", {}), ("sync", {"pipelined": False}),
                         ("tile", {"batched": False})):
            opt = ConvertOptions(manifest={"uids": uids}, **kw)
            outs[(name, path)] = convert_wsi_to_dicom(
                blob, {"slide_id": "X"}, opt)
    ref = outs[("psv", "pipe")]
    assert all(v == ref for v in outs.values())
    assert len(study_levels(ref)) == 3  # study.json + 2 levels


def test_mixed_format_batch_through_event_driven_pipeline():
    """One deployment, one landing bucket, three containers (.psv/.tiff/.svs)
    — every slide converts, and the PSV/TIFF deliveries of identical pixels
    produce byte-identical study tars end to end."""
    sc = SyntheticScanner(seed=23)
    psv = sc.scan(512, 512, 256)
    tif = sc.scan_tiff(512, 512, 256)
    svs = SyntheticScanner(seed=24).scan_tiff(256, 256, 256)
    uids = {"S": json.dumps([new_uid(), new_uid()]),
            "V": json.dumps([new_uid(), new_uid()])}

    def convert(data, meta):
        opt = ConvertOptions(manifest={"uids": uids[meta["slide_id"]]})
        return convert_wsi_to_dicom(data, {"slide_id": meta["slide_id"]},
                                    options=opt)

    sched = RealScheduler(workers=4)
    pipe = ConversionPipeline(
        sched, convert=convert, max_instances=2, cold_start=0.0,
        scale_down_delay=2.0, subscribers=False,
    )
    outs = pipe.run_batch(
        {"psv/slide.psv": psv, "tiff/slide.tiff": tif, "svs/extra.svs": svs},
        metadata={"psv/slide.psv": {"slide_id": "S"},
                  "tiff/slide.tiff": {"slide_id": "S"},
                  "svs/extra.svs": {"slide_id": "V"}},
        timeout=240.0)
    sched.shutdown()
    assert outs["psv/slide.psv"] == outs["tiff/slide.tiff"]
    assert outs["svs/extra.svs"] != outs["psv/slide.psv"]
    assert pipe.metrics.get("pipeline.format.psv") == 1
    assert pipe.metrics.get("pipeline.format.tiff") == 2


def test_garbage_landing_object_dead_letters_with_actionable_reason():
    """Unknown container in the landing bucket → DLQ with the sniff error as
    dlq_reason, and run_batch fails fast instead of timing out."""
    sched = RealScheduler(workers=4)
    pipe = ConversionPipeline(
        sched, convert=lambda data, meta: convert_wsi_to_dicom(data, meta),
        max_instances=2, cold_start=0.0, scale_down_delay=2.0,
        max_delivery_attempts=2, min_backoff=0.05, max_backoff=0.05,
        subscribers=False,
    )
    t0 = clock.monotonic()
    with pytest.raises(RuntimeError,
                       match="dead-lettered.*unknown slide container"):
        pipe.run_batch({"slides/junk.psv": b"not a slide at all"},
                       timeout=120.0)
    assert clock.monotonic() - t0 < 60.0  # fail-fast, not the full timeout
    assert pipe.dead_lettered and \
        "supported formats" in pipe.dead_lettered[0][1]
    sched.shutdown()
