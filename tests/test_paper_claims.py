"""The paper's quantitative claims, asserted against the calibrated simulation
(EXPERIMENTS.md §Reproduction)."""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.fig2_workflows import (autoscaling_time, parallel_time,
                                       serial_time)
from benchmarks.fig3_autoscaling import run as fig3_run


def test_fig2_cold_start_loses_at_one_image():
    tau = 90.0
    assert autoscaling_time(1, tau) > serial_time(1, tau)
    assert autoscaling_time(1, tau) > parallel_time(1, tau)


def test_fig2_autoscaling_wins_at_batch_sizes():
    tau = 90.0
    for n in (10, 25, 50):
        a = autoscaling_time(n, tau)
        p = parallel_time(n, tau)
        s = serial_time(n, tau)
        assert a < p < s, (n, a, p, s)


def test_fig2_autoscaling_is_flat_in_batch_size():
    """The paper's plateau: once hot, completion time ~independent of n."""
    tau = 90.0
    times = [autoscaling_time(n, tau) for n in (10, 25, 50)]
    assert max(times) - min(times) < 0.05 * min(times)


def test_fig2_cold_start_tradeoff_with_warm_instances():
    """Paper §Limitations: min_instances removes the cold start but costs
    idle capacity — quantified."""
    from repro.core import ConversionPipeline, SimScheduler

    def one_image_latency(min_instances):
        sched = SimScheduler()
        pipe = ConversionPipeline(sched, service_time=90.0, cold_start=12.0,
                                  min_instances=min_instances)
        pipe.ingest("s.psv", b"x")
        sched.run()
        lat = pipe.metrics.timeseries("svc.wsi2dcm.latency")
        return lat[-1][1]

    assert one_image_latency(0) - one_image_latency(1) >= 11.0


def test_fig3_ramp_plateau_decay():
    minutes, pipe = fig3_run(n=50, tau=90.0)
    values = [v for _, v in minutes]
    assert max(values) >= 45  # ramp to ~one instance per slide
    assert values[-1] == 0  # decay to zero (no idle cost)
    assert pipe.done_count() == 50
    assert pipe.service.cold_starts == 50
