"""Batched inverse JPEG path: fused inverse-kernel differential, the
vectorized entropy decoder vs the per-tile loop (pixel identity +
coefficient-exact round-trip), and decode hardening against truncated or
garbage bitstreams."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax.numpy as jnp

from repro.kernels import jpeg_inverse, jpeg_transform
from repro.kernels import ref
from repro.wsi.jpeg import (decode_coef_batch, decode_tile,
                            decode_tiles_batch, encode_coef_batch,
                            encode_tile, encode_tiles_batch)
from repro.wsi.slide import PSVReader, SyntheticScanner

RNG = np.random.default_rng(13)


def _tissue_tiles(n, hw=256, seed=3):
    rd = PSVReader(SyntheticScanner(seed=seed).scan(1024, 1024, hw))
    bh, bw = rd.grid
    tiles = [rd.read_tile(r, c) for r in range(bh) for c in range(bw)]
    return np.stack((tiles * (n // len(tiles) + 1))[:n])


# --------------------------------------------------------------------------
# fused jpeg_inverse kernel vs jnp oracle
# --------------------------------------------------------------------------
@pytest.mark.parametrize("n,h,w", [(1, 8, 128), (2, 64, 128), (3, 32, 256)])
@pytest.mark.parametrize("seed", [0, 1])
def test_jpeg_inverse_pallas_matches_ref(n, h, w, seed):
    rng = np.random.default_rng(seed)
    tiles = rng.integers(0, 256, size=(n, 3, h, w)).astype(np.float32)
    coef = jpeg_transform(jnp.asarray(tiles))
    out = jpeg_inverse(coef, impl="pallas")
    expect = ref.jpeg_inverse_ref(coef)
    assert out.dtype == jnp.uint8
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


def test_jpeg_inverse_batch_size_independent():
    """Pixel identity between the batched and per-tile decode paths rests
    on the fused inverse producing the same bytes for any batch size."""
    tiles = RNG.integers(0, 256, size=(4, 3, 64, 128)).astype(np.float32)
    coef = np.asarray(jpeg_transform(jnp.asarray(tiles)))
    full = np.asarray(jpeg_inverse(coef))
    for i in range(4):
        one = np.asarray(jpeg_inverse(coef[i : i + 1]))[0]
        np.testing.assert_array_equal(one, full[i])


def test_jpeg_inverse_roundtrips_transform():
    """inverse ∘ transform ≈ identity up to quantization loss."""
    tiles = _tissue_tiles(4)
    chw = np.transpose(tiles, (0, 3, 1, 2)).astype(np.float32)
    rec = np.asarray(jpeg_inverse(jpeg_transform(jnp.asarray(chw))))
    err = np.abs(rec.astype(np.int32) - chw.astype(np.int32)).mean()
    assert err < 8.0  # q50 baseline quality


def test_jpeg_inverse_unaligned_falls_back_to_ref():
    coef = jnp.asarray(RNG.integers(-64, 64, size=(2, 3, 24, 72)),
                       jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(jpeg_inverse(coef)),
        np.asarray(ref.jpeg_inverse_ref(coef)))


# --------------------------------------------------------------------------
# batched entropy decoder vs per-tile reference loop
# --------------------------------------------------------------------------
def test_decode_batch_pixel_identical_to_per_tile():
    jpgs = encode_tiles_batch(_tissue_tiles(6))
    per = np.stack([decode_tile(j) for j in jpgs])
    bat = decode_tiles_batch(jpgs)
    np.testing.assert_array_equal(per, bat)


@pytest.mark.parametrize("kind", ["noise", "flat", "gradient"])
def test_decode_batch_identical_on_adversarial_content(kind):
    """Worst cases for the lockstep decoder: dense symbols (noise), EOB
    everywhere with one outlier (flat), smooth DC drift (gradient)."""
    if kind == "noise":
        tiles = RNG.integers(0, 256, size=(3, 64, 128, 3)).astype(np.uint8)
    elif kind == "flat":
        tiles = np.full((3, 64, 128, 3), 200, np.uint8)
        tiles[1, 11, 13] = [0, 255, 7]  # one outlier block
    else:
        g = np.linspace(0, 255, 64 * 128).reshape(64, 128)
        one = np.stack([g, g[::-1], 255 - g], axis=-1).astype(np.uint8)
        tiles = np.stack([one, one[:, ::-1], one[::-1]])
    jpgs = encode_tiles_batch(tiles)
    per = np.stack([decode_tile(j) for j in jpgs])
    np.testing.assert_array_equal(per, decode_tiles_batch(jpgs))
    np.testing.assert_array_equal(
        decode_coef_batch(jpgs),
        np.asarray(jpeg_transform(jnp.asarray(
            np.transpose(tiles, (0, 3, 1, 2)).astype(np.float32)))))


def test_decode_coef_batch_is_exact_inverse():
    tiles = _tissue_tiles(5)
    chw = np.transpose(tiles, (0, 3, 1, 2)).astype(np.float32)
    coef = np.asarray(jpeg_transform(jnp.asarray(chw)))
    np.testing.assert_array_equal(
        decode_coef_batch(encode_coef_batch(coef)), coef)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**32 - 1), st.integers(1, 4), st.booleans())
def test_coef_roundtrip_property(seed, n, sparse):
    """encode_coef_batch → decode_coef_batch is exact for any in-range
    coefficient content (random dense and sparse blocks)."""
    rng = np.random.default_rng(seed)
    coef = rng.integers(-1023, 1024, size=(n, 3, 16, 16)).astype(np.int32)
    if sparse:
        coef *= rng.random(coef.shape) < 0.05  # long zero runs / ZRLs
    np.testing.assert_array_equal(
        decode_coef_batch(encode_coef_batch(coef)), coef)


def test_decode_batch_empty_and_geometry_guard():
    assert decode_coef_batch([]).shape == (0, 3, 0, 0)
    assert decode_tiles_batch([]).shape == (0, 0, 0, 3)
    a = encode_tile(np.zeros((8, 8, 3), np.uint8))
    b = encode_tile(np.zeros((16, 16, 3), np.uint8))
    with pytest.raises(ValueError, match="mixed tile geometries"):
        decode_coef_batch([a, b])


# --------------------------------------------------------------------------
# hardening: truncated / garbage bitstreams
# --------------------------------------------------------------------------
@pytest.fixture(scope="module")
def tissue_jpg():
    return encode_tile(_tissue_tiles(1, seed=7)[0])


@pytest.mark.parametrize("cut", [0, 1, 2, 3, 19, 0.25, 0.5, 0.9, -1])
def test_decode_tile_truncation_raises_corrupt(tissue_jpg, cut):
    """Truncation anywhere — header, tables, or mid-scan — must be the
    actionable corrupt-JPEG ValueError, never IndexError or a hang."""
    n = len(tissue_jpg)
    cut = int(n * cut) if isinstance(cut, float) else (n + cut if cut < 0
                                                      else cut)
    with pytest.raises(ValueError, match="corrupt JPEG"):
        decode_tile(tissue_jpg[:cut])
    with pytest.raises(ValueError, match="corrupt JPEG"):
        decode_coef_batch([tissue_jpg[:cut]])


def test_decode_tile_garbage_raises_corrupt(tissue_jpg):
    rng = np.random.default_rng(0)
    for blob in (b"", b"\xff", b"not a jpeg at all",
                 rng.integers(0, 256, 512).astype(np.uint8).tobytes(),
                 tissue_jpg[:30] + b"\x00" * 40):
        with pytest.raises(ValueError, match="corrupt JPEG"):
            decode_tile(blob)
        with pytest.raises(ValueError, match="corrupt JPEG"):
            decode_coef_batch([blob])


def test_decode_tile_scan_bitflip_never_escapes_value_error(tissue_jpg):
    """Corrupting scan bytes may still decode (a different valid stream) or
    must raise the corrupt-JPEG error — both decoders, same contract."""
    from repro.wsi.jpeg import _parse_jfif

    _, _, start, _ = _parse_jfif(tissue_jpg)
    rng = np.random.default_rng(1)
    for _ in range(12):
        mut = bytearray(tissue_jpg)
        i = rng.integers(start, len(tissue_jpg) - 2)
        mut[i] ^= 1 << int(rng.integers(0, 8))
        for api in (decode_tile, lambda b: decode_tiles_batch([b])):
            try:
                api(bytes(mut))
            except ValueError as exc:
                assert str(exc).startswith("corrupt JPEG")


def test_decode_tile_accepts_dicom_even_length_pad(tissue_jpg):
    """Encapsulated DICOM fragments pad odd-length JPEGs with one 0x00."""
    padded = tissue_jpg + b"\x00"
    np.testing.assert_array_equal(decode_tile(padded),
                                  decode_tile(tissue_jpg))
    np.testing.assert_array_equal(decode_tiles_batch([padded])[0],
                                  decode_tile(tissue_jpg))


# --------------------------------------------------------------------------
# jitted lockstep entropy engine vs the numpy oracle
# --------------------------------------------------------------------------
def _scans(jpgs):
    """Unstuffed scan arrays + geometry, as _entropy_decode_batch sees
    them."""
    from repro.wsi import jpeg as J

    scans, H, W = [], None, None
    for j in jpgs:
        H, W, s, e = J._parse_jfif(j)
        scans.append(J._unstuff(np.frombuffer(j, np.uint8)[s:e]))
    return scans, H, W


@pytest.mark.parametrize("kind", ["noise", "gradient"])
def test_entropy_engines_coefficient_exact(kind):
    """engine="jax" (lax.while_loop lockstep) must match engine="numpy"
    coefficient-for-coefficient, odd batch sizes included (pad lanes)."""
    from repro.wsi.jpeg import _entropy_decode_batch

    if kind == "noise":
        tiles = RNG.integers(0, 256, size=(5, 64, 128, 3)).astype(np.uint8)
    else:
        g = np.linspace(0, 255, 64 * 128).reshape(64, 128)
        one = np.stack([g, g[::-1], 255 - g], axis=-1).astype(np.uint8)
        tiles = np.stack([one, one[:, ::-1], one[::-1]])
    scans, H, W = _scans(encode_tiles_batch(tiles))
    np.testing.assert_array_equal(
        _entropy_decode_batch(scans, H, W, engine="jax"),
        _entropy_decode_batch(scans, H, W, engine="numpy"))


def test_entropy_engines_raise_identical_errors(tissue_jpg):
    """Both engines must raise the same actionable string at the same
    failure class: truncation, garbage (invalid Huffman code)."""
    from repro.wsi.jpeg import _entropy_decode_batch

    scans, H, W = _scans([tissue_jpg] * 2)
    for mutate in (
        lambda s: s[: max(4, s.size // 2)],          # mid-stream truncation
        lambda s: s[:2],                             # near-empty scan
        lambda s: RNG.integers(0, 256, s.size).astype(np.uint8),  # garbage
    ):
        bad = [scans[0], mutate(scans[1].copy())]
        errs = []
        for engine in ("jax", "numpy"):
            with pytest.raises(ValueError, match="corrupt JPEG") as ei:
                _entropy_decode_batch(bad, H, W, engine=engine)
            errs.append(str(ei.value))
        assert errs[0] == errs[1], errs


def test_entropy_engine_auto_thresholds():
    """auto routes big batches to the jitted engine, tiny ones to numpy."""
    from repro.wsi import jpeg as J

    assert J._JAX_MIN_UNITS > 0 and J._JAX_MAX_BYTES > 0
    tiles = _tissue_tiles(2)
    scans, H, W = _scans(encode_tiles_batch(tiles))
    # 2 tiles × 3072 units ≥ _JAX_MIN_UNITS → the jax engine; equality with
    # the numpy oracle is the contract either way
    np.testing.assert_array_equal(
        J._entropy_decode_batch(scans, H, W),
        J._entropy_decode_batch(scans, H, W, engine="numpy"))
    with pytest.raises(ValueError, match="engine"):
        J._entropy_decode_batch(scans, H, W, engine="cuda")
