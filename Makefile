PYTHONPATH := src
export PYTHONPATH

.PHONY: test bench

# tier-1 verify
test:
	python -m pytest -x -q

# benchmark suite: paper figures + kernels + conversion hot path
# (writes BENCH_*.json into the working directory)
bench:
	python -m benchmarks.run
	python -m benchmarks.convert_bench
