PYTHONPATH := src
export PYTHONPATH

.PHONY: test verify smoke bench

# tier-1 verify
test:
	python -m pytest -x -q

# same entry point, named the way the docs and CI refer to it
verify: test

# CPU byte-identity smoke: the conversion benchmark with --fast asserts
# per-tile ≡ batched ≡ pipelined ≡ concurrent output bytes on small slides
# AND runs the mixed-format batch (PSV + tiled-TIFF deliveries of the same
# pixels through one sniffing deployment must emit byte-identical study
# tars); the store benchmark asserts indexed-WADO byte identity + ≥10x
# plus re-STOW / crash-rebuild QIDO/WADO identity; the export benchmark
# asserts batched-decode pixel identity + coefficient-exact round-trip,
# a >1x whole-level decode speedup, and byte-identical repeated /
# post-rebuild exports that reopen through the TIFF sniffer
smoke:
	python -m benchmarks.convert_bench --fast
	python -m benchmarks.store_bench --fast
	python -m benchmarks.export_bench --fast

# benchmark suite: paper figures + kernels + conversion + store + export
# hot paths (writes BENCH_*.json into the working directory)
bench:
	python -m benchmarks.run
	python -m benchmarks.convert_bench
	python -m benchmarks.store_bench
	python -m benchmarks.export_bench
