PYTHONPATH := src
export PYTHONPATH

.PHONY: test lint verify smoke bench race trace

# tier-1 verify (conftest arms lockdep AND racedep for the whole suite:
# any lock-order inversion / callback-under-lock / held-too-long /
# acquired-in-jit violation — or a data race on tracked shared state —
# fails the test that provoked it)
test:
	python -m pytest -x -q

# project AST lint rules (see src/repro/analysis/lint.py: bare-lock,
# bare-thread, wall-clock, unseeded-random, direct-pallas, counter-name,
# jit-global-mutation); exits nonzero on any finding
lint:
	python -m repro.analysis.lint src tests benchmarks

# same entry point, named the way the docs and CI refer to it
verify: lint test

# systematic schedule exploration (see src/repro/analysis/schedules.py):
# runs the sim fleet scenario and the real-bytes fleet scenario — synthetic
# slides through the real converter under drop/duplicate/delay faults and
# an instance kill — across N seeded event schedules plus legacy FIFO,
# asserting exactly-once settlement, cross-schedule byte-identical study
# tars, and zero data races (racedep armed). A failing schedule dumps its
# seed + trace under artifacts/ and prints a one-line replay command
race:
	python -m repro.analysis.schedules --explore sim --seeds 30
	python -m repro.analysis.schedules --explore realbytes --seeds 20

# instrumented observability smoke (see src/repro/core/dashboard.py):
# runs a small real-conversion batch on the wall-clock scheduler with the
# distributed tracer armed, delivery faults injected, and an instance
# killed mid-run; renders the single dashboard and writes
# artifacts/dashboard.json + artifacts/trace-sample.json (one slide's
# full span tree) — exits nonzero if any slide's trace is disconnected
trace:
	python -m repro.core.dashboard --smoke --out artifacts

# CPU byte-identity smoke: the conversion benchmark with --fast asserts
# per-tile ≡ batched ≡ pipelined ≡ concurrent output bytes on small slides
# AND runs the mixed-format batch (PSV + tiled-TIFF deliveries of the same
# pixels through one sniffing deployment must emit byte-identical study
# tars) and the fused-engine transfer ledger (1 upload + 1 dispatch per
# slide); the store benchmark asserts indexed-WADO byte identity + ≥10x
# plus re-STOW / crash-rebuild QIDO/WADO identity; the export benchmark
# asserts batched-decode pixel identity + coefficient-exact round-trip
# and a >1x decode speedup at EVERY batch-scaling point; the kernel
# benchmark asserts flat batch scaling (no small-batch recompile cliff)
# and pow2-bucket jit-cache reuse, and writes the roofline terms; the
# fleet benchmark asserts the Figure-2 crossover (fleet loses at n=1,
# wins at n>=10), the Figure-3 ramp/plateau/decay, and the full
# fault-injection gauntlet (drop/delay/duplicate deliveries + instance
# kill + shard crash -> zero lost/double-converted, study tars
# byte-identical to a serial conversion)
smoke:
	python -m benchmarks.convert_bench --fast
	python -m benchmarks.store_bench --fast
	python -m benchmarks.export_bench --fast
	python -m benchmarks.kernels_bench --fast
	python -m benchmarks.fleet_bench --fast

# benchmark suite: paper figures + kernels + conversion + store + export
# + fleet hot paths (writes BENCH_*.json into the working directory)
bench:
	python -m benchmarks.run
	python -m benchmarks.convert_bench
	python -m benchmarks.store_bench
	python -m benchmarks.export_bench
	python -m benchmarks.kernels_bench
	python -m benchmarks.fleet_bench
