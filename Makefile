PYTHONPATH := src
export PYTHONPATH

.PHONY: test verify smoke bench

# tier-1 verify
test:
	python -m pytest -x -q

# same entry point, named the way the docs and CI refer to it
verify: test

# CPU byte-identity smoke: the conversion benchmark with --fast asserts
# per-tile ≡ batched ≡ pipelined ≡ concurrent output bytes on small slides
smoke:
	python -m benchmarks.convert_bench --fast

# benchmark suite: paper figures + kernels + conversion hot path
# (writes BENCH_*.json into the working directory)
bench:
	python -m benchmarks.run
	python -m benchmarks.convert_bench
