"""Serving: step builders + the event-driven continuous-batching engine."""
from repro.serve.steps import (  # noqa: F401
    decode_input_defs,
    make_decode_step,
    make_prefill_step,
    prefill_input_defs,
)
