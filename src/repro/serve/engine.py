"""Continuous-batching serving engine with event-driven intake.

The paper's pattern applied to LM serving: requests land on a pub/sub topic
(the "landing zone"), a push subscription feeds engine instances (the
"containers"), results publish to a response topic. Inside one engine:

* a fixed-size slot array (the decode batch) over one shared KV cache,
* per-request prefill (batch-1) writes its KV into a free slot,
* one ``decode_step`` per tick advances every active slot together
  (continuous batching — no head-of-line blocking on long generations),
* finished slots free immediately and the backlog refills them.

The engine is synchronous and deterministic (tests drive ``tick()``
directly); ``PubSubFrontend`` adapts it to the event bus.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M

__all__ = ["ContinuousBatchingEngine", "PubSubFrontend", "Request"]

_ids = itertools.count(1)


@dataclasses.dataclass
class Request:
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 16
    eos_id: int | None = None
    req_id: int = dataclasses.field(default_factory=lambda: next(_ids))
    done: Callable | None = None  # callback(tokens)


class ContinuousBatchingEngine:
    def __init__(self, cfg, params, *, batch_size: int = 4,
                 max_len: int = 256, greedy: bool = True):
        self.cfg = cfg
        self.params = params
        self.B = batch_size
        self.max_len = max_len
        self.cache = M.init_cache(cfg, batch_size, max_len)
        self.pos = np.zeros(batch_size, np.int32)
        self.active: list[Request | None] = [None] * batch_size
        self.budget = np.zeros(batch_size, np.int32)
        self.generated: dict[int, list[int]] = {}
        self.backlog: deque[Request] = deque()
        self.steps = 0

        self._decode = jax.jit(
            lambda p, c, t, pos: M.decode_step(p, cfg, c, t, pos)
        )
        self._last_tok = np.zeros(batch_size, np.int32)

    # ---- intake -----------------------------------------------------------
    def submit(self, req: Request):
        self.backlog.append(req)
        self._fill_slots()

    def _fill_slots(self):
        for b in range(self.B):
            if self.active[b] is None and self.backlog:
                req = self.backlog.popleft()
                self._prefill_into(b, req)

    def _prefill_into(self, b: int, req: Request):
        S = len(req.prompt)
        toks = jnp.asarray(req.prompt, jnp.int32)[None]
        cond = None
        if self.cfg.family in ("vlm", "audio"):
            cond = jnp.zeros((1, self.cfg.n_cross_tokens, self.cfg.d_model),
                             self.cfg.dtype)
        logits, cache1 = M.prefill(self.params, self.cfg, toks, cond=cond,
                                   max_len=self.max_len)
        # splice the request's caches into slot b
        def splice(dst, src):
            if dst.ndim >= 2 and src.shape[1] == 1 and dst.shape[1] == self.B:
                return dst.at[:, b].set(src[:, 0].astype(dst.dtype))
            if src.shape[0] == 1 and dst.shape[0] == self.B:  # (B, ...) states
                return dst.at[b].set(src[0].astype(dst.dtype))
            return dst
        self.cache = jax.tree_util.tree_map(splice, self.cache, cache1)
        tok = int(np.argmax(np.asarray(logits)[0]))
        self.active[b] = req
        self.pos[b] = S
        self.budget[b] = req.max_new_tokens - 1
        self.generated[req.req_id] = [tok]
        self._last_tok[b] = tok

    # ---- decode tick -----------------------------------------------------
    def tick(self) -> int:
        """One decode step over all active slots. Returns #active."""
        if not any(r is not None for r in self.active):
            self._fill_slots()
            if not any(r is not None for r in self.active):
                return 0
        toks = jnp.asarray(self._last_tok, jnp.int32)[:, None]
        pos = jnp.asarray(self.pos, jnp.int32)
        logits, self.cache = self._decode(self.params, self.cache, toks, pos)
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        self.steps += 1
        for b, req in enumerate(self.active):
            if req is None:
                continue
            self.pos[b] += 1
            tok = int(nxt[b])
            out = self.generated[req.req_id]
            if self.budget[b] > 0 and (req.eos_id is None or tok != req.eos_id) \
                    and self.pos[b] < self.max_len - 1:
                out.append(tok)
                self.budget[b] -= 1
                self._last_tok[b] = tok
            else:
                self._finish(b, req)
        self._fill_slots()
        return sum(r is not None for r in self.active)

    def _finish(self, b: int, req: Request):
        tokens = self.generated.pop(req.req_id)
        self.active[b] = None
        if req.done:
            req.done(tokens)

    def run_until_drained(self, max_steps: int = 10_000):
        while (self.backlog or any(self.active)) and self.steps < max_steps:
            self.tick()


class PubSubFrontend:
    """Event-bus adapter: request topic → engine, results → response topic."""

    def __init__(self, engine: ContinuousBatchingEngine, topic, response_topic,
                 name: str = "llm-serve"):
        from repro.core.pubsub import Subscription

        self.engine = engine
        self.response_topic = response_topic
        self.sub = Subscription(topic, name, self._on_message,
                                ack_deadline=300.0)

    def _on_message(self, msg, ctx):
        data = msg.data

        def done(tokens):
            self.response_topic.publish(
                {"request_id": data.get("request_id"), "tokens": tokens})
            ctx.ack()

        self.engine.submit(Request(
            prompt=np.asarray(data["prompt"], np.int32),
            max_new_tokens=int(data.get("max_new_tokens", 16)),
            done=done,
        ))
