"""Serving-step builders (prefill / decode), jit-able and dry-run friendly."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models import model as M
from repro.models.params import ParamDef

__all__ = ["make_prefill_step", "make_decode_step", "decode_input_defs",
           "prefill_input_defs"]


def make_prefill_step(cfg, max_len: int | None = None):
    """step(params, tokens[, cond]) -> (last_logits, cache)."""

    if cfg.family in ("vlm", "audio"):
        def step(params, tokens, cond):
            return M.prefill(params, cfg, tokens, cond=cond, max_len=max_len)
    else:
        def step(params, tokens):
            return M.prefill(params, cfg, tokens, max_len=max_len)
    return step


def make_decode_step(cfg):
    """step(params, cache, token, pos) -> (logits, cache)."""

    def step(params, cache, token, pos):
        return M.decode_step(params, cfg, cache, token, pos)

    return step


def prefill_input_defs(cfg, batch: int, seq_len: int) -> dict:
    d = {"tokens": ParamDef((batch, seq_len), ("batch", "seq"), dtype=jnp.int32)}
    if cfg.family in ("vlm", "audio"):
        d["cond"] = ParamDef(
            (batch, cfg.n_cross_tokens, cfg.d_model), ("batch", "", "embed"),
            dtype=cfg.dtype,
        )
    return d


def decode_input_defs(cfg, batch: int) -> dict:
    return {
        "token": ParamDef((batch, 1), ("batch", ""), dtype=jnp.int32),
        "pos": ParamDef((batch,), ("batch",), dtype=jnp.int32),
    }
