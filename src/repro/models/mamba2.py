"""Mamba2 mixer (SSD — state-space duality, chunked scan).

Follows the reference SSD algorithm: the sequence is split into chunks; each
chunk computes its quadratic intra-chunk attention-like term, per-chunk final
states are combined by a sequential scan over chunks, and the inter-chunk term
projects the carried state back onto each position.  Decode is the O(1)
recurrent update.  Channel dims (d_inner, ssm heads) are tensor-parallel over
'model'; the (small) B/C state projections are replicated.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import sharding as shd
from repro.models.params import ParamDef

__all__ = ["mamba2_defs", "mamba2_apply", "mamba2_decode", "mamba2_state_defs"]


def mamba2_defs(cfg) -> dict:
    D, DI, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    W = cfg.ssm_conv
    return {
        "norm": ParamDef((D,), ("embed",), init="ones"),
        "wz": ParamDef((D, DI), ("embed", "tp")),
        "wx": ParamDef((D, DI), ("embed", "tp")),
        "wB": ParamDef((D, N), ("embed", "")),
        "wC": ParamDef((D, N), ("embed", "")),
        "wdt": ParamDef((D, H), ("embed", "tp")),
        "conv_x": ParamDef((W, DI), ("", "tp"), scale=0.5),
        "conv_B": ParamDef((W, N), ("", ""), scale=0.5),
        "conv_C": ParamDef((W, N), ("", ""), scale=0.5),
        "A_log": ParamDef((H,), ("tp",), init="zeros"),
        "dt_bias": ParamDef((H,), ("tp",), init="zeros"),
        "D_skip": ParamDef((H,), ("tp",), init="ones"),
        "gnorm": ParamDef((DI,), ("tp",), init="ones"),
        "wo": ParamDef((DI, D), ("tp", "embed")),
    }


def mamba2_state_defs(cfg, batch: int) -> dict:
    """Decode-state ShapeDtype layout for one layer."""
    DI, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    W = cfg.ssm_conv
    return {
        "conv_x": ParamDef((batch, W - 1, DI), ("batch", "", "tp"), init="zeros"),
        "conv_B": ParamDef((batch, W - 1, N), ("batch", "", ""), init="zeros"),
        "conv_C": ParamDef((batch, W - 1, N), ("batch", "", ""), init="zeros"),
        "ssm": ParamDef((batch, H, P, N), ("batch", "tp", "", ""),
                        dtype=jnp.float32, init="zeros"),
    }


def _causal_conv(x, w):
    """Depthwise causal conv. x: (B, S, C); w: (W, C)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(W))
    return out


def _project(p, cfg, x):
    dt_ = x.dtype
    z = jnp.einsum("bsd,de->bse", x, p["wz"].astype(dt_))
    xs = jnp.einsum("bsd,de->bse", x, p["wx"].astype(dt_))
    Bp = jnp.einsum("bsd,dn->bsn", x, p["wB"].astype(dt_))
    Cp = jnp.einsum("bsd,dn->bsn", x, p["wC"].astype(dt_))
    dt = jnp.einsum("bsd,dh->bsh", x, p["wdt"].astype(dt_))
    return z, xs, Bp, Cp, dt


def mamba2_apply(p, cfg, x, *, chunk: int = 64, return_state: bool = False):
    """Full-sequence SSD. x: (B, S, D) -> (out, final_state | None).

    A checkpointed scan over sequence chunks: each chunk computes its
    quadratic intra-chunk term and state update locally (the (Q, Q) decay
    tensor lives only inside one chunk's body and is rematerialized in the
    backward pass), and the carried (B, H, P, N) state provides the
    inter-chunk contribution.
    """
    B, S, D = x.shape
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    z, xr, Br, Cr, dt = _project(p, cfg, x)
    xs = jax.nn.silu(_causal_conv(xr, p["conv_x"].astype(xr.dtype)))
    Bp = jax.nn.silu(_causal_conv(Br, p["conv_B"].astype(Br.dtype)))
    Cp = jax.nn.silu(_causal_conv(Cr, p["conv_C"].astype(Cr.dtype)))
    xs = shd.constrain(xs, "batch", "seq", "tp")

    Q = min(chunk, S)
    if S % Q:
        Q = S
    NC = S // Q
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (H,)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))

    xh = xs.reshape(B, NC, Q, H, P).astype(jnp.float32)
    Bc = Bp.reshape(B, NC, Q, N).astype(jnp.float32)
    Cc = Cp.reshape(B, NC, Q, N).astype(jnp.float32)
    dtc = dt.reshape(B, NC, Q, H)
    Lmask = jnp.tril(jnp.ones((Q, Q), bool))

    def per_chunk(st_in, xs_):
        xh_, Bc_, Cc_, dt_ = xs_  # (B,Q,H,P), (B,Q,N), (B,Q,N), (B,Q,H)
        xh_ = shd.constrain(xh_, "batch", "", "", "")
        dA = dt_ * A  # (B,Q,H)
        cum = jnp.cumsum(dA, axis=1)
        xdt = xh_ * dt_[..., None]
        # intra-chunk quadratic term (clamp before exp: valid (t>=s) entries
        # are <= 0 in log space; unclamped masked entries poison the grad)
        ldiff = cum[:, :, None, :] - cum[:, None, :, :]  # (B,t,s,H)
        decay = jnp.exp(jnp.minimum(ldiff, 0.0))
        decay = jnp.where(Lmask[None, :, :, None], decay, 0.0)
        att = jnp.einsum("btn,bsn->bts", Cc_, Bc_)[..., None] * decay
        y = jnp.einsum("btsh,bshp->bthp", att, xdt)
        # inter-chunk term from the carried state
        y = y + jnp.einsum("btn,bth,bhpn->bthp", Cc_, jnp.exp(cum), st_in)
        # state update
        decay_end = jnp.exp(cum[:, -1:, :] - cum)  # (B,Q,H)
        st_new = st_in * jnp.exp(cum[:, -1])[..., None, None] + jnp.einsum(
            "bsn,bsh,bshp->bhpn", Bc_, decay_end, xdt
        )
        return st_new, y

    st0 = jnp.zeros((B, H, P, N), jnp.float32)
    final_state, ys = jax.lax.scan(
        jax.checkpoint(per_chunk),
        st0,
        tuple(jnp.moveaxis(t, 1, 0) for t in (xh, Bc, Cc, dtc)),
    )
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, P)
    y = y + xs.reshape(B, S, H, P).astype(jnp.float32) * p["D_skip"].astype(
        jnp.float32
    )[None, None, :, None]
    y = y.reshape(B, S, cfg.d_inner)

    out = _gate_norm_out(p, cfg, y, z)
    if return_state:
        conv_tail = {
            "conv_x": xs_tail(xr, cfg.ssm_conv),
            "conv_B": xs_tail(Br, cfg.ssm_conv),
            "conv_C": xs_tail(Cr, cfg.ssm_conv),
            "ssm": final_state,
        }
        return out, conv_tail
    return out, None


def xs_tail(x, width):
    """Last (width-1) raw inputs, as the decode conv state."""
    return x[:, -(width - 1):, :]


def _gate_norm_out(p, cfg, y, z):
    y = y * jax.nn.silu(z.astype(jnp.float32))
    # gated RMSNorm over d_inner
    y = y * jax.lax.rsqrt(jnp.mean(y * y, axis=-1, keepdims=True) + cfg.norm_eps)
    y = y * p["gnorm"].astype(jnp.float32)
    y = y.astype(z.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["wo"].astype(z.dtype))
    return shd.constrain(out, "batch", "seq", "embed")


def mamba2_decode(p, cfg, x1, state):
    """One-token recurrent step. x1: (B, 1, D); state: see mamba2_state_defs."""
    B = x1.shape[0]
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    z, xs, Bp, Cp, dt = _project(p, cfg, x1)

    def step_conv(buf, new, w):
        # buf: (B, W-1, C); new: (B, 1, C) -> (out (B,C), new_buf)
        full = jnp.concatenate([buf, new], axis=1)  # (B, W, C)
        out = jnp.einsum("bwc,wc->bc", full, w)
        return out, full[:, 1:, :]

    cx, ncx = step_conv(state["conv_x"].astype(xs.dtype), xs,
                        p["conv_x"].astype(xs.dtype))
    cB, ncB = step_conv(state["conv_B"].astype(Bp.dtype), Bp,
                        p["conv_B"].astype(Bp.dtype))
    cC, ncC = step_conv(state["conv_C"].astype(Cp.dtype), Cp,
                        p["conv_C"].astype(Cp.dtype))
    cx, cB, cC = jax.nn.silu(cx), jax.nn.silu(cB), jax.nn.silu(cC)

    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dts = jax.nn.softplus(
        dt[:, 0].astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )  # (B,H)
    dA = jnp.exp(dts * A)  # (B,H)
    xh = cx.reshape(B, H, P).astype(jnp.float32)
    ssm = state["ssm"] * dA[..., None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dts, xh, cB.astype(jnp.float32)
    )
    y = jnp.einsum("bhpn,bn->bhp", ssm, cC.astype(jnp.float32))
    y = y + xh * p["D_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(B, 1, cfg.d_inner)
    out = _gate_norm_out(p, cfg, y, z)
    new_state = {"conv_x": ncx, "conv_B": ncB, "conv_C": ncC, "ssm": ssm}
    return out, new_state
