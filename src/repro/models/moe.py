"""Top-k mixture-of-experts FFN (Mixtral-style).

Capacity-based dispatch: per sequence, each token's top-k expert assignments
are packed into (E, C) slots via a cumulative-position scatter, experts run as
a batched matmul over their capacity slice, and results scatter back weighted
by the (renormalized) router probabilities.  Compiled FLOPs therefore track
``capacity_factor × active`` FLOPs — there is no O(T·E·C) one-hot dispatch
einsum and no ragged op (keeps the CPU dry-run backend happy).  Expert weights
are (E, D, F) with F tensor-parallel over 'model' and D FSDP over 'data'.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import sharding as shd
from repro.models.params import ParamDef

__all__ = ["moe_defs", "moe_apply"]


def moe_defs(cfg) -> dict:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    return {
        "router": ParamDef((D, E), ("embed", ""), dtype=jnp.float32),
        "wg": ParamDef((E, D, F), ("", "embed", "mlp")),
        "wu": ParamDef((E, D, F), ("", "embed", "mlp")),
        "wd": ParamDef((E, F, D), ("", "mlp", "embed")),
    }


def moe_apply(p, cfg, x):
    """x: (B, S, D) -> (out, aux_loss)."""
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    C = max(1, int(round(cfg.capacity_factor * S * K / E)))
    dt = x.dtype

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)  # (B, S, K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # load-balance auxiliary loss (Switch-style)
    me = probs.mean(axis=(0, 1))  # (E,)
    ce = jax.nn.one_hot(top_e[..., 0], E).mean(axis=(0, 1))
    aux = E * jnp.sum(me * ce)

    # ---- dispatch: pack assignments into (E, C) capacity slots ----------
    fe = top_e.reshape(B, S * K)
    fw = top_p.reshape(B, S * K).astype(dt)
    onehot = jax.nn.one_hot(fe, E, dtype=jnp.int32)  # (B, S*K, E)
    pos = (jnp.cumsum(onehot, axis=1) - 1) * onehot
    pos = pos.sum(-1)  # (B, S*K) position within the chosen expert
    keep = pos < C
    slot = jnp.where(keep, fe * C + pos, E * C)  # E*C = overflow slot

    tok = jnp.broadcast_to(jnp.arange(S * K, dtype=jnp.int32) // K, (B, S * K))
    src = jnp.full((B, E * C + 1), S, jnp.int32)  # S = zero sentinel row
    src = jax.vmap(lambda s, sl, ti: s.at[sl].set(ti, mode="drop"))(src, slot, tok)

    xpad = jnp.concatenate([x, jnp.zeros((B, 1, D), dt)], axis=1)
    xe = jnp.take_along_axis(xpad, src[:, : E * C, None], axis=1)
    xe = xe.reshape(B, E, C, D)
    xe = shd.constrain(xe, "batch", "", "", "embed")

    # ---- expert FFN (batched over experts) -------------------------------
    g = jnp.einsum("becd,edf->becf", xe, p["wg"].astype(dt))
    u = jnp.einsum("becd,edf->becf", xe, p["wu"].astype(dt))
    h = jax.nn.silu(g) * u
    h = shd.constrain(h, "batch", "", "", "mlp")
    y = jnp.einsum("becf,efd->becd", h, p["wd"].astype(dt))

    # ---- combine ----------------------------------------------------------
    yflat = jnp.concatenate(
        [y.reshape(B, E * C, D), jnp.zeros((B, 1, D), dt)], axis=1
    )
    gathered = jnp.take_along_axis(yflat, slot[..., None], axis=1)  # (B, S*K, D)
    gathered = gathered * (fw * keep.astype(dt))[..., None]
    out = gathered.reshape(B, S, K, D).sum(axis=2)
    return shd.constrain(out, "batch", "seq", "embed"), aux
