"""RWKV6 (Finch) — attention-free time-mix with data-dependent decay.

Implements the Finch recurrence

    out_t = r_t · (S_{t-1} + diag(u) k_tᵀ v_t)
    S_t   = diag(w_t) S_{t-1} + k_tᵀ v_t          (w_t per key channel)

in three forms sharing one parameter set:

* ``wkv_sequential`` — the O(S) per-step oracle (tests only),
* ``wkv_chunked``    — the parallel training/prefill form: sequence chunks of
  ``Q`` positions, sub-blocks of ``q`` inside each chunk. All decay factors are
  expressed as ``exp(Δ)`` with Δ ≤ 0 by factoring every cross-position decay
  through a boundary that lies between source and target (the same trick the
  GLA/FLA chunked kernels use), so nothing overflows regardless of how extreme
  the learned decays are.
* ``wkv_decode``     — the O(1) recurrent decode update.

Token-shift ("ddlerp") and the decay LoRA follow the published Finch
formulation; LayerNorms are replaced by RMSNorm for codebase uniformity
(recorded in DESIGN.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import sharding as shd
from repro.models.params import ParamDef

__all__ = [
    "rwkv_defs",
    "rwkv_state_defs",
    "rwkv_block",
    "rwkv_block_decode",
    "wkv_sequential",
    "wkv_chunked",
    "wkv_decode",
]

N_MIX = 5  # w, k, v, r, g token-shift mixes


# --------------------------------------------------------------------------
# parameter / state definitions
# --------------------------------------------------------------------------
def rwkv_defs(cfg) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    H, K = cfg.num_heads, cfg.head_dim
    R, Rd = cfg.rwkv_lora_dim, cfg.rwkv_decay_lora_dim
    return {
        "norm_tm": ParamDef((D,), ("embed",), init="ones"),
        "norm_cm": ParamDef((D,), ("embed",), init="ones"),
        # time-mix token shift (ddlerp)
        "mu_x": ParamDef((D,), ("embed",), init="zeros"),
        "mu5": ParamDef((N_MIX, D), ("", "embed"), init="zeros"),
        "tm_w1": ParamDef((D, N_MIX * R), ("embed", ""), scale=0.01),
        "tm_w2": ParamDef((N_MIX, R, D), ("", "", "embed"), scale=0.01),
        # data-dependent decay
        "w0": ParamDef((D,), ("embed",), init="zeros"),
        "td_w1": ParamDef((D, Rd), ("embed", ""), scale=0.01),
        "td_w2": ParamDef((Rd, D), ("", "embed"), scale=0.01),
        "u": ParamDef((H, K), ("heads", ""), init="zeros"),
        # projections
        "wr": ParamDef((D, D), ("embed", "tp")),
        "wk": ParamDef((D, D), ("embed", "tp")),
        "wv": ParamDef((D, D), ("embed", "tp")),
        "wg": ParamDef((D, D), ("embed", "tp")),
        "wo": ParamDef((D, D), ("tp", "embed")),
        "ln_x": ParamDef((D,), ("embed",), init="ones"),
        # channel-mix
        "mu_k": ParamDef((D,), ("embed",), init="zeros"),
        "mu_r": ParamDef((D,), ("embed",), init="zeros"),
        "cm_k": ParamDef((D, F), ("embed", "mlp")),
        "cm_v": ParamDef((F, D), ("mlp", "embed")),
        "cm_r": ParamDef((D, D), ("embed", "tp")),
    }


def rwkv_state_defs(cfg, batch: int) -> dict:
    """Decode-state layout for one layer."""
    D, H, K = cfg.d_model, cfg.num_heads, cfg.head_dim
    return {
        "wkv": ParamDef((batch, H, K, K), ("batch", "heads", "", ""),
                        dtype=jnp.float32, init="zeros"),
        "shift_tm": ParamDef((batch, D), ("batch", "embed"), init="zeros"),
        "shift_cm": ParamDef((batch, D), ("batch", "embed"), init="zeros"),
    }


# --------------------------------------------------------------------------
# wkv cores
# --------------------------------------------------------------------------
def wkv_sequential(r, k, v, logw, u, state):
    """Oracle: explicit per-step recurrence.

    r/k/v/logw: (B, S, H, K) fp32; u: (H, K); state: (B, H, K, K).
    Returns (out (B, S, H, K), final_state).
    """

    def step(s, xs):
        rt, kt, vt, lw = xs  # (B, H, K)
        kv = kt[..., :, None] * vt[..., None, :]  # (B,H,K,V)
        out = jnp.einsum("bhk,bhkv->bhv", rt, s + u[None, :, :, None] * kv)
        s = jnp.exp(lw)[..., None] * s + kv
        return s, out

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, logw))
    state, out = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(out, 0, 1), state


def wkv_chunked(r, k, v, logw, u, state, *, chunk: int = 64, sub: int = 16):
    """Parallel chunked form; exact (up to fp) match of ``wkv_sequential``.

    All tensors fp32. r/k/v/logw: (B, S, H, K); state: (B, H, K, V=K).
    """
    B, S, H, K = r.shape
    Q = min(chunk, S)
    if S % Q:
        Q = S
    q = min(sub, Q)
    if Q % q:
        q = Q
    ns = Q // q
    NC = S // Q

    def per_chunk(state, xs):
        rc, kc, vc, lw = xs  # (B, Q, H, K)
        rc = shd.constrain(rc, "batch", "", "", "")
        kc = shd.constrain(kc, "batch", "", "", "")
        L = jnp.cumsum(lw, axis=1)  # inclusive log-decay
        Lex = L - lw  # exclusive
        Lend = L[:, -1]  # (B, H, K)

        # --- inter-chunk: carried state projected onto every position -----
        out = jnp.einsum("bqhk,bhkv->bqhv", rc * jnp.exp(Lex), state)

        # --- cross-sub-block (within chunk), boundary-factored -------------
        # boundary log-decay at the start of each target sub-block
        Lb = jnp.concatenate(
            [jnp.zeros((B, 1, H, K), L.dtype), L[:, q - 1 :: q][:, : ns - 1]],
            axis=1,
        )  # (B, ns, H, K);  Lb[j] = L at position j*q - 1 (0 for j=0)
        rg = rc.reshape(B, ns, q, H, K)
        Lexg = Lex.reshape(B, ns, q, H, K)
        r2 = rg * jnp.exp(jnp.minimum(Lexg - Lb[:, :, None], 0.0))
        # k2[j, s] = k_s · exp(Lb[j] - L_s), masked to s < j*q
        k2 = k_dec = jnp.exp(jnp.minimum(Lb[:, :, None] - L[:, None], 0.0))
        k2 = kc[:, None] * k_dec  # (B, ns, Q, H, K)
        smask = jnp.arange(Q)[None, :] < (jnp.arange(ns) * q)[:, None]  # (ns, Q)
        att_x = jnp.einsum("bjthk,bjshk->bjhts", r2, k2)
        att_x = att_x * smask[None, :, None, None, :]
        out_x = jnp.einsum("bjhts,bshv->bjthv", att_x, vc)
        out = out + out_x.reshape(B, Q, H, K)

        # --- diagonal sub-blocks: explicit log-diff (t, s in same block) --
        kg = kc.reshape(B, ns, q, H, K)
        vg = vc.reshape(B, ns, q, H, K)
        Lg = L.reshape(B, ns, q, H, K)
        Ldiff = jnp.minimum(Lexg[:, :, :, None] - Lg[:, :, None], 0.0)
        # (B, ns, t, s, H, K)
        tri = jnp.tril(jnp.ones((q, q), bool), -1)
        att_d = jnp.einsum(
            "bjthk,bjshk,bjtshk->bjhts",
            rg, kg, jnp.where(tri[None, None, :, :, None, None], jnp.exp(Ldiff), 0.0),
        )
        out_d = jnp.einsum("bjhts,bjshv->bjthv", att_d, vg)
        # u-bonus diagonal (s == t)
        out_u = (rg * u[None, None, None] * kg).sum(-1, keepdims=True) * vg
        out = out + (out_d + out_u).reshape(B, Q, H, K)

        # --- state update --------------------------------------------------
        kdec = kc * jnp.exp(jnp.minimum(Lend[:, None] - L, 0.0))
        state = state * jnp.exp(Lend)[..., None] + jnp.einsum(
            "bqhk,bqhv->bhkv", kdec, vc
        )
        return state, out

    xs = tuple(
        jnp.moveaxis(t.reshape(B, NC, Q, H, K), 1, 0) for t in (r, k, v, logw)
    )
    # checkpoint each chunk: backward recomputes the (B, ns, q, q, H, K)
    # intra-chunk tensors instead of saving them for every chunk — without
    # this, 32k-token training stores O(S·q·K) fp32 residuals per layer.
    state, outs = jax.lax.scan(jax.checkpoint(per_chunk), state, xs)
    return jnp.moveaxis(outs, 0, 1).reshape(B, S, H, K), state


def wkv_decode(r1, k1, v1, logw1, u, state):
    """One-token update. r1/k1/v1/logw1: (B, H, K); state: (B, H, K, V)."""
    kv = k1[..., :, None] * v1[..., None, :]
    out = jnp.einsum("bhk,bhkv->bhv", r1, state + u[None, :, :, None] * kv)
    state = jnp.exp(logw1)[..., None] * state + kv
    return out, state


# --------------------------------------------------------------------------
# full block (time-mix + channel-mix)
# --------------------------------------------------------------------------
def _ddlerp(p, x, xprev):
    """Finch data-dependent token-shift. Returns the 5 mixed inputs."""
    B, S, D = x.shape
    xx = xprev - x
    xxx = x + xx * p["mu_x"].astype(x.dtype)
    lora = jnp.tanh(jnp.einsum("bsd,dr->bsr", xxx, p["tm_w1"].astype(x.dtype)))
    lora = lora.reshape(B, S, N_MIX, -1)
    deltas = jnp.einsum("bsmr,mrd->bsmd", lora, p["tm_w2"].astype(x.dtype))
    mixed = x[:, :, None] + xx[:, :, None] * (
        p["mu5"].astype(x.dtype)[None, None] + deltas
    )
    return [mixed[:, :, i] for i in range(N_MIX)]


def _decay(p, xw):
    ww = p["w0"].astype(jnp.float32) + jnp.einsum(
        "bsd,dr->bsr", xw.astype(jnp.float32), p["td_w1"].astype(jnp.float32)
    ) @ p["td_w2"].astype(jnp.float32)
    return -jnp.exp(jnp.clip(ww, -20.0, 20.0))  # log w  (strictly < 0)


def _head_norm(p, cfg, y):
    """Per-head RMS norm of the wkv output (stands in for Finch's GroupNorm)."""
    B, S, H, K = y.shape
    y = y * jax.lax.rsqrt(jnp.mean(y * y, axis=-1, keepdims=True) + 64e-5)
    return y.reshape(B, S, H * K) * p["ln_x"].astype(y.dtype)


def _time_mix(p, cfg, x, xprev, wkv_state, *, decode: bool):
    from repro.models.layers import rms_norm  # local import to avoid cycle

    B, S, D = x.shape
    H, K = cfg.num_heads, cfg.head_dim
    xw, xk, xv, xr, xg = _ddlerp(p, x, xprev)
    dt = x.dtype
    r = jnp.einsum("bsd,de->bse", xr, p["wr"].astype(dt)).reshape(B, S, H, K)
    k = jnp.einsum("bsd,de->bse", xk, p["wk"].astype(dt)).reshape(B, S, H, K)
    v = jnp.einsum("bsd,de->bse", xv, p["wv"].astype(dt)).reshape(B, S, H, K)
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p["wg"].astype(dt)))
    logw = _decay(p, xw).reshape(B, S, H, K)
    r32, k32, v32 = (t.astype(jnp.float32) for t in (r, k, v))
    u = p["u"].astype(jnp.float32)
    if decode:
        y, wkv_state = wkv_decode(
            r32[:, 0], k32[:, 0], v32[:, 0], logw[:, 0], u, wkv_state
        )
        y = y[:, None]
    else:
        r32 = shd.constrain(r32, "batch", "seq", "heads", "head_dim")
        y, wkv_state = wkv_chunked(r32, k32, v32, logw, u, wkv_state)
    y = _head_norm(p, cfg, y).astype(dt) * g
    out = jnp.einsum("bse,ed->bsd", y, p["wo"].astype(dt))
    return shd.constrain(out, "batch", "seq", "embed"), wkv_state


def _channel_mix(p, cfg, x, xprev):
    dt = x.dtype
    xx = xprev - x
    xk = x + xx * p["mu_k"].astype(dt)
    xr = x + xx * p["mu_r"].astype(dt)
    kk = jnp.einsum("bsd,df->bsf", xk, p["cm_k"].astype(dt))
    kk = jnp.square(jax.nn.relu(kk))
    kk = shd.constrain(kk, "batch", "seq", "mlp")
    kv = jnp.einsum("bsf,fd->bsd", kk, p["cm_v"].astype(dt))
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["cm_r"].astype(dt)))
    return shd.constrain(rr * kv, "batch", "seq", "embed")


def _shifted(x, first):
    """x_{t-1} with ``first`` (B, D) in slot 0."""
    return jnp.concatenate([first[:, None], x[:, :-1]], axis=1)


def rwkv_block(p, cfg, x, state=None):
    """Full-sequence block. x: (B, S, D). state: rwkv_state_defs layout or None.

    Returns (x_out, new_state | None).
    """
    from repro.models.layers import rms_norm

    B, S, D = x.shape
    H, K = cfg.num_heads, cfg.head_dim
    if state is None:
        wkv0 = jnp.zeros((B, H, K, K), jnp.float32)
        sh_tm = jnp.zeros((B, D), x.dtype)
        sh_cm = jnp.zeros((B, D), x.dtype)
        keep = False
    else:
        wkv0, sh_tm, sh_cm = (
            state["wkv"], state["shift_tm"].astype(x.dtype),
            state["shift_cm"].astype(x.dtype),
        )
        keep = True
    h = rms_norm(x, p["norm_tm"], cfg.norm_eps)
    tm_out, wkv = _time_mix(p, cfg, h, _shifted(h, sh_tm), wkv0, decode=False)
    x = x + tm_out
    h2 = rms_norm(x, p["norm_cm"], cfg.norm_eps)
    x = x + _channel_mix(p, cfg, h2, _shifted(h2, sh_cm))
    new_state = None
    if keep or state is None:
        new_state = {"wkv": wkv, "shift_tm": h[:, -1], "shift_cm": h2[:, -1]}
    return x, new_state


def rwkv_block_decode(p, cfg, x1, state):
    """One-token block. x1: (B, 1, D); state per rwkv_state_defs."""
    from repro.models.layers import rms_norm

    h = rms_norm(x1, p["norm_tm"], cfg.norm_eps)
    tm_out, wkv = _time_mix(
        p, cfg, h, state["shift_tm"].astype(h.dtype)[:, None], state["wkv"],
        decode=True,
    )
    x1 = x1 + tm_out
    h2 = rms_norm(x1, p["norm_cm"], cfg.norm_eps)
    cm_out = _channel_mix(
        p, cfg, h2, state["shift_cm"].astype(h2.dtype)[:, None]
    )
    x1 = x1 + cm_out
    return x1, {"wkv": wkv, "shift_tm": h[:, 0], "shift_cm": h2[:, 0]}
