"""Model assembly for the architecture zoo.

One functional LM covering six families behind a single interface:

* ``model_defs(cfg)``              — ParamDef tree (scan-stacked layers)
* ``init_params`` / ``abstract_params``
* ``forward(params, cfg, tokens, cond=..., mode="train")`` — full-sequence
  forward; ``mode="prefill"`` additionally returns a decode cache
* ``lm_loss(params, cfg, batch)``  — next-token xent (+ MoE aux)
* ``cache_defs(cfg, batch, max_len)`` — decode-state ParamDef tree
* ``decode_step(params, cfg, cache, token, pos)`` — one serving step

Families:
  dense  — [norm→attn, norm→mlp] or Cohere-style parallel block
  moe    — attention + top-k expert FFN (SWA rolling KV)
  audio  — musicgen: self-attn + cross-attn (text cond) + mlp, every layer
  vlm    — llama-3.2-vision: cross-attn image block before every 5th layer
  hybrid — zamba2: Mamba2 backbone, weight-shared attn+mlp block every 6
  ssm    — rwkv6: time-mix + channel-mix

All full-sequence paths scan over stacked layer parameters (compile time is
O(1) in depth) with a configurable remat policy.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro import sharding as shd
from repro.models import layers as lyr
from repro.models import mamba2 as mb
from repro.models import moe as moe_mod
from repro.models import rwkv6 as rwkv
from repro.models.params import ParamDef, abstractify, count_params, materialize

__all__ = [
    "model_defs",
    "init_params",
    "abstract_params",
    "forward",
    "lm_loss",
    "cache_defs",
    "decode_step",
    "param_count",
    "active_param_count",
    "zamba_groups",
]


# --------------------------------------------------------------------------
# parameter trees
# --------------------------------------------------------------------------
def _stack(defs, n: int):
    """Add a leading stacked-layers axis to every ParamDef in a tree."""
    return jax.tree_util.tree_map(
        lambda d: dataclasses.replace(
            d, shape=(n,) + d.shape, logical=("layers",) + d.logical
        ),
        defs,
        is_leaf=lambda x: isinstance(x, ParamDef),
    )


def _norm_def(cfg):
    return ParamDef((cfg.d_model,), ("embed",), init="ones")


def _dense_layer_defs(cfg) -> dict:
    d = {"norm1": _norm_def(cfg), "attn": lyr.attn_defs(cfg)}
    if cfg.parallel_block:
        d["mlp"] = lyr.mlp_defs(cfg)
    else:
        d["norm2"] = _norm_def(cfg)
        d["mlp"] = lyr.mlp_defs(cfg)
    return d


def _moe_layer_defs(cfg) -> dict:
    return {
        "norm1": _norm_def(cfg),
        "attn": lyr.attn_defs(cfg),
        "norm2": _norm_def(cfg),
        "moe": moe_mod.moe_defs(cfg),
    }


def _audio_layer_defs(cfg) -> dict:
    return {
        "norm1": _norm_def(cfg),
        "attn": lyr.attn_defs(cfg),
        "norm_x": _norm_def(cfg),
        "xattn": lyr.attn_defs(cfg),
        "norm2": _norm_def(cfg),
        "mlp": lyr.mlp_defs(cfg),
    }


def _cross_block_defs(cfg) -> dict:
    return {"norm_x": _norm_def(cfg), "xattn": lyr.attn_defs(cfg, cross=True)}


def zamba_groups(cfg) -> list[int]:
    """Mamba-layer counts between shared-block applications."""
    every = cfg.shared_attn_every
    L = cfg.num_layers
    out = []
    while L > 0:
        out.append(min(every, L))
        L -= every
    return out


def model_defs(cfg) -> dict:
    d = {"embed": lyr.embed_defs(cfg), "final_norm": _norm_def(cfg)}
    fam = cfg.family
    L = cfg.num_layers
    if fam == "dense":
        d["layers"] = _stack(_dense_layer_defs(cfg), L)
    elif fam == "moe":
        d["layers"] = _stack(_moe_layer_defs(cfg), L)
    elif fam == "audio":
        d["layers"] = _stack(_audio_layer_defs(cfg), L)
    elif fam == "vlm":
        d["layers"] = _stack(_dense_layer_defs(cfg), L)
        d["cross"] = _stack(_cross_block_defs(cfg), L // cfg.cross_attn_every)
    elif fam == "hybrid":
        d["layers"] = _stack(mb.mamba2_defs(cfg), L)
        d["shared"] = {
            "norm1": _norm_def(cfg),
            "attn": lyr.attn_defs(cfg),
            "norm2": _norm_def(cfg),
            "mlp": lyr.mlp_defs(cfg),
        }
    elif fam == "ssm":
        d["layers"] = _stack(rwkv.rwkv_defs(cfg), L)
    else:  # pragma: no cover
        raise ValueError(f"unknown family {fam!r}")
    return d


def init_params(cfg, key):
    return materialize(model_defs(cfg), key)


def abstract_params(cfg):
    return abstractify(model_defs(cfg))


def param_count(cfg) -> int:
    return count_params(model_defs(cfg))


def active_param_count(cfg) -> int:
    """Params touched per token (MoE: top-k of E experts)."""
    n = param_count(cfg)
    if cfg.num_experts:
        expert = 3 * cfg.d_model * cfg.d_ff  # wg, wu, wd
        inactive = cfg.num_layers * (cfg.num_experts - cfg.num_experts_per_tok) * expert
        n -= inactive
    return n


# --------------------------------------------------------------------------
# remat
# --------------------------------------------------------------------------
def _maybe_remat(fn, cfg):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return jax.checkpoint(fn)  # "nothing": recompute everything


# --------------------------------------------------------------------------
# layer bodies (full sequence)
# --------------------------------------------------------------------------
def _apply_dense(pl, cfg, x, positions):
    h = lyr.rms_norm(x, pl["norm1"], cfg.norm_eps)
    attn_out, kv = lyr.self_attention(pl["attn"], cfg, h, positions,
                                      window=cfg.sliding_window)
    if cfg.parallel_block:
        x = x + attn_out + lyr.mlp_apply(pl["mlp"], cfg, h)
    else:
        x = x + attn_out
        h2 = lyr.rms_norm(x, pl["norm2"], cfg.norm_eps)
        x = x + lyr.mlp_apply(pl["mlp"], cfg, h2)
    return x, kv


def _apply_moe(pl, cfg, x, positions):
    h = lyr.rms_norm(x, pl["norm1"], cfg.norm_eps)
    attn_out, kv = lyr.self_attention(pl["attn"], cfg, h, positions,
                                      window=cfg.sliding_window)
    x = x + attn_out
    h2 = lyr.rms_norm(x, pl["norm2"], cfg.norm_eps)
    moe_out, aux = moe_mod.moe_apply(pl["moe"], cfg, h2)
    return x + moe_out, kv, aux


def _apply_cross(pl, cfg, x, cond):
    """Cross-attention sub-block; KV computed from the conditioning stream."""
    h = lyr.rms_norm(x, pl["norm_x"], cfg.norm_eps)
    k, v = lyr.attn_project_kv(pl["xattn"], cfg, cond, None, rope=False)
    out = lyr.cross_attention(pl["xattn"], cfg, h, (k, v))
    return x + out, (k, v)


def _apply_audio(pl, cfg, x, positions, cond):
    h = lyr.rms_norm(x, pl["norm1"], cfg.norm_eps)
    attn_out, kv = lyr.self_attention(pl["attn"], cfg, h, positions)
    x = x + attn_out
    x, xkv = _apply_cross({"norm_x": pl["norm_x"], "xattn": pl["xattn"]}, cfg, x, cond)
    h2 = lyr.rms_norm(x, pl["norm2"], cfg.norm_eps)
    x = x + lyr.mlp_apply(pl["mlp"], cfg, h2)
    return x, kv, xkv


def _apply_shared(ps, cfg, x, positions):
    """Zamba2 weight-shared attention+MLP block."""
    h = lyr.rms_norm(x, ps["norm1"], cfg.norm_eps)
    attn_out, kv = lyr.self_attention(ps["attn"], cfg, h, positions)
    x = x + attn_out
    h2 = lyr.rms_norm(x, ps["norm2"], cfg.norm_eps)
    x = x + lyr.mlp_apply(ps["mlp"], cfg, h2)
    return x, kv


# --------------------------------------------------------------------------
# full-sequence forward
# --------------------------------------------------------------------------
def forward(params, cfg, tokens, *, cond=None, mode: str = "train"):
    """tokens: (B, S) int32; cond: (B, n_cross, D) for vlm/audio.

    Returns (hidden (B, S, D), aux_loss, cache_parts) where cache_parts is a
    dict of per-layer KV/state stacks when ``mode == "prefill"`` else {}.
    """
    B, S = tokens.shape
    want = mode == "prefill"
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = lyr.embed_apply(params["embed"], cfg, tokens)
    aux = jnp.float32(0.0)
    parts: dict = {}
    fam = cfg.family

    if fam in ("dense", "vlm"):
        def body(x, pl):
            x, kv = _apply_dense(pl, cfg, x, positions)
            return x, kv if want else None

        if fam == "dense":
            x, kvs = jax.lax.scan(_maybe_remat(body, cfg), x, params["layers"])
            if want:
                parts["k"], parts["v"] = kvs
        else:  # vlm: cross block + `every` self layers per group
            every = cfg.cross_attn_every
            ng = cfg.num_layers // every
            grouped = jax.tree_util.tree_map(
                lambda a: a.reshape((ng, every) + a.shape[1:]), params["layers"]
            )

            cross_fn = _maybe_remat(
                lambda x_, pc: _apply_cross(pc, cfg, x_, cond), cfg
            )

            def group(x, xs):
                pc, pg = xs
                x, xkv = cross_fn(x, pc)
                x, kvs = jax.lax.scan(_maybe_remat(body, cfg), x, pg)
                return x, (kvs, xkv) if want else None

            x, ys = jax.lax.scan(group, x, (params["cross"], grouped))
            if want:
                (k, v), (xk, xv) = ys[0], ys[1]
                parts["k"] = k.reshape((cfg.num_layers,) + k.shape[2:])
                parts["v"] = v.reshape((cfg.num_layers,) + v.shape[2:])
                parts["cross_k"], parts["cross_v"] = xk, xv

    elif fam == "moe":
        def body(carry, pl):
            x, aux = carry
            x, kv, a = _apply_moe(pl, cfg, x, positions)
            return (x, aux + a), kv if want else None

        (x, aux), kvs = jax.lax.scan(
            _maybe_remat(body, cfg), (x, aux), params["layers"]
        )
        if want:
            parts["k"], parts["v"] = kvs

    elif fam == "audio":
        def body(x, pl):
            x, kv, xkv = _apply_audio(pl, cfg, x, positions, cond)
            return x, (kv, xkv) if want else None

        x, ys = jax.lax.scan(_maybe_remat(body, cfg), x, params["layers"])
        if want:
            (parts["k"], parts["v"]), (parts["cross_k"], parts["cross_v"]) = ys

    elif fam == "hybrid":
        def mbody(x, pl):
            h = lyr.rms_norm(x, pl["norm"], cfg.norm_eps)
            out, st = mb.mamba2_apply(pl, cfg, h, return_state=want)
            return x + out, st

        groups = zamba_groups(cfg)
        shared_fn = _maybe_remat(
            lambda x_, ps: _apply_shared(ps, cfg, x_, positions), cfg
        )
        skv, states = [], []
        start = 0
        for cnt in groups:
            x, kv = shared_fn(x, params["shared"])
            sl = jax.tree_util.tree_map(
                lambda a: a[start : start + cnt], params["layers"]
            )
            x, st = jax.lax.scan(_maybe_remat(mbody, cfg), x, sl)
            start += cnt
            if want:
                skv.append(kv)
                states.append(st)
        if want:
            parts["shared_k"] = jnp.stack([k for k, _ in skv])
            parts["shared_v"] = jnp.stack([v for _, v in skv])
            parts["mamba"] = jax.tree_util.tree_map(
                lambda *xs: jnp.concatenate(xs), *states
            )

    elif fam == "ssm":
        def body(x, pl):
            x, st = rwkv.rwkv_block(pl, cfg, x)
            return x, st if want else None

        x, states = jax.lax.scan(_maybe_remat(body, cfg), x, params["layers"])
        if want:
            parts["rwkv"] = states

    else:  # pragma: no cover
        raise ValueError(fam)

    x = lyr.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, aux, parts


def lm_loss(params, cfg, batch):
    """batch: {"tokens": (B,S), "labels": (B,S)[, "cond": (B,n,D)]}."""
    x, aux, _ = forward(
        params, cfg, batch["tokens"], cond=batch.get("cond"), mode="train"
    )
    loss = lyr.softmax_xent_chunked(params["embed"], cfg, x, batch["labels"])
    return loss + 0.01 * aux


# --------------------------------------------------------------------------
# decode caches
# --------------------------------------------------------------------------
def _kv_int8(cfg) -> bool:
    return cfg.kv_cache_dtype == "int8"


def _kv_cache_def(cfg, n_layers, batch, W, *, quantizable: bool = True):
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    dtype = jnp.int8 if (quantizable and _kv_int8(cfg)) else cfg.dtype
    return ParamDef(
        (n_layers, batch, W, KV, hd),
        ("layers", "batch", "kvseq", "heads", "head_dim"),
        dtype=dtype,
        init="zeros",
    )


def _kv_scale_def(cfg, n_layers, batch, W):
    return ParamDef(
        (n_layers, batch, W, cfg.num_kv_heads),
        ("layers", "batch", "kvseq", "heads"),
        dtype=jnp.float32,
        init="zeros",
    )


def cache_defs(cfg, batch: int, max_len: int) -> dict:
    """Decode-state ParamDef tree. ``max_len`` is the KV window the serving
    shape demands; SWA archs cap it at their window (rolling buffer)."""
    fam = cfg.family
    L = cfg.num_layers
    d: dict = {}
    W = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    kv_pos = ParamDef((batch, W), ("batch", "kvseq"), dtype=jnp.int32,
                      init="unwritten")
    if fam in ("dense", "moe", "audio", "vlm"):
        d["k"] = _kv_cache_def(cfg, L, batch, W)
        d["v"] = _kv_cache_def(cfg, L, batch, W)
        d["kv_pos"] = kv_pos
        if _kv_int8(cfg):
            d["k_scale"] = _kv_scale_def(cfg, L, batch, W)
            d["v_scale"] = _kv_scale_def(cfg, L, batch, W)
    if fam in ("audio", "vlm"):
        nx = L if cfg.cross_attn_all_layers else L // cfg.cross_attn_every
        # cross KV stays bf16 (small, computed once per request)
        d["cross_k"] = _kv_cache_def(cfg, nx, batch, cfg.n_cross_tokens,
                                     quantizable=False)
        d["cross_v"] = _kv_cache_def(cfg, nx, batch, cfg.n_cross_tokens,
                                     quantizable=False)
    if fam == "hybrid":
        d["mamba"] = _stack(mb.mamba2_state_defs(cfg, batch), L)
        ns = len(zamba_groups(cfg))
        d["shared_k"] = _kv_cache_def(cfg, ns, batch, W)
        d["shared_v"] = _kv_cache_def(cfg, ns, batch, W)
        d["kv_pos"] = kv_pos
    if fam == "ssm":
        d["rwkv"] = _stack(rwkv.rwkv_state_defs(cfg, batch), L)
    return d


def init_cache(cfg, batch: int, max_len: int):
    """Materialized zero cache (kv_pos slots marked unwritten)."""
    defs = cache_defs(cfg, batch, max_len)

    def make(d: ParamDef):
        if d.init == "unwritten":
            return jnp.full(d.shape, 2**30, d.dtype)
        return jnp.zeros(d.shape, d.dtype)

    return jax.tree_util.tree_map(
        make, defs, is_leaf=lambda x: isinstance(x, ParamDef)
    )


def abstract_cache(cfg, batch: int, max_len: int):
    return abstractify(cache_defs(cfg, batch, max_len))


# --------------------------------------------------------------------------
# one-token decode
# --------------------------------------------------------------------------
def decode_step(params, cfg, cache, token, pos):
    """token: (B, 1) int32; pos: (B,) int32. Returns (logits (B, V), cache)."""
    fam = cfg.family
    x = lyr.embed_apply(params["embed"], cfg, token)
    new_cache = dict(cache)
    win = cfg.sliding_window

    if "kv_pos" in cache:
        kv_pos = lyr.write_kv_pos(cache["kv_pos"], pos, window=win)
        new_cache["kv_pos"] = kv_pos

    int8 = _kv_int8(cfg)

    def _kv_xs(kc, vc):
        if int8:
            return (kc, vc, cache["k_scale"], cache["v_scale"])
        return (kc, vc, None, None)

    def _store_kv(nc, ys):
        if int8:
            nc["k"], nc["v"], nc["k_scale"], nc["v_scale"] = ys
        else:
            nc["k"], nc["v"] = ys[0], ys[1]

    if fam in ("dense", "moe"):
        def body(x, xs):
            pl, kc, vc, ks, vs = xs
            h = lyr.rms_norm(x, pl["norm1"], cfg.norm_eps)
            a, kc, vc, ks, vs = lyr.decode_self_attention(
                pl["attn"], cfg, h, kc, vc, kv_pos, pos, window=win,
                k_scale=ks, v_scale=vs,
            )
            if fam == "moe":
                x = x + a
                h2 = lyr.rms_norm(x, pl["norm2"], cfg.norm_eps)
                m, _ = moe_mod.moe_apply(pl["moe"], cfg, h2)
                x = x + m
            elif cfg.parallel_block:
                x = x + a + lyr.mlp_apply(pl["mlp"], cfg, h)
            else:
                x = x + a
                h2 = lyr.rms_norm(x, pl["norm2"], cfg.norm_eps)
                x = x + lyr.mlp_apply(pl["mlp"], cfg, h2)
            return x, (kc, vc) + ((ks, vs) if int8 else ())

        x, ys = jax.lax.scan(
            body, x, (params["layers"],) + _kv_xs(cache["k"], cache["v"])
        )
        _store_kv(new_cache, ys)

    elif fam == "audio":
        def body(x, xs):
            pl, kc, vc, ks, vs, xk, xv = xs
            h = lyr.rms_norm(x, pl["norm1"], cfg.norm_eps)
            a, kc, vc, ks, vs = lyr.decode_self_attention(
                pl["attn"], cfg, h, kc, vc, kv_pos, pos,
                k_scale=ks, v_scale=vs,
            )
            x = x + a
            h2 = lyr.rms_norm(x, pl["norm_x"], cfg.norm_eps)
            x = x + lyr.cross_attention(pl["xattn"], cfg, h2, (xk, xv))
            h3 = lyr.rms_norm(x, pl["norm2"], cfg.norm_eps)
            x = x + lyr.mlp_apply(pl["mlp"], cfg, h3)
            return x, (kc, vc) + ((ks, vs) if int8 else ())

        x, ys = jax.lax.scan(
            body, x,
            (params["layers"],) + _kv_xs(cache["k"], cache["v"])
            + (cache["cross_k"], cache["cross_v"]),
        )
        _store_kv(new_cache, ys)

    elif fam == "vlm":
        every = cfg.cross_attn_every
        ng = cfg.num_layers // every
        regroup = lambda a: (
            a.reshape((ng, every) + a.shape[1:]) if a is not None else None
        )
        grouped = jax.tree_util.tree_map(regroup, params["layers"])
        kv_xs = tuple(regroup(a) for a in _kv_xs(cache["k"], cache["v"]))

        def self_body(x, xs):
            pl, kc, vc, ks, vs = xs
            h = lyr.rms_norm(x, pl["norm1"], cfg.norm_eps)
            a, kc, vc, ks, vs = lyr.decode_self_attention(
                pl["attn"], cfg, h, kc, vc, kv_pos, pos,
                k_scale=ks, v_scale=vs,
            )
            x = x + a
            h2 = lyr.rms_norm(x, pl["norm2"], cfg.norm_eps)
            x = x + lyr.mlp_apply(pl["mlp"], cfg, h2)
            return x, (kc, vc) + ((ks, vs) if int8 else ())

        def group(x, xs):
            pc, pg, kc, vc, ks, vs, xk, xv = xs
            h = lyr.rms_norm(x, pc["norm_x"], cfg.norm_eps)
            x = x + lyr.cross_attention(pc["xattn"], cfg, h, (xk, xv))
            x, ys = jax.lax.scan(self_body, x, (pg, kc, vc, ks, vs))
            return x, ys

        x, ys = jax.lax.scan(
            group, x,
            (params["cross"], grouped) + kv_xs
            + (cache["cross_k"], cache["cross_v"]),
        )
        unflat = lambda a: a.reshape((cfg.num_layers,) + a.shape[2:])
        new_cache["k"], new_cache["v"] = unflat(ys[0]), unflat(ys[1])
        if int8:
            new_cache["k_scale"] = unflat(ys[2])
            new_cache["v_scale"] = unflat(ys[3])

    elif fam == "hybrid":
        def mbody(x, xs):
            pl, st = xs
            h = lyr.rms_norm(x, pl["norm"], cfg.norm_eps)
            out, st = mb.mamba2_decode(pl, cfg, h, st)
            return x + out, st

        groups = zamba_groups(cfg)
        sk, sv = cache["shared_k"], cache["shared_v"]
        states = []
        start = 0
        for g, cnt in enumerate(groups):
            h = lyr.rms_norm(x, params["shared"]["norm1"], cfg.norm_eps)
            a, nk, nv, _, _ = lyr.decode_self_attention(
                params["shared"]["attn"], cfg, h, sk[g], sv[g], kv_pos, pos
            )
            sk, sv = sk.at[g].set(nk), sv.at[g].set(nv)
            x = x + a
            h2 = lyr.rms_norm(x, params["shared"]["norm2"], cfg.norm_eps)
            x = x + lyr.mlp_apply(params["shared"]["mlp"], cfg, h2)
            pl = jax.tree_util.tree_map(
                lambda a_: a_[start : start + cnt], params["layers"]
            )
            stl = jax.tree_util.tree_map(
                lambda a_: a_[start : start + cnt], cache["mamba"]
            )
            x, st = jax.lax.scan(mbody, x, (pl, stl))
            states.append(st)
            start += cnt
        new_cache["shared_k"], new_cache["shared_v"] = sk, sv
        new_cache["mamba"] = jax.tree_util.tree_map(
            lambda *xs: jnp.concatenate(xs), *states
        )

    elif fam == "ssm":
        def body(x, xs):
            pl, st = xs
            x, st = rwkv.rwkv_block_decode(pl, cfg, x, st)
            return x, st

        x, st = jax.lax.scan(body, x, (params["layers"], cache["rwkv"]))
        new_cache["rwkv"] = st

    else:  # pragma: no cover
        raise ValueError(fam)

    x = lyr.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = lyr.logits_apply(params["embed"], cfg, x)[:, 0]
    return logits, new_cache


# --------------------------------------------------------------------------
# prefill → cache
# --------------------------------------------------------------------------
def prefill(params, cfg, tokens, *, cond=None, max_len: int | None = None):
    """Run the full prompt and build a decode cache of size ``max_len``.

    Returns (last_token_logits (B, V), cache).
    """
    B, S = tokens.shape
    max_len = max_len or S
    x, _, parts = forward(params, cfg, tokens, cond=cond, mode="prefill")
    cache = init_cache(cfg, B, max_len)
    win = cfg.sliding_window
    W = min(max_len, win) if win else max_len

    def tail_of(src):
        # src: (L, B, S, KV, hd) → the last min(S, W) positions, slot-ordered
        keep = min(S, W)
        src_tail = src[:, :, S - keep :]
        if win and S > W:
            # rolling buffer: slot of absolute position p is p % W
            order = jnp.argsort(jnp.arange(S - keep, S) % W)
            src_tail = src_tail[:, :, order]
        return src_tail, keep

    def place_kv(dst, src):
        src_tail, keep = tail_of(src)
        return dst.at[:, :, :keep].set(src_tail.astype(dst.dtype))

    if "k" in cache and "k" in parts:
        if _kv_int8(cfg):
            for side in ("k", "v"):
                src_tail, keep = tail_of(parts[side])
                q, scale = lyr.quantize_kv(src_tail)
                cache[side] = cache[side].at[:, :, :keep].set(q)
                cache[side + "_scale"] = (
                    cache[side + "_scale"].at[:, :, :keep].set(scale)
                )
        else:
            cache["k"] = place_kv(cache["k"], parts["k"])
            cache["v"] = place_kv(cache["v"], parts["v"])
    if "shared_k" in cache:
        cache["shared_k"] = place_kv(cache["shared_k"], parts["shared_k"])
        cache["shared_v"] = place_kv(cache["shared_v"], parts["shared_v"])
    if "kv_pos" in cache:
        keep = min(S, W)
        pos_tail = jnp.arange(S - keep, S, dtype=jnp.int32)
        if win and S > W:
            pos_tail = pos_tail[jnp.argsort(pos_tail % W)]
        kv_pos = cache["kv_pos"].at[:, :keep].set(pos_tail[None])
        cache["kv_pos"] = kv_pos
    if "cross_k" in cache and "cross_k" in parts:
        cache["cross_k"] = parts["cross_k"].astype(cache["cross_k"].dtype)
        cache["cross_v"] = parts["cross_v"].astype(cache["cross_v"].dtype)
    if "mamba" in cache:
        cache["mamba"] = jax.tree_util.tree_map(
            lambda dst, src: src.astype(dst.dtype), cache["mamba"], parts["mamba"]
        )
    if "rwkv" in cache:
        cache["rwkv"] = jax.tree_util.tree_map(
            lambda dst, src: src.astype(dst.dtype), cache["rwkv"], parts["rwkv"]
        )
    logits = lyr.logits_apply(params["embed"], cfg, x[:, -1:])[:, 0]
    return logits, cache
