"""Declarative parameter definitions.

Every model in the zoo declares its parameters as a pytree of ``ParamDef``
(shape + logical axis names + dtype).  From that single declaration we derive:

* materialized parameters (``materialize``) for real runs,
* abstract ``jax.ShapeDtypeStruct`` trees (``abstractify``) for the dry-run
  (no memory is ever allocated for the full-size models),
* ``PartitionSpec`` trees (see ``repro.sharding``) for pjit in/out shardings,
* analytic parameter counts for the roofline's ``6*N*D`` term.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "ParamDef",
    "materialize",
    "abstractify",
    "count_params",
    "tree_defs",
]


@dataclasses.dataclass(frozen=True)
class ParamDef:
    """Declarative description of one parameter tensor."""

    shape: tuple[int, ...]
    logical: tuple[str, ...]  # logical axis name per dim ("" = never sharded)
    dtype: Any = jnp.bfloat16
    init: str = "normal"  # normal | zeros | ones | small
    scale: float | None = None  # stddev override for "normal"

    def __post_init__(self):
        if len(self.shape) != len(self.logical):
            raise ValueError(
                f"shape {self.shape} and logical axes {self.logical} rank mismatch"
            )

    @property
    def size(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1


def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def tree_defs(tree):
    """Flatten a pytree of ParamDef into (paths, defs)."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree, is_leaf=_is_def)
    return flat


def _fan_in(d: ParamDef) -> int:
    if not d.shape:
        return 1
    if len(d.shape) == 1:
        return d.shape[0]
    # weights are stored (in_dims..., out_dims...) by convention; treat all but
    # the final axis as fan-in, skipping a leading stacked-layer axis.
    dims = d.shape[:-1]
    if d.logical and d.logical[0] == "layers":
        dims = dims[1:] or (1,)
    return int(np.prod(dims))


def materialize(defs, key, dtype_override=None):
    """Initialize real parameter arrays for a ParamDef tree."""
    flat = tree_defs(defs)
    keys = jax.random.split(key, max(len(flat), 1))
    out = {}
    leaves = []
    for (path, d), k in zip(flat, keys):
        dtype = dtype_override or d.dtype
        if d.init == "zeros":
            arr = jnp.zeros(d.shape, dtype)
        elif d.init == "ones":
            arr = jnp.ones(d.shape, dtype)
        else:
            std = d.scale if d.scale is not None else 1.0 / math.sqrt(max(_fan_in(d), 1))
            if d.init == "small":
                std = 0.02
            arr = (jax.random.normal(k, d.shape, jnp.float32) * std).astype(dtype)
        leaves.append(arr)
    treedef = jax.tree_util.tree_structure(defs, is_leaf=_is_def)
    out = jax.tree_util.tree_unflatten(treedef, leaves)
    return out


def abstractify(defs):
    """ShapeDtypeStruct tree for lowering without allocation."""
    return jax.tree_util.tree_map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), defs, is_leaf=_is_def
    )


def count_params(defs) -> int:
    return sum(d.size for _, d in tree_defs(defs))
