"""Shared transformer building blocks.

Everything is functional: ``*_defs(cfg)`` returns a ParamDef tree, the
corresponding ``*_apply`` consumes the materialized subtree.  Attention is
implemented in a blocked, online-softmax ("flash-style") form so 32k-token
prefill never materializes an S×S score matrix; the same primitive serves
training, prefill, decode-against-cache, and (non-causal) cross-attention.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro import sharding as shd
from repro.models.params import ParamDef

NEG_INF = -1.0e30


# --------------------------------------------------------------------------
# norms / rope
# --------------------------------------------------------------------------
def rms_norm(x, w, eps: float, plus_one: bool = False):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    w = w.astype(jnp.float32)
    if plus_one:
        w = w + 1.0
    return (x * w).astype(dt)


def apply_rope(x, positions, theta: float, fraction: float = 1.0):
    """x: (B, S, H, D); positions: broadcastable to (B, S)."""
    d = x.shape[-1]
    rot = int(d * fraction)
    rot -= rot % 2
    if rot == 0:
        return x
    half = rot // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    pos = jnp.asarray(positions, jnp.float32)
    angles = pos[..., None] * freqs  # (B?, S, half)
    while angles.ndim < x.ndim:  # -> (B, S, 1, half)
        angles = jnp.expand_dims(angles, 0 if angles.ndim < 2 else -2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1 = x[..., :half].astype(jnp.float32)
    x2 = x[..., half:rot].astype(jnp.float32)
    rotated = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    out = jnp.concatenate([rotated.astype(x.dtype), x[..., rot:]], axis=-1)
    return out


# --------------------------------------------------------------------------
# blocked (flash-style) attention
# --------------------------------------------------------------------------
def blocked_attention(
    q,
    k,
    v,
    q_pos,
    kv_pos,
    *,
    causal: bool = True,
    window: int = 0,
    chunk: int = 1024,
):
    """Online-softmax attention over KV chunks.

    q: (B, Sq, H, D); k/v: (B, Skv, KV, D); q_pos: (B, Sq); kv_pos: (B, Skv).
    Never materializes (Sq, Skv); peak extra memory is O(Sq · chunk).

    GQA keys/values are broadcast to the full ``H`` head dim *inside* each
    chunk (cheap: chunk-sized) so every big intermediate carries one plain
    head axis — with heads % model == 0 the O(Sq·chunk) score/prob tensors
    tensor-parallel cleanly, which the split (KV, G) layout cannot do.
    The broadcast only pays when that sharding is actually possible, so it is
    applied iff H divides the mesh's model axis; otherwise grouped KV stays
    un-expanded (virtually, via an extra G head-group dim folded into H).
    Each chunk body is checkpointed: the backward pass recomputes s/p instead
    of saving them per chunk (the flash-attention recompute trade).
    """
    B, Sq, H, D = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = D**-0.5
    mesh = shd.current_mesh()
    tp = 1
    if mesh is not None and "model" in mesh.axis_names:
        tp = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    expand_kv = G > 1 and tp > 1 and H % tp == 0
    chunk = min(chunk, Skv)
    if Skv % chunk:  # pad KV to a chunk multiple with masked-out slots
        pad = chunk - Skv % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=2**30)
        Skv += pad
    n_chunks = Skv // chunk

    q32 = q.astype(jnp.float32)
    kc = jnp.moveaxis(k.reshape(B, n_chunks, chunk, KV, D), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, n_chunks, chunk, KV, D), 1, 0)
    pc = jnp.moveaxis(kv_pos.reshape(B, n_chunks, chunk), 1, 0)

    m0 = jnp.full((B, H, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, Sq, H, D), jnp.float32)

    def body(carry, xs):
        m, l, acc = carry
        kj, vj, pj = xs
        if expand_kv and G > 1:  # broadcast grouped KV to all H heads
            kj = jnp.repeat(kj, G, axis=2)
            vj = jnp.repeat(vj, G, axis=2)
            kj = shd.constrain(kj, "batch", "", "heads", "")
        if expand_kv or G == 1:
            s = jnp.einsum("bqhd,bchd->bhqc", q32,
                           kj.astype(jnp.float32)) * scale
            s = shd.constrain(s, "batch", "heads", "seq", "")
        else:  # grouped path: no KV broadcast (heads can't TP-shard anyway)
            qg = q32.reshape(B, Sq, KV, G, D)
            s = jnp.einsum("bqkgd,bckd->bkgqc", qg,
                           kj.astype(jnp.float32)) * scale
            s = s.reshape(B, H, Sq, -1)
        valid = pj[:, None, :] <= q_pos[:, :, None] if causal else (
            pj[:, None, :] < 2**30
        ) & jnp.ones((B, Sq, 1), bool)
        if window:
            valid = valid & (q_pos[:, :, None] - pj[:, None, :] < window)
        valid = valid[:, None]  # (B,1,Sq,c)
        s = jnp.where(valid, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.where(valid, jnp.exp(s - m_new[..., None]), 0.0)
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(-1)
        if expand_kv or G == 1:
            pv = jnp.einsum("bhqc,bchd->bqhd", p, vj.astype(jnp.float32))
        else:
            pg = p.reshape(B, KV, G, Sq, -1)
            pv = jnp.einsum("bkgqc,bckd->bqkgd", pg,
                            vj.astype(jnp.float32)).reshape(B, Sq, H, D)
        acc = acc * jnp.moveaxis(corr, 1, 2)[..., None] + pv
        return (m_new, l, acc), None

    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(body), (m0, l0, a0), (kc, vc, pc)
    )
    denom = jnp.maximum(jnp.moveaxis(l, 1, 2)[..., None], 1e-30)
    out = (acc / denom).reshape(B, Sq, H, D)
    return out.astype(q.dtype)


# --------------------------------------------------------------------------
# attention block
# --------------------------------------------------------------------------
def attn_defs(cfg, *, cross: bool = False) -> dict:
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    d = {
        "wq": ParamDef((D, H, hd), ("embed", "heads", "head_dim")),
        "wk": ParamDef((D, KV, hd), ("embed", "heads", "head_dim")),
        "wv": ParamDef((D, KV, hd), ("embed", "heads", "head_dim")),
        "wo": ParamDef((H, hd, D), ("heads", "head_dim", "embed")),
    }
    if cross:
        d["gate"] = ParamDef((), (), init="zeros", dtype=jnp.float32)
    return d


def attn_project_q(p, cfg, x, positions, *, rope: bool = True):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
    # TP over heads when divisible; context-parallel fallback over seq otherwise
    if q.shape[1] > 1:
        q = shd.constrain(q, "batch", "seq", "heads", "head_dim")
    return q


def attn_project_kv(p, cfg, x, positions, *, rope: bool = True):
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if rope:
        k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_fraction)
    return k, v


def attn_out(p, cfg, ctx):
    out = jnp.einsum("bshk,hkd->bsd", ctx, p["wo"].astype(ctx.dtype))
    return shd.constrain(out, "batch", "seq", "embed")


def self_attention(p, cfg, x, positions, *, window: int = 0):
    """Full-sequence self attention (train / prefill). Returns (out, (k, v))."""
    q = attn_project_q(p, cfg, x, positions)
    k, v = attn_project_kv(p, cfg, x, positions)
    pos = jnp.broadcast_to(positions, (x.shape[0], x.shape[1]))
    ctx = blocked_attention(
        q, k, v, pos, pos, causal=True, window=window, chunk=cfg.attn_chunk
    )
    return attn_out(p, cfg, ctx), (k, v)


def cross_attention(p, cfg, x, kv_cached):
    """Non-causal attention over a fixed (precomputed) KV set."""
    B, S = x.shape[:2]
    q = attn_project_q(p, cfg, x, jnp.zeros((S,), jnp.int32), rope=False)
    k, v = kv_cached
    n = k.shape[1]
    zeros_q = jnp.zeros((B, S), jnp.int32)
    zeros_kv = jnp.zeros((B, n), jnp.int32)
    ctx = blocked_attention(
        q, k, v, zeros_q, zeros_kv, causal=False, chunk=min(cfg.attn_chunk, n)
    )
    out = attn_out(p, cfg, ctx)
    if "gate" in p:
        out = jnp.tanh(p["gate"]).astype(out.dtype) * out
    return out


def quantize_kv(x, axis: int = -1):
    """Symmetric int8 per-(token, kv-head) quantization. Returns (q, scale)."""
    x = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(x), axis=axis) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale


def decode_self_attention(p, cfg, x1, k_cache, v_cache, kv_pos, pos, *,
                          window=0, k_scale=None, v_scale=None):
    """One-token decode against a (possibly rolling) KV cache.

    Attention is a *direct* softmax over the whole cache (no chunk scan): with
    the cache sequence dim sharded over 'model' this lowers to flash-decoding
    (split-KV) semantics — per-shard partial scores, then O(B·H) softmax-stat
    and O(B·H·hd) output all-reduces — instead of an all-gather of the cache.

    ``k_scale``/``v_scale`` (B, W, KV) select the int8-quantized cache path
    (per-token-per-head symmetric scales; halves serving HBM).

    x1: (B, 1, D); caches: (B, W, KV, hd); kv_pos: (B, W) absolute positions of
    cache slots (2**30 marks unwritten slots); pos: (B,) current position.
    Returns (out, k_cache, v_cache, k_scale, v_scale).
    """
    q = attn_project_q(p, cfg, x1, pos[:, None])
    k_new, v_new = attn_project_kv(p, cfg, x1, pos[:, None])
    W = k_cache.shape[1]
    slot = (pos % W if window else jnp.minimum(pos, W - 1)).astype(jnp.int32)
    if k_scale is not None:
        kq, ks = quantize_kv(k_new[:, 0])
        vq, vs = quantize_kv(v_new[:, 0])
        k_cache = _write_slot(k_cache, kq, slot)
        v_cache = _write_slot(v_cache, vq, slot)
        k_scale = _write_slot(k_scale, ks, slot)
        v_scale = _write_slot(v_scale, vs, slot)
        kf = k_cache.astype(jnp.float32) * k_scale[..., None]
        vf = v_cache.astype(jnp.float32) * v_scale[..., None]
    else:
        k_cache = _write_slot(k_cache, k_new[:, 0], slot)
        v_cache = _write_slot(v_cache, v_new[:, 0], slot)
        kf = k_cache.astype(jnp.float32)
        vf = v_cache.astype(jnp.float32)

    B, _, H, hd = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    qr = q.reshape(B, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("bkgd,bwkd->bkgw", qr, kf)
    s = s * hd**-0.5
    valid = kv_pos <= pos[:, None]  # (B, W); unwritten slots are 2**30
    if window:
        valid = valid & (pos[:, None] - kv_pos < window)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    probs = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bkgw,bwkd->bkgd", probs, vf)
    ctx = ctx.reshape(B, 1, H, hd).astype(x1.dtype)
    return attn_out(p, cfg, ctx), k_cache, v_cache, k_scale, v_scale


def write_kv_pos(kv_pos, pos, *, window: int = 0):
    """Update the shared slot-position book-keeping for one decode step."""
    W = kv_pos.shape[1]
    slot = (pos % W if window else jnp.minimum(pos, W - 1)).astype(jnp.int32)
    return jax.vmap(lambda a, s, p_: a.at[s].set(p_))(kv_pos, slot, pos)


def _write_slot(cache, new, slot):
    """cache: (B, W, ...); new: (B, ...); slot: (B,)."""
    zeros = (0,) * (cache.ndim - 2)
    return jax.vmap(lambda c, n, s: jax.lax.dynamic_update_slice(
        c, n[None].astype(c.dtype), (s,) + zeros))(cache, new, slot)


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------
def mlp_defs(cfg, d_ff: int | None = None) -> dict:
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    if cfg.mlp_type in ("swiglu", "geglu"):
        return {
            "wg": ParamDef((D, F), ("embed", "mlp")),
            "wu": ParamDef((D, F), ("embed", "mlp")),
            "wd": ParamDef((F, D), ("mlp", "embed")),
        }
    return {  # relu2 / gelu: single up-projection
        "wu": ParamDef((D, F), ("embed", "mlp")),
        "wd": ParamDef((F, D), ("mlp", "embed")),
    }


def mlp_apply(p, cfg, x):
    dt = x.dtype
    if cfg.mlp_type in ("swiglu", "geglu"):
        g = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(dt))
        u = jnp.einsum("bsd,df->bsf", x, p["wu"].astype(dt))
        act = jax.nn.silu(g) if cfg.mlp_type == "swiglu" else jax.nn.gelu(g)
        h = act * u
    else:
        u = jnp.einsum("bsd,df->bsf", x, p["wu"].astype(dt))
        if cfg.mlp_type == "relu2":
            h = jnp.square(jax.nn.relu(u))
        else:
            h = jax.nn.gelu(u)
    h = shd.constrain(h, "batch", "seq", "mlp")
    out = jnp.einsum("bsf,fd->bsd", h, p["wd"].astype(dt))
    return shd.constrain(out, "batch", "seq", "embed")


# --------------------------------------------------------------------------
# embedding / head
# --------------------------------------------------------------------------
def embed_defs(cfg) -> dict:
    d = {"table": ParamDef((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                           init="small")}
    if not cfg.tie_embeddings:
        d["head"] = ParamDef((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                             init="small")
    return d


def embed_apply(p, cfg, tokens):
    x = jnp.take(p["table"], tokens, axis=0).astype(cfg.dtype)
    if cfg.embed_scale:
        x = x * math.sqrt(cfg.d_model)
    return shd.constrain(x, "batch", "seq", "embed")


def logits_apply(p, cfg, x):
    table = p.get("head", p["table"]).astype(x.dtype)
    logits = jnp.einsum("bsd,vd->bsv", x, table)
    return shd.constrain(logits, "batch", "seq", "vocab")


def softmax_xent_chunked(p, cfg, x, labels, mask=None):
    """Cross-entropy over the vocab head, scanning sequence chunks so the
    (B, S, V) logits tensor is never fully materialized."""
    B, S, D = x.shape
    C = min(cfg.loss_chunk, S)
    if S % C:
        C = S  # fall back for odd smoke shapes
    n = S // C
    xc = jnp.moveaxis(x.reshape(B, n, C, D), 1, 0)
    lc = jnp.moveaxis(labels.reshape(B, n, C), 1, 0)
    mc = (
        jnp.moveaxis(mask.reshape(B, n, C), 1, 0)
        if mask is not None
        else jnp.ones((n, B, C), x.dtype)
    )

    def body(carry, xs):
        tot, cnt = carry
        xi, li, mi = xs
        logits = logits_apply(p, cfg, xi).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, li[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mi
        return (tot + nll.sum(), cnt + mi.sum()), None

    # checkpoint: recompute each chunk's (B, C, V) logits in the backward
    # instead of saving all n chunks' logits (that's the point of chunking)
    (tot, cnt), _ = jax.lax.scan(
        jax.checkpoint(body), (jnp.float32(0.0), jnp.float32(0.0)),
        (xc, lc, mc),
    )
    return tot / jnp.maximum(cnt, 1.0)
