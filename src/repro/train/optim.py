"""AdamW with fp32 moments over (possibly bf16) sharded parameters.

The optimizer state mirrors the ParamDef tree, so the same logical-axis
sharding rules cover params, moments, and gradients — a ZeRO-style layout
falls out of the 'embed'→data FSDP rule with zero extra code.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.params import ParamDef

__all__ = ["TrainConfig", "opt_defs", "init_opt", "adamw_update", "lr_at"]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    microbatches: int = 1
    # gradient compression across the slow (pod) axis: "none" | "int8_ef"
    compress: str = "none"


def _f32_like(d: ParamDef) -> ParamDef:
    return dataclasses.replace(d, dtype=jnp.float32, init="zeros")


def opt_defs(param_defs) -> dict:
    """ParamDef tree for the optimizer state."""
    mom = lambda: jax.tree_util.tree_map(
        _f32_like, param_defs, is_leaf=lambda x: isinstance(x, ParamDef)
    )
    return {
        "m": mom(),
        "v": mom(),
        "count": ParamDef((), (), dtype=jnp.int32, init="zeros"),
    }


def init_opt(params) -> dict:
    z = lambda: jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )
    return {"m": z(), "v": z(), "count": jnp.zeros((), jnp.int32)}


def lr_at(tc: TrainConfig, step):
    """Linear warmup → cosine decay to min_lr_frac·lr."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(tc.warmup_steps, 1), 1.0)
    frac = jnp.clip(
        (step - tc.warmup_steps) / jnp.maximum(tc.total_steps - tc.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return tc.lr * warm * (tc.min_lr_frac + (1 - tc.min_lr_frac) * cos)


def global_norm(tree):
    sq = jax.tree_util.tree_reduce(
        lambda a, g: a + jnp.sum(jnp.square(g.astype(jnp.float32))), tree, 0.0
    )
    return jnp.sqrt(sq)


def adamw_update(tc: TrainConfig, params, grads, opt):
    """One AdamW step. Returns (new_params, new_opt, metrics)."""
    count = opt["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, tc.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = lr_at(tc, count)
    bc1 = 1 - tc.b1 ** count.astype(jnp.float32)
    bc2 = 1 - tc.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = tc.b1 * m + (1 - tc.b1) * g
        v = tc.b2 * v + (1 - tc.b2) * jnp.square(g)
        step = (m / bc1) / (jnp.sqrt(v / bc2) + tc.eps)
        step = step + tc.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt["m"])
    flat_v = treedef.flatten_up_to(opt["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "count": count}, metrics
