"""Train-step builder: loss → grads (microbatched) → AdamW, fully sharded.

``make_train_step`` returns (step_fn, state_shardings, batch_shardings) so the
same builder serves the real trainer, the checkpoint tests, and the multi-pod
dry-run (which lowers the returned function against ShapeDtypeStructs).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro import sharding as shd
from repro.comms.compress import ef_compress, ef_init
from repro.models import model as M
from repro.models.params import ParamDef, abstractify, materialize
from repro.train.optim import TrainConfig, adamw_update, init_opt, opt_defs

__all__ = [
    "train_state_defs",
    "init_train_state",
    "abstract_train_state",
    "make_train_step",
    "batch_defs",
]


def train_state_defs(cfg, tc: TrainConfig) -> dict:
    pdefs = M.model_defs(cfg)
    d = {"params": pdefs, "opt": opt_defs(pdefs)}
    if tc.compress == "int8_ef":
        d["ef"] = jax.tree_util.tree_map(
            lambda x: ParamDef(x.shape, x.logical, jnp.float32, "zeros"),
            pdefs, is_leaf=lambda x: isinstance(x, ParamDef),
        )
    return d


def init_train_state(cfg, tc: TrainConfig, key):
    params = M.init_params(cfg, key)
    state = {"params": params, "opt": init_opt(params)}
    if tc.compress == "int8_ef":
        state["ef"] = ef_init(params)
    return state


def abstract_train_state(cfg, tc: TrainConfig):
    return abstractify(train_state_defs(cfg, tc))


def batch_defs(cfg, global_batch: int, seq_len: int) -> dict:
    d = {
        "tokens": ParamDef((global_batch, seq_len), ("batch", "seq"),
                           dtype=jnp.int32),
        "labels": ParamDef((global_batch, seq_len), ("batch", "seq"),
                           dtype=jnp.int32),
    }
    if cfg.family in ("vlm", "audio"):
        d["cond"] = ParamDef(
            (global_batch, cfg.n_cross_tokens, cfg.d_model),
            ("batch", "", "embed"), dtype=cfg.dtype,
        )
    return d


def make_train_step(cfg, tc: TrainConfig):
    """Returns ``step(state, batch) -> (state, metrics)`` (pure, jit-able)."""

    def loss_fn(params, mb):
        return M.lm_loss(params, cfg, mb)

    def grads_of(params, batch):
        if tc.microbatches <= 1:
            return jax.value_and_grad(loss_fn)(params, batch)

        k = tc.microbatches
        split = jax.tree_util.tree_map(
            lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]), batch
        )

        def body(carry, mb):
            loss_acc, g_acc = carry
            mb = jax.tree_util.tree_map(
                lambda x: shd.constrain(
                    x, *(("batch",) + ("",) * (x.ndim - 1))
                ), mb
            )
            loss, g = jax.value_and_grad(loss_fn)(params, mb)
            g_acc = jax.tree_util.tree_map(
                lambda a, b: a + b.astype(jnp.float32), g_acc, g
            )
            return (loss_acc + loss, g_acc), None

        zero = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        (loss, grads), _ = jax.lax.scan(body, (jnp.float32(0.0), zero), split)
        inv = 1.0 / k
        return loss * inv, jax.tree_util.tree_map(lambda g: g * inv, grads)

    def step(state, batch):
        loss, grads = grads_of(state["params"], batch)
        new_state = dict(state)
        if tc.compress == "int8_ef":
            grads, new_state["ef"] = ef_compress(grads, state["ef"])
        params, opt, metrics = adamw_update(
            tc, state["params"], grads, state["opt"]
        )
        new_state["params"], new_state["opt"] = params, opt
        metrics["loss"] = loss
        return new_state, metrics

    return step


def state_shardings(cfg, tc: TrainConfig, mesh):
    return shd.param_specs(train_state_defs(cfg, tc), mesh)


def batch_shardings(cfg, global_batch: int, seq_len: int, mesh):
    return shd.param_specs(batch_defs(cfg, global_batch, seq_len), mesh)
