"""Sharded checkpointing with elastic re-shard on restore.

Layout: one directory per step —

    ckpt_dir/step_000123/
        meta.json            # step, leaf paths, shapes, dtypes
        arrays.npz           # one entry per pytree leaf
    ckpt_dir/LATEST          # atomic pointer

Writes are atomic (tmp dir + rename) so a crash mid-save never corrupts the
restore point — the checkpoint/restart half of the fault-tolerance story
(the conversion pipeline's half is pub/sub redelivery + idempotent writes).
Restore takes the *target* mesh and shardings, so a job restarted on a
different topology (elastic scaling: 256 → 512 chips or down to 1 CPU) gets
correctly re-sharded arrays via ``jax.device_put``.

``AsyncCheckpointer`` overlaps serialization with the next train step
(device→host copy happens at save() call; disk I/O on a worker thread).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path

import jax
import numpy as np

from repro.analysis import racedep
from repro.core.clock import wall_time

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "AsyncCheckpointer"]

_SEP = "/"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or arr.dtype.name == "bfloat16":
            # npz has no bf16: store the raw bits; restore views them back
            arr = arr.view(np.uint16)
        flat[key] = arr
    return flat


def save_checkpoint(ckpt_dir: str | Path, step: int, state, keep: int = 3):
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp_{step:08d}_{os.getpid()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    flat = _flatten(state)
    np.savez(tmp / "arrays.npz", **flat)
    meta = {
        "step": step,
        "time": wall_time(),
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in flat.items()},
    }
    (tmp / "meta.json").write_text(json.dumps(meta))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    # atomic LATEST pointer
    ptr = ckpt_dir / ".LATEST.tmp"
    ptr.write_text(final.name)
    ptr.rename(ckpt_dir / "LATEST")
    # retention
    steps = sorted(p for p in ckpt_dir.glob("step_*") if p.is_dir())
    for old in steps[:-keep]:
        shutil.rmtree(old)
    return final


def latest_step(ckpt_dir: str | Path) -> int | None:
    ckpt_dir = Path(ckpt_dir)
    ptr = ckpt_dir / "LATEST"
    if not ptr.exists():
        return None
    name = ptr.read_text().strip()
    if not (ckpt_dir / name).is_dir():
        return None
    return int(name.split("_")[1])


def restore_checkpoint(ckpt_dir: str | Path, abstract_state,
                       shardings=None, step: int | None = None):
    """Restore into the structure of ``abstract_state``; re-shard to
    ``shardings`` (same tree structure) if given — elastic restore."""
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    arrays = np.load(d / "arrays.npz")
    paths, treedef = jax.tree_util.tree_flatten_with_path(abstract_state)
    sh_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                 if shardings is not None else [None] * len(paths))
    leaves = []
    for (path, ref), sh in zip(paths, sh_leaves):
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        arr = arrays[key]
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {ref.shape}")
        ref_dtype = np.dtype(ref.dtype)
        if arr.dtype == np.uint16 and ref_dtype.name == "bfloat16":
            arr = arr.view(ref_dtype)  # stored as raw bf16 bits
        else:
            arr = arr.astype(ref_dtype)
        leaves.append(jax.device_put(arr, sh) if sh is not None
                      else jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), step


class AsyncCheckpointer:
    """Fire-and-forget saves on a worker thread; ``wait()`` joins the last."""

    def __init__(self, ckpt_dir: str | Path, keep: int = 3):
        self.ckpt_dir = Path(ckpt_dir)
        self.keep = keep
        self._thread: threading.Thread | None = None
        self.error: Exception | None = None

    def save(self, step: int, state):
        self.wait()
        host_state = jax.tree_util.tree_map(np.asarray, state)  # D2H now

        def work():
            try:
                save_checkpoint(self.ckpt_dir, step, host_state, self.keep)
            except Exception as e:  # pragma: no cover
                self.error = e

        # tracked spawn: racedep sees the fork here and the join in wait(),
        # so host_state handoff and self.error are ordered, not racy
        self._thread = racedep.spawn(work, name=f"ckpt-save-{step}")

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.error:
            raise self.error
