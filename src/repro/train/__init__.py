"""Training: optimizer, step builder, checkpointing."""
from repro.train.optim import TrainConfig, adamw_update, init_opt, lr_at  # noqa: F401
from repro.train.step import (  # noqa: F401
    abstract_train_state,
    batch_defs,
    batch_shardings,
    init_train_state,
    make_train_step,
    state_shardings,
    train_state_defs,
)
