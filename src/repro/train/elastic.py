"""Elastic data-parallel training orchestration over the event bus.

The paper's dispatch pattern applied to a trainer fleet: data shards are
pub/sub messages, trainer workers are subscribers, and the parameter server
applies worker gradients. Failure semantics compose exactly like the
conversion pipeline's:

* a worker that dies mid-shard never acks → the shard redelivers to a
  healthy worker (at-least-once ⇒ no data loss on preemption),
* gradient application is keyed by (epoch, shard) → a redelivered shard a
  dead worker *did* finish is ignored (effectively-once updates),
* workers can join/leave at any time (elastic scaling): throughput tracks
  the live worker count, correctness doesn't depend on it.

This is the *job-level* layer — within a worker a step is still one
synchronous SPMD program. ``ElasticTrainer.run_epoch`` drives everything on
the deterministic SimScheduler so the fault-injection tests are exact; a
real deployment maps workers onto pod slices and the bus onto Pub/Sub.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.pubsub import Topic
from repro.train.optim import TrainConfig, adamw_update

__all__ = ["ElasticTrainer", "Worker"]


@dataclasses.dataclass
class Worker:
    name: str
    speed: float = 1.0  # relative step rate (sim time per shard = base/speed)
    alive: bool = True


class ElasticTrainer:
    """Parameter server + worker fleet over a shard topic."""

    def __init__(self, scheduler, cfg, tc: TrainConfig, state: dict,
                 batch_fn: Callable[[int], dict], *, step_time: float = 10.0,
                 grad_fn: Callable | None = None):
        from repro.models.model import lm_loss

        self.scheduler = scheduler
        self.cfg = cfg
        self.tc = tc
        self.state = state
        self.batch_fn = batch_fn
        self.step_time = step_time
        self.topic = Topic("elastic-shards", scheduler)
        self.applied: set[tuple[int, int]] = set()
        self.losses: list[float] = []
        self.workers: dict[str, Worker] = {}
        self._grad = grad_fn or jax.jit(
            jax.value_and_grad(lambda p, b: lm_loss(p, cfg, b))
        )
        self._backlog: list = []
        from repro.core.pubsub import Subscription

        self.sub = Subscription(self.topic, "trainers", self._on_shard,
                                ack_deadline=step_time * 6,
                                max_outstanding=64, min_backoff=1.0)

    # ---- fleet management -------------------------------------------------
    def add_worker(self, name: str, speed: float = 1.0) -> Worker:
        w = Worker(name, speed)
        self.workers[name] = w
        self.scheduler.schedule(0.0, self._pump)
        return w

    def kill_worker(self, name: str):
        if name in self.workers:
            self.workers[name].alive = False

    def _idle_workers(self):
        return [w for w in self.workers.values() if w.alive]

    # ---- shard flow ---------------------------------------------------------
    def publish_epoch(self, n_shards: int, epoch: int = 0):
        for s in range(n_shards):
            self.topic.publish({"shard": s, "epoch": epoch})

    def _on_shard(self, msg, ctx):
        self._backlog.append((msg.data, ctx))
        self._pump()

    def _pump(self):
        while self._backlog and self._idle_workers():
            data, ctx = self._backlog.pop(0)
            worker = self._idle_workers()[0]
            # worker "computes" for step_time/speed sim-seconds, then applies
            self.scheduler.schedule(
                self.step_time / worker.speed, self._finish, worker, data, ctx
            )

    def _finish(self, worker: Worker, data: dict, ctx):
        if not worker.alive:
            return  # died mid-step: no ack → redelivery
        key = (data["epoch"], data["shard"])
        if key in self.applied:  # duplicate after redelivery: effectively-once
            ctx.ack()
            return
        batch = {k: jnp.asarray(v) for k, v in
                 self.batch_fn(data["shard"]).items()}
        loss, grads = self._grad(self.state["params"], batch)
        params, opt, _ = adamw_update(self.tc, self.state["params"], grads,
                                      self.state["opt"])
        self.state["params"], self.state["opt"] = params, opt
        self.applied.add(key)
        self.losses.append(float(loss))
        ctx.ack()
        self._pump()

    # ---- driver ---------------------------------------------------------------
    def run_epoch(self, n_shards: int, epoch: int = 0,
                  chaos: Callable | None = None):
        """Publish an epoch and drain it; ``chaos(t, trainer)`` may be
        scheduled by the caller beforehand for fault injection."""
        self.publish_epoch(n_shards, epoch)
        self.scheduler.run(max_events=1_000_000)
        return sorted(s for e, s in self.applied if e == epoch)
