"""Enterprise DICOM store — the final arrow of the paper's Figure 1.

A DICOMweb-shaped service over a bucket:

* **STOW** — instances land under canonical keys
  (``instances/{study}/{series}/{sop}.dcm``), so re-storing a SOP UID
  **replaces** its blob and index entry, never duplicates it: redelivered
  pub/sub messages (at-least-once) and re-uploaded study archives leave
  QIDO/WADO results byte-identical to a single clean store.
* **QIDO** — study/series/instance search with patient/modality/date
  filtering (a study matches if *any* of its instances does) plus study-
  and series-level aggregation, always in a stable sorted order regardless
  of instance arrival order.
* **WADO** — whole-instance retrieve, and frame-level retrieve served from
  a cached :class:`~repro.wsi.dicom.Part10Index` so a single frame fetch
  costs O(frame), not a full Part-10 reparse.
* **Durability** — the metadata index is checkpointed into the bucket
  (``_meta/index.json``) and ``rebuild_index()`` reconstructs it after a
  crash from the checkpoint plus a blob rescan, so a restarted store serves
  identical QIDO/WADO results.

Every stored instance is published on the store's own
``dicom-instance-stored`` topic; downstream consumers (the paper's "ML
model subscriber", the validation/QA workflow — see
``repro.wsi.subscribers``) attach there without touching ingestion,
demonstrating the extensibility claim.
"""
from __future__ import annotations

import hashlib
import json
from collections import OrderedDict

from repro.analysis.lockdep import TrackedLock
from repro.analysis.racedep import tracked_state
from repro.core import tracing
from repro.core.pubsub import Topic
from repro.core.storage import Bucket
from repro.wsi.convert import study_levels
from repro.wsi.dicom import Part10Index

__all__ = ["DicomStoreService", "ShardedDicomStore"]


@tracked_state("_index", "_studies", "_frame_cache")
class DicomStoreService:
    #: bucket key of the persistent index checkpoint
    INDEX_KEY = "_meta/index.json"
    #: prefix under which instance blobs live (rescanned on rebuild)
    PREFIX = "instances/"
    #: retained Part10Index objects for frame-level WADO (LRU)
    FRAME_CACHE = 128

    def __init__(self, bucket: Bucket, scheduler, metrics=None, *,
                 topic: Topic | None = None):
        self.bucket = bucket
        self.scheduler = scheduler
        self.metrics = metrics or bucket.metrics
        # shards of a ShardedDicomStore share one instance-stored topic so
        # downstream subscribers attach once, not once per shard
        self.topic = topic if topic is not None else \
            Topic("dicom-instance-stored", scheduler, self.metrics)
        self._lock = TrackedLock("DicomStoreService._lock", reentrant=True)
        self._index: dict[str, dict] = {}  # sop_uid -> metadata
        self._studies: dict[str, list[str]] = {}  # study_uid -> [sop_uid]
        self._frame_cache: OrderedDict[str, tuple[str, Part10Index]] = \
            OrderedDict()  # sop_uid -> (generation, index)

    # ---- STOW ---------------------------------------------------------------
    def store_study_archive(self, key: str, archive: bytes) -> list[str]:
        """Ingest a converted study tar (one .dcm per pyramid level)."""
        with tracing.span("stow.archive", key=key):
            stored = []
            for name, blob in study_levels(archive).items():
                if not name.endswith(".dcm"):
                    continue
                stored.append(
                    self.store_instance(blob, source=f"{key}/{name}"))
            self.checkpoint()
        return stored

    def store_instance(self, part10: bytes, *, source: str | None = None,
                       _index: Part10Index | None = None) -> str:
        """Store one Part-10 instance; idempotent per SOP instance UID.

        The blob key is derived from the instance identity, so a re-store
        (redelivery, re-upload) replaces rather than duplicates. The
        instance-stored event is published only when the stored bytes are
        new or changed — identical redeliveries are silent. ``_index`` lets
        the sharded router pass its already-parsed structural scan through
        instead of re-parsing.
        """
        # raises ValueError on corrupt input
        idx = _index if _index is not None else Part10Index(part10)
        meta = self._meta_from_index(idx, source)
        sop, study = meta["sop_instance_uid"], meta["study_uid"]
        if not sop or not study:
            raise ValueError(
                "corrupt Part-10 stream: instance without SOP/study UID")
        key = f"{self.PREFIX}{study}/{meta['series_uid']}/{sop}.dcm"
        meta["key"] = key
        obj = self.bucket.put(key, part10, {"sop_instance_uid": sop})
        meta["generation"] = obj.generation
        with self._lock:
            prev = self._index.get(sop)
            if prev is not None and prev["key"] != key:
                # identity moved (study/series changed): drop the old blob
                self.bucket.delete(prev["key"])
                old = self._studies.get(prev["study_uid"], [])
                old[:] = [s for s in old if s != sop]
                if not old:  # no ghost studies in QIDO
                    self._studies.pop(prev["study_uid"], None)
            self._index[sop] = meta
            sops = self._studies.setdefault(study, [])
            if sop not in sops:
                sops.append(sop)
            self._frame_cache.pop(sop, None)
        if prev is None:
            self.metrics.inc("dicomstore.instances")
        else:
            self.metrics.inc("dicomstore.replaced")
        tracing.add_event(None, "stow.instance", sop=sop,
                          replaced=prev is not None)
        if prev is None or prev["generation"] != obj.generation:
            self.topic.publish(dict(meta))
        return sop

    @staticmethod
    def _meta_from_index(idx: Part10Index, source: str | None) -> dict:
        return {
            "sop_instance_uid": idx.get_str(0x0008, 0x0018),
            "sop_class_uid": idx.get_str(0x0008, 0x0016),
            "study_uid": idx.get_str(0x0020, 0x000D),
            "series_uid": idx.get_str(0x0020, 0x000E),
            "instance_number": idx.get_int(0x0020, 0x0013),
            "patient_id": idx.get_str(0x0010, 0x0020),
            "modality": idx.get_str(0x0008, 0x0060),
            "study_date": idx.get_str(0x0008, 0x0020),
            "rows": idx.get_int(0x0028, 0x0010),
            "columns": idx.get_int(0x0028, 0x0011),
            "frames": idx.get_int(0x0028, 0x0008),
            "total_rows": idx.get_int(0x0048, 0x0007),
            "total_cols": idx.get_int(0x0048, 0x0006),
            "transfer_syntax": idx.get_str(0x0002, 0x0010),
            "source": source,
        }

    def delete_instance(self, sop_instance_uid: str) -> dict:
        """Remove an instance (blob + index + cache); returns its metadata.

        This is the quarantine path: the validation subscriber copies the
        corrupt blob to its DLQ bucket first, then deletes it here so
        QIDO/WADO stop serving it.
        """
        with self._lock:
            meta = self._index.pop(sop_instance_uid, None)
            if meta is None:
                raise KeyError(f"unknown SOP instance {sop_instance_uid}")
            study = meta["study_uid"]
            sops = self._studies.get(study, [])
            sops[:] = [s for s in sops if s != sop_instance_uid]
            if not sops:
                self._studies.pop(study, None)
            self._frame_cache.pop(sop_instance_uid, None)
        self.bucket.delete(meta["key"])
        self.metrics.inc("dicomstore.deleted")
        return meta

    # ---- persistent index ----------------------------------------------------
    def checkpoint(self) -> None:
        """Write the metadata index into the bucket (crash-recovery point)."""
        with self._lock:
            # copy under the lock: serialization runs outside it, and a
            # concurrent STOW mutating the live dict would crash json.dumps
            snap = {"instances": dict(self._index)}
        self.bucket.put(self.INDEX_KEY,
                        json.dumps(snap, sort_keys=True).encode())
        self.metrics.inc("dicomstore.checkpoints")

    def rebuild_index(self) -> int:
        """Rebuild the in-memory index after a crash.

        Loads the last checkpoint, then rescans every blob under
        ``instances/`` — blobs missing from the checkpoint (or stored after
        it) are re-parsed with :class:`Part10Index` (header scan only, no
        frame materialization); checkpoint entries whose blob is gone are
        dropped. Returns the number of blobs that had to be re-parsed.
        Unparseable blobs are skipped and counted in
        ``dicomstore.rebuild_skipped`` (the validation subscriber is the
        quarantine path for those).
        """
        try:
            snap = json.loads(self.bucket.get(self.INDEX_KEY).data)
        except KeyError:
            snap = {"instances": {}}
        by_key = {m["key"]: m for m in snap["instances"].values()}
        index: dict[str, dict] = {}
        studies: dict[str, list[str]] = {}
        reparsed = 0
        for key in self.bucket.list(self.PREFIX):
            obj = self.bucket.get(key)
            meta = by_key.get(key)
            if meta is None or meta.get("generation") != obj.generation:
                try:
                    idx = Part10Index(obj.data)
                except ValueError:
                    self.metrics.inc("dicomstore.rebuild_skipped")
                    continue
                meta = self._meta_from_index(idx, None)
                meta["key"], meta["generation"] = key, obj.generation
                reparsed += 1
            index[meta["sop_instance_uid"]] = meta
            studies.setdefault(meta["study_uid"], []).append(
                meta["sop_instance_uid"])
        with self._lock:
            self._index = index
            self._studies = studies
            self._frame_cache.clear()
        self.metrics.inc("dicomstore.rebuilds")
        return reparsed

    # ---- QIDO ---------------------------------------------------------------
    @staticmethod
    def _instance_order(meta: dict):
        return (meta["series_uid"] or "", meta["instance_number"] or 0,
                meta["sop_instance_uid"])

    def _study_metas(self, study_uid: str) -> list[dict]:
        # lock held
        return sorted((self._index[s] for s in self._studies.get(study_uid, [])),
                      key=self._instance_order)

    def search_studies(self, *, patient_id: str | None = None,
                       modality: str | None = None,
                       study_date: str | None = None) -> list[str]:
        """Study UIDs matching every given filter, in stable sorted order.

        A study matches a filter if **any** of its instances carries the
        value — instances of one study can disagree (multi-modality, merged
        patients), and judging from the first-arrived instance only would
        make results depend on delivery order.
        """
        def matches(metas: list[dict]) -> bool:
            for field, want in (("patient_id", patient_id),
                                ("modality", modality),
                                ("study_date", study_date)):
                if want is not None and \
                        not any(m[field] == want for m in metas):
                    return False
            return True

        with self._lock:
            return sorted(study for study, sops in self._studies.items()
                          if matches([self._index[s] for s in sops]))

    def search_instances(self, study_uid: str, *,
                         modality: str | None = None) -> list[dict]:
        with self._lock:
            metas = self._study_metas(study_uid)
        return [dict(m) for m in metas
                if modality is None or m["modality"] == modality]

    def study_summary(self, study_uid: str) -> dict:
        """Study-level QIDO aggregation."""
        with self._lock:
            metas = self._study_metas(study_uid)
        if not metas:
            raise KeyError(f"unknown study {study_uid}")
        return {
            "study_uid": study_uid,
            "patient_ids": sorted({m["patient_id"] for m in metas}),
            "modalities": sorted({m["modality"] for m in metas}),
            "study_dates": sorted({m["study_date"] for m in metas}),
            "n_series": len({m["series_uid"] for m in metas}),
            "n_instances": len(metas),
            "total_frames": sum(m["frames"] or 0 for m in metas),
        }

    def search_series(self, study_uid: str | None = None, *,
                      modality: str | None = None) -> list[dict]:
        """Series-level QIDO aggregation, stable (study, series) order."""
        with self._lock:
            studies = [study_uid] if study_uid is not None \
                else sorted(self._studies)
            groups: dict[tuple[str, str], list[dict]] = {}
            for study in studies:
                for m in self._study_metas(study):
                    groups.setdefault((study, m["series_uid"]), []).append(m)
        out = []
        for (study, series) in sorted(groups):
            metas = groups[(study, series)]
            if modality is not None and \
                    not any(m["modality"] == modality for m in metas):
                continue
            out.append({
                "study_uid": study,
                "series_uid": series,
                "modalities": sorted({m["modality"] for m in metas}),
                "n_instances": len(metas),
                "total_frames": sum(m["frames"] or 0 for m in metas),
            })
        return out

    # ---- WADO ----------------------------------------------------------------
    def read_blob(self, key: str) -> bytes:
        """Raw blob fetch by store key (the subscribers' re-read path);
        raises ``KeyError`` when the blob is gone (quarantined/deleted)."""
        return self.bucket.get(key).data

    def _meta(self, sop_instance_uid: str) -> dict:
        with self._lock:
            meta = self._index.get(sop_instance_uid)
        if meta is None:
            raise KeyError(f"unknown SOP instance {sop_instance_uid}")
        return meta

    def retrieve(self, sop_instance_uid: str) -> bytes:
        return self.bucket.get(self._meta(sop_instance_uid)["key"]).data

    def frame_index(self, sop_instance_uid: str) -> Part10Index:
        """The instance's Part10Index, cached per (SOP UID, generation)."""
        meta = self._meta(sop_instance_uid)
        with self._lock:
            hit = self._frame_cache.get(sop_instance_uid)
            if hit is not None and hit[0] == meta["generation"]:
                self._frame_cache.move_to_end(sop_instance_uid)
                self.metrics.inc("dicomstore.wado_index_hits")
                return hit[1]
        idx = Part10Index(self.bucket.get(meta["key"]).data)
        with self._lock:
            self._frame_cache[sop_instance_uid] = (meta["generation"], idx)
            self._frame_cache.move_to_end(sop_instance_uid)
            while len(self._frame_cache) > self.FRAME_CACHE:
                self._frame_cache.popitem(last=False)
        self.metrics.inc("dicomstore.wado_index_misses")
        return idx

    def retrieve_frame(self, sop_instance_uid: str, frame: int) -> bytes:
        """Frame-level WADO: one slice off the cached index — no reparse."""
        self.metrics.inc("dicomstore.wado_frames")
        return self.frame_index(sop_instance_uid).read_frame(frame)


class ShardedDicomStore:
    """Study-UID-hash-sharded DICOM store over N bucket partitions.

    Writes scale with the converter fleet: each study routes to exactly one
    shard (stable sha-256 hash of the study UID), so N shards take
    concurrent STOW traffic on N independent buckets, index locks, and
    checkpoints. Every shard is a full :class:`DicomStoreService` — with
    its own ``_meta/index.json`` checkpoint and per-shard
    :meth:`DicomStoreService.rebuild_index` crash recovery — but all
    shards publish on ONE shared ``dicom-instance-stored`` topic, so the
    validation/ML subscribers attach once, exactly as for the unsharded
    store.

    The DICOMweb surface (QIDO/WADO/STOW) is the same as
    ``DicomStoreService``: study-scoped calls route by hash; cross-study
    search merges the shards' (already sorted) results into one stable
    order; SOP-scoped retrieval probes the shard indexes (an O(n_shards)
    dict lookup, not a scan).

    ``crash_shard(i)`` is the fault-injection hook: it replaces shard *i*
    with a fresh service over the same bucket — all in-memory index state
    lost, exactly like an instance restart — after which
    ``rebuild_index()`` must restore byte-identical QIDO/WADO.
    """

    def __init__(self, store, scheduler, metrics=None, *, n_shards: int = 4,
                 bucket_prefix: str = "dicom-instances"):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.scheduler = scheduler
        self.metrics = metrics if metrics is not None else store.metrics
        self.n_shards = n_shards
        self.topic = Topic("dicom-instance-stored", scheduler, self.metrics)
        self.buckets = [store.bucket(f"{bucket_prefix}-{i:02d}")
                        for i in range(n_shards)]
        self.shards = [DicomStoreService(b, scheduler, self.metrics,
                                         topic=self.topic)
                       for b in self.buckets]

    # ---- routing ----------------------------------------------------------
    @staticmethod
    def shard_index_for_uid(study_uid: str, n_shards: int) -> int:
        digest = hashlib.sha256(study_uid.encode()).hexdigest()
        return int(digest[:8], 16) % n_shards

    def shard_index_for(self, study_uid: str) -> int:
        return self.shard_index_for_uid(study_uid, self.n_shards)

    def shard_for(self, study_uid: str) -> DicomStoreService:
        return self.shards[self.shard_index_for(study_uid)]

    def _shard_with_sop(self, sop_instance_uid: str) -> DicomStoreService:
        for shard in self.shards:
            with shard._lock:
                if sop_instance_uid in shard._index:
                    return shard
        raise KeyError(f"unknown SOP instance {sop_instance_uid}")

    # ---- STOW -------------------------------------------------------------
    def store_instance(self, part10: bytes, *,
                       source: str | None = None) -> str:
        idx = Part10Index(part10)  # raises ValueError on corrupt input
        study = idx.get_str(0x0020, 0x000D)
        if not study:
            raise ValueError(
                "corrupt Part-10 stream: instance without SOP/study UID")
        return self.shard_for(study).store_instance(part10, source=source,
                                                    _index=idx)

    def store_study_archive(self, key: str, archive: bytes) -> list[str]:
        with tracing.span("stow.archive", key=key, shards=self.n_shards):
            stored, touched = [], set()
            for name, blob in study_levels(archive).items():
                if not name.endswith(".dcm"):
                    continue
                idx = Part10Index(blob)
                study = idx.get_str(0x0020, 0x000D)
                if not study:
                    raise ValueError(
                        "corrupt Part-10 stream: instance without "
                        "SOP/study UID")
                si = self.shard_index_for(study)
                stored.append(self.shards[si].store_instance(
                    blob, source=f"{key}/{name}", _index=idx))
                touched.add(si)
            for si in sorted(touched):
                self.shards[si].checkpoint()
        return stored

    def delete_instance(self, sop_instance_uid: str) -> dict:
        return self._shard_with_sop(sop_instance_uid).delete_instance(
            sop_instance_uid)

    # ---- durability --------------------------------------------------------
    def checkpoint(self) -> None:
        for shard in self.shards:
            shard.checkpoint()

    def rebuild_index(self) -> int:
        """Rebuild every shard; returns total blobs re-parsed."""
        return sum(shard.rebuild_index() for shard in self.shards)

    def crash_shard(self, i: int) -> DicomStoreService:
        """Fault injection: lose shard *i*'s in-memory state (index,
        studies map, frame cache) as an abrupt restart would. Its bucket —
        blobs and checkpoint — survives; ``rebuild_index()`` recovers."""
        self.shards[i] = DicomStoreService(self.buckets[i], self.scheduler,
                                           self.metrics, topic=self.topic)
        self.metrics.inc("dicomstore.shard_crashes")
        return self.shards[i]

    # ---- QIDO -------------------------------------------------------------
    def search_studies(self, **filters) -> list[str]:
        return sorted(study for shard in self.shards
                      for study in shard.search_studies(**filters))

    def search_instances(self, study_uid: str, **kw) -> list[dict]:
        return self.shard_for(study_uid).search_instances(study_uid, **kw)

    def study_summary(self, study_uid: str) -> dict:
        return self.shard_for(study_uid).study_summary(study_uid)

    def search_series(self, study_uid: str | None = None, *,
                      modality: str | None = None) -> list[dict]:
        if study_uid is not None:
            return self.shard_for(study_uid).search_series(
                study_uid, modality=modality)
        rows = [row for shard in self.shards
                for row in shard.search_series(modality=modality)]
        return sorted(rows, key=lambda r: (r["study_uid"], r["series_uid"]))

    # ---- WADO -------------------------------------------------------------
    def read_blob(self, key: str) -> bytes:
        # store keys are "instances/{study}/{series}/{sop}.dcm" — the study
        # UID in the key routes straight to the owning shard
        parts = key.split("/")
        if len(parts) >= 2 and f"{parts[0]}/" == DicomStoreService.PREFIX:
            return self.shard_for(parts[1]).read_blob(key)
        raise KeyError(f"not a sharded instance key: {key}")

    def retrieve(self, sop_instance_uid: str) -> bytes:
        return self._shard_with_sop(sop_instance_uid).retrieve(
            sop_instance_uid)

    def frame_index(self, sop_instance_uid: str) -> Part10Index:
        return self._shard_with_sop(sop_instance_uid).frame_index(
            sop_instance_uid)

    def retrieve_frame(self, sop_instance_uid: str, frame: int) -> bytes:
        return self._shard_with_sop(sop_instance_uid).retrieve_frame(
            sop_instance_uid, frame)

    # ---- introspection -----------------------------------------------------
    def shard_distribution(self) -> list[int]:
        """Indexed instances per shard (the write-scaling balance check)."""
        out = []
        for shard in self.shards:
            with shard._lock:
                out.append(len(shard._index))
        return out
