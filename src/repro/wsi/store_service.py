"""Enterprise DICOM store — the final arrow of the paper's Figure 1.

A DICOMweb-shaped service over a bucket: STOW (store instances), QIDO
(search studies/instances by UID / patient), WADO (retrieve). Converted
studies land here from the conversion service; downstream consumers (the
paper's "ML model subscriber", QA workflows) subscribe to the store's
own instance-stored topic — demonstrating the extensibility claim that new
services attach to existing topics without touching ingestion.
"""
from __future__ import annotations

import json

from repro.core.pubsub import Topic
from repro.core.storage import Bucket
from repro.wsi.convert import study_levels
from repro.wsi.dicom import read_part10

__all__ = ["DicomStoreService"]


class DicomStoreService:
    def __init__(self, bucket: Bucket, scheduler, metrics=None):
        self.bucket = bucket
        self.scheduler = scheduler
        self.metrics = metrics or bucket.metrics
        self.topic = Topic("dicom-instance-stored", scheduler, self.metrics)
        self._index: dict[str, dict] = {}  # sop_uid -> metadata
        self._studies: dict[str, list[str]] = {}  # study_uid -> [sop_uid]

    # ---- STOW ---------------------------------------------------------------
    def store_study_archive(self, key: str, archive: bytes) -> list[str]:
        """Ingest a converted study tar (one .dcm per pyramid level)."""
        stored = []
        for name, blob in study_levels(archive).items():
            if not name.endswith(".dcm"):
                continue
            stored.append(self.store_instance(f"{key}/{name}", blob))
        return stored

    def store_instance(self, key: str, part10: bytes) -> str:
        ds, frames = read_part10(part10)
        sop = ds.get_str(0x0008, 0x0018)
        study = ds.get_str(0x0020, 0x000D)
        meta = {
            "sop_instance_uid": sop,
            "sop_class_uid": ds.get_str(0x0008, 0x0016),
            "study_uid": study,
            "series_uid": ds.get_str(0x0020, 0x000E),
            "patient_id": ds.get_str(0x0010, 0x0020),
            "modality": ds.get_str(0x0008, 0x0060),
            "rows": ds.get_int(0x0028, 0x0010),
            "columns": ds.get_int(0x0028, 0x0011),
            "frames": ds.get_int(0x0028, 0x0008),
            "total_rows": ds.get_int(0x0048, 0x0007),
            "total_cols": ds.get_int(0x0048, 0x0006),
            "transfer_syntax": ds.get_str(0x0002, 0x0010),
            "key": key,
        }
        self.bucket.put(key, part10, {"sop_instance_uid": sop})
        self._index[sop] = meta
        self._studies.setdefault(study, []).append(sop)
        self.metrics.inc("dicomstore.instances")
        self.topic.publish(meta)
        return sop

    # ---- QIDO ---------------------------------------------------------------
    def search_studies(self, *, patient_id: str | None = None) -> list[str]:
        out = []
        for study, sops in self._studies.items():
            meta = self._index[sops[0]]
            if patient_id is None or meta["patient_id"] == patient_id:
                out.append(study)
        return sorted(out)

    def search_instances(self, study_uid: str) -> list[dict]:
        return [self._index[s] for s in self._studies.get(study_uid, [])]

    # ---- WADO ----------------------------------------------------------------
    def retrieve(self, sop_instance_uid: str) -> bytes:
        meta = self._index.get(sop_instance_uid)
        if meta is None:
            raise KeyError(f"unknown SOP instance {sop_instance_uid}")
        return self.bucket.get(meta["key"]).data

    def retrieve_frame(self, sop_instance_uid: str, frame: int) -> bytes:
        _, frames = read_part10(self.retrieve(sop_instance_uid))
        return frames[frame]
