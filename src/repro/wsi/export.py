"""Event-driven DICOM → tiled-TIFF export — ingestion's mirror image.

The paper's interoperability claim runs both directions: slides must get
*into* the archive from any scanner container, and *out* of it into the
containers existing open-source analysis tools consume (cf. ``dicom2tiff``;
tiled TIFF is what the downstream ecosystem reads). This service is the
pipeline's third event-driven hop, symmetric with ingestion:

    export-request topic ──push──▶ ExportService ──▶ derived bucket
        ▲      (at-least-once, retries, its own DLQ)     (tiled TIFFs)
        │
        ├── ConversionPipeline.request_export(study_uid)   (explicit)
        └── dicom-instance-stored ─▶ auto-export trigger   (optional)

Per request, the whole study is read back through the store's own public
retrieval surface — QIDO (``search_instances``) for the level inventory,
frame-level WADO (``retrieve_frame`` off the cached
:class:`~repro.wsi.dicom.Part10Index`) for the tile bytes — so the export
path exercises exactly what an external DICOMweb consumer would. Each
level's frames are decoded with the batched inverse path
(``decode_tiles_batch``: one vectorized entropy-decode pass + one fused
``jpeg_inverse`` dispatch per level) and rewritten as one classic tiled
TIFF per level in the ``derived`` bucket.

**Determinism invariant** (asserted in tests and ``export_bench``): the
decoded pixels, the Aperio-style ``ImageDescription`` provenance, and the
``write_tiff`` serialization are all deterministic, so exporting the same
study twice — including after a store crash + ``rebuild_index()`` — yields
**byte-identical** TIFFs. Determinism is also what makes re-exports cheap:
a level whose derived TIFF already records the instance's content
generation is skipped outright by default (no WADO fetch, no decode), and
even a forced re-derivation lands as a content-addressed bucket no-op.
The exported TIFF reopens through the
``TiffSlideReader`` sniffer, closing the loop: a study can round-trip
store → TIFF → (re-ingest) → store with no manual format plumbing.

**Failure semantics**: a corrupt stored frame surfaces as the decoder's
actionable ``ValueError("corrupt JPEG …")``; the handler nacks with that
reason, so after the retry budget it becomes the dead-letter's
``dlq_reason`` — the same observability contract as the ingestion hop.
"""
from __future__ import annotations

from contextlib import nullcontext

from repro.analysis.lockdep import TrackedLock
from repro.core import tracing
from repro.core.pubsub import DeliveryCtx, Message, Subscription, Topic
from repro.core.storage import Bucket
from repro.kernels import ops as kernel_ops
from repro.wsi.formats import write_tiff
from repro.wsi.jpeg import decode_frames
from repro.wsi.store_service import DicomStoreService

__all__ = ["ExportService"]


class ExportService:
    """Turns stored DICOM studies back into tiled-TIFF pyramids.

    ``request_topic`` is the ``export-request`` topic; requests are
    ``{"study_uid": …}`` dicts. Pass ``request_topic=None`` to use the
    service as a plain library (direct ``export_study`` calls) without any
    subscription — benchmarks and tests do this.

    ``mesh`` (optional ``jax.sharding.Mesh`` with a ``"data"`` axis) scopes
    the decode path's batched ``jpeg_inverse`` dispatches: each level's
    frame batch is split over the mesh's data axis (see
    ``kernels.ops.use_mesh``). Sharding never changes the exported bytes.
    """

    def __init__(self, store: DicomStoreService, derived: Bucket, *,
                 request_topic: Topic | None = None, dlq: Topic | None = None,
                 name: str = "dicom2tiff", ack_deadline: float = 600.0,
                 max_delivery_attempts: int = 5, min_backoff: float = 10.0,
                 max_backoff: float = 600.0, mesh=None):
        self.store = store
        self.derived = derived
        self.mesh = mesh
        self.metrics = store.metrics
        self._lock = TrackedLock("ExportService._lock")
        self.exported: list[tuple[str, tuple[str, ...]]] = []
        self.subscription = None
        if request_topic is not None:
            self.subscription = Subscription(
                request_topic, name, self._handle,
                ack_deadline=ack_deadline,
                max_delivery_attempts=max_delivery_attempts,
                min_backoff=min_backoff, max_backoff=max_backoff, dlq=dlq)

    # ---- push endpoint ---------------------------------------------------
    def _handle(self, msg: Message, ctx: DeliveryCtx):
        study_uid = msg.data.get("study_uid")
        try:
            if not study_uid:
                raise KeyError("export request without study_uid")
            self.export_study(study_uid)
        except (KeyError, ValueError) as exc:
            # unknown study (racing delete) or corrupt stored frames — the
            # decoder's "corrupt JPEG …" string rides the nack so the
            # dead-letter carries an actionable dlq_reason
            ctx.nack(f"export failed: {exc}")
        else:
            ctx.ack()

    # ---- the export ------------------------------------------------------
    def export_study(self, study_uid: str, *,
                     skip_unchanged: bool = True) -> list[str]:
        """Export every level of a study; returns the derived-bucket keys.

        Deterministic: repeated exports (including after a store
        ``rebuild_index()``) write byte-identical TIFFs. By default a
        level whose derived TIFF already records the instance's content
        generation is skipped outright — no WADO fetch, no decode —
        which keeps the per-instance auto-export fan-out O(levels)
        instead of O(levels²); ``skip_unchanged=False`` forces the full
        re-derivation (the benchmark uses it to *prove* byte identity
        rather than assume it).
        """
        self.metrics.inc("pipeline.export.requests")
        with tracing.span("export.study", study=study_uid):
            metas = self.store.search_instances(study_uid)
            if not metas:
                raise KeyError(f"unknown study {study_uid}")
            keys = []
            ctx = kernel_ops.use_mesh(self.mesh) if self.mesh is not None \
                else nullcontext()
            with ctx:
                for li, meta in enumerate(metas):
                    key = self._export_level(study_uid, li, meta,
                                             skip_unchanged)
                    if key is not None:
                        keys.append(key)
                        tracing.add_event(None, "export.level", key=key)
        with self._lock:
            self.exported.append((study_uid, tuple(keys)))
        return keys

    def _export_level(self, study_uid: str, li: int, meta: dict,
                      skip_unchanged: bool) -> str | None:
        """One WSM instance (one pyramid level) → one tiled TIFF."""
        sop = meta["sop_instance_uid"]
        level = li if meta["instance_number"] is None \
            else meta["instance_number"] - 1
        key = f"{study_uid}/level_{level}.tiff"
        if skip_unchanged and self.derived.exists(key) and \
                self.derived.get(key).metadata.get("source_generation") \
                == meta["generation"]:
            # the derived TIFF already reflects these instance bytes and
            # the export is deterministic — nothing to re-derive
            self.metrics.inc("pipeline.export.levels_unchanged")
            return key
        tile, cols = meta["rows"] or 0, meta["columns"] or 0
        total_rows, total_cols = meta["total_rows"] or 0, \
            meta["total_cols"] or 0
        n = self.store.frame_index(sop).n_frames
        if n == 0:
            # a level smaller than one tile stores no full frames — there
            # are no pixels to export (the converter's per-tile path agrees)
            self.metrics.inc("pipeline.export.levels_skipped")
            return None
        if tile <= 0 or tile != cols:
            raise ValueError(
                f"unsupported WSM instance {sop}: non-square "
                f"{tile}x{cols} tiles")
        bh, bw = total_rows // tile, total_cols // tile
        if bh * bw != n:
            raise ValueError(
                f"corrupt WSM instance {sop}: {n} frames for a "
                f"{bh}x{bw} tile grid")

        frames = [self.store.retrieve_frame(sop, i) for i in range(n)]
        try:
            rgb = decode_frames(frames,
                                transfer_syntax=meta["transfer_syntax"],
                                rows=tile, cols=tile)
        except ValueError as exc:
            raise ValueError(f"instance {sop}: {exc}") from None
        self.metrics.inc("pipeline.export.frames_decoded", n)

        tiles = {(r, c): rgb[r * bw + c]
                 for r in range(bh) for c in range(bw)}
        desc = (f"repro-dicom2tiff|study = {study_uid}"
                f"|series = {meta['series_uid']}|sop = {sop}"
                f"|level = {level}|total_rows = {total_rows}"
                f"|total_cols = {total_cols}"
                f"|source_generation = {meta['generation']}")
        tif = write_tiff(tiles, bh * tile, bw * tile, tile, description=desc)
        self.derived.put(key, tif, metadata={
            "study_uid": study_uid, "sop_instance_uid": sop,
            "source_generation": meta["generation"]})
        self.metrics.inc("pipeline.export.bytes_written", len(tif))
        return key
