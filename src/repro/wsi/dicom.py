"""Minimal DICOM Part-10 writer/parser — VL Whole Slide Microscopy IOD.

Writes standards-shaped files: 128-byte preamble + 'DICM', explicit-VR-LE
file-meta group (its own group length), explicit-VR-LE dataset with the WSM
module subset (tiled TILED_FULL organization), and multi-frame PixelData —
either native (uncompressed, defined length) or encapsulated JPEG baseline
(undefined length, basic offset table + one fragment per frame). The parser
reads back everything the tests need (tags, frames, encapsulation).
"""
from __future__ import annotations

import struct
import uuid

__all__ = [
    "Dataset", "Part10Index", "write_part10", "read_part10",
    "SOP_CLASS_VL_WSM", "TS_EXPLICIT_LE", "TS_JPEG_BASELINE", "new_uid",
]

SOP_CLASS_VL_WSM = "1.2.840.10008.5.1.4.1.1.77.1.6"
TS_EXPLICIT_LE = "1.2.840.10008.1.2.1"
TS_JPEG_BASELINE = "1.2.840.10008.1.2.4.50"
_IMPL_UID = "2.25.4242424242424242"

_LONG_VRS = {"OB", "OW", "OF", "SQ", "UT", "UN"}


def new_uid() -> str:
    return "2.25." + str(uuid.uuid4().int)[:32]


def _pad(value: bytes, even_pad: bytes = b" ") -> bytes:
    return value + (even_pad if len(value) % 2 else b"")


class Dataset:
    """Ordered (group, element) → (VR, raw value) map with typed helpers."""

    def __init__(self):
        self.elements: dict[tuple[int, int], tuple[str, bytes]] = {}

    def put(self, group: int, elem: int, vr: str, value):
        if isinstance(value, str):
            raw = value.encode()
            raw = _pad(raw, b"\x00" if vr == "UI" else b" ")
        elif isinstance(value, int):
            if vr == "US":
                raw = struct.pack("<H", value)
            elif vr == "UL":
                raw = struct.pack("<I", value)
            else:  # IS / DS etc. as string
                raw = _pad(str(value).encode())
        elif isinstance(value, bytes):
            raw = _pad(value, b"\x00")
        else:
            raise TypeError(type(value))
        self.elements[(group, elem)] = (vr, raw)

    def get(self, group: int, elem: int):
        return self.elements.get((group, elem))

    def get_str(self, group: int, elem: int) -> str | None:
        v = self.get(group, elem)
        return v[1].decode(errors="replace").rstrip(" \x00") if v else None

    def get_int(self, group: int, elem: int) -> int | None:
        v = self.get(group, elem)
        if v is None:
            return None
        vr, raw = v
        if vr == "US":
            return struct.unpack("<H", raw[:2])[0]
        if vr == "UL":
            return struct.unpack("<I", raw[:4])[0]
        return int(raw.decode().strip() or 0)

    def encode(self) -> bytes:
        out = bytearray()
        for (g, e) in sorted(self.elements):
            vr, raw = self.elements[(g, e)]
            out += struct.pack("<HH", g, e) + vr.encode()
            if vr in _LONG_VRS:
                out += b"\x00\x00" + struct.pack("<I", len(raw))
            else:
                out += struct.pack("<H", len(raw))
            out += raw
        return bytes(out)


def _encapsulate(frames: list[bytes]) -> bytes:
    """Encapsulated pixel data: basic offset table + one fragment per frame."""
    out = bytearray()
    offsets = []
    off = 0
    frags = []
    for f in frames:
        f = _pad(f, b"\x00")
        offsets.append(off)
        frags.append(f)
        off += 8 + len(f)
    bot = b"".join(struct.pack("<I", o) for o in offsets)
    out += struct.pack("<HHI", 0xFFFE, 0xE000, len(bot)) + bot
    for f in frags:
        out += struct.pack("<HHI", 0xFFFE, 0xE000, len(f)) + f
    out += struct.pack("<HHI", 0xFFFE, 0xE0DD, 0)
    return bytes(out)


def write_part10(
    *,
    frames: list[bytes],
    rows: int,
    cols: int,
    total_rows: int,
    total_cols: int,
    transfer_syntax: str = TS_JPEG_BASELINE,
    sop_instance_uid: str | None = None,
    study_uid: str | None = None,
    series_uid: str | None = None,
    instance_number: int = 1,
    patient_id: str = "ANON",
    metadata: dict | None = None,
) -> bytes:
    """Build one WSM instance (one pyramid level) as Part-10 bytes."""
    sop_uid = sop_instance_uid or new_uid()
    encapsulated = transfer_syntax != TS_EXPLICIT_LE

    meta = Dataset()
    meta.put(0x0002, 0x0001, "OB", b"\x00\x01")
    meta.put(0x0002, 0x0002, "UI", SOP_CLASS_VL_WSM)
    meta.put(0x0002, 0x0003, "UI", sop_uid)
    meta.put(0x0002, 0x0010, "UI", transfer_syntax)
    meta.put(0x0002, 0x0012, "UI", _IMPL_UID)
    meta_bytes = meta.encode()

    ds = Dataset()
    ds.put(0x0008, 0x0016, "UI", SOP_CLASS_VL_WSM)
    ds.put(0x0008, 0x0018, "UI", sop_uid)
    ds.put(0x0008, 0x0020, "DA", "20220101")
    ds.put(0x0008, 0x0030, "TM", "000000")
    ds.put(0x0008, 0x0060, "CS", "SM")
    ds.put(0x0010, 0x0010, "PN", "Synthetic^Slide")
    ds.put(0x0010, 0x0020, "LO", patient_id)
    ds.put(0x0020, 0x000D, "UI", study_uid or new_uid())
    ds.put(0x0020, 0x000E, "UI", series_uid or new_uid())
    ds.put(0x0020, 0x0011, "IS", 1)
    ds.put(0x0020, 0x0013, "IS", instance_number)
    ds.put(0x0020, 0x9311, "CS", "TILED_FULL")
    ds.put(0x0028, 0x0002, "US", 3)
    ds.put(0x0028, 0x0004, "CS",
           "YBR_FULL" if encapsulated else "RGB")
    ds.put(0x0028, 0x0006, "US", 0)
    ds.put(0x0028, 0x0008, "IS", len(frames))
    ds.put(0x0028, 0x0010, "US", rows)
    ds.put(0x0028, 0x0011, "US", cols)
    ds.put(0x0028, 0x0100, "US", 8)
    ds.put(0x0028, 0x0101, "US", 8)
    ds.put(0x0028, 0x0102, "US", 7)
    ds.put(0x0028, 0x0103, "US", 0)
    ds.put(0x0048, 0x0006, "UL", total_cols)
    ds.put(0x0048, 0x0007, "UL", total_rows)
    for k, v in (metadata or {}).items():  # private vendor block
        ds.put(0x0009, 0x1000 + k, "LO", str(v))
    body = ds.encode()

    out = bytearray()
    out += b"\x00" * 128 + b"DICM"
    # group length element for file meta
    gl = Dataset()
    gl.put(0x0002, 0x0000, "UL", len(meta_bytes))
    out += gl.encode() + meta_bytes
    out += body
    # pixel data
    if encapsulated:
        out += struct.pack("<HH", 0x7FE0, 0x0010) + b"OB\x00\x00"
        out += struct.pack("<I", 0xFFFFFFFF)
        out += _encapsulate(frames)
    else:
        blob = b"".join(frames)
        blob = _pad(blob, b"\x00")
        out += struct.pack("<HH", 0x7FE0, 0x0010) + b"OB\x00\x00"
        out += struct.pack("<I", len(blob)) + blob
    return bytes(out)


def read_part10(data: bytes) -> tuple[Dataset, list[bytes]]:
    """Parse a Part-10 file produced by ``write_part10``.

    Returns (dataset incl. file meta, pixel-data frames), materializing
    every frame — a thin wrapper over :class:`Part10Index`, which owns the
    single structural pass (and therefore the single copy of the
    corruption checks: truncated/malformed input raises
    ``ValueError("corrupt Part-10 …")`` from the scan).
    """
    idx = Part10Index(data)
    ds = Dataset()
    for (g, e), (vr, off, ln) in idx.elements.items():
        ds.elements[(g, e)] = (vr, data[off : off + ln])
    return ds, [idx.read_frame(i) for i in range(idx.n_frames)]


class Part10Index:
    """Offset index over a Part-10 byte stream — parse once, seek forever.

    One scan over ``data`` records every element's (VR, value offset, value
    length) and the pixel-data frame geometry — encapsulated fragment
    extents cross-checked against the basic offset table, or the native
    frame stride — **without materializing any frame**. After construction,
    ``read_element`` and ``read_frame(i)`` are single slices of the raw
    bytes: a frame fetch costs O(frame size), not O(file size) as with
    ``read_part10``, which is what makes frame-level WADO on a cached index
    cheap (see ``DicomStoreService.retrieve_frame``).

    Malformed input raises ``ValueError("corrupt Part-10 …")`` exactly like
    ``read_part10``; additionally a basic offset table whose length is not a
    multiple of 4, or whose entries disagree with the actual fragment
    positions, is rejected.

    Thread-safety (PR 8 lockdep audit): the index is **immutable after
    construction** — ``__init__`` does the whole scan and readers only
    slice ``self.data`` — so one instance is safely shared across threads
    with no lock of its own. The mutable state around it (the store's LRU
    of these, ``DicomStoreService._frame_cache``) is what gets the
    ``TrackedLock``.
    """

    def __init__(self, data: bytes):
        if len(data) < 132 or data[128:132] != b"DICM":
            raise ValueError("corrupt Part-10 stream: missing DICM magic")
        self.data = data
        # (group, elem) -> (vr, value offset, value length)
        self.elements: dict[tuple[int, int], tuple[str, int, int]] = {}
        self.frames: list[tuple[int, int]] = []  # (offset, length)
        self.encapsulated = False
        try:
            self._scan()
        except (struct.error, UnicodeDecodeError) as exc:
            raise ValueError(f"corrupt Part-10 stream: {exc}") from None

    # ---- the single structural pass --------------------------------------
    def _scan(self) -> None:
        data, n = self.data, len(self.data)
        pos = 132
        while pos < n:
            g, e = struct.unpack_from("<HH", data, pos)
            pos += 4
            vr = data[pos : pos + 2].decode("ascii")
            if not (vr.isalpha() and vr.isupper()):
                raise ValueError(
                    f"corrupt Part-10 stream: invalid VR {vr!r} at "
                    f"offset {pos}")
            if vr in _LONG_VRS:
                ln = struct.unpack_from("<I", data, pos + 4)[0]
                pos += 8
            else:
                ln = struct.unpack_from("<H", data, pos + 2)[0]
                pos += 4
            if (g, e) == (0x7FE0, 0x0010):
                pos = self._scan_pixel_data(pos, ln)
                continue
            if pos + ln > n:
                raise ValueError(
                    f"corrupt Part-10 stream: element ({g:04x},{e:04x}) "
                    "value truncated")
            self.elements[(g, e)] = (vr, pos, ln)
            pos += ln

    def _scan_pixel_data(self, pos: int, ln: int) -> int:
        data, n = self.data, len(self.data)
        if ln != 0xFFFFFFFF:  # native: frames are a fixed stride into blob
            if pos + ln > n:
                raise ValueError(
                    "corrupt Part-10 stream: pixel data truncated")
            nf = self.get_int(0x0028, 0x0008) or 1
            rows = self.get_int(0x0028, 0x0010)
            cols = self.get_int(0x0028, 0x0011)
            spp = self.get_int(0x0028, 0x0002) or 1
            if not rows or not cols:
                raise ValueError(
                    "corrupt Part-10 stream: native pixel data without "
                    "Rows/Columns")
            fsize = rows * cols * spp
            if nf * fsize > ln:
                raise ValueError(
                    "corrupt Part-10 stream: native pixel data shorter "
                    f"than {nf} frames of {fsize} bytes")
            self.frames = [(pos + i * fsize, fsize) for i in range(nf)]
            return pos + ln
        # encapsulated: basic offset table item, then one fragment per frame
        self.encapsulated = True
        ig, ie, il = struct.unpack_from("<HHI", data, pos)
        pos += 8
        if (ig, ie) != (0xFFFE, 0xE000) or pos + il > n:
            raise ValueError(
                "corrupt Part-10 stream: missing basic offset table item")
        if il % 4:
            raise ValueError(
                "corrupt Part-10 stream: basic offset table length "
                f"{il} is not a multiple of 4")
        bot = list(struct.unpack_from(f"<{il // 4}I", data, pos))
        pos += il
        offsets = []  # of each fragment's item header, relative to the first
        first = pos
        while True:
            ig, ie, il = struct.unpack_from("<HHI", data, pos)
            pos += 8
            if (ig, ie) == (0xFFFE, 0xE0DD):
                break
            if (ig, ie) != (0xFFFE, 0xE000) or pos + il > n:
                raise ValueError(
                    "corrupt Part-10 stream: bad pixel-data item at "
                    f"offset {pos - 8}")
            offsets.append(pos - 8 - first)
            self.frames.append((pos, il))
            pos += il
        if bot and bot != offsets:
            raise ValueError(
                "corrupt Part-10 stream: basic offset table disagrees "
                f"with fragment positions ({bot} != {offsets})")
        return pos

    # ---- seeks -------------------------------------------------------------
    @property
    def n_frames(self) -> int:
        return len(self.frames)

    def read_element(self, group: int, elem: int) -> bytes | None:
        """Raw value bytes of one element (None if absent) — a single slice."""
        v = self.elements.get((group, elem))
        if v is None:
            return None
        _, off, ln = v
        return self.data[off : off + ln]

    def get_str(self, group: int, elem: int) -> str | None:
        raw = self.read_element(group, elem)
        return raw.decode(errors="replace").rstrip(" \x00") \
            if raw is not None else None

    def get_int(self, group: int, elem: int) -> int | None:
        v = self.elements.get((group, elem))
        if v is None:
            return None
        vr, off, ln = v
        raw = self.data[off : off + ln]
        if vr == "US":
            return struct.unpack("<H", raw[:2])[0]
        if vr == "UL":
            return struct.unpack("<I", raw[:4])[0]
        return int(raw.decode().strip() or 0)

    def read_frame(self, i: int) -> bytes:
        """Frame ``i``'s bytes — byte-identical to ``read_part10(...)[1][i]``
        but O(frame size): one slice at the indexed offset."""
        if not 0 <= i < len(self.frames):
            raise IndexError(
                f"frame {i} out of range (instance has {len(self.frames)})")
        off, ln = self.frames[i]
        return self.data[off : off + ln]

    # ---- integrity ---------------------------------------------------------
    def verify(self) -> None:
        """Deep integrity checks beyond the structural scan.

        Raises ``ValueError("corrupt Part-10 …")`` if the declared frame
        count disagrees with the indexed frames, identity elements are
        missing, or (encapsulated JPEG) a frame does not start with an SOI
        marker — the bit-rot class the validation subscriber quarantines.
        """
        for g, e, what in ((0x0008, 0x0018, "SOP instance UID"),
                           (0x0020, 0x000D, "study UID"),
                           (0x0020, 0x000E, "series UID")):
            if not self.get_str(g, e):
                raise ValueError(f"corrupt Part-10 stream: missing {what}")
        declared = self.get_int(0x0028, 0x0008)
        if declared is not None and declared != len(self.frames):
            raise ValueError(
                f"corrupt Part-10 stream: {declared} frames declared, "
                f"{len(self.frames)} indexed")
        if self.encapsulated:
            for i, (off, ln) in enumerate(self.frames):
                if ln < 2 or self.data[off : off + 2] != b"\xff\xd8":
                    raise ValueError(
                        f"corrupt Part-10 stream: frame {i} lacks a JPEG "
                        "SOI marker")
