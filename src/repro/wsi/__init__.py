"""WSI→DICOM conversion substrate: synthetic slides, pyramid, JPEG, DICOM."""
from repro.wsi.convert import ConvertOptions, convert_wsi_to_dicom, study_levels  # noqa: F401
from repro.wsi.dicom import Part10Index, read_part10, write_part10  # noqa: F401
from repro.wsi.formats import (SlideFormat, SlideReader,  # noqa: F401
                               TiffSlideReader, open_slide, register_format,
                               sniff, write_psv, write_tiff)
from repro.wsi.export import ExportService  # noqa: F401
from repro.wsi.jpeg import (decode_coef_batch, decode_frames,  # noqa: F401
                            decode_tile, decode_tiles_batch,
                            encode_coef_batch, encode_tile,
                            encode_tiles_batch, psnr)
from repro.wsi.slide import PSVReader, SyntheticScanner  # noqa: F401
from repro.wsi.store_service import DicomStoreService  # noqa: F401
from repro.wsi.subscribers import InferenceSubscriber, ValidationService  # noqa: F401
