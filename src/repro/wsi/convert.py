"""The converter: any registered slide container → multi-level DICOM WSM study.

Per slide: sniff the container (``repro.wsi.formats.open_slide`` — PSV,
tiled TIFF/SVS, or any registered format), stream tiles through the
``SlideReader`` protocol, build the multi-resolution pyramid with the
Pallas downsample kernel, transform-code every tile (Pallas DCT/quant +
host Huffman), wrap each level in a DICOM Part-10 instance (TILED_FULL),
and bundle the study as a tar archive. The converter consumes only the
reader protocol, so identical pixel content produces byte-identical study
tars regardless of the source container (given the same manifest UIDs) —
asserted across PSV vs tiled-TIFF in tests and the benchmark.

Three compute paths (see DESIGN.md, "Whole-level batched dispatch" and
"Kernel roofline & sharding"), all emitting **byte-identical** study tars:

- **pipelined/fused** (default): the device-resident engine. Level-0 tile
  rows are uploaded to the device as the reader inflates them (no full
  host ``(H, W, 3)`` array), then the **entire pyramid** — every level's
  ``jpeg_transform`` and the ``downsample2x2`` chain between levels — is
  one jitted dispatch (``donate_argnums`` retires the pixel buffer on
  accelerators). The host consumes per-level coefficients behind async
  fetches (``copy_to_host_async``), entropy-coding level N while the
  device is still transforming levels > N. Exactly one host→device upload
  and one dispatch per slide (counted by ``TRANSFER_STATS``, asserted in
  the conversion bench).
- **batched sync** (``ConvertOptions(pipelined=False)``): level 0 is
  uploaded once; every further level is produced by chaining
  ``downsample2x2`` on device, and all tiles of a level are transform-coded
  by a single fused ``jpeg_transform`` dispatch followed by the vectorized
  host entropy coder — but each level's host work completes before the next
  level's device work is enqueued. Kept as the A/B baseline for the
  pipelined path.
- **per-tile** (``ConvertOptions(batched=False)``): the original path — host
  pyramid, ``[encode_tile(f) for f in frames]`` with 4 dispatches per tile.
  Kept for A/B benchmarking.

**Determinism**: the study/series UIDs are minted once and stored in the
manifest (key ``"uids"``), and every level's SOP instance UID is derived
from the series UID + instance number. Two conversions of the same slide
that share a manifest (or whose manifests were seeded with the same
``"uids"`` entry) therefore produce byte-identical study tars — this is
what the pipelined-vs-sync A/B asserts on whole archives, and what makes
manifest resume reproduce a fresh conversion exactly.

**Crash/resume**: ``ConvertOptions.manifest`` is the single store of
finished-level DICOM bytes (level index → Part-10 bytes). A converter
restarted against the same manifest skips completed levels (this backs the
checkpoint/restart fault-tolerance tests — at-least-once delivery plus this
idempotent resume gives effectively-once conversion). The study tar is
assembled directly from the manifest, so finished-level bytes are stored
exactly once; call ``ConvertOptions.clear_manifest()`` to release them once
the study archive has been durably stored.

**Thread safety**: ``convert_wsi_to_dicom`` shares no mutable module state
(the entropy coder's caches are lock-protected), so the real-mode pipeline
runs up to ``concurrency`` conversions in parallel worker threads — the
transform dispatch, the numpy entropy coder, and zlib inflation all release
the GIL for their heavy regions.
"""
from __future__ import annotations

import io
import json
import tarfile
from contextlib import nullcontext
from functools import lru_cache

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import tracing
from repro.kernels import downsample2x2, jpeg_transform, ops as kernel_ops
from repro.wsi.dicom import (TS_EXPLICIT_LE, TS_JPEG_BASELINE, new_uid,
                             write_part10)
from repro.wsi.formats import SlideReader, open_slide
from repro.wsi.jpeg import encode_coef_batch, encode_tile

__all__ = ["convert_wsi_to_dicom", "study_levels", "ConvertOptions"]


class ConvertOptions:
    """Converter knobs.

    min_level_size
        Stop the pyramid once the next level's short edge would fall below
        this (pixels). Levels smaller than one tile emit zero full frames.
    jpeg
        ``True`` → encapsulated JPEG baseline transfer syntax; ``False`` →
        native (uncompressed) explicit-VR-LE pixel data. The batched/
        pipelined device paths only apply to JPEG output; ``jpeg=False``
        always runs the host per-tile wrap.
    manifest
        Resume checkpoint *and* the only copy of finished-level bytes held
        by the converter: maps level index (str) to that level's Part-10
        bytes, plus the ``"uids"`` entry (JSON ``[study_uid, series_uid]``)
        minted on first use so a resumed — or deliberately re-seeded —
        conversion reproduces the original bytes exactly. The output tar is
        written from the manifest directly.
    batched
        ``True`` (default): device-resident pyramid, one fused transform
        dispatch per level, vectorized host entropy coder. ``False``: the
        original per-tile path (4 dispatches + Python Huffman loop per
        tile), kept for A/B benchmarking.
    pipelined
        ``True`` (default): the fused device-resident engine — streamed
        level-0 upload, the whole pyramid (transforms + downsample chain)
        in one jitted dispatch, async per-level coefficient fetches.
        ``False``: strictly sequential per-level stages (the PR-1 batched
        path), kept as the byte-identity A/B baseline. Only effective when
        ``batched`` and ``jpeg`` are both ``True``.
    mesh
        Optional ``jax.sharding.Mesh`` with a ``"data"`` axis: scope the
        conversion's batched kernel dispatches to this mesh (level batches
        are split over the axis — see ``kernels.ops.use_mesh``). ``None``
        (default) uses the ambient mesh (all visible devices). Sharding
        never changes output bytes, only where tiles are computed.
    """

    def __init__(self, *, min_level_size: int = 256, jpeg: bool = True,
                 manifest: dict | None = None, batched: bool = True,
                 pipelined: bool = True, mesh=None):
        self.min_level_size = min_level_size
        self.jpeg = jpeg
        self.batched = batched
        self.pipelined = pipelined
        self.mesh = mesh
        self.manifest = manifest if manifest is not None else {}

    def clear_manifest(self) -> None:
        """Drop finished-level bytes (call after the study tar is stored).

        Also drops the stored study/series UIDs, so a conversion rerun
        against the cleared manifest mints fresh identifiers.
        """
        self.manifest.clear()


def _study_uids(opt: ConvertOptions) -> tuple[str, str]:
    """(study_uid, series_uid), minted once and persisted in the manifest."""
    raw = opt.manifest.get("uids")
    if raw is None:
        raw = json.dumps([new_uid(), new_uid()])
        opt.manifest["uids"] = raw
    study_uid, series_uid = json.loads(raw)
    return study_uid, series_uid


def _level_frames(img: np.ndarray, tile: int) -> tuple[list[np.ndarray], int, int]:
    """Tile a (H, W, 3) level into row-major frames."""
    H, W, _ = img.shape
    frames = []
    for r in range(H // tile):
        for c in range(W // tile):
            frames.append(img[r * tile:(r + 1) * tile,
                              c * tile:(c + 1) * tile])
    return frames, H // tile, W // tile


def _tile_batch(dev: jnp.ndarray, tile: int) -> jnp.ndarray:
    """(3, H, W) device level → (N, 3, tile, tile) row-major tile batch."""
    _, H, W = dev.shape
    bh, bw = H // tile, W // tile
    if bh == 0 or bw == 0:
        # level smaller than one tile: no full frames (matches the per-tile
        # path, whose _level_frames loop body never runs)
        return jnp.zeros((0, 3, tile, tile), dev.dtype)
    return (dev[:, :bh * tile, :bw * tile].reshape(3, bh, tile, bw, tile)
            .transpose(1, 3, 0, 2, 4).reshape(bh * bw, 3, tile, tile))


class TransferStats:
    """Host↔device traffic ledger for the fused engine.

    ``uploads`` counts streamed level-0 uploads (one per slide — the strip
    ``device_put`` calls of a single slide are one logical transfer),
    ``dispatches`` counts jitted pyramid-chain launches, and ``fetches``
    counts per-level coefficient downloads. The conversion bench resets
    this, converts a slide, and asserts ``uploads == 1`` and
    ``dispatches == 1`` — the "≤1 host↔device round trip per slide"
    acceptance gate. Counters are advisory (not thread-synchronized);
    reset + assert from a single thread.
    """

    def __init__(self):
        self.reset()

    def reset(self) -> None:
        self.uploads = 0
        self.dispatches = 0
        self.fetches = 0


TRANSFER_STATS = TransferStats()


def _upload_level0(rd: SlideReader) -> jnp.ndarray:
    """Stream level 0 to the device one tile row at a time.

    Each row strip is handed to ``jax.device_put`` as soon as its tiles are
    inflated, so the host↔device copy of row r overlaps the zlib inflation
    of row r+1; the full-resolution ``(H, W, 3)`` host array of the sync
    path is never materialized. The strips hold exact uint8 values in
    float32, so the device concatenation is bit-identical to a whole-level
    upload.
    """
    tile, W = rd.tile, rd.W
    bh, bw = rd.grid
    TRANSFER_STATS.uploads += 1
    strips = []
    for r in range(bh):
        row = np.empty((3, tile, W), np.float32)
        for c in range(bw):
            row[:, :, c * tile:(c + 1) * tile] = \
                np.transpose(rd.read_tile(r, c), (2, 0, 1))
        strips.append(jax.device_put(row))
    return strips[0] if len(strips) == 1 else jnp.concatenate(strips, axis=1)


def _wrap_level(opt: ConvertOptions, li: int, frames: list[bytes], ts: str,
                tile: int, H: int, W: int, metadata: dict | None,
                study_uid: str, series_uid: str) -> None:
    """Wrap one finished level as Part-10 bytes into the manifest."""
    opt.manifest[str(li)] = write_part10(
        frames=frames, rows=tile, cols=tile,
        total_rows=H, total_cols=W, transfer_syntax=ts,
        study_uid=study_uid, series_uid=series_uid,
        sop_instance_uid=f"{series_uid}.{li + 1}",
        instance_number=li + 1,
        metadata={0: (metadata or {}).get("slide_id", "unknown"),
                  1: f"level={li}"},
    )


def _level_chunks(batch, bh: int, bw: int) -> list:
    """Split a level's (N, 3, T, T) coefficient batch into row-aligned
    chunks for the host entropy coder.

    Chunk boundaries sit on whole tile rows and each tile is entropy-coded
    as its own scan, so per-chunk encode emits exactly the frames of a
    whole-level encode, in the same row-major order. ~4 chunks per level
    keeps a crash between chunks cheap to resume (each finished level is
    checkpointed as soon as its last chunk is coded) without shrinking the
    vectorized encode batches too far.
    """
    rows_per = max(1, bh // 4)
    return [batch[r0 * bw:min(r0 + rows_per, bh) * bw]
            for r0 in range(0, bh, rows_per)]


def _pyramid_dims(H: int, W: int,
                  min_level_size: int) -> list[tuple[int, int]]:
    """Host-side geometry walk: (H, W) per pyramid level, same stopping
    rule as the sync engine's device walk."""
    dims = []
    while True:
        dims.append((H, W))
        if min(H, W) // 2 < min_level_size:
            return dims
        H, W = H // 2, W // 2


@lru_cache(maxsize=None)
def _pyramid_chain(n_levels: int, needed: tuple[int, ...], tile: int,
                   donate: bool, mesh=None):
    """One jitted dispatch for the whole pyramid.

    The traced graph chains ``downsample2x2`` level to level and emits
    ``jpeg_transform`` coefficients for every level in ``needed`` (levels
    already checkpointed in the manifest are skipped — their downsamples
    still run, because deeper levels derive from them). Fusing the chain
    means the pixel pyramid never leaves the device: the old engine's
    per-level dispatch + fetch round trips collapse to a single launch.
    ``donate=True`` (accelerators only; CPU warns and cannot donate) lets
    XLA retire the level-0 pixel buffer into the chain's scratch space.
    ``mesh`` only keys the cache: sharding constraints are baked into the
    trace from the ambient mesh, so distinct meshes need distinct jits.
    """
    def chain(dev):
        outs = []
        for li in range(n_levels):
            if li in needed:
                outs.append(jpeg_transform(_tile_batch(dev, tile)))
            if li + 1 < n_levels:
                dev = jnp.clip(jnp.round(downsample2x2(dev)), 0, 255)
        return outs
    kw = {"donate_argnums": (0,)} if donate else {}
    return jax.jit(chain, **kw)


def _convert_pipelined(rd: SlideReader, metadata: dict | None,
                       opt: ConvertOptions, study_uid: str,
                       series_uid: str) -> int:
    """The fused device-resident engine. Returns the number of levels.

    One streamed upload, one dispatch, ordered consumption:

    1. **Upload** — level-0 tile rows go to the device as the reader
       inflates them (``_upload_level0``); no full host pixel array.
    2. **Fused pyramid dispatch** — a single jitted call
       (``_pyramid_chain``) runs every level's ``jpeg_transform`` and the
       ``downsample2x2`` chain between levels in one traced graph. The
       dispatch returns immediately (JAX async dispatch); every level's
       coefficient fetch is started with ``copy_to_host_async`` so
       downloads overlap the remaining device work.
    3. **Ordered consume** — levels are entropy-coded and Part-10-wrapped
       in pyramid order, in row-aligned chunks (``_level_chunks``); each
       finished level is checkpointed into the manifest immediately, so a
       crash mid-pyramid resumes from every completed level. While the
       host codes level N, the device is still transforming levels > N.

    The per-tile math and emitted frame order are identical to the sync
    engine's per-level dispatch — fusion changes only where buffers live —
    so the output bytes are identical (asserted in tests and the bench).
    """
    tile = rd.tile
    dims = _pyramid_dims(rd.H, rd.W, opt.min_level_size)
    n_levels = len(dims)
    needed = tuple(li for li in range(n_levels)
                   if str(li) not in opt.manifest)
    if not needed:
        return n_levels

    with tracing.span("convert.upload"):
        dev = _upload_level0(rd)
    donate = jax.default_backend() != "cpu"
    with tracing.span("convert.dispatch", levels=len(needed)):
        # async dispatch: the span covers trace/launch, not device time —
        # device work overlaps the per-level entropy spans below
        outs = _pyramid_chain(n_levels, needed, tile, donate, opt.mesh)(dev)
    TRANSFER_STATS.dispatches += 1
    del dev  # donated / retired: the chain owns the pixel pyramid now
    for coef in outs:
        if hasattr(coef, "copy_to_host_async"):
            coef.copy_to_host_async()

    for li, coef_dev in zip(needed, outs):
        H, W = dims[li]
        with tracing.span("convert.entropy", level=li):
            coef = np.asarray(coef_dev)
            TRANSFER_STATS.fetches += 1
            bh, bw = H // tile, W // tile
            chunks = [coef] if (bh == 0 or bw == 0) \
                else _level_chunks(coef, bh, bw)
            frames: list[bytes] = []
            for ch in chunks:
                frames += encode_coef_batch(np.asarray(ch))
            _wrap_level(opt, li, frames, TS_JPEG_BASELINE, tile, H, W,
                        metadata, study_uid, series_uid)
            tracing.add_event(None, "convert.checkpoint", level=li,
                              frames=len(frames))
    return n_levels


def _convert_sync(rd: SlideReader, metadata: dict | None, opt: ConvertOptions,
                  study_uid: str, series_uid: str) -> int:
    """The strictly sequential engine (batched or per-tile). Returns the
    number of levels."""
    tile = rd.tile

    # level 0 assembled tile-by-tile (streaming); higher levels by 2× pooling
    H, W = rd.H, rd.W
    level = np.empty((H, W, 3), np.uint8)
    for (r, c), t in rd.tiles():
        level[r * tile:(r + 1) * tile, c * tile:(c + 1) * tile] = t

    # batched path: the pyramid lives on device as float32 planes holding
    # exact uint8 values (downsample output is re-quantized on device), so
    # the transform input matches the per-tile uint8 path bit-for-bit
    dev = jnp.asarray(np.transpose(level, (2, 0, 1)).astype(np.float32)) \
        if opt.batched else None

    li = 0
    while True:
        if opt.batched:
            H, W = int(dev.shape[1]), int(dev.shape[2])
        else:
            H, W = level.shape[:2]
        if str(li) not in opt.manifest:
            if opt.jpeg and opt.batched:
                coef = np.asarray(jpeg_transform(_tile_batch(dev, tile)))
                frames = encode_coef_batch(coef)
                ts = TS_JPEG_BASELINE
            else:
                if opt.batched:
                    level = np.asarray(dev).transpose(1, 2, 0).astype(np.uint8)
                frames_rgb, _, _ = _level_frames(level, tile)
                if opt.jpeg:
                    frames = [encode_tile(f) for f in frames_rgb]
                    ts = TS_JPEG_BASELINE
                else:
                    frames = [np.ascontiguousarray(f).tobytes()
                              for f in frames_rgb]
                    ts = TS_EXPLICIT_LE
            _wrap_level(opt, li, frames, ts, tile, H, W, metadata,
                        study_uid, series_uid)
        if min(H, W) // 2 < opt.min_level_size:
            return li + 1
        if opt.batched:
            dev = jnp.clip(jnp.round(downsample2x2(dev)), 0, 255)
        else:
            chw = np.transpose(level, (2, 0, 1)).astype(np.float32)
            down = np.asarray(downsample2x2(chw))
            level = np.clip(np.round(np.transpose(down, (1, 2, 0))),
                            0, 255).astype(np.uint8)
        li += 1


def _pack_study(opt: ConvertOptions, n_levels: int, study_uid: str,
                tile: int) -> bytes:
    """Assemble the study tar directly from the manifest (deterministic:
    fixed member mtimes, levels in index order)."""
    buf = io.BytesIO()
    with tarfile.open(fileobj=buf, mode="w") as tar:
        manifest = {"levels": n_levels, "study_uid": study_uid,
                    "tile": tile}
        mb = json.dumps(manifest).encode()
        info = tarfile.TarInfo("study.json")
        info.size = len(mb)
        tar.addfile(info, io.BytesIO(mb))
        for i in range(n_levels):
            blob = opt.manifest[str(i)]
            info = tarfile.TarInfo(f"level_{i}.dcm")
            info.size = len(blob)
            tar.addfile(info, io.BytesIO(blob))
    return buf.getvalue()


def convert_wsi_to_dicom(slide_bytes: bytes, metadata: dict | None = None,
                         options: ConvertOptions | None = None) -> bytes:
    """Full conversion of any registered container (sniffed by magic bytes).

    Returns a tar archive of per-level .dcm files. Raises an actionable
    ``ValueError`` for unknown/truncated containers (see
    ``repro.wsi.formats.sniff``)."""
    opt = options or ConvertOptions()
    rd = open_slide(slide_bytes)
    if rd.H % rd.tile or rd.W % rd.tile:
        raise ValueError(
            f"slide is {rd.H}x{rd.W} with {rd.tile}px tiles — the pyramid "
            "engine requires tile-aligned dimensions (pad the scan)")
    study_uid, series_uid = _study_uids(opt)
    ctx = kernel_ops.use_mesh(opt.mesh) if opt.mesh is not None \
        else nullcontext()
    stats0 = (TRANSFER_STATS.uploads, TRANSFER_STATS.dispatches,
              TRANSFER_STATS.fetches)
    with tracing.span("convert.slide",
                      slide=(metadata or {}).get("slide_id")) as sp:
        with ctx:
            if opt.pipelined and opt.batched and opt.jpeg:
                n_levels = _convert_pipelined(rd, metadata, opt, study_uid,
                                              series_uid)
            else:
                n_levels = _convert_sync(rd, metadata, opt, study_uid,
                                         series_uid)
        with tracing.span("convert.pack", levels=n_levels):
            out = _pack_study(opt, n_levels, study_uid, rd.tile)
        if sp is not None:
            # TRANSFER_STATS is advisory (not thread-synced): under
            # concurrent conversions the deltas may include a neighbour's
            # transfers — they annotate, they don't assert
            sp.attrs.update(
                levels=n_levels,
                uploads=TRANSFER_STATS.uploads - stats0[0],
                dispatches=TRANSFER_STATS.dispatches - stats0[1],
                fetches=TRANSFER_STATS.fetches - stats0[2])
    return out


def study_levels(study_tar: bytes) -> dict[str, bytes]:
    """Unpack a converted study archive (non-file members are skipped)."""
    out = {}
    with tarfile.open(fileobj=io.BytesIO(study_tar)) as tar:
        for m in tar.getmembers():
            f = tar.extractfile(m)
            if f is None:  # directory / link member
                continue
            out[m.name] = f.read()
    return out
