"""The converter: PSV (proprietary) → multi-level DICOM WSM study.

Per slide: stream tiles from the container, build the multi-resolution
pyramid with the Pallas downsample kernel, transform-code every tile (Pallas
DCT/quant + host Huffman), wrap each level in a DICOM Part-10 instance
(TILED_FULL), and bundle the study as a tar archive.

Two compute paths (see DESIGN.md, "Whole-level batched dispatch"):

- **batched** (default): level 0 is uploaded to the device once; every
  further level is produced by chaining ``downsample2x2`` on device (no
  per-level host ``transpose``/``astype``/``clip`` round-trip), and all
  tiles of a level are transform-coded by a single fused ``jpeg_transform``
  dispatch followed by the vectorized host entropy coder.
- **per-tile** (``ConvertOptions(batched=False)``): the original path — host
  pyramid, ``[encode_tile(f) for f in frames]`` with 4 dispatches per tile.
  Kept for A/B benchmarking; both paths emit byte-identical DICOM pixel
  data.

**Crash/resume**: ``ConvertOptions.manifest`` is the single store of
finished-level DICOM bytes (level index → Part-10 bytes). A converter
restarted against the same manifest skips completed levels (this backs the
checkpoint/restart fault-tolerance tests — at-least-once delivery plus this
idempotent resume gives effectively-once conversion). The study tar is
assembled directly from the manifest, so finished-level bytes are stored
exactly once; call ``ConvertOptions.clear_manifest()`` to release them once
the study archive has been durably stored.
"""
from __future__ import annotations

import io
import json
import tarfile

import numpy as np

import jax.numpy as jnp

from repro.kernels import downsample2x2, jpeg_transform
from repro.wsi.dicom import (TS_EXPLICIT_LE, TS_JPEG_BASELINE, new_uid,
                             write_part10)
from repro.wsi.jpeg import encode_coef_batch, encode_tile
from repro.wsi.slide import PSVReader

__all__ = ["convert_wsi_to_dicom", "study_levels", "ConvertOptions"]


class ConvertOptions:
    """Converter knobs.

    ``manifest`` maps level index (str) to the finished level's Part-10
    bytes; it is both the resume checkpoint and the only copy of those bytes
    held by the converter (the output tar is written from it directly).
    """

    def __init__(self, *, min_level_size: int = 256, jpeg: bool = True,
                 manifest: dict | None = None, batched: bool = True):
        self.min_level_size = min_level_size
        self.jpeg = jpeg
        self.batched = batched
        self.manifest = manifest if manifest is not None else {}

    def clear_manifest(self) -> None:
        """Drop finished-level bytes (call after the study tar is stored)."""
        self.manifest.clear()


def _level_frames(img: np.ndarray, tile: int) -> tuple[list[np.ndarray], int, int]:
    """Tile a (H, W, 3) level into row-major frames."""
    H, W, _ = img.shape
    frames = []
    for r in range(H // tile):
        for c in range(W // tile):
            frames.append(img[r * tile:(r + 1) * tile,
                              c * tile:(c + 1) * tile])
    return frames, H // tile, W // tile


def _tile_batch(dev: jnp.ndarray, tile: int) -> jnp.ndarray:
    """(3, H, W) device level → (N, 3, tile, tile) row-major tile batch."""
    _, H, W = dev.shape
    bh, bw = H // tile, W // tile
    if bh == 0 or bw == 0:
        # level smaller than one tile: no full frames (matches the per-tile
        # path, whose _level_frames loop body never runs)
        return jnp.zeros((0, 3, tile, tile), dev.dtype)
    return (dev[:, :bh * tile, :bw * tile].reshape(3, bh, tile, bw, tile)
            .transpose(1, 3, 0, 2, 4).reshape(bh * bw, 3, tile, tile))


def _encode_level_batched(dev: jnp.ndarray, tile: int) -> list[bytes]:
    """All tiles of a device-resident level in one transform dispatch."""
    coef = np.asarray(jpeg_transform(_tile_batch(dev, tile)))
    return encode_coef_batch(coef)


def convert_wsi_to_dicom(psv_bytes: bytes, metadata: dict | None = None,
                         options: ConvertOptions | None = None) -> bytes:
    """Full conversion. Returns a tar archive of per-level .dcm files."""
    opt = options or ConvertOptions()
    rd = PSVReader(psv_bytes)
    tile = rd.tile
    study_uid, series_uid = new_uid(), new_uid()

    # level 0 assembled tile-by-tile (streaming); higher levels by 2× pooling
    H, W = rd.H, rd.W
    level = np.empty((H, W, 3), np.uint8)
    for (r, c), t in rd.tiles():
        level[r * tile:(r + 1) * tile, c * tile:(c + 1) * tile] = t

    # batched path: the pyramid lives on device as float32 planes holding
    # exact uint8 values (downsample output is re-quantized on device), so
    # the transform input matches the per-tile uint8 path bit-for-bit
    dev = jnp.asarray(np.transpose(level, (2, 0, 1)).astype(np.float32)) \
        if opt.batched else None

    li = 0
    while True:
        if opt.batched:
            H, W = int(dev.shape[1]), int(dev.shape[2])
        else:
            H, W = level.shape[:2]
        if str(li) not in opt.manifest:
            if opt.jpeg and opt.batched:
                frames = _encode_level_batched(dev, tile)
                ts = TS_JPEG_BASELINE
            else:
                if opt.batched:
                    level = np.asarray(dev).transpose(1, 2, 0).astype(np.uint8)
                frames_rgb, _, _ = _level_frames(level, tile)
                if opt.jpeg:
                    frames = [encode_tile(f) for f in frames_rgb]
                    ts = TS_JPEG_BASELINE
                else:
                    frames = [np.ascontiguousarray(f).tobytes()
                              for f in frames_rgb]
                    ts = TS_EXPLICIT_LE
            opt.manifest[str(li)] = write_part10(
                frames=frames, rows=tile, cols=tile,
                total_rows=H, total_cols=W, transfer_syntax=ts,
                study_uid=study_uid, series_uid=series_uid,
                instance_number=li + 1,
                metadata={0: (metadata or {}).get("slide_id", "unknown"),
                          1: f"level={li}"},
            )
        if min(H, W) // 2 < opt.min_level_size:
            break
        if opt.batched:
            dev = jnp.clip(jnp.round(downsample2x2(dev)), 0, 255)
        else:
            chw = np.transpose(level, (2, 0, 1)).astype(np.float32)
            down = np.asarray(downsample2x2(chw))
            level = np.clip(np.round(np.transpose(down, (1, 2, 0))),
                            0, 255).astype(np.uint8)
        li += 1

    n_levels = li + 1
    buf = io.BytesIO()
    with tarfile.open(fileobj=buf, mode="w") as tar:
        manifest = {"levels": n_levels, "study_uid": study_uid,
                    "tile": tile}
        mb = json.dumps(manifest).encode()
        info = tarfile.TarInfo("study.json")
        info.size = len(mb)
        tar.addfile(info, io.BytesIO(mb))
        for i in range(n_levels):
            blob = opt.manifest[str(i)]
            info = tarfile.TarInfo(f"level_{i}.dcm")
            info.size = len(blob)
            tar.addfile(info, io.BytesIO(blob))
    return buf.getvalue()


def study_levels(study_tar: bytes) -> dict[str, bytes]:
    """Unpack a converted study archive (non-file members are skipped)."""
    out = {}
    with tarfile.open(fileobj=io.BytesIO(study_tar)) as tar:
        for m in tar.getmembers():
            f = tar.extractfile(m)
            if f is None:  # directory / link member
                continue
            out[m.name] = f.read()
    return out
