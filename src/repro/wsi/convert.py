"""The converter: PSV (proprietary) → multi-level DICOM WSM study.

Per slide: stream tiles from the container, build the multi-resolution
pyramid with the Pallas downsample kernel, transform-code every tile (Pallas
DCT/quant + host Huffman), wrap each level in a DICOM Part-10 instance
(TILED_FULL), and bundle the study as a tar archive.

**Crash/resume**: a per-level manifest records finished levels; a converter
restarted against the same manifest store skips completed levels (this backs
the checkpoint/restart fault-tolerance tests — at-least-once delivery plus
this idempotent resume gives effectively-once conversion).
"""
from __future__ import annotations

import io
import json
import tarfile

import numpy as np

from repro.kernels import downsample2x2
from repro.wsi.dicom import (TS_EXPLICIT_LE, TS_JPEG_BASELINE, new_uid,
                             write_part10)
from repro.wsi.jpeg import encode_tile
from repro.wsi.slide import PSVReader

__all__ = ["convert_wsi_to_dicom", "study_levels", "ConvertOptions"]


class ConvertOptions:
    def __init__(self, *, min_level_size: int = 256, jpeg: bool = True,
                 manifest: dict | None = None):
        self.min_level_size = min_level_size
        self.jpeg = jpeg
        # manifest: level index -> finished DICOM bytes (resume support)
        self.manifest = manifest if manifest is not None else {}


def _level_frames(img: np.ndarray, tile: int) -> tuple[list[bytes], int, int]:
    """Tile a (H, W, 3) level into row-major frames (JPEG or raw)."""
    H, W, _ = img.shape
    frames = []
    for r in range(H // tile):
        for c in range(W // tile):
            frames.append(img[r * tile:(r + 1) * tile,
                              c * tile:(c + 1) * tile])
    return frames, H // tile, W // tile


def convert_wsi_to_dicom(psv_bytes: bytes, metadata: dict | None = None,
                         options: ConvertOptions | None = None) -> bytes:
    """Full conversion. Returns a tar archive of per-level .dcm files."""
    opt = options or ConvertOptions()
    rd = PSVReader(psv_bytes)
    tile = rd.tile
    study_uid, series_uid = new_uid(), new_uid()

    # level 0 assembled tile-by-tile (streaming); higher levels by 2× pooling
    H, W = rd.H, rd.W
    level = np.empty((H, W, 3), np.uint8)
    for (r, c), t in rd.tiles():
        level[r * tile:(r + 1) * tile, c * tile:(c + 1) * tile] = t

    dcm_files: dict[str, bytes] = {}
    li = 0
    while True:
        H, W = level.shape[:2]
        if str(li) in opt.manifest:
            dcm_files[f"level_{li}.dcm"] = opt.manifest[str(li)]
        else:
            frames_rgb, _, _ = _level_frames(level, tile)
            if opt.jpeg:
                frames = [encode_tile(f) for f in frames_rgb]
                ts = TS_JPEG_BASELINE
            else:
                frames = [np.ascontiguousarray(f).tobytes()
                          for f in frames_rgb]
                ts = TS_EXPLICIT_LE
            dcm = write_part10(
                frames=frames, rows=tile, cols=tile,
                total_rows=H, total_cols=W, transfer_syntax=ts,
                study_uid=study_uid, series_uid=series_uid,
                instance_number=li + 1,
                metadata={0: (metadata or {}).get("slide_id", "unknown"),
                          1: f"level={li}"},
            )
            dcm_files[f"level_{li}.dcm"] = dcm
            opt.manifest[str(li)] = dcm
        if min(H, W) // 2 < opt.min_level_size:
            break
        chw = np.transpose(level, (2, 0, 1)).astype(np.float32)
        down = np.asarray(downsample2x2(chw))
        level = np.clip(np.round(np.transpose(down, (1, 2, 0))),
                        0, 255).astype(np.uint8)
        li += 1

    buf = io.BytesIO()
    with tarfile.open(fileobj=buf, mode="w") as tar:
        manifest = {"levels": len(dcm_files), "study_uid": study_uid,
                    "tile": tile}
        mb = json.dumps(manifest).encode()
        info = tarfile.TarInfo("study.json")
        info.size = len(mb)
        tar.addfile(info, io.BytesIO(mb))
        for name, blob in sorted(dcm_files.items()):
            info = tarfile.TarInfo(name)
            info.size = len(blob)
            tar.addfile(info, io.BytesIO(blob))
    return buf.getvalue()


def study_levels(study_tar: bytes) -> dict[str, bytes]:
    """Unpack a converted study archive."""
    out = {}
    with tarfile.open(fileobj=io.BytesIO(study_tar)) as tar:
        for m in tar.getmembers():
            out[m.name] = tar.extractfile(m).read()
    return out
