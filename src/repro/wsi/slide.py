"""Proprietary-format whole-slide images: a synthetic scanner + tiled reader.

Real WSIs are gigapixel images in vendor formats (SVS etc.) that cannot be
loaded whole. We model that with **PSV** ("pretend-SVS"), a tiled container:

    magic 'PSV1' | u32 H | u32 W | u32 tile | u32 n_tiles
    per tile: u32 row | u32 col | u32 nbytes | zlib(RGB uint8 tile)

The reader streams one tile at a time (the HBM→VMEM discipline of the real
converters), never materializing the full image. ``SyntheticScanner``
procedurally renders H&E-like content — smooth eosin background + scattered
hematoxylin "nuclei" — deterministically from a seed, so tests and benchmarks
get realistic, compressible, reproducible pixel data at any size.
"""
from __future__ import annotations

import io
import struct
import zlib

import numpy as np

__all__ = ["SyntheticScanner", "PSVReader", "write_psv"]

_MAGIC = b"PSV1"


def write_psv(tiles: dict[tuple[int, int], np.ndarray], H: int, W: int,
              tile: int) -> bytes:
    buf = io.BytesIO()
    buf.write(_MAGIC)
    buf.write(struct.pack("<IIII", H, W, tile, len(tiles)))
    for (r, c), arr in sorted(tiles.items()):
        raw = zlib.compress(np.ascontiguousarray(arr, np.uint8).tobytes(), 6)
        buf.write(struct.pack("<III", r, c, len(raw)))
        buf.write(raw)
    return buf.getvalue()


class SyntheticScanner:
    """Renders deterministic H&E-like slides into PSV bytes."""

    def __init__(self, seed: int = 0):
        self.seed = seed

    def _render_tile(self, y0: int, x0: int, h: int, w: int,
                     rng_grid: np.ndarray) -> np.ndarray:
        yy = (np.arange(y0, y0 + h, dtype=np.float32))[:, None]
        xx = (np.arange(x0, x0 + w, dtype=np.float32))[None, :]
        # smooth eosin-pink stroma
        base = (
            0.5
            + 0.22 * np.sin(yy / 97.0 + self.seed)
            + 0.18 * np.cos(xx / 131.0 - self.seed * 0.7)
            + 0.10 * np.sin((xx + yy) / 53.0)
        )
        r = 230 - 40 * base
        g = 170 - 70 * base
        b = 200 - 30 * base
        # hematoxylin nuclei: pseudo-random blobs from a hash lattice
        cell = 48
        gy, gx = yy // cell, xx // cell
        hash_ = np.sin(gy * 12.9898 + gx * 78.233 + self.seed) * 43758.5453
        frac = hash_ - np.floor(hash_)
        cy = (gy + 0.2 + 0.6 * frac) * cell
        cx = (gx + 0.2 + 0.6 * (frac * 7 % 1)) * cell
        d2 = (yy - cy) ** 2 + (xx - cx) ** 2
        radius2 = (6 + 8 * (frac * 3 % 1)) ** 2
        nucleus = (d2 < radius2) & (frac > 0.35)
        r = np.where(nucleus, 80 + 30 * frac, r)
        g = np.where(nucleus, 60 + 20 * frac, g)
        b = np.where(nucleus, 140 + 40 * frac, b)
        img = np.stack([r, g, b], axis=-1)
        return np.clip(img, 0, 255).astype(np.uint8)

    def scan(self, H: int = 1024, W: int = 1024, tile: int = 256) -> bytes:
        """Produce a PSV slide of the given dimensions."""
        assert H % tile == 0 and W % tile == 0
        tiles = {}
        for r in range(H // tile):
            for c in range(W // tile):
                tiles[(r, c)] = self._render_tile(
                    r * tile, c * tile, tile, tile, None
                )
        return write_psv(tiles, H, W, tile)


class PSVReader:
    """Streaming tile reader; indexes the container once, inflates on demand."""

    def __init__(self, data: bytes):
        if data[:4] != _MAGIC:
            raise ValueError("not a PSV container")
        self.H, self.W, self.tile, n = struct.unpack_from("<IIII", data, 4)
        self._data = data
        self._index: dict[tuple[int, int], tuple[int, int]] = {}
        off = 20
        for _ in range(n):
            r, c, nb = struct.unpack_from("<III", data, off)
            off += 12
            self._index[(r, c)] = (off, nb)
            off += nb

    @property
    def grid(self) -> tuple[int, int]:
        return self.H // self.tile, self.W // self.tile

    def read_tile(self, r: int, c: int) -> np.ndarray:
        off, nb = self._index[(r, c)]
        raw = zlib.decompress(self._data[off : off + nb])
        t = self.tile
        return np.frombuffer(raw, np.uint8).reshape(t, t, 3)

    def tiles(self):
        for (r, c) in sorted(self._index):
            yield (r, c), self.read_tile(r, c)
