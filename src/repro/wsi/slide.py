"""Synthetic whole-slide scanner (+ back-compat re-exports of the readers).

Real WSIs are gigapixel images in vendor containers that cannot be loaded
whole; the readers that stream them tile-by-tile live in
``repro.wsi.formats`` (PSV and tiled TIFF/SVS — ``PSVReader``/``write_psv``
are re-exported here for existing callers).

``SyntheticScanner`` procedurally renders H&E-like content — smooth eosin
background + scattered hematoxylin "nuclei" — deterministically from a
seed, so tests and benchmarks get realistic, compressible, reproducible
pixel data at any size. It can emit the *same pixels* in either container
(``scan`` → PSV, ``scan_tiff`` → SVS-shaped tiled TIFF), which is what the
cross-format byte-identity assertions are built on.
"""
from __future__ import annotations

import numpy as np

from repro.wsi.formats.psv import PSVReader, write_psv  # noqa: F401
from repro.wsi.formats.tiff import write_tiff

__all__ = ["SyntheticScanner", "PSVReader", "write_psv"]


class SyntheticScanner:
    """Renders deterministic H&E-like slides into PSV or tiled-TIFF bytes."""

    def __init__(self, seed: int = 0):
        self.seed = seed

    def _render_tile(self, y0: int, x0: int, h: int, w: int,
                     rng_grid: np.ndarray) -> np.ndarray:
        yy = (np.arange(y0, y0 + h, dtype=np.float32))[:, None]
        xx = (np.arange(x0, x0 + w, dtype=np.float32))[None, :]
        # smooth eosin-pink stroma
        base = (
            0.5
            + 0.22 * np.sin(yy / 97.0 + self.seed)
            + 0.18 * np.cos(xx / 131.0 - self.seed * 0.7)
            + 0.10 * np.sin((xx + yy) / 53.0)
        )
        r = 230 - 40 * base
        g = 170 - 70 * base
        b = 200 - 30 * base
        # hematoxylin nuclei: pseudo-random blobs from a hash lattice
        cell = 48
        gy, gx = yy // cell, xx // cell
        hash_ = np.sin(gy * 12.9898 + gx * 78.233 + self.seed) * 43758.5453
        frac = hash_ - np.floor(hash_)
        cy = (gy + 0.2 + 0.6 * frac) * cell
        cx = (gx + 0.2 + 0.6 * (frac * 7 % 1)) * cell
        d2 = (yy - cy) ** 2 + (xx - cx) ** 2
        radius2 = (6 + 8 * (frac * 3 % 1)) ** 2
        nucleus = (d2 < radius2) & (frac > 0.35)
        r = np.where(nucleus, 80 + 30 * frac, r)
        g = np.where(nucleus, 60 + 20 * frac, g)
        b = np.where(nucleus, 140 + 40 * frac, b)
        img = np.stack([r, g, b], axis=-1)
        return np.clip(img, 0, 255).astype(np.uint8)

    def _render_tiles(self, H: int, W: int,
                      tile: int) -> dict[tuple[int, int], np.ndarray]:
        assert H % tile == 0 and W % tile == 0
        return {(r, c): self._render_tile(r * tile, c * tile, tile, tile,
                                          None)
                for r in range(H // tile) for c in range(W // tile)}

    def scan(self, H: int = 1024, W: int = 1024, tile: int = 256) -> bytes:
        """Produce a PSV slide of the given dimensions."""
        return write_psv(self._render_tiles(H, W, tile), H, W, tile)

    def scan_tiff(self, H: int = 1024, W: int = 1024, tile: int = 256,
                  description: str | None = None) -> bytes:
        """Produce the same pixels as ``scan`` in an SVS-shaped tiled TIFF.

        The default ``ImageDescription`` carries Aperio-style ``Key =
        Value`` vendor metadata, which ``TiffSlideReader`` parses back into
        its ``metadata`` dict.
        """
        if description is None:
            description = (f"repro SyntheticScanner v1 {W}x{H} "
                           f"|AppMag = 20|MPP = 0.5|seed = {self.seed}")
        return write_tiff(self._render_tiles(H, W, tile), H, W, tile,
                          description=description)
