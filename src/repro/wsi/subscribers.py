"""Downstream consumers of the DICOM store's instance-stored topic.

The paper's extensibility claim is that new services attach to existing
pub/sub topics without touching ingestion. These two subscribers are that
claim made concrete — both hang off ``DicomStoreService.topic``
(``dicom-instance-stored``) and never talk to the conversion service:

* :class:`ValidationService` — the community-validation workflow (cf.
  Silva et al.'s DICOM validation service): re-reads every stored blob,
  runs the :class:`~repro.wsi.dicom.Part10Index` structural scan plus
  ``verify()`` deep checks, and **quarantines** corrupt instances — blob
  copied into a DLQ bucket with the failure reason, instance deleted from
  the store so QIDO/WADO stop serving it.
* :class:`InferenceSubscriber` — a mock ML model (cf. the Slim viewer's
  model integrations): pulls frames through frame-level WADO
  (``retrieve_frame`` off the cached index — no full-file reparse) and
  records a per-instance feature summary, standing in for patch-level
  inference over the pyramid.
"""
from __future__ import annotations

import threading

from repro.core.pubsub import DeliveryCtx, Message, Subscription
from repro.core.storage import Bucket
from repro.wsi.dicom import Part10Index
from repro.wsi.store_service import DicomStoreService

__all__ = ["ValidationService", "InferenceSubscriber"]


class ValidationService:
    """Integrity-checks every stored instance; quarantines corrupt ones."""

    def __init__(self, store: DicomStoreService, quarantine_bucket: Bucket,
                 *, name: str = "dicom-validation"):
        self.store = store
        self.quarantine_bucket = quarantine_bucket
        self.metrics = store.metrics
        self._lock = threading.Lock()
        self.checked: list[str] = []
        self.quarantined: list[tuple[str, str]] = []  # (sop_uid, reason)
        self.subscription = Subscription(store.topic, name, self._handle)

    def _handle(self, msg: Message, ctx: DeliveryCtx):
        sop = msg.data["sop_instance_uid"]
        try:
            blob = self.store.bucket.get(msg.data["key"]).data
        except KeyError:
            ctx.ack()  # already deleted/quarantined — nothing to validate
            return
        try:
            Part10Index(blob).verify()
        except ValueError as exc:
            self._quarantine(sop, blob, str(exc))
        else:
            with self._lock:
                self.checked.append(sop)
            self.metrics.inc("validation.passed")
        ctx.ack()

    def _quarantine(self, sop: str, blob: bytes, reason: str):
        self.quarantine_bucket.put(f"quarantine/{sop}.dcm", blob,
                                   {"reason": reason})
        try:
            self.store.delete_instance(sop)
        except KeyError:
            pass  # concurrently deleted
        with self._lock:
            self.quarantined.append((sop, reason))
        self.metrics.inc("validation.quarantined")

    def sweep(self) -> int:
        """Re-validate every indexed instance (bit-rot patrol, cron-style).

        Event delivery catches corruption present at store time; the sweep
        catches blobs that rotted afterwards. Returns the number
        quarantined.
        """
        before = len(self.quarantined)
        for study in self.store.search_studies():
            for meta in self.store.search_instances(study):
                try:
                    blob = self.store.bucket.get(meta["key"]).data
                    Part10Index(blob).verify()
                except KeyError:
                    continue
                except ValueError as exc:
                    self._quarantine(meta["sop_instance_uid"], blob,
                                     str(exc))
        return len(self.quarantined) - before


class InferenceSubscriber:
    """Mock ML model: frame-level WADO fetches + a toy per-frame feature."""

    def __init__(self, store: DicomStoreService, *,
                 name: str = "ml-inference", max_frames: int = 4):
        self.store = store
        self.metrics = store.metrics
        self.max_frames = max_frames
        self._lock = threading.Lock()
        self.predictions: dict[str, dict] = {}  # sop_uid -> result
        self.subscription = Subscription(store.topic, name, self._handle)

    @staticmethod
    def frame_feature(frame: bytes) -> float:
        """The stand-in embedding: mean byte value of the frame."""
        return sum(frame) / len(frame) if frame else 0.0

    def _handle(self, msg: Message, ctx: DeliveryCtx):
        sop = msg.data["sop_instance_uid"]
        try:
            # clamp to the *indexed* frame count, not the declared one — an
            # instance over-declaring (0028,0008) must not burn redeliveries
            idx = self.store.frame_index(sop)
            n = min(idx.n_frames, self.max_frames)
            features = [self.frame_feature(self.store.retrieve_frame(sop, i))
                        for i in range(n)]
        except (KeyError, ValueError):
            # quarantined/deleted before we ran, or rotted since storing —
            # the validation subscriber owns that path; nothing to score
            ctx.ack()
            return
        with self._lock:
            self.predictions[sop] = {
                "study_uid": msg.data["study_uid"],
                "frames_scored": n,
                "features": features,
            }
        self.metrics.inc("inference.instances")
        self.metrics.inc("inference.frames", n)
        ctx.ack()
