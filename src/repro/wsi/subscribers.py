"""Downstream consumers of the DICOM store's instance-stored topic.

The paper's extensibility claim is that new services attach to existing
pub/sub topics without touching ingestion. These two subscribers are that
claim made concrete — both hang off ``DicomStoreService.topic``
(``dicom-instance-stored``) and never talk to the conversion service:

* :class:`ValidationService` — the community-validation workflow (cf.
  Silva et al.'s DICOM validation service): re-reads every stored blob,
  runs the :class:`~repro.wsi.dicom.Part10Index` structural scan plus
  ``verify()`` deep checks, and **quarantines** corrupt instances — blob
  copied into a DLQ bucket with the failure reason, instance deleted from
  the store so QIDO/WADO stop serving it.
* :class:`InferenceSubscriber` — a mock ML model (cf. the Slim viewer's
  model integrations): pulls frames through frame-level WADO
  (``retrieve_frame`` off the cached index — no full-file reparse),
  **decodes** them to pixels — the batched decode path
  (``decode_tiles_batch``) when it pulls more than one frame, the
  per-tile decoder otherwise — and records per-frame pixel statistics,
  standing in for patch-level inference over the pyramid.
"""
from __future__ import annotations

import numpy as np

from repro.analysis.lockdep import TrackedLock
from repro.core import tracing
from repro.core.pubsub import DeliveryCtx, Message, Subscription
from repro.core.storage import Bucket
from repro.wsi.dicom import Part10Index
from repro.wsi.jpeg import decode_frames
from repro.wsi.store_service import DicomStoreService

__all__ = ["ValidationService", "InferenceSubscriber"]


class ValidationService:
    """Integrity-checks every stored instance; quarantines corrupt ones."""

    def __init__(self, store: DicomStoreService, quarantine_bucket: Bucket,
                 *, name: str = "dicom-validation"):
        self.store = store
        self.quarantine_bucket = quarantine_bucket
        self.metrics = store.metrics
        self._lock = TrackedLock("ValidationService._lock")
        self.checked: list[str] = []
        self.quarantined: list[tuple[str, str]] = []  # (sop_uid, reason)
        self.subscription = Subscription(store.topic, name, self._handle)

    def _handle(self, msg: Message, ctx: DeliveryCtx):
        sop = msg.data["sop_instance_uid"]
        try:
            blob = self.store.read_blob(msg.data["key"])
        except KeyError:
            ctx.ack()  # already deleted/quarantined — nothing to validate
            return
        try:
            Part10Index(blob).verify()
        except ValueError as exc:
            self._quarantine(sop, blob, str(exc))
        else:
            with self._lock:
                self.checked.append(sop)
            self.metrics.inc("validation.passed")
            # per-instance verify outcome as a structured span event on the
            # ambient delivery span (quarantines annotate in _quarantine)
            tracing.add_event(None, "validate.instance", sop=sop,
                              verdict="passed")
        ctx.ack()

    def _quarantine(self, sop: str, blob: bytes, reason: str):
        self.quarantine_bucket.put(f"quarantine/{sop}.dcm", blob,
                                   {"reason": reason})
        try:
            self.store.delete_instance(sop)
        except KeyError:
            pass  # concurrently deleted
        with self._lock:
            self.quarantined.append((sop, reason))
        self.metrics.inc("validation.quarantined")
        tracing.add_event(None, "validate.instance", sop=sop,
                          verdict="quarantined", reason=reason)

    def sweep(self) -> int:
        """Re-validate every indexed instance (bit-rot patrol, cron-style).

        Event delivery catches corruption present at store time; the sweep
        catches blobs that rotted afterwards. Returns the number
        quarantined.
        """
        before = len(self.quarantined)
        for study in self.store.search_studies():
            for meta in self.store.search_instances(study):
                try:
                    blob = self.store.read_blob(meta["key"])
                    Part10Index(blob).verify()
                except KeyError:
                    continue
                except ValueError as exc:
                    self._quarantine(meta["sop_instance_uid"], blob,
                                     str(exc))
        return len(self.quarantined) - before


class InferenceSubscriber:
    """Mock ML model: frame-level WADO fetches + decoded per-frame stats."""

    def __init__(self, store: DicomStoreService, *,
                 name: str = "ml-inference", max_frames: int = 4):
        self.store = store
        self.metrics = store.metrics
        self.max_frames = max_frames
        self._lock = TrackedLock("InferenceSubscriber._lock")
        self.predictions: dict[str, dict] = {}  # sop_uid -> result
        self.subscription = Subscription(store.topic, name, self._handle)

    @staticmethod
    def frame_stats(pixels: np.ndarray) -> dict:
        """The stand-in embedding: decoded-pixel statistics of one frame."""
        f = pixels.astype(np.float64)
        return {"mean": float(f.mean()), "std": float(f.std()),
                "min": int(pixels.min()), "max": int(pixels.max())}

    def _handle(self, msg: Message, ctx: DeliveryCtx):
        sop = msg.data["sop_instance_uid"]
        try:
            # clamp to the *indexed* frame count, not the declared one — an
            # instance over-declaring (0028,0008) must not burn redeliveries
            idx = self.store.frame_index(sop)
            n = min(idx.n_frames, self.max_frames)
            frames = [self.store.retrieve_frame(sop, i) for i in range(n)]
            # the shared store-consumer dispatch: batched decode path when
            # more than one frame is pulled, per-tile decoder otherwise
            pixels = decode_frames(
                frames, transfer_syntax=msg.data.get("transfer_syntax"),
                rows=msg.data.get("rows") or 0,
                cols=msg.data.get("columns") or 0)
            stats = [self.frame_stats(pixels[i]) for i in range(n)]
        except (KeyError, ValueError):
            # quarantined/deleted before we ran, rotted since storing, or
            # undecodable ("corrupt JPEG …") — the validation subscriber
            # owns that path; nothing to score
            ctx.ack()
            return
        with self._lock:
            self.predictions[sop] = {
                "study_uid": msg.data["study_uid"],
                "frames_scored": n,
                "pixel_stats": stats,
            }
        self.metrics.inc("inference.instances")
        self.metrics.inc("inference.frames", n)
        self.metrics.inc("inference.pixels",
                         int(np.prod(pixels.shape[:3])) if n else 0)
        tracing.add_event(None, "inference.instance", sop=sop, frames=n)
        ctx.ack()
