"""JPEG baseline codec: JAX/Pallas transform stage + host entropy stage.

Hardware-adaptation split (recorded in DESIGN.md, "Transform/entropy split"):
the transform math (color conversion, 8×8 DCT, quantization) is data-parallel
→ Pallas kernels; Huffman coding is a sequential, branchy bitstream operation
with no MXU/VPU analogue → host numpy. This mirrors what the C++ ``wsi2dcm``
converter does (SIMD transform, scalar entropy coder).

Two encoder paths, byte-identical by construction (tested):

- ``encode_tile``: the original per-tile path — 4 jitted dispatches per tile
  (rgb2ycbcr + 3× dct8x8_quant) and a per-coefficient Python Huffman loop.
  Kept as the A/B baseline for benchmarks.
- ``encode_tiles_batch``: the whole-level batched path — one fused
  ``jpeg_transform`` dispatch for every tile of a level, then a
  numpy-vectorized symbol-stream entropy coder (``encode_coef_batch``) whose
  cost scales with the number of emitted symbols, not coefficients.

And two decoder paths, pixel-identical by construction (tested) — the
export subsystem's compute spine run in reverse:

- ``decode_tile``: the per-tile path — a per-symbol Python Huffman loop,
  then the fused ``jpeg_inverse`` dispatch. Kept as the A/B baseline.
- ``decode_tiles_batch``: the whole-level batched path — the lockstep
  entropy **decoder** (``decode_coef_batch``: every tile of a level is an
  independent bitstream, so N tiles advance one symbol position per step;
  level-sized batches run the step automaton as a single jitted
  ``lax.while_loop`` dispatch (``repro.wsi.entropy_jax``), tiny batches as
  vectorized numpy steps), then a single fused ``jpeg_inverse`` dispatch
  for the whole level. Entropy ``decode ∘ encode`` is exact at the
  coefficient level (the bitstream is lossless; only quantization loses
  information).

Produces/consumes real JFIF bytes (SOI/APP0/DQT/SOF0/DHT/SOS/EOI, standard
Annex-K tables, 4:4:4, byte stuffing). Truncated or garbage input raises
``ValueError("corrupt JPEG …")`` from every decode entry point — that
string is what the export service turns into an actionable DLQ reason.

Both encoder paths are thread-safe (the zigzag gather-index cache is the
only module-level mutable state and is lock-protected), and the heavy numpy
regions release the GIL — the real-mode pipeline entropy-codes several
slides' levels in parallel worker threads.
"""
from __future__ import annotations

import struct

import numpy as np

from repro.analysis.lockdep import TrackedLock

from repro.kernels import (dct8x8_quant, jpeg_inverse, jpeg_transform,
                           rgb2ycbcr)
from repro.kernels.ref import JPEG_CHROMA_Q, JPEG_LUMA_Q
from repro.wsi.dicom import TS_EXPLICIT_LE, TS_JPEG_BASELINE

__all__ = ["encode_tile", "encode_tiles_batch", "encode_coef_batch",
           "decode_tile", "decode_tiles_batch", "decode_coef_batch",
           "decode_frames", "psnr"]

# --------------------------------------------------------------------------
# Annex-K Huffman tables
# --------------------------------------------------------------------------
_DC_L_BITS = [0, 1, 5, 1, 1, 1, 1, 1, 1, 0, 0, 0, 0, 0, 0, 0]
_DC_L_VALS = list(range(12))
_DC_C_BITS = [0, 3, 1, 1, 1, 1, 1, 1, 1, 1, 1, 0, 0, 0, 0, 0]
_DC_C_VALS = list(range(12))
_AC_L_BITS = [0, 2, 1, 3, 3, 2, 4, 3, 5, 5, 4, 4, 0, 0, 1, 0x7D]
_AC_L_VALS = [
    0x01, 0x02, 0x03, 0x00, 0x04, 0x11, 0x05, 0x12, 0x21, 0x31, 0x41, 0x06,
    0x13, 0x51, 0x61, 0x07, 0x22, 0x71, 0x14, 0x32, 0x81, 0x91, 0xA1, 0x08,
    0x23, 0x42, 0xB1, 0xC1, 0x15, 0x52, 0xD1, 0xF0, 0x24, 0x33, 0x62, 0x72,
    0x82, 0x09, 0x0A, 0x16, 0x17, 0x18, 0x19, 0x1A, 0x25, 0x26, 0x27, 0x28,
    0x29, 0x2A, 0x34, 0x35, 0x36, 0x37, 0x38, 0x39, 0x3A, 0x43, 0x44, 0x45,
    0x46, 0x47, 0x48, 0x49, 0x4A, 0x53, 0x54, 0x55, 0x56, 0x57, 0x58, 0x59,
    0x5A, 0x63, 0x64, 0x65, 0x66, 0x67, 0x68, 0x69, 0x6A, 0x73, 0x74, 0x75,
    0x76, 0x77, 0x78, 0x79, 0x7A, 0x83, 0x84, 0x85, 0x86, 0x87, 0x88, 0x89,
    0x8A, 0x92, 0x93, 0x94, 0x95, 0x96, 0x97, 0x98, 0x99, 0x9A, 0xA2, 0xA3,
    0xA4, 0xA5, 0xA6, 0xA7, 0xA8, 0xA9, 0xAA, 0xB2, 0xB3, 0xB4, 0xB5, 0xB6,
    0xB7, 0xB8, 0xB9, 0xBA, 0xC2, 0xC3, 0xC4, 0xC5, 0xC6, 0xC7, 0xC8, 0xC9,
    0xCA, 0xD2, 0xD3, 0xD4, 0xD5, 0xD6, 0xD7, 0xD8, 0xD9, 0xDA, 0xE1, 0xE2,
    0xE3, 0xE4, 0xE5, 0xE6, 0xE7, 0xE8, 0xE9, 0xEA, 0xF1, 0xF2, 0xF3, 0xF4,
    0xF5, 0xF6, 0xF7, 0xF8, 0xF9, 0xFA,
]
_AC_C_BITS = [0, 2, 1, 2, 4, 4, 3, 4, 7, 5, 4, 4, 0, 1, 2, 0x77]
_AC_C_VALS = [
    0x00, 0x01, 0x02, 0x03, 0x11, 0x04, 0x05, 0x21, 0x31, 0x06, 0x12, 0x41,
    0x51, 0x07, 0x61, 0x71, 0x13, 0x22, 0x32, 0x81, 0x08, 0x14, 0x42, 0x91,
    0xA1, 0xB1, 0xC1, 0x09, 0x23, 0x33, 0x52, 0xF0, 0x15, 0x62, 0x72, 0xD1,
    0x0A, 0x16, 0x24, 0x34, 0xE1, 0x25, 0xF1, 0x17, 0x18, 0x19, 0x1A, 0x26,
    0x27, 0x28, 0x29, 0x2A, 0x35, 0x36, 0x37, 0x38, 0x39, 0x3A, 0x43, 0x44,
    0x45, 0x46, 0x47, 0x48, 0x49, 0x4A, 0x53, 0x54, 0x55, 0x56, 0x57, 0x58,
    0x59, 0x5A, 0x63, 0x64, 0x65, 0x66, 0x67, 0x68, 0x69, 0x6A, 0x73, 0x74,
    0x75, 0x76, 0x77, 0x78, 0x79, 0x7A, 0x82, 0x83, 0x84, 0x85, 0x86, 0x87,
    0x88, 0x89, 0x8A, 0x92, 0x93, 0x94, 0x95, 0x96, 0x97, 0x98, 0x99, 0x9A,
    0xA2, 0xA3, 0xA4, 0xA5, 0xA6, 0xA7, 0xA8, 0xA9, 0xAA, 0xB2, 0xB3, 0xB4,
    0xB5, 0xB6, 0xB7, 0xB8, 0xB9, 0xBA, 0xC2, 0xC3, 0xC4, 0xC5, 0xC6, 0xC7,
    0xC8, 0xC9, 0xCA, 0xD2, 0xD3, 0xD4, 0xD5, 0xD6, 0xD7, 0xD8, 0xD9, 0xDA,
    0xE2, 0xE3, 0xE4, 0xE5, 0xE6, 0xE7, 0xE8, 0xE9, 0xEA, 0xF2, 0xF3, 0xF4,
    0xF5, 0xF6, 0xF7, 0xF8, 0xF9, 0xFA,
]

_ZIGZAG = np.array([
    0, 1, 8, 16, 9, 2, 3, 10, 17, 24, 32, 25, 18, 11, 4, 5,
    12, 19, 26, 33, 40, 48, 41, 34, 27, 20, 13, 6, 7, 14, 21, 28,
    35, 42, 49, 56, 57, 50, 43, 36, 29, 22, 15, 23, 30, 37, 44, 51,
    58, 59, 52, 45, 38, 31, 39, 46, 53, 60, 61, 54, 47, 55, 62, 63,
])


def _build_codes(bits, vals):
    """Canonical Huffman: symbol -> (code, length)."""
    codes = {}
    code = 0
    k = 0
    for ln in range(1, 17):
        for _ in range(bits[ln - 1]):
            codes[vals[k]] = (code, ln)
            code += 1
            k += 1
        code <<= 1
    return codes

_ENC = {
    ("dc", 0): _build_codes(_DC_L_BITS, _DC_L_VALS),
    ("dc", 1): _build_codes(_DC_C_BITS, _DC_C_VALS),
    ("ac", 0): _build_codes(_AC_L_BITS, _AC_L_VALS),
    ("ac", 1): _build_codes(_AC_C_BITS, _AC_C_VALS),
}
_DEC = {
    k: {v: sym for sym, v in table.items()} for k, table in _ENC.items()
}


class _BitWriter:
    def __init__(self):
        self.out = bytearray()
        self.acc = 0
        self.nbits = 0

    def put(self, code: int, length: int):
        self.acc = (self.acc << length) | (code & ((1 << length) - 1))
        self.nbits += length
        while self.nbits >= 8:
            byte = (self.acc >> (self.nbits - 8)) & 0xFF
            self.out.append(byte)
            if byte == 0xFF:
                self.out.append(0x00)  # byte stuffing
            self.nbits -= 8
        self.acc &= (1 << self.nbits) - 1

    def flush(self):
        if self.nbits:
            pad = 8 - self.nbits
            self.put((1 << pad) - 1, pad)
        return bytes(self.out)


class _BitReader:
    def __init__(self, data: bytes):
        self.data = data
        self.pos = 0
        self.acc = 0
        self.nbits = 0

    def _fill(self):
        if self.pos >= len(self.data):
            raise ValueError("corrupt JPEG stream: truncated scan data")
        b = self.data[self.pos]
        self.pos += 1
        if b == 0xFF and self.pos < len(self.data) \
                and self.data[self.pos] == 0x00:
            self.pos += 1  # unstuff
        self.acc = (self.acc << 8) | b
        self.nbits += 8

    def get(self, n: int) -> int:
        while self.nbits < n:
            self._fill()
        v = (self.acc >> (self.nbits - n)) & ((1 << n) - 1)
        self.nbits -= n
        self.acc &= (1 << self.nbits) - 1
        return v

    def huff(self, table: dict) -> int:
        code, ln = 0, 0
        while ln < 16:
            code = (code << 1) | self.get(1)
            ln += 1
            sym = table.get((code, ln))
            if sym is not None:
                return sym
        raise ValueError("corrupt JPEG stream: invalid Huffman code")


def _category(v: int) -> int:
    return int(v).bit_length() if v > 0 else int(-v).bit_length()


def _encode_blocks(bw: _BitWriter, planes: list[np.ndarray]):
    """planes: 3 × (H, W) int coefficient planes (blocks in place), 4:4:4."""
    H, W = planes[0].shape
    bh, bwid = H // 8, W // 8
    zz = [
        p.reshape(bh, 8, bwid, 8).transpose(0, 2, 1, 3)
        .reshape(bh, bwid, 64)[:, :, _ZIGZAG]
        for p in planes
    ]
    pred = [0, 0, 0]
    for r in range(bh):
        for c in range(bwid):
            for comp in range(3):
                tid = 0 if comp == 0 else 1
                blk = zz[comp][r, c]
                dc = int(blk[0])
                diff = dc - pred[comp]
                pred[comp] = dc
                s = _category(diff)
                code, ln = _ENC[("dc", tid)][s]
                bw.put(code, ln)
                if s:
                    bw.put(diff if diff >= 0 else diff + (1 << s) - 1, s)
                run = 0
                ac = blk[1:]
                nz = np.nonzero(ac)[0]
                last = nz[-1] if len(nz) else -1
                for i in range(last + 1):
                    v = int(ac[i])
                    if v == 0:
                        run += 1
                        continue
                    while run > 15:
                        code, ln = _ENC[("ac", tid)][0xF0]
                        bw.put(code, ln)
                        run -= 16
                    s = _category(v)
                    code, ln = _ENC[("ac", tid)][(run << 4) | s]
                    bw.put(code, ln)
                    bw.put(v if v >= 0 else v + (1 << s) - 1, s)
                    run = 0
                if last < 62:
                    code, ln = _ENC[("ac", tid)][0x00]  # EOB
                    bw.put(code, ln)


# --------------------------------------------------------------------------
# Vectorized entropy coder (the batched path)
# --------------------------------------------------------------------------
def _code_table_arrays(table: dict, nsym: int):
    codes = np.zeros(nsym, np.uint32)
    lens = np.zeros(nsym, np.int64)
    for sym, (code, ln) in table.items():
        codes[sym] = code
        lens[sym] = ln
    return codes, lens

_DC_ARR = [_code_table_arrays(_ENC[("dc", t)], 12) for t in (0, 1)]
_AC_ARR = [_code_table_arrays(_ENC[("ac", t)], 256) for t in (0, 1)]

# entry-order key: ((block*3 + comp)*65 + slot)*8 + sub — slot is the zigzag
# position (DC=0, AC z∈[1,63], EOB=64); sub orders ZRLs (0..2) before the
# Huffman code (4) before the magnitude bits (5) of the same coefficient.
_SUB_HUFF, _SUB_MAG = 4, 5


def _category_vec(v: np.ndarray) -> np.ndarray:
    """Vectorized bit_length(|v|): frexp's exponent is exact for integers."""
    return np.frexp(np.abs(v).astype(np.float64))[1].astype(np.int64)


def _magnitude_vec(v: np.ndarray, s: np.ndarray) -> np.ndarray:
    """JPEG magnitude bits: v if v ≥ 0 else v + 2^s - 1 (fits in s bits)."""
    return np.where(v >= 0, v, v + (1 << s) - 1).astype(np.uint32)


def _comp_symbols(zz: np.ndarray, comp: int, nb_tile: int):
    """One component's symbol stream: (key, code, length) int64/uint32/int64.

    zz: (n_tiles · nb_tile, 64) zigzagged coefficients — all tiles of a
    level concatenated, blocks in scan (row-major) order within each tile.
    Emits exactly the symbols of the per-coefficient reference loop
    (_encode_blocks) for every tile, each tagged with its bitstream-order
    key (global block index keeps tiles contiguous and ordered; the DC
    predictor resets at tile boundaries since each tile is its own scan).
    """
    tid = 0 if comp == 0 else 1
    dc_codes, dc_lens = _DC_ARR[tid]
    ac_codes, ac_lens = _AC_ARR[tid]
    nb = zz.shape[0]
    base = (np.arange(nb, dtype=np.int64) * 3 + comp) * 65  # key / 8, slot 0

    keys, codes, lens = [], [], []

    # DC: differential against the previous block of the same component,
    # predictor reset to 0 on the first block of every tile
    dc = zz[:, 0].astype(np.int64).reshape(-1, nb_tile)
    prev = np.empty_like(dc)
    prev[:, 0] = 0
    prev[:, 1:] = dc[:, :-1]
    diff = (dc - prev).reshape(-1)
    s_dc = _category_vec(diff)
    if (s_dc > 11).any():  # baseline DC table has categories 0..11
        raise ValueError(
            "DC difference out of range for the baseline Huffman table "
            f"(max |diff|={int(np.abs(diff).max())})")
    keys.append(base * 8 + 0)
    codes.append(dc_codes[s_dc])
    lens.append(dc_lens[s_dc])
    has_mag = s_dc > 0
    keys.append(base[has_mag] * 8 + 1)
    codes.append(_magnitude_vec(diff[has_mag], s_dc[has_mag]))
    lens.append(s_dc[has_mag])

    # AC: run-length between nonzeros within each block
    ac = zz[:, 1:]
    bi, pz = np.nonzero(ac)  # ordered: block-major, position-minor
    vals = ac[bi, pz].astype(np.int64)
    first = np.ones(bi.size, bool)
    first[1:] = bi[1:] != bi[:-1]
    prevpos = np.concatenate(([0], pz[:-1]))
    run = np.where(first, pz, pz - prevpos - 1).astype(np.int64)
    nzrl, rem = run >> 4, run & 15
    slot_key = ((bi * 3 + comp) * 65 + (pz + 1)) * 8

    # ZRL (0xF0) emitted ⌊run/16⌋ times just before the coefficient's symbol
    if nzrl.any():
        rep = np.repeat(np.arange(bi.size), nzrl)
        j = np.arange(rep.size) - np.repeat(np.cumsum(nzrl) - nzrl, nzrl)
        keys.append(slot_key[rep] + j)
        codes.append(np.full(rep.size, ac_codes[0xF0], np.uint32))
        lens.append(np.full(rep.size, ac_lens[0xF0], np.int64))

    s_ac = _category_vec(vals)
    if (s_ac > 10).any():  # baseline AC table has categories 1..10; a
        # larger category would alias into the run nibble of sym below
        raise ValueError(
            "AC coefficient magnitude out of range for the baseline "
            f"Huffman table (max |v|={int(np.abs(vals).max())})")
    sym = (rem << 4) | s_ac
    ac_l = ac_lens[sym]
    keys.append(slot_key + _SUB_HUFF)
    codes.append(ac_codes[sym])
    lens.append(ac_l)
    keys.append(slot_key + _SUB_MAG)
    codes.append(_magnitude_vec(vals, s_ac))
    lens.append(s_ac)

    # EOB for every block whose last nonzero AC sits before position 62
    lastpos = np.full(nb, -1, np.int64)
    lastpos[bi] = pz  # later (= larger pz) assignments win
    eob = lastpos < 62
    keys.append((base[eob] + 64) * 8)
    codes.append(np.full(int(eob.sum()), ac_codes[0x00], np.uint32))
    lens.append(np.full(int(eob.sum()), ac_lens[0x00], np.int64))

    return (np.concatenate(keys), np.concatenate(codes).astype(np.uint32),
            np.concatenate(lens))


_ZZ_IDX_CACHE: dict[tuple[int, int], np.ndarray] = {}
_ZZ_IDX_LOCK = TrackedLock("jpeg._ZZ_IDX_LOCK")


def _zigzag_gather_index(H: int, W: int) -> np.ndarray:
    """Flat (H·W,) index map: plane → row-major 8×8 blocks in zigzag order."""
    key = (H, W)
    with _ZZ_IDX_LOCK:
        cached = _ZZ_IDX_CACHE.get(key)
    if cached is None:
        idx = np.arange(H * W).reshape(H // 8, 8, W // 8, 8)
        idx = idx.transpose(0, 2, 1, 3).reshape(-1, 64)[:, _ZIGZAG]
        cached = np.ascontiguousarray(idx.reshape(-1))
        with _ZZ_IDX_LOCK:
            _ZZ_IDX_CACHE[key] = cached
    return cached


def _stuff(packed: np.ndarray) -> bytes:
    """0xFF byte stuffing over one tile's packed scan bytes."""
    ff = packed == 0xFF
    if ff.any():
        out = np.zeros(packed.size + int(ff.sum()), np.uint8)
        out[np.arange(packed.size) + (np.cumsum(ff) - ff)] = packed
        packed = out  # gaps after each 0xFF stay 0x00 (stuffing)
    return packed.tobytes()


def _pack_bits_tiled(codes: np.ndarray, lens: np.ndarray,
                     tile_ids: np.ndarray, n_tiles: int) -> list[bytes]:
    """MSB-first bit-pack of all tiles' symbol streams in one pass.

    Symbols are sorted, so each tile's run is contiguous. Every tile's
    stream is flush-padded with 1-bits to a byte boundary (as
    ``_BitWriter.flush``) inside one flat bit array, packed with a single
    ``np.packbits``, then split per tile and 0xFF-stuffed.
    """
    totals = np.bincount(tile_ids, weights=lens,
                         minlength=n_tiles).astype(np.int64)
    pads = (-totals) % 8
    padded = totals + pads
    tile_start = np.cumsum(padded) - padded  # bit offset of each tile

    cum = np.cumsum(lens) - lens  # global unpadded bit offsets
    first = np.searchsorted(tile_ids, np.arange(n_tiles))
    offs = tile_start[tile_ids] + (cum - cum[first][tile_ids])

    # scatter each symbol into its ≤3 bytes: align the ≤16-bit code inside
    # a 24-bit window starting at its byte, split into byte lanes, and sum
    # per byte with bincount — bits are disjoint, so the sum is the OR
    byte_pos = offs >> 3
    shifted = (codes.astype(np.int64)
               << (24 - (offs & 7) - lens)).astype(np.uint32)
    n_bytes = int(padded.sum()) >> 3
    pos = np.concatenate([byte_pos, byte_pos + 1, byte_pos + 2])
    val = np.concatenate([(shifted >> 16) & 0xFF, (shifted >> 8) & 0xFF,
                          shifted & 0xFF])
    packed = np.bincount(pos, weights=val,
                         minlength=n_bytes)[:n_bytes].astype(np.uint8)

    byte_start = tile_start >> 3
    byte_end = (tile_start + padded) >> 3
    # flush: each tile's trailing pad bits are 1s (as _BitWriter.flush)
    packed[byte_end - 1] |= ((1 << pads) - 1).astype(np.uint8)
    return [_stuff(packed[byte_start[t]:byte_end[t]])
            for t in range(n_tiles)]


def _entropy_encode_batch(coef: np.ndarray) -> list[bytes]:
    """Vectorized twin of ``_encode_blocks`` over a whole level at once.

    coef: (N, 3, H, W) int coefficient planes (blocks in place, 4:4:4) →
    N entropy-coded scan byte strings, each byte-identical to the
    per-coefficient reference loop's output for that tile.
    """
    N, _, H, W = coef.shape
    bh, bwid = H // 8, W // 8
    nb_tile = bh * bwid
    zz_idx = _zigzag_gather_index(H, W)
    flat = coef.reshape(N, 3, H * W)
    parts = []
    for comp in range(3):
        # one gather: (H, W) plane → (nb, 64) blocks already in zigzag order
        zz = flat[:, comp].take(zz_idx, axis=1).reshape(N * nb_tile, 64)
        parts.append(_comp_symbols(zz, comp, nb_tile))
    keys = np.concatenate([p[0] for p in parts])
    codes = np.concatenate([p[1] for p in parts])
    lens = np.concatenate([p[2] for p in parts])
    order = np.argsort(keys)  # keys are unique → scan order, tiles grouped
    tile_ids = (keys[order] // (8 * 65 * 3)) // nb_tile
    return _pack_bits_tiled(codes[order], lens[order], tile_ids, N)


def _decode_blocks(br: _BitReader, H: int, W: int) -> list[np.ndarray]:
    bh, bwid = H // 8, W // 8
    out = [np.zeros((bh, bwid, 64), np.int32) for _ in range(3)]
    pred = [0, 0, 0]
    inv_zz = np.argsort(_ZIGZAG)
    for r in range(bh):
        for c in range(bwid):
            for comp in range(3):
                tid = 0 if comp == 0 else 1
                blk = np.zeros(64, np.int32)
                s = br.huff(_DEC[("dc", tid)])
                diff = 0
                if s:
                    bits = br.get(s)
                    diff = bits if bits >= (1 << (s - 1)) else bits - (1 << s) + 1
                pred[comp] += diff
                blk[0] = pred[comp]
                k = 1
                while k < 64:
                    sym = br.huff(_DEC[("ac", tid)])
                    if sym == 0x00:
                        break
                    run, s = sym >> 4, sym & 0xF
                    if sym == 0xF0:
                        k += 16
                        continue
                    k += run
                    if k > 63:
                        raise ValueError(
                            "corrupt JPEG stream: AC run past end of block")
                    bits = br.get(s)
                    v = bits if bits >= (1 << (s - 1)) else bits - (1 << s) + 1
                    blk[k] = v
                    k += 1
                out[comp][r, c] = blk
    planes = []
    for comp in range(3):
        zz = out[comp][:, :, inv_zz].reshape(bh, bwid, 8, 8)
        planes.append(zz.transpose(0, 2, 1, 3).reshape(H, W))
    return planes


# --------------------------------------------------------------------------
# Vectorized entropy decoder (the batched export path)
# --------------------------------------------------------------------------
# 16-bit-lookahead Huffman tables: LUT[peek] = (symbol, code length). Codes
# are ≤ 16 bits, so every 16-bit window starting at a code boundary resolves
# the symbol in one gather; windows matching no code have length 0 (corrupt).
def _huff_lut(table: dict) -> tuple[np.ndarray, np.ndarray]:
    sym = np.zeros(1 << 16, np.int16)
    ln = np.zeros(1 << 16, np.int16)
    for s, (code, length) in table.items():
        lo = code << (16 - length)
        sym[lo:lo + (1 << (16 - length))] = s
        ln[lo:lo + (1 << (16 - length))] = length
    return sym, ln

# stacked [dc-luma, dc-chroma, ac-luma, ac-chroma]: the lockstep decoder
# selects a row per tile from its (DC/AC phase, component) state
_LUTS = [_huff_lut(_ENC[(kind, tid)])
         for kind in ("dc", "ac") for tid in (0, 1)]
_LUT_SYM = np.stack([s for s, _ in _LUTS])
_LUT_LEN = np.stack([ln for _, ln in _LUTS])
del _LUTS

# magnitude decode, tabulated per category s: value = bits if bits ≥ 2^(s-1)
# else bits - (2^s - 1)   (s = 0 ⇒ no bits, value 0)
_MAG_MASK = np.array([(1 << s) - 1 for s in range(16)], np.uint64)
_MAG_HALF = np.array([1 << max(s - 1, 0) for s in range(16)], np.int64)
_MAG_EXT = np.array([(1 << s) - 1 for s in range(16)], np.int64)

#: zero bytes appended after every tile's unstuffed scan so the sliding
#: 64-bit window at a (possibly truncated) stream's end stays in bounds —
#: one iteration can advance a corrupt tile's cursor ≤ 27 bits past its end
#: before the overrun check fires
_GUARD = 8


def _unstuff(scan: np.ndarray) -> np.ndarray:
    """Drop the stuffed 0x00 after every 0xFF (vectorized per tile)."""
    if scan.size < 2:
        return scan
    stuffed = (scan[:-1] == 0xFF) & (scan[1:] == 0x00)
    if not stuffed.any():
        return scan
    keep = np.ones(scan.size, bool)
    keep[1:][stuffed] = False
    return scan[keep]


def _window64(buf: np.ndarray) -> np.ndarray:
    """``w[p]`` = bytes ``p..p+7`` of ``buf`` as one big-endian uint64.

    Built once per batch with 8 vectorized passes, so the lockstep loop
    reads each tile's next 57+ lookahead bits with a *single* gather: a
    Huffman code (≤ 16 bits) plus its magnitude bits (≤ 11) plus the ≤ 7
    sub-byte phase is ≤ 34 bits, comfortably inside the window.
    """
    pad = np.concatenate([buf, np.zeros(8, np.uint8)])
    w = np.zeros(buf.size, np.uint64)
    for i in range(8):
        w |= pad[i:i + buf.size].astype(np.uint64) << np.uint64(56 - 8 * i)
    return w


#: batches with at least this many block-component units (N × nu) run the
#: jitted lockstep engine; below it the numpy engine wins because a compile
#: (one per padded lane-count/buffer bucket) would dominate the decode
_JAX_MIN_UNITS = 4096

#: jitted-engine bit cursors are int32 — batches whose concatenated scan
#: buffer would approach 2^31 bits stay on the numpy engine (uint64 windows)
_JAX_MAX_BYTES = 1 << 27


def _entropy_decode_batch(scans: list[np.ndarray], H: int, W: int,
                          engine: str = "auto") -> np.ndarray:
    """Lockstep twin of ``_decode_blocks`` over N independent scans.

    Every tile of a level is its own bitstream (one scan per tile, DC
    predictors reset at tile boundaries), which is the vectorization axis
    the sequential Huffman dependency cannot remove *within* a stream: all
    N tiles advance one symbol per step. Two engines run the identical
    automaton (coefficient-exact, same error strings — differentially
    tested):

    - ``"numpy"`` — the reference engine: one vectorized numpy step per
      symbol *position*. Interpreter cost is per step, so small batches of
      long scans pay heavily (the 0.82x small-batch cliff).
    - ``"jax"`` — the same automaton compiled into a single
      ``lax.while_loop`` dispatch (``repro.wsi.entropy_jax``): per-step
      cost drops from ~50–90µs of interpreter to a few µs of compiled
      gathers, keeping the batched path ahead of the per-tile loop at
      every batch size (see BENCH_export.json's ``batch_scaling``).
    - ``"auto"`` (default) picks the jitted engine for level-sized work
      and the numpy engine for tiny batches where a compile would
      dominate.

    DC slots hold differentials during the loop and are integrated with
    one cumsum at the end. Returns (N, nb, 3, 64) int32 zigzag
    coefficients, exactly the symbols the per-tile reference loop decodes.
    """
    N = len(scans)
    nb = (H // 8) * (W // 8)
    nu = nb * 3  # block-component units per tile, in bitstream order

    if engine not in ("auto", "numpy", "jax"):
        raise ValueError(f"engine must be 'auto', 'numpy' or 'jax': "
                         f"{engine!r}")
    total_bytes = sum(s.size for s in scans)
    if engine == "jax" or (engine == "auto" and N * nu >= _JAX_MIN_UNITS
                           and total_bytes < _JAX_MAX_BYTES):
        from repro.wsi.entropy_jax import decode_scans
        return decode_scans(scans, H, W)

    offs = np.zeros(N, np.int64)
    ends = np.zeros(N, np.int64)  # exclusive bit end of each tile's stream
    parts, cur = [], 0
    for i, scan in enumerate(scans):
        offs[i] = cur
        ends[i] = (cur + scan.size) * 8
        parts += [scan, np.zeros(_GUARD, np.uint8)]
        cur += scan.size + _GUARD
    w64 = _window64(np.concatenate(parts))

    pos = offs * 8
    u = np.zeros(N, np.int64)  # unit index: block * 3 + component
    k = np.zeros(N, np.int64)  # next zigzag slot; 0 ⇒ the DC symbol is next
    zzf = np.zeros(N * nu * 64, np.int32)  # flat (tile, block, comp, slot)
    base = np.arange(N, dtype=np.int64) * (nu * 64)
    active = u < nu
    chroma = (np.arange(nu + 1) % 3 > 0).astype(np.int64)  # unit → table
    _c48, _c64 = np.uint64(48), np.uint64(64)
    _m16, _one = np.uint64(0xFFFF), np.uint64(1)

    while active.any():
        w = w64[pos >> 3]
        sh = (pos & 7).astype(np.uint64)
        code = ((w >> (_c48 - sh)) & _m16).astype(np.int64)
        is_dc = k == 0
        tbl = np.where(is_dc, 0, 2) + chroma[u]
        sym = _LUT_SYM[tbl, code]
        ln = _LUT_LEN[tbl, code]
        # EOB (0x00) and ZRL (0xF0) have zero magnitude bits by construction
        s = np.where(is_dc, sym, sym & 0xF)
        su = s.astype(np.uint64)
        bits = ((w >> (_c64 - sh - ln.astype(np.uint64) - su))
                & _MAG_MASK[s]).astype(np.int64)
        v = np.where(bits >= _MAG_HALF[s], bits, bits - _MAG_EXT[s])
        pos = np.where(active, pos + ln + s, pos)

        is_eob = ~is_dc & (sym == 0x00)
        is_zrl = ~is_dc & (sym == 0xF0)
        is_coef = ~(is_dc | is_eob | is_zrl)
        # sym >> 4 is 0 for every valid DC category and for EOB; ZRL's
        # junk value is never read (its k-update uses k + 16 directly)
        knew = k + (sym >> 4)
        bad = active & ((ln == 0) | (is_coef & (knew > 63)))
        if bad.any():
            if (active & (ln == 0)).any():
                raise ValueError("corrupt JPEG stream: invalid Huffman code")
            raise ValueError("corrupt JPEG stream: AC run past end of block")

        # one scatter: the DC differential at slot 0, AC values at slot knew
        rows = np.flatnonzero(active & (is_dc | is_coef))
        zzf[base[rows] + u[rows] * 64
            + np.where(is_dc, 0, knew)[rows]] = v[rows]

        # next slot: DC → 1; ZRL skips 16; a written value advances past
        # itself; EOB leaves k to be reset below. A run past slot 63 ends
        # the unit, as in the reference loop's `while k < 64` recheck.
        k = np.where(is_dc, 1,
                     np.where(is_zrl, k + 16,
                              np.where(is_coef, knew + 1, k)))
        adv = active & (is_eob | (k >= 64))  # k ≥ 64 implies an AC phase
        u = u + adv
        k = np.where(adv, 0, k)
        active = u < nu
        if (active & (pos > ends)).any():
            raise ValueError("corrupt JPEG stream: truncated scan data")

    zz = zzf.reshape(N, nb, 3, 64)
    # integrate the DC differentials (predictor resets at tile boundaries)
    zz[:, :, :, 0] = np.cumsum(zz[:, :, :, 0], axis=1)
    return zz


def _parse_jfif(jpg: bytes) -> tuple[int, int, int, int]:
    """Parse one tile's JFIF container → (H, W, scan start, scan end).

    Accepts what ``encode_tile``/``encode_coef_batch`` emit (baseline,
    4:4:4, standard tables), plus DICOM's even-length convention of one
    trailing 0x00 pad byte after the EOI marker (encapsulated fragments).
    Truncated or malformed containers raise ``ValueError("corrupt JPEG
    …")`` — never ``IndexError``/``struct.error``.
    """
    if len(jpg) < 4 or jpg[:2] != b"\xff\xd8":
        raise ValueError("corrupt JPEG stream: missing SOI marker")
    end = len(jpg)
    if jpg[end - 1] == 0x00 and jpg[end - 3:end - 1] == b"\xff\xd9":
        end -= 1  # DICOM even-length fragment pad
    if jpg[end - 2:end] != b"\xff\xd9":
        raise ValueError("corrupt JPEG stream: missing EOI marker")
    pos = 0
    H = W = None
    while pos + 2 <= end:
        if jpg[pos] != 0xFF:
            raise ValueError(
                f"corrupt JPEG stream: expected a marker at offset {pos}")
        code = jpg[pos + 1]
        pos += 2
        if code in (0xD8, 0xD9):
            continue
        if pos + 2 > end:
            raise ValueError("corrupt JPEG stream: truncated marker segment")
        ln = struct.unpack_from(">H", jpg, pos)[0]
        if ln < 2 or pos + ln > end:
            raise ValueError(
                "corrupt JPEG stream: marker segment overruns container")
        if code == 0xC0:
            if ln < 9:
                raise ValueError("corrupt JPEG stream: short SOF segment")
            _, H, W, _ = struct.unpack_from(">BHHB", jpg, pos + 2)
            if not H or not W or H % 8 or W % 8:
                raise ValueError(
                    f"corrupt JPEG stream: unsupported frame size {H}x{W}")
        if code == 0xDA:
            if H is None:
                raise ValueError("corrupt JPEG stream: SOS before SOF")
            start = pos + ln
            if start > end - 2:
                raise ValueError("corrupt JPEG stream: no scan data")
            return H, W, start, end - 2
        pos += ln
    raise ValueError("corrupt JPEG stream: no SOS marker")


def decode_coef_batch(jpgs: list[bytes]) -> np.ndarray:
    """N baseline JFIF tiles → (N, 3, H, W) int32 quantized coefficients.

    The host entropy stage of the batched decode path — the exact inverse
    of ``encode_coef_batch`` (``decode_coef_batch(encode_coef_batch(c))``
    is coefficient-exact; only the transform stage is lossy). All tiles of
    a batch must share one geometry, as a pyramid level's frames do.
    Raises ``ValueError("corrupt JPEG …")`` on truncated/garbage input.
    """
    jpgs = list(jpgs)
    if not jpgs:
        return np.zeros((0, 3, 0, 0), np.int32)
    geom = [_parse_jfif(j) for j in jpgs]
    H, W = geom[0][:2]
    if any((h, w) != (H, W) for h, w, _, _ in geom):
        raise ValueError(
            "corrupt JPEG stream: mixed tile geometries in one batch "
            f"({sorted({(h, w) for h, w, _, _ in geom})})")
    scans = [_unstuff(np.frombuffer(jpg, np.uint8, end - start, start))
             for jpg, (_, _, start, end) in zip(jpgs, geom)]
    zz = _entropy_decode_batch(scans, H, W)  # (N, nb, 3, 64)
    N, nb = zz.shape[:2]
    out = np.empty((N, 3, H * W), np.int32)
    # scatter back through the encoder's zigzag gather index (its inverse)
    out[:, :, _zigzag_gather_index(H, W)] = \
        zz.transpose(0, 2, 1, 3).reshape(N, 3, nb * 64)
    return out.reshape(N, 3, H, W)


def decode_tiles_batch(jpgs: list[bytes]) -> np.ndarray:
    """N baseline JFIF tiles → (N, H, W, 3) uint8 RGB.

    The whole-level batched decode path: one vectorized entropy-decode
    pass (``decode_coef_batch``), then a single fused ``jpeg_inverse``
    dispatch. Output is pixel-identical to ``[decode_tile(j) for j in
    jpgs]`` — both paths share the one ``jpeg_inverse`` transform, so
    identity reduces to the (exact, integer) coefficient streams matching.
    """
    coef = decode_coef_batch(jpgs)
    if coef.shape[0] == 0:
        return np.zeros((0, 0, 0, 3), np.uint8)
    rgb = np.asarray(jpeg_inverse(coef))
    return np.ascontiguousarray(rgb.transpose(0, 2, 3, 1))


def decode_frames(frames: list[bytes], *, transfer_syntax: str,
                  rows: int, cols: int) -> np.ndarray:
    """WADO frame bytes of one WSM instance → (n, rows, cols, 3) uint8 RGB.

    The single transfer-syntax dispatch shared by every store consumer
    (the export service, the ML-inference subscriber): JPEG-baseline
    frames go through the batched decode path when there is more than one
    (the lockstep decoder's win grows with the batch — see
    BENCH_export.json's ``batch_scaling``; small pulls sit near parity,
    whole levels win outright), native explicit-VR-LE frames are reshaped
    directly. Geometry mismatches and unknown syntaxes raise ``ValueError``.
    """
    frames = list(frames)
    n = len(frames)
    if rows <= 0 or cols <= 0:
        raise ValueError(f"bad frame geometry {rows}x{cols}")
    if n == 0:
        return np.zeros((0, rows, cols, 3), np.uint8)
    if transfer_syntax == TS_JPEG_BASELINE:
        rgb = decode_tiles_batch(frames) if n > 1 \
            else decode_tile(frames[0])[None]
        if rgb.shape[1:3] != (rows, cols):
            raise ValueError(
                f"frames decode to {rgb.shape[1]}x{rgb.shape[2]}, "
                f"expected {rows}x{cols}")
        return rgb
    if transfer_syntax == TS_EXPLICIT_LE:
        if any(len(f) != rows * cols * 3 for f in frames):
            raise ValueError(
                f"native frame size mismatch (expected {rows * cols * 3} "
                "bytes)")
        return np.stack([np.frombuffer(f, np.uint8).reshape(rows, cols, 3)
                         for f in frames])
    raise ValueError(
        f"unsupported transfer syntax {transfer_syntax} (JPEG baseline "
        "and explicit-VR-LE native are decodable)")


# --------------------------------------------------------------------------
# JFIF container
# --------------------------------------------------------------------------
def _marker(buf: bytearray, code: int, payload: bytes = b""):
    buf += struct.pack(">BB", 0xFF, code)
    if payload:
        buf += struct.pack(">H", len(payload) + 2) + payload


def _dqt_payload(tid: int, table: np.ndarray) -> bytes:
    return bytes([tid]) + bytes(
        int(v) for v in table.reshape(64)[_ZIGZAG]
    )


def _dht_payload(cls: int, tid: int, bits, vals) -> bytes:
    return bytes([cls << 4 | tid]) + bytes(bits) + bytes(vals)


def _jfif_header(H: int, W: int) -> bytearray:
    """SOI…SOS for a 4:4:4 baseline scan with the standard Annex-K tables."""
    buf = bytearray()
    _marker(buf, 0xD8)  # SOI
    _marker(buf, 0xE0, b"JFIF\x00\x01\x01\x00\x00\x01\x00\x01\x00\x00")
    _marker(buf, 0xDB, _dqt_payload(0, JPEG_LUMA_Q))
    _marker(buf, 0xDB, _dqt_payload(1, JPEG_CHROMA_Q))
    sof = struct.pack(">BHHB", 8, H, W, 3)
    for cid, tq in ((1, 0), (2, 1), (3, 1)):
        sof += bytes([cid, 0x11, tq])  # h=v=1 (4:4:4)
    _marker(buf, 0xC0, sof)
    _marker(buf, 0xC4, _dht_payload(0, 0, _DC_L_BITS, _DC_L_VALS))
    _marker(buf, 0xC4, _dht_payload(1, 0, _AC_L_BITS, _AC_L_VALS))
    _marker(buf, 0xC4, _dht_payload(0, 1, _DC_C_BITS, _DC_C_VALS))
    _marker(buf, 0xC4, _dht_payload(1, 1, _AC_C_BITS, _AC_C_VALS))
    sos = bytes([3, 1, 0x00, 2, 0x11, 3, 0x11, 0, 63, 0])
    _marker(buf, 0xDA, sos)
    return buf


def encode_tile(tile_rgb: np.ndarray) -> bytes:
    """RGB (H, W, 3) uint8 → baseline JFIF bytes (4:4:4).

    The per-tile path: 4 jitted dispatches + the Python Huffman loop. Kept
    as the A/B baseline for ``encode_tiles_batch`` (byte-identical output).
    """
    H, W, _ = tile_rgb.shape
    assert H % 8 == 0 and W % 8 == 0
    chw = np.transpose(tile_rgb, (2, 0, 1)).astype(np.float32)
    ycc = np.asarray(rgb2ycbcr(chw))  # kernels (level-shifted)
    qs = [JPEG_LUMA_Q, JPEG_CHROMA_Q, JPEG_CHROMA_Q]
    planes = [np.asarray(dct8x8_quant(ycc[i], qs[i])) for i in range(3)]

    buf = _jfif_header(H, W)
    bw = _BitWriter()
    _encode_blocks(bw, planes)
    buf += bw.flush()
    _marker(buf, 0xD9)  # EOI
    return bytes(buf)


def encode_coef_batch(coef: np.ndarray) -> list[bytes]:
    """(N, 3, H, W) int quantized YCbCr DCT coefficients → N JFIF tiles.

    The host entropy stage of the batched path: vectorized symbol-stream
    encoding (scales with emitted symbols, not coefficients).
    """
    coef = np.asarray(coef)
    N, _, H, W = coef.shape
    if N == 0:
        return []
    header = bytes(_jfif_header(H, W))
    eoi = bytes((0xFF, 0xD9))
    return [header + scan + eoi for scan in _entropy_encode_batch(coef)]


def encode_tiles_batch(tiles_rgb: np.ndarray) -> list[bytes]:
    """RGB (N, H, W, 3) uint8 → N baseline JFIF byte strings (4:4:4).

    The whole-level batched path: all N tiles transform-coded in a single
    fused ``jpeg_transform`` dispatch, then the vectorized entropy coder.
    Output is byte-identical to ``[encode_tile(t) for t in tiles_rgb]``.
    """
    tiles = np.asarray(tiles_rgb)
    N, H, W, _ = tiles.shape
    assert H % 8 == 0 and W % 8 == 0
    chw = np.transpose(tiles, (0, 3, 1, 2)).astype(np.float32)
    coef = np.asarray(jpeg_transform(chw))
    return encode_coef_batch(coef)


def decode_tile(jpg: bytes) -> np.ndarray:
    """Baseline JFIF (as produced by ``encode_tile``) → RGB (H, W, 3) uint8.

    The per-tile decode path: a per-symbol Python Huffman loop, then the
    shared fused ``jpeg_inverse`` transform on a batch of one — kept as
    the A/B baseline for ``decode_tiles_batch`` (pixel-identical output).
    Truncated/garbage input raises ``ValueError("corrupt JPEG …")``.
    """
    H, W, data_start, data_end = _parse_jfif(jpg)
    br = _BitReader(jpg[data_start:data_end])
    planes = _decode_blocks(br, H, W)
    coef = np.stack(planes)[None].astype(np.int32)  # (1, 3, H, W)
    rgb = np.asarray(jpeg_inverse(coef))[0]
    return np.ascontiguousarray(rgb.transpose(1, 2, 0))


def psnr(a: np.ndarray, b: np.ndarray) -> float:
    mse = np.mean((a.astype(np.float64) - b.astype(np.float64)) ** 2)
    return float(10 * np.log10(255.0**2 / max(mse, 1e-12)))
