"""PSV ("pretend-SVS"): the synthetic proprietary tiled container.

    magic 'PSV1' | u32 H | u32 W | u32 tile | u32 n_tiles
    per tile: u32 row | u32 col | u32 nbytes | zlib(RGB uint8 tile)

Kept as the simplest possible ``SlideReader`` implementation — the vendor
format a scanner emits before anything standard exists. Real archives are
tiled TIFF/SVS (see ``repro.wsi.formats.tiff``).
"""
from __future__ import annotations

import io
import struct
import zlib

import numpy as np

from repro.wsi.formats.base import SlideFormat

__all__ = ["PSVReader", "write_psv", "PSV_FORMAT"]

_MAGIC = b"PSV1"


def write_psv(tiles: dict[tuple[int, int], np.ndarray], H: int, W: int,
              tile: int) -> bytes:
    buf = io.BytesIO()
    buf.write(_MAGIC)
    buf.write(struct.pack("<IIII", H, W, tile, len(tiles)))
    for (r, c), arr in sorted(tiles.items()):
        raw = zlib.compress(np.ascontiguousarray(arr, np.uint8).tobytes(), 6)
        buf.write(struct.pack("<III", r, c, len(raw)))
        buf.write(raw)
    return buf.getvalue()


class PSVReader:
    """Streaming tile reader; indexes the container once, inflates on demand."""

    def __init__(self, data: bytes):
        if data[:4] != _MAGIC:
            raise ValueError("not a PSV container")
        if len(data) < 20:
            raise ValueError("truncated PSV container: missing header")
        self.H, self.W, self.tile, n = struct.unpack_from("<IIII", data, 4)
        if self.H <= 0 or self.W <= 0 or self.tile <= 0:
            raise ValueError(
                f"corrupt PSV container: dimensions {self.H}x{self.W}, "
                f"tile {self.tile}")
        self.metadata: dict = {}  # PSV carries no vendor metadata
        self._data = data
        self._index: dict[tuple[int, int], tuple[int, int]] = {}
        off = 20
        for _ in range(n):
            if off + 12 > len(data):
                raise ValueError(
                    f"truncated PSV container: tile directory ends at byte "
                    f"{len(data)}, expected {n} tile records")
            r, c, nb = struct.unpack_from("<III", data, off)
            off += 12
            if off + nb > len(data):
                raise ValueError(
                    f"truncated PSV container: tile ({r},{c}) data runs to "
                    f"byte {off + nb}, container is {len(data)} bytes")
            self._index[(r, c)] = (off, nb)
            off += nb

    @property
    def grid(self) -> tuple[int, int]:
        return self.H // self.tile, self.W // self.tile

    def read_tile(self, r: int, c: int) -> np.ndarray:
        off, nb = self._index[(r, c)]
        raw = zlib.decompress(self._data[off : off + nb])
        t = self.tile
        return np.frombuffer(raw, np.uint8).reshape(t, t, 3)

    def tiles(self):
        for (r, c) in sorted(self._index):
            yield (r, c), self.read_tile(r, c)


PSV_FORMAT = SlideFormat(
    name="psv",
    description="synthetic proprietary tiled container (PSV1)",
    extensions=(".psv",),
    matches=lambda data: bytes(data[:4]) == _MAGIC,
    reader=PSVReader,
)
