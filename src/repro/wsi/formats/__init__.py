"""Multi-format slide ingestion: ``SlideReader`` protocol + container registry.

    from repro.wsi.formats import open_slide
    rd = open_slide(blob)          # sniffs PSV / tiled-TIFF / SVS by magic
    for (r, c), tile in rd.tiles():
        ...

See DESIGN.md, "Format ingestion", for the TIFF layout and how to add a
reader (~150 lines: implement ``SlideReader``, register a ``SlideFormat``).
"""
from repro.wsi.formats.base import (SlideFormat, SlideReader,  # noqa: F401
                                    formats, open_slide, register_format,
                                    sniff)
from repro.wsi.formats.psv import PSV_FORMAT, PSVReader, write_psv  # noqa: F401
from repro.wsi.formats.tiff import (TIFF_FORMAT, TiffSlideReader,  # noqa: F401
                                    write_tiff)

register_format(PSV_FORMAT)
register_format(TIFF_FORMAT)
