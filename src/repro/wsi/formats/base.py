"""The slide-ingestion abstraction: ``SlideReader`` + the format registry.

The paper's institutional-adoption claim is *format* interoperability —
"compatibility with existing scanners, microscopes, and data archives" —
and the durable interface for that is not any one container but the reader
protocol: a tiled, streaming view of a gigapixel image. Every concrete
container (our synthetic PSV, tiled TIFF/SVS, …) plugs in as one
``SlideFormat`` entry; the converter and the event-driven pipeline consume
only the protocol, so adding a format is a reader drop-in, never a
converter fork.

``sniff(data)`` resolves a container by magic bytes (never by filename —
the landing bucket receives whatever key the scanner chose) and raises an
actionable ``ValueError`` naming the supported formats for anything it
does not recognize, which is exactly the string that ends up as the
``dlq_reason`` when garbage lands in the bucket.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterator, Protocol, runtime_checkable

import numpy as np

__all__ = ["SlideReader", "SlideFormat", "register_format", "formats",
           "sniff", "open_slide"]


@runtime_checkable
class SlideReader(Protocol):
    """A tiled, streaming view of one slide level (the scan resolution).

    Implementations index the container once at construction and inflate
    pixel data on demand — never materializing the full image (the
    HBM→VMEM discipline of the converters). ``read_tile`` always returns a
    full ``(tile, tile, 3)`` uint8 array (edge tiles are padded, as in
    TIFF); ``tiles()`` streams them in row-major order. ``metadata`` holds
    whatever vendor key/values the container carries (e.g. the parsed
    Aperio ``ImageDescription``) — empty for formats without any.
    """

    H: int
    W: int
    tile: int
    metadata: dict

    @property
    def grid(self) -> tuple[int, int]: ...

    def read_tile(self, r: int, c: int) -> np.ndarray: ...

    def tiles(self) -> Iterator[tuple[tuple[int, int], np.ndarray]]: ...


@dataclasses.dataclass(frozen=True)
class SlideFormat:
    """One registry entry: how to recognize and open a container."""

    name: str  # short id ("psv", "tiff") — also the pipeline format metric
    description: str
    extensions: tuple[str, ...]  # conventional suffixes, for error messages
    matches: Callable[[bytes], bool]  # magic-byte check on the raw container
    reader: Callable[[bytes], SlideReader]


_REGISTRY: dict[str, SlideFormat] = {}


def register_format(fmt: SlideFormat) -> None:
    """Add (or replace) a container format. Match order = registration order."""
    _REGISTRY[fmt.name] = fmt


def formats() -> dict[str, SlideFormat]:
    """The registered formats, by name."""
    return dict(_REGISTRY)


def sniff(data: bytes) -> str:
    """Resolve a container's format name from its magic bytes.

    Raises an actionable ``ValueError`` for unknown containers — this
    string is what a dead-lettered landing object carries as its
    ``dlq_reason``, so it names every supported format.
    """
    for fmt in _REGISTRY.values():
        if fmt.matches(data):
            return fmt.name
    known = ", ".join(f"{f.name} ({'/'.join(f.extensions)})"
                      for f in _REGISTRY.values())
    head = bytes(data[:8]).hex() or "<empty>"
    raise ValueError(
        f"unknown slide container (leading bytes {head}): supported "
        f"formats are {known}; register new ones with "
        "repro.wsi.formats.register_format")


def open_slide(data: bytes) -> SlideReader:
    """Sniff ``data`` and construct the matching reader."""
    return _REGISTRY[sniff(data)].reader(data)
