"""Tiled TIFF — the SVS-shaped archive container, read and written in pure
Python.

This is the layout real slide archives hold: a classic (non-Big) TIFF whose
baseline image is carved into fixed-size tiles —

    header  'II' (or 'MM') | u16 42 | u32 IFD offset
    IFD     u16 n_entries | n × (u16 tag, u16 type, u32 count, u32 value/off)
    tags    ImageWidth/ImageLength, BitsPerSample 8,8,8, Compression 8
            (Deflate), Photometric RGB, SamplesPerPixel 3, TileWidth/
            TileLength, TileOffsets, TileByteCounts, ImageDescription

— which is exactly how Aperio ``.svs`` lays out its pyramid levels (an SVS
file *is* a tiled TIFF; its vendor metadata rides in ``ImageDescription``
as ``Aperio …|Key = Value|…`` pairs, which the reader parses into
``metadata``). The writer emits little-endian by default (what every
scanner ships) but both byte orders round-trip; the reader accepts either.

Unsupported-but-recognizable containers fail with *actionable* errors
(striped layout, JPEG/LZW compression, non-RGB), and every tile extent is
bounds-checked against the container at open time so a truncated file is a
clear ``ValueError`` rather than a mid-conversion explosion.
"""
from __future__ import annotations

import io
import struct
import zlib

import numpy as np

from repro.wsi.formats.base import SlideFormat

__all__ = ["TiffSlideReader", "write_tiff", "TIFF_FORMAT"]

# the tags we read/write (TIFF 6.0 baseline + tiled extension)
_IMAGE_WIDTH = 256
_IMAGE_LENGTH = 257
_BITS_PER_SAMPLE = 258
_COMPRESSION = 259
_PHOTOMETRIC = 262
_IMAGE_DESCRIPTION = 270
_STRIP_OFFSETS = 273
_SAMPLES_PER_PIXEL = 277
_ROWS_PER_STRIP = 278
_PLANAR_CONFIG = 284
_TILE_WIDTH = 322
_TILE_LENGTH = 323
_TILE_OFFSETS = 324
_TILE_BYTE_COUNTS = 325

_ASCII, _SHORT, _LONG = 2, 3, 4
_TYPE_SIZE = {1: 1, _ASCII: 1, _SHORT: 2, _LONG: 4}

_COMP_NONE = 1
_COMP_DEFLATE_ADOBE = 8  # what Adobe/Aperio write
_COMP_DEFLATE_OLD = 32946  # the original libtiff Deflate code
_DEFLATE = (_COMP_DEFLATE_ADOBE, _COMP_DEFLATE_OLD)
_COMP_NAMES = {2: "CCITT RLE", 3: "CCITT G3", 4: "CCITT G4", 5: "LZW",
               6: "old-style JPEG", 7: "JPEG", 33003: "Aperio JPEG2000 YCbCr",
               33005: "Aperio JPEG2000 RGB", 34712: "JPEG2000"}


def _grid(H: int, W: int, tile: int) -> tuple[int, int]:
    return -(-H // tile), -(-W // tile)  # ceil: TIFF tiles pad the edges


# --------------------------------------------------------------------------
# writer
# --------------------------------------------------------------------------
def write_tiff(tiles: dict[tuple[int, int], np.ndarray], H: int, W: int,
               tile: int, *, description: str = "", byteorder: str = "<",
               level: int = 6) -> bytes:
    """Serialize RGB tiles as a classic tiled TIFF (Deflate-compressed).

    ``tiles`` maps (row, col) → (tile, tile, 3) uint8 arrays covering the
    full ceil(H/tile) × ceil(W/tile) grid (edge tiles pre-padded, as the
    TIFF spec requires). ``description`` lands in ``ImageDescription`` —
    use ``Vendor …|Key = Value`` pairs for SVS-style metadata. Output is
    deterministic for identical input, so bucket content-hashing (and
    therefore idempotent re-ingestion) works on TIFF slides exactly as it
    does on PSV.
    """
    if byteorder not in ("<", ">"):
        raise ValueError("byteorder must be '<' (II) or '>' (MM)")
    e = byteorder
    bh, bw = _grid(H, W, tile)
    want = {(r, c) for r in range(bh) for c in range(bw)}
    if set(tiles) != want:
        raise ValueError(
            f"tile grid mismatch: need all of {bh}x{bw} row-major tiles, "
            f"got {len(tiles)}")
    blobs = []
    for r in range(bh):
        for c in range(bw):
            arr = np.ascontiguousarray(tiles[(r, c)], np.uint8)
            if arr.shape != (tile, tile, 3):
                raise ValueError(
                    f"tile ({r},{c}) shape {arr.shape}, expected "
                    f"({tile}, {tile}, 3) — pad edge tiles to full size")
            blobs.append(zlib.compress(arr.tobytes(), level))

    buf = io.BytesIO()
    buf.write(b"II" if e == "<" else b"MM")
    buf.write(struct.pack(e + "HI", 42, 0))  # IFD offset patched at the end
    offsets = []
    for b in blobs:
        offsets.append(buf.tell())
        buf.write(b)
        if buf.tell() % 2:
            buf.write(b"\0")  # keep everything word-aligned

    entries: list[tuple[int, int, object]] = [
        (_IMAGE_WIDTH, _LONG, [W]),
        (_IMAGE_LENGTH, _LONG, [H]),
        (_BITS_PER_SAMPLE, _SHORT, [8, 8, 8]),
        (_COMPRESSION, _SHORT, [_COMP_DEFLATE_ADOBE]),
        (_PHOTOMETRIC, _SHORT, [2]),  # RGB
        (_IMAGE_DESCRIPTION, _ASCII, description.encode() + b"\0"),
        (_SAMPLES_PER_PIXEL, _SHORT, [3]),
        (_PLANAR_CONFIG, _SHORT, [1]),  # chunky RGBRGB…
        (_TILE_WIDTH, _LONG, [tile]),
        (_TILE_LENGTH, _LONG, [tile]),
        (_TILE_OFFSETS, _LONG, offsets),
        (_TILE_BYTE_COUNTS, _LONG, [len(b) for b in blobs]),
    ]
    if not description:
        entries = [en for en in entries if en[0] != _IMAGE_DESCRIPTION]

    packed = []
    for tag, typ, vals in entries:  # already in ascending tag order
        if typ == _ASCII:
            count, payload = len(vals), bytes(vals)
        else:
            count = len(vals)
            payload = struct.pack(
                f"{e}{count}{'H' if typ == _SHORT else 'I'}", *vals)
        if len(payload) <= 4:
            value = payload.ljust(4, b"\0")
        else:
            if buf.tell() % 2:
                buf.write(b"\0")
            value = struct.pack(e + "I", buf.tell())
            buf.write(payload)
        packed.append(struct.pack(e + "HHI", tag, typ, count) + value)

    if buf.tell() % 2:
        buf.write(b"\0")
    ifd_off = buf.tell()
    buf.write(struct.pack(e + "H", len(packed)))
    for en in packed:
        buf.write(en)
    buf.write(struct.pack(e + "I", 0))  # no next IFD
    out = bytearray(buf.getvalue())
    out[4:8] = struct.pack(e + "I", ifd_off)
    return bytes(out)


# --------------------------------------------------------------------------
# reader
# --------------------------------------------------------------------------
def _parse_description(desc: str) -> dict:
    """Aperio-style ``Vendor header|Key = Value|…`` → metadata dict."""
    meta: dict = {}
    if not desc:
        return meta
    meta["description"] = desc
    parts = desc.split("|")
    meta["vendor"] = parts[0].strip()
    for p in parts[1:]:
        if "=" in p:
            k, v = p.split("=", 1)
            meta[k.strip()] = v.strip()
    return meta


class TiffSlideReader:
    """Streaming tile reader over a classic tiled TIFF/SVS container.

    Indexes the first IFD once (both byte orders accepted), validates the
    layout it can serve — tiled, 8-bit chunky RGB, Deflate or uncompressed
    — with actionable errors for everything else, bounds-checks every tile
    extent against the container size, and inflates tiles on demand.
    """

    def __init__(self, data: bytes):
        data = bytes(data)
        if len(data) < 8:
            raise ValueError("truncated TIFF container: shorter than the "
                             "8-byte header")
        if data[:2] == b"II":
            e = "<"
        elif data[:2] == b"MM":
            e = ">"
        else:
            raise ValueError("not a TIFF container (no II/MM byte-order mark)")
        self._e = e
        magic, ifd_off = struct.unpack_from(e + "HI", data, 2)
        if magic != 42:
            raise ValueError(
                f"unsupported TIFF: magic {magic} (classic TIFF is 42; "
                "BigTIFF (43) is not supported)")
        tags = self._read_ifd(data, ifd_off)

        if _IMAGE_WIDTH not in tags or _IMAGE_LENGTH not in tags:
            raise ValueError("corrupt TIFF: missing ImageWidth/ImageLength")
        self.W = int(tags[_IMAGE_WIDTH][0])
        self.H = int(tags[_IMAGE_LENGTH][0])
        if _TILE_OFFSETS not in tags or _TILE_WIDTH not in tags:
            if _STRIP_OFFSETS in tags or _ROWS_PER_STRIP in tags:
                raise ValueError(
                    "unsupported TIFF: striped layout (StripOffsets) — this "
                    "pipeline streams tiles; re-save with TileWidth/"
                    "TileLength (tiled TIFF / SVS)")
            raise ValueError("unsupported TIFF: no TileOffsets — not a "
                             "tiled container")
        if self.H <= 0 or self.W <= 0:
            raise ValueError(
                f"corrupt TIFF: image dimensions {self.H}x{self.W}")
        tw = int(tags[_TILE_WIDTH][0])
        th = int(tags.get(_TILE_LENGTH, tags[_TILE_WIDTH])[0])
        if tw != th:
            raise ValueError(
                f"unsupported TIFF: non-square {tw}x{th} tiles (the "
                "converter's pyramid assumes square tiles)")
        if tw <= 0:
            raise ValueError(f"corrupt TIFF: tile size {tw}")
        self.tile = tw

        comp = int(tags.get(_COMPRESSION, [_COMP_NONE])[0])
        if comp not in (_COMP_NONE, *_DEFLATE):
            name = _COMP_NAMES.get(comp, f"code {comp}")
            raise ValueError(
                f"unsupported TIFF compression: {name} — this reader "
                "handles Deflate (8/32946) and uncompressed (1); "
                "re-encode the slide with Deflate tiles")
        self._comp = comp
        photo = int(tags.get(_PHOTOMETRIC, [2])[0])
        spp = int(tags.get(_SAMPLES_PER_PIXEL, [1])[0])
        bps = [int(b) for b in tags.get(_BITS_PER_SAMPLE, [8])]
        if photo != 2 or spp != 3 or any(b != 8 for b in bps):
            raise ValueError(
                f"unsupported TIFF: photometric={photo} samples={spp} "
                f"bits={bps} — need 8-bit chunky RGB (photometric 2, "
                "3 samples of 8 bits)")
        if int(tags.get(_PLANAR_CONFIG, [1])[0]) != 1:
            raise ValueError("unsupported TIFF: planar (separate-plane) "
                             "configuration — need chunky RGB")

        bh, bw = _grid(self.H, self.W, self.tile)
        offsets = [int(o) for o in tags[_TILE_OFFSETS]]
        counts = [int(n) for n in tags.get(_TILE_BYTE_COUNTS, [])]
        if len(offsets) != bh * bw or len(counts) != len(offsets):
            raise ValueError(
                f"corrupt TIFF: {len(offsets)} tile offsets / {len(counts)} "
                f"byte counts for a {bh}x{bw} tile grid")
        for i, (o, n) in enumerate(zip(offsets, counts)):
            if o + n > len(data):
                raise ValueError(
                    f"truncated TIFF container: tile {i} data runs to byte "
                    f"{o + n}, container is {len(data)} bytes")
        self._offsets, self._counts = offsets, counts
        self._data = data
        self.metadata = _parse_description(tags.get(_IMAGE_DESCRIPTION, ""))

    def _read_ifd(self, data: bytes, off: int) -> dict:
        e = self._e
        if off + 2 > len(data):
            raise ValueError(
                f"truncated TIFF container: IFD offset {off} past EOF")
        (n,) = struct.unpack_from(e + "H", data, off)
        if off + 2 + 12 * n + 4 > len(data):
            raise ValueError(
                f"truncated TIFF container: IFD with {n} entries at byte "
                f"{off} past EOF")
        tags: dict = {}
        for i in range(n):
            tag, typ, count = struct.unpack_from(e + "HHI", data,
                                                 off + 2 + 12 * i)
            size = _TYPE_SIZE.get(typ)
            if size is None:
                continue  # rational/float tags: nothing we need
            nbytes = size * count
            pos = off + 2 + 12 * i + 8
            if nbytes > 4:
                (pos,) = struct.unpack_from(e + "I", data, pos)
                if pos + nbytes > len(data):
                    raise ValueError(
                        f"truncated TIFF container: tag {tag} values at "
                        f"byte {pos} past EOF")
            if typ == _ASCII:
                tags[tag] = data[pos:pos + count].split(b"\0")[0] \
                    .decode("latin-1")
            else:
                fmt = {1: "B", _SHORT: "H", _LONG: "I"}[typ]
                tags[tag] = list(struct.unpack_from(f"{e}{count}{fmt}",
                                                    data, pos))
        return tags

    @property
    def grid(self) -> tuple[int, int]:
        return _grid(self.H, self.W, self.tile)

    def read_tile(self, r: int, c: int) -> np.ndarray:
        bh, bw = self.grid
        if not (0 <= r < bh and 0 <= c < bw):
            raise KeyError((r, c))
        i = r * bw + c
        raw = self._data[self._offsets[i]:self._offsets[i] + self._counts[i]]
        if self._comp in _DEFLATE:
            try:
                raw = zlib.decompress(raw)
            except zlib.error as exc:
                raise ValueError(f"corrupt TIFF tile ({r},{c}): {exc}") \
                    from None
        t = self.tile
        if len(raw) != t * t * 3:
            raise ValueError(
                f"corrupt TIFF tile ({r},{c}): {len(raw)} bytes after "
                f"decompression, expected {t * t * 3}")
        return np.frombuffer(raw, np.uint8).reshape(t, t, 3)

    def tiles(self):
        bh, bw = self.grid
        for r in range(bh):
            for c in range(bw):
                yield (r, c), self.read_tile(r, c)


TIFF_FORMAT = SlideFormat(
    name="tiff",
    description="classic tiled TIFF / SVS (Deflate RGB tiles)",
    extensions=(".tiff", ".tif", ".svs"),
    # match on the byte-order mark alone so recognizable-but-unsupported
    # variants (BigTIFF, striped, JPEG-compressed) reach the reader's
    # *specific* error instead of the generic unknown-container one
    matches=lambda data: bytes(data[:2]) in (b"II", b"MM"),
    reader=TiffSlideReader,
)
