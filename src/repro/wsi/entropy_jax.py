"""Jitted lockstep JPEG entropy decoder — the small-batch cliff fix.

The numpy lockstep decoder in ``repro.wsi.jpeg`` pays interpreter and
numpy-dispatch cost once per symbol *position* across the batch (~50–90µs
per step). A 16-tile level of tissue tiles runs ~10k lockstep steps, so the
"vectorized" path costs ~800ms of pure interpreter overhead — slower than
the per-tile Python loop it is supposed to amortize (BENCH_export.json
recorded 0.82x at 16 tiles). The overhead is per *step*, so no batch-size
bucketing of the transform kernels can remove it.

This module compiles the identical lockstep automaton into a single
``jax.lax.while_loop`` dispatch: one compiled step costs a few µs of
gathers/elementwise work instead of an interpreter sweep, so the batched
decode path stays ahead of the per-tile loop at **every** batch size — the
``batch_scaling`` acceptance gate in ``benchmarks/export_bench.py``.

Contract with the numpy engine (``jpeg._entropy_decode_batch``, which
remains the differential oracle and still serves tiny batches where a
compile would dominate):

* coefficient-exact equality on every decodable stream — the automaton is
  a transliteration, step for step, of the numpy loop;
* identical ``ValueError("corrupt JPEG …")`` strings raised at identical
  failure points. The compiled loop cannot raise mid-flight, so each lane
  carries an error flag; the loop exits on the first flagged step, and the
  host replays the numpy engine's raise priority (invalid Huffman code
  before AC overrun before truncation — all surviving flags are from the
  same step, so the replay is exact).

Everything runs in int32 (no x64): the ≤16-bit Huffman code and the ≤11
magnitude bits are each read through a 24-bit window built from a 3-byte
gather, so bit cursors stay well under 2^31 for any realistic level
(callers keep batches below ``2^27`` buffer bytes).
"""
from __future__ import annotations

from functools import partial

import numpy as np

import jax
import jax.numpy as jnp

__all__ = ["decode_scans"]

_ERR_INVALID, _ERR_RUN, _ERR_TRUNC = 1, 2, 3

#: per-tile zero bytes after each scan — same layout (and same reason) as
#: the numpy engine's guard: one step can overrun a corrupt stream's end by
#: ≤ 27 bits before the truncation flag fires, and the 3-byte windows must
#: stay inside the buffer
_GUARD = 8


@partial(jax.jit, static_argnames=("nu",))
def _lockstep(buf, pos0, ends, u0, lut_sym, lut_len, mag_half, mag_ext, *,
              nu: int):
    """Run all lanes to completion (or first error). Shapes are the compile
    key: callers pad the lane count and buffer length to powers of two so
    every level of a slide reuses a handful of cached executables."""
    n = pos0.shape[0]
    total = n * nu * 64
    base = jnp.arange(n, dtype=jnp.int32) * (nu * 64)

    def cond(st):
        pos, u, k, err, zzf = st
        return jnp.any(u < nu) & ~jnp.any(err > 0)

    def body(st):
        pos, u, k, err, zzf = st
        active = u < nu

        # 16-bit Huffman window: 3 bytes from the bit cursor's byte
        bp = pos >> 3
        w24 = ((buf[bp].astype(jnp.int32) << 16)
               | (buf[bp + 1].astype(jnp.int32) << 8)
               | buf[bp + 2].astype(jnp.int32))
        sh = pos & 7
        code = (w24 >> (8 - sh)) & 0xFFFF
        is_dc = k == 0
        tbl = jnp.where(is_dc, 0, 2) + ((u % 3) != 0)
        sym = lut_sym[tbl * 65536 + code]
        ln = lut_len[tbl * 65536 + code]

        # magnitude bits (≤ 11) through a second 3-byte window at pos + ln
        s = jnp.where(is_dc, sym, sym & 0xF)
        pos2 = pos + ln
        bp2 = pos2 >> 3
        w24m = ((buf[bp2].astype(jnp.int32) << 16)
                | (buf[bp2 + 1].astype(jnp.int32) << 8)
                | buf[bp2 + 2].astype(jnp.int32))
        bits = (w24m >> (24 - (pos2 & 7) - s)) & mag_ext[s]
        v = jnp.where(bits >= mag_half[s], bits, bits - mag_ext[s])
        pos = jnp.where(active, pos2 + s, pos)

        is_eob = ~is_dc & (sym == 0x00)
        is_zrl = ~is_dc & (sym == 0xF0)
        is_coef = ~(is_dc | is_eob | is_zrl)
        knew = k + (sym >> 4)
        err = jnp.where(active & (ln == 0), _ERR_INVALID,
                        jnp.where(active & is_coef & (knew > 63),
                                  _ERR_RUN, err))

        # one scatter: DC differential at slot 0, AC values at slot knew;
        # non-writing lanes aim past the buffer and are dropped
        write = active & (is_dc | is_coef) & (err == 0)
        tgt = jnp.where(write, base + u * 64 + jnp.where(is_dc, 0, knew),
                        total)
        zzf = zzf.at[tgt].set(v, mode="drop")

        k = jnp.where(is_dc, 1,
                      jnp.where(is_zrl, k + 16,
                                jnp.where(is_coef, knew + 1, k)))
        adv = active & (is_eob | (k >= 64))
        u = u + adv
        k = jnp.where(adv, 0, k)
        err = jnp.where((u < nu) & (err == 0) & (pos > ends),
                        _ERR_TRUNC, err)
        return pos, u, k, err, zzf

    state = (pos0, u0, jnp.zeros(n, jnp.int32), jnp.zeros(n, jnp.int32),
             jnp.zeros(total, jnp.int32))
    pos, u, k, err, zzf = jax.lax.while_loop(cond, body, state)
    return err, zzf


_TABLES: dict | None = None


def _device_tables():
    """LUTs committed once: the four stacked 16-bit-lookahead Huffman tables
    (flattened for a single-gather lookup) and the magnitude-decode rows."""
    global _TABLES
    if _TABLES is None:
        from repro.wsi import jpeg
        _TABLES = {
            "sym": jnp.asarray(jpeg._LUT_SYM.reshape(-1), jnp.int32),
            "len": jnp.asarray(jpeg._LUT_LEN.reshape(-1), jnp.int32),
            "half": jnp.asarray(jpeg._MAG_HALF, jnp.int32),
            "ext": jnp.asarray(jpeg._MAG_EXT, jnp.int32),
        }
    return _TABLES


def _pow2(n: int) -> int:
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def decode_scans(scans: list[np.ndarray], H: int, W: int) -> np.ndarray:
    """N unstuffed scans → (N, nb, 3, 64) int32 zigzag coefficients.

    Drop-in twin of the numpy lockstep engine: same output (DC slots
    integrated), same error strings. Lane count and buffer length are
    padded to powers of two so the jit cache stays small; pad lanes start
    exhausted (``u = nu``) and can neither write nor flag errors.
    """
    N = len(scans)
    nb = (H // 8) * (W // 8)
    nu = nb * 3
    npad = _pow2(N)

    offs = np.zeros(npad, np.int64)
    ends = np.zeros(npad, np.int64)
    parts, cur = [], 0
    for i, scan in enumerate(scans):
        offs[i] = cur
        ends[i] = (cur + scan.size) * 8
        parts += [scan, np.zeros(_GUARD, np.uint8)]
        cur += scan.size + _GUARD
    buf = np.concatenate(parts) if parts else np.zeros(_GUARD, np.uint8)
    blen = _pow2(max(buf.size, _GUARD))
    if blen > buf.size:
        buf = np.concatenate([buf, np.zeros(blen - buf.size, np.uint8)])
    assert blen * 8 < 2**31, "scan buffer too large for int32 bit cursors"

    u0 = np.full(npad, nu, np.int32)
    u0[:N] = 0
    t = _device_tables()
    err, zzf = _lockstep(
        jnp.asarray(buf), jnp.asarray(offs * 8, jnp.int32),
        jnp.asarray(ends, jnp.int32), jnp.asarray(u0),
        t["sym"], t["len"], t["half"], t["ext"], nu=nu)
    err = np.asarray(err)
    if (err == _ERR_INVALID).any():
        raise ValueError("corrupt JPEG stream: invalid Huffman code")
    if (err == _ERR_RUN).any():
        raise ValueError("corrupt JPEG stream: AC run past end of block")
    if (err == _ERR_TRUNC).any():
        raise ValueError("corrupt JPEG stream: truncated scan data")

    zz = np.array(zzf).reshape(npad, nu * 64)[:N].reshape(N, nb, 3, 64)
    # integrate the DC differentials (predictor resets at tile boundaries)
    zz[:, :, :, 0] = np.cumsum(zz[:, :, :, 0], axis=1)
    return zz
