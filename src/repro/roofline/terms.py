"""Three-term roofline derivation (TPU v5e targets).

    compute term    = HLO_FLOPs_total   / (chips × peak_FLOP/s)
    memory term     = HLO_bytes_total   / (chips × HBM_bw)
    collective term = link_bytes/device / link_bw

``cost_analysis()`` of an SPMD executable reports *per-partition* numbers, so
totals are per-device × chips (the division by chips then cancels — we keep
the assignment's formula explicitly for clarity).
"""
from __future__ import annotations

import dataclasses

__all__ = ["HW", "derive_terms"]


@dataclasses.dataclass(frozen=True)
class HW:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12  # bf16 / chip
    hbm_bw: float = 819e9  # bytes/s / chip
    link_bw: float = 50e9  # bytes/s / ICI link
    hbm_bytes: float = 16e9  # capacity / chip


def derive_terms(
    *,
    flops_per_device: float,
    bytes_per_device: float,
    collective_bytes_per_device: float,
    chips: int,
    model_flops_total: float,
    hw: HW = HW(),
) -> dict:
    compute_s = flops_per_device / hw.peak_flops
    memory_s = bytes_per_device / hw.hbm_bw
    collective_s = collective_bytes_per_device / hw.link_bw
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    bound_s = terms[dominant]
    model_compute_s = model_flops_total / (chips * hw.peak_flops)
    return {
        **terms,
        "dominant": dominant,
        "bound_s": bound_s,
        "hlo_flops_total": flops_per_device * chips,
        "hlo_bytes_total": bytes_per_device * chips,
        "model_flops_total": model_flops_total,
        # fraction of compiled compute that is "useful" model math
        "useful_flops_ratio": (
            model_flops_total / (flops_per_device * chips)
            if flops_per_device else 0.0
        ),
        # end-to-end MFU upper bound implied by the compiled program
        "mfu_bound": model_compute_s / bound_s if bound_s else 0.0,
        "chips": chips,
    }
