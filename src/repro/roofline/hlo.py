"""Post-partitioning HLO text analysis — loop-aware roofline inputs.

``compiled.cost_analysis()`` has two gaps for our purposes:

1. it does not expose collective bytes at all, and
2. it counts ``while``-loop bodies **once**, so any scan-over-layers program
   (all of ours) under-reports FLOPs/bytes by ~the layer count.

This module parses the optimized, SPMD-partitioned HLO text (per-device
shapes) and produces loop-aware totals:

* **collectives** — per-device link traffic per op kind, ring-algorithm
  accounting (see ``_traffic``), multiplied by loop trip counts,
* **flops** — 2·M·N·K for every ``dot`` (fusion bodies included), multiplied
  by trip counts,
* **bytes** — per-kernel HBM traffic model: for every top-level op in an
  executed computation, result bytes + resolvable operand bytes (fusion
  internals excluded — they live in registers/VMEM). Two CPU-backend
  artifacts are discounted because they would not exist on the TPU target:
  (a) dtype/layout-only fusions (the CPU upcasts bf16 dot inputs to f32 and
  hoists whole-array converts — native-bf16 MXUs don't), and (b) in-place
  ``dynamic-update-slice`` buffers, where only the updated slice moves, not
  the whole KV cache,
* trip counts come from the ``backend_config known_trip_count`` XLA attaches
  to scan-lowered whiles (fallback: largest integer constant in the loop
  condition).

Residual known bias: f32 dot reads of bf16 weights inflate weight traffic by
≤2× on this CPU proxy; recorded in EXPERIMENTS.md §Roofline methodology.
"""
from __future__ import annotations

import re
from collections import defaultdict

__all__ = ["analyze_hlo", "collective_traffic", "shape_bytes"]

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OPLINE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s([\w\-]+)\("
)
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_GROUPS_RE = re.compile(
    r"replica_groups=(\{\{[\d, ]*\}[^=]*?\}|\[[\d,]+\]<=\[[^\]]*\](?:T\([\d,]+\))?)"
)
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*?(\d+)')
_CONST_RE = re.compile(r"constant\((\d+)\)")
_WHILE_RE = re.compile(r"condition=%?([\w.\-]+).*?body=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_LHS_BATCH_RE = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")

_SKIP_BYTES_OPS = {
    "get-tuple-element", "tuple", "parameter", "constant", "bitcast",
    "after-all", "partition-id", "replica-id", "copy",
}

# ops that leave a fusion "layout/dtype-only" (zero-traffic on the TPU target)
_LAYOUT_ONLY = {
    "convert", "bitcast", "copy", "reshape", "transpose", "broadcast",
    "parameter", "tuple", "get-tuple-element", "constant", "slice",
}


def shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _first_shape_dims(shape_str: str):
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",") if d]
    return dims


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if not m:
        return 2
    g = m.group(1)
    if g.startswith("{{"):
        first = g[2:].split("}")[0].strip()
        if not first:
            return 1
        return first.count(",") + 1
    dims = re.match(r"\[(\d+)(?:,(\d+))?\]", g)
    if dims and dims.group(2):
        return int(dims.group(2))
    return 2


def _traffic(kind: str, result_bytes: int, g: int) -> float:
    """Per-device ring-collective link bytes (documented in EXPERIMENTS.md)."""
    if g <= 1:
        return 0.0
    if kind == "all-gather":
        return result_bytes * (g - 1) / g
    if kind == "all-reduce":
        return 2.0 * result_bytes * (g - 1) / g
    if kind == "reduce-scatter":
        return float(result_bytes) * (g - 1)  # result is the shard
    if kind == "all-to-all":
        return result_bytes * (g - 1) / g
    return float(result_bytes)  # collective-permute


def _split_computations(hlo: str) -> tuple[dict, str | None]:
    comps: dict[str, list[str]] = {}
    entry = None
    cur = None
    for line in hlo.splitlines():
        stripped = line.rstrip()
        if cur is None and stripped.endswith("{"):
            m = re.match(r"^\s*(ENTRY\s+)?%?([\w.\-]+)", line)
            if m:
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry = cur
            continue
        if cur is not None and stripped.startswith("}"):
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)
    return comps, entry


def _classify_comp(lines: list[str]) -> str:
    """'layout' (dtype/layout-only), 'dus' (contains dynamic-update-slice),
    'slice' (dynamic-slice + layout-only ops), or 'compute'."""
    has_dus = False
    has_ds = False
    compute = False
    for ln in lines:
        m = _OPLINE_RE.match(ln)
        if not m:
            continue
        op = m.group(3)
        if op == "dynamic-update-slice":
            has_dus = True
        elif op in ("dynamic-slice", "gather"):
            has_ds = True
        elif op not in _LAYOUT_ONLY:
            compute = True
    if has_dus:
        return "dus"
    if has_ds and not compute:
        return "slice"
    return "compute" if compute else "layout"


def analyze_hlo(hlo: str) -> dict:
    comps, entry = _split_computations(hlo)
    comp_kind = {name: _classify_comp(lines) for name, lines in comps.items()}

    # global name -> (dims, bytes) for operand resolution
    shapes: dict[str, tuple[list[int] | None, int]] = {}
    for lines in comps.values():
        for ln in lines:
            m = _OPLINE_RE.match(ln)
            if m:
                name, shape_str, _ = m.groups()
                shapes[name] = (_first_shape_dims(shape_str),
                                shape_bytes(shape_str))
            elif "parameter(" in ln:
                pm = re.match(r"^\s*%([\w.\-]+)\s*=\s*(.+?)\sparameter\(", ln)
                if pm:
                    shapes[pm.group(1)] = (
                        _first_shape_dims(pm.group(2)),
                        shape_bytes(pm.group(2)),
                    )

    own: dict[str, dict] = {}
    loop_edges: dict[str, list[tuple[str, int]]] = defaultdict(list)
    fusion_edges: dict[str, list[str]] = defaultdict(list)
    loops: list[tuple[str, int]] = []

    for name, lines in comps.items():
        kinds: dict[str, float] = defaultdict(float)
        flops = 0.0
        bts = 0.0
        for ln in lines:
            m = _OPLINE_RE.match(ln)
            if not m:
                continue
            _, shape_str, op = m.groups()
            base_op = op[:-6] if op.endswith("-start") else op
            if base_op in _COLLECTIVES and not op.endswith("-done"):
                kinds[base_op] += _traffic(
                    base_op, shape_bytes(shape_str), _group_size(ln)
                )
            if op == "dot":
                cm = _LHS_CONTRACT_RE.search(ln)
                paren = ln[m.end():]
                ops_ = _OPERAND_RE.findall(paren.split("),")[0].split("), ")[0])
                k = 1
                if cm and ops_:
                    lhs_dims = shapes.get(ops_[0], (None, 0))[0]
                    if lhs_dims:
                        for idx in cm.group(1).split(","):
                            if idx and int(idx) < len(lhs_dims):
                                k *= lhs_dims[int(idx)]
                rdims = _first_shape_dims(shape_str) or []
                out = 1
                for d in rdims:
                    out *= d
                flops += 2.0 * out * k
            if op == "while":
                wm = _WHILE_RE.search(ln)
                if wm:
                    cond, body = wm.groups()
                    tm = _TRIP_RE.search(ln)
                    if tm:
                        trip = int(tm.group(1))
                    else:
                        consts = [int(c) for c in _CONST_RE.findall(
                            "\n".join(comps.get(cond, [])))]
                        trip = max(consts) if consts else 1
                    loop_edges[name].append((body, trip))
                    loop_edges[name].append((cond, trip))
                    loops.append((body, trip))
            if op in ("fusion", "call"):
                fm = _CALLS_RE.search(ln) or re.search(r"to_apply=%?([\w.\-]+)", ln)
                if fm:
                    fusion_edges[name].append(fm.group(1))
            if op not in _SKIP_BYTES_OPS and op != "while":
                res_b = shape_bytes(shape_str)
                paren = ln[m.end():]
                arg_str = paren.split("), ")[0]
                op_bytes = [shapes[o][1] for o in _OPERAND_RE.findall(arg_str)
                            if o in shapes]
                kind = "compute"
                if op == "fusion":
                    fm = _CALLS_RE.search(ln)
                    if fm:
                        kind = comp_kind.get(fm.group(1), "compute")
                elif op == "dynamic-update-slice":
                    kind = "dus"
                elif op in ("dynamic-slice", "gather"):
                    kind = "slice"
                elif op in _LAYOUT_ONLY:
                    kind = "layout"
                if kind == "layout":
                    pass  # fused away / native-dtype on the TPU target
                elif kind == "slice":
                    bts += 2.0 * res_b
                elif kind == "dus":
                    # in-place buffer update: only the slice moves
                    small = [b for b in op_bytes if b != res_b]
                    bts += 2.0 * sum(small)
                else:
                    bts += res_b + sum(op_bytes)
        own[name] = {"kinds": dict(kinds), "flops": flops, "bytes": bts}

    memo: dict[str, dict] = {}

    def resolve(name: str, stack=()) -> dict:
        if name in memo:
            return memo[name]
        if name in stack or name not in own:
            return {"kinds": {}, "flops": 0.0, "bytes": 0.0}
        kinds = defaultdict(float, own[name]["kinds"])
        flops = own[name]["flops"]
        bts = own[name]["bytes"]
        for callee in fusion_edges.get(name, []):
            sub = resolve(callee, stack + (name,))
            flops += sub["flops"]  # fusion-internal dots count; bytes don't
            for k, v in sub["kinds"].items():
                kinds[k] += v
        for callee, trip in loop_edges.get(name, []):
            sub = resolve(callee, stack + (name,))
            flops += sub["flops"] * trip
            bts += sub["bytes"] * trip
            for k, v in sub["kinds"].items():
                kinds[k] += v * trip
        memo[name] = {"kinds": dict(kinds), "flops": flops, "bytes": bts}
        return memo[name]

    if entry is None:
        res = max((resolve(n) for n in own),
                  key=lambda r: r["flops"] + sum(r["kinds"].values()),
                  default={"kinds": {}, "flops": 0.0, "bytes": 0.0})
    else:
        res = resolve(entry)
    return {
        "collective_bytes": float(sum(res["kinds"].values())),
        "by_kind": res["kinds"],
        "flops": res["flops"],
        "bytes": res["bytes"],
        "loops": loops[:64],
    }


def collective_traffic(hlo: str) -> dict:
    """Back-compat wrapper: collective numbers only."""
    r = analyze_hlo(hlo)
    return {"total": r["collective_bytes"], "by_kind": r["by_kind"],
            "loops": r["loops"], "ops": None}
