"""Roofline analysis: HLO collective parsing + three-term derivation."""
from repro.roofline.hlo import analyze_hlo, collective_traffic, shape_bytes  # noqa: F401
from repro.roofline.terms import HW, derive_terms  # noqa: F401
