"""Data pipeline: deterministic synthetic LM streams + elastic shard queue."""
from repro.data.pipeline import ShardQueue, TokenDataset, make_lm_batch  # noqa: F401
