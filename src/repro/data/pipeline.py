"""Training data pipeline.

``TokenDataset`` — deterministic synthetic LM token stream: shard ``i`` of
``n`` is reproducible from (seed, shard) alone, so any worker can regenerate
any shard (the stateless-worker property the elastic trainer relies on).
A light Markov structure gives the loss something learnable.

``ShardQueue`` — the paper's pattern applied to training data: shards are
messages on a pub/sub topic; trainer workers are the subscribers. A worker
that dies mid-shard never acks, so the shard redelivers to a healthy worker
(at-least-once ⇒ no data loss on preemption); hedged redelivery doubles as
straggler mitigation. This is the job-level event-driven layer — inside a
training step everything stays synchronous SPMD.
"""
from __future__ import annotations

import numpy as np

__all__ = ["TokenDataset", "make_lm_batch", "ShardQueue"]


class TokenDataset:
    def __init__(self, vocab_size: int, seq_len: int, *, seed: int = 0,
                 order: int = 1):
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.seed = seed
        # a small deterministic Markov transition to make loss learnable
        rng = np.random.default_rng(seed)
        self._shift = rng.integers(1, vocab_size, size=64)

    def shard_batch(self, shard: int, batch: int) -> dict[str, np.ndarray]:
        """Batch for one shard id — stateless and reproducible."""
        rng = np.random.default_rng((self.seed << 20) ^ shard)
        S = self.seq_len
        toks = np.empty((batch, S + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab_size, size=batch)
        noise = rng.integers(0, self.vocab_size, size=(batch, S))
        use_noise = rng.random((batch, S)) < 0.15
        for t in range(S):
            step = self._shift[toks[:, t] % 64]
            nxt = (toks[:, t] + step) % self.vocab_size
            toks[:, t + 1] = np.where(use_noise[:, t], noise[:, t], nxt)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def make_lm_batch(cfg, batch: int, seq_len: int, *, shard: int = 0,
                  seed: int = 0) -> dict:
    ds = TokenDataset(cfg.vocab_size, seq_len, seed=seed)
    b = ds.shard_batch(shard, batch)
    if cfg.family in ("vlm", "audio"):
        rng = np.random.default_rng(seed + 1)
        b["cond"] = rng.normal(
            0, 1, size=(batch, cfg.n_cross_tokens, cfg.d_model)
        ).astype(np.float32)
    return b


class ShardQueue:
    """Data shards as pub/sub messages; at-least-once, idempotent by shard id."""

    def __init__(self, topic, name: str = "train-shards", *,
                 ack_deadline: float = 900.0, hedge_after: float | None = None):
        from repro.core.pubsub import Subscription

        self.topic = topic
        self._pending: list[tuple[dict, object]] = []
        self.sub = Subscription(topic, name, self._on_msg,
                                ack_deadline=ack_deadline,
                                hedge_after=hedge_after)
        self.seen: set[int] = set()

    def publish_epoch(self, n_shards: int, epoch: int = 0):
        for s in range(n_shards):
            self.topic.publish({"shard": s, "epoch": epoch},
                               ordering_key=None)

    def _on_msg(self, msg, ctx):
        self._pending.append((msg.data, ctx))

    def poll(self):
        """Next (shard_dict, ack_fn) or None; duplicates are auto-acked."""
        while self._pending:
            data, ctx = self._pending.pop(0)
            key = (data["epoch"] << 32) | data["shard"]
            if key in self.seen:  # redelivered after we already trained on it
                ctx.ack()
                continue
            def ack(ctx=ctx, key=key):
                self.seen.add(key)
                ctx.ack()
            return data, ack
        return None
