"""Topic-based publish/subscribe broker with push delivery.

Implements the delivery semantics the paper relies on (and that our
fault-tolerance claims rest on):

* **at-least-once** — a message leaves the subscription only on explicit ack;
  no ack within ``ack_deadline`` ⇒ redelivery with exponential backoff,
* **dead-lettering** — after ``max_delivery_attempts`` the message is
  published to the DLQ topic instead of retried forever,
* **push flow control** — at most ``max_outstanding`` in-flight deliveries
  per subscription; excess messages queue in the backlog,
* **ordering keys** — messages sharing a key are delivered one-at-a-time in
  publish order (per-key serialization),
* **hedging** (straggler mitigation, beyond the paper's GCP defaults) — an
  optional duplicate delivery fires if no ack lands within ``hedge_after``;
  consumers are idempotent so duplicates are harmless.

The push endpoint is any callable ``endpoint(message, ctx)``; it reports
completion via ``ctx.ack()`` / ``ctx.nack()`` (asynchronously is fine).
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
from collections import defaultdict, deque
from typing import Callable

from repro.core.metrics import Metrics

__all__ = ["Message", "Topic", "Subscription", "DeliveryCtx"]

_msg_ids = itertools.count(1)


@dataclasses.dataclass
class Message:
    data: dict
    attributes: dict = dataclasses.field(default_factory=dict)
    ordering_key: str | None = None
    message_id: int = dataclasses.field(default_factory=lambda: next(_msg_ids))
    publish_time: float = 0.0


class Topic:
    def __init__(self, name: str, scheduler, metrics: Metrics | None = None):
        self.name = name
        self.scheduler = scheduler
        self.metrics = metrics or Metrics(scheduler)
        self.subscriptions: list[Subscription] = []

    def subscribe(self, sub: "Subscription"):
        self.subscriptions.append(sub)

    def publish(self, data: dict, attributes: dict | None = None,
                ordering_key: str | None = None) -> Message:
        msg = Message(data=data, attributes=attributes or {},
                      ordering_key=ordering_key,
                      publish_time=self.scheduler.now())
        self.metrics.inc(f"topic.{self.name}.published")
        self.metrics.log("publish", topic=self.name, id=msg.message_id)
        for sub in self.subscriptions:
            sub._enqueue(msg)
        return msg


class DeliveryCtx:
    """Ack handle given to push endpoints.

    Settlement (ack / nack / deadline expiry) is atomic under the owning
    subscription's lock, so concurrent real-mode workers racing a deadline
    timer resolve to exactly one outcome.
    """

    def __init__(self, sub: "Subscription", msg: Message, attempt: int):
        self.sub, self.msg, self.attempt = sub, msg, attempt
        self.done = False
        self.deadline_handle = None
        self.hedge_handle = None

    def ack(self):
        if self.sub._settle(self):
            self.sub._on_ack(self)

    def nack(self, reason: str = ""):
        if self.sub._settle(self):
            self.sub._on_nack(self, reason or "nack")


class Subscription:
    def __init__(
        self,
        topic: Topic,
        name: str,
        endpoint: Callable[[Message, DeliveryCtx], None],
        *,
        ack_deadline: float = 600.0,
        max_delivery_attempts: int = 5,
        min_backoff: float = 10.0,
        max_backoff: float = 600.0,
        max_outstanding: int = 1000,
        hedge_after: float | None = None,
        dlq: Topic | None = None,
    ):
        self.topic = topic
        self.name = name
        self.endpoint = endpoint
        self.scheduler = topic.scheduler
        self.metrics = topic.metrics
        self.ack_deadline = ack_deadline
        self.max_delivery_attempts = max_delivery_attempts
        self.min_backoff, self.max_backoff = min_backoff, max_backoff
        self.max_outstanding = max_outstanding
        self.hedge_after = hedge_after
        self.dlq = dlq
        self.backlog: deque[tuple[Message, int]] = deque()
        self.outstanding: dict[int, DeliveryCtx] = {}
        self.acked: set[int] = set()
        self._ordered_busy: set[str] = set()
        self._ordered_backlog: dict[str, deque] = defaultdict(deque)
        # guards backlog/outstanding/acked; endpoints are always invoked
        # through the scheduler (never under this lock), so concurrent
        # real-mode workers acking in parallel cannot corrupt the pump
        self._lock = threading.RLock()
        topic.subscribe(self)

    def _settle(self, ctx: DeliveryCtx) -> bool:
        """Atomically claim a delivery's completion; False if already done."""
        with self._lock:
            if ctx.done:
                return False
            ctx.done = True
            return True

    # ---- intake ----------------------------------------------------------
    def _enqueue(self, msg: Message, attempt: int = 1):
        with self._lock:
            if msg.ordering_key is not None:
                if msg.ordering_key in self._ordered_busy:
                    self._ordered_backlog[msg.ordering_key].append(
                        (msg, attempt))
                    return
                self._ordered_busy.add(msg.ordering_key)
            self.backlog.append((msg, attempt))
            self._pump()

    def _pump(self):
        # lock held
        while self.backlog and len(self.outstanding) < self.max_outstanding:
            msg, attempt = self.backlog.popleft()
            self._deliver(msg, attempt)

    # ---- delivery --------------------------------------------------------
    def _deliver(self, msg: Message, attempt: int):
        # lock held
        if msg.message_id in self.acked:  # duplicate of an acked message
            return
        ctx = DeliveryCtx(self, msg, attempt)
        self.outstanding[msg.message_id] = ctx
        self.metrics.inc(f"sub.{self.name}.deliveries")
        ctx.deadline_handle = self.scheduler.schedule(
            self.ack_deadline, self._on_deadline, ctx
        )
        if self.hedge_after is not None:
            ctx.hedge_handle = self.scheduler.schedule(
                self.hedge_after, self._on_hedge, ctx
            )
        self.scheduler.schedule(0.0, self._push, ctx)

    def _push(self, ctx: DeliveryCtx):
        try:
            self.endpoint(ctx.msg, ctx)
        except Exception as e:  # endpoint crashed synchronously
            ctx.nack(f"exception: {e}")

    # ---- completion paths --------------------------------------------------
    def _cleanup(self, ctx: DeliveryCtx):
        with self._lock:
            self.outstanding.pop(ctx.msg.message_id, None)
            for h in (ctx.deadline_handle, ctx.hedge_handle):
                if h is not None:
                    h.cancel()
            key = ctx.msg.ordering_key
            if key is not None and ctx.msg.message_id in self.acked:
                self._ordered_busy.discard(key)
                if self._ordered_backlog[key]:
                    nxt, att = self._ordered_backlog[key].popleft()
                    self._enqueue(nxt, att)
            self._pump()

    def _on_ack(self, ctx: DeliveryCtx):
        with self._lock:
            self.acked.add(ctx.msg.message_id)
        self.metrics.inc(f"sub.{self.name}.acks")
        self.metrics.record(
            f"sub.{self.name}.latency",
            self.scheduler.now() - ctx.msg.publish_time,
        )
        self._cleanup(ctx)

    def _on_nack(self, ctx: DeliveryCtx, reason: str):
        self.metrics.inc(f"sub.{self.name}.nacks")
        self._cleanup(ctx)
        self._retry(ctx, reason)

    def _on_deadline(self, ctx: DeliveryCtx):
        if not self._settle(ctx):
            return
        self.metrics.inc(f"sub.{self.name}.deadline_expired")
        self._cleanup(ctx)
        self._retry(ctx, "ack deadline expired")

    def _on_hedge(self, ctx: DeliveryCtx):
        """Straggler mitigation: fire a duplicate delivery, original stays."""
        with self._lock:
            if ctx.done or ctx.msg.message_id in self.acked:
                return
        self.metrics.inc(f"sub.{self.name}.hedged")
        # duplicate delivery outside the outstanding map (original still owns it)
        dup = DeliveryCtx(self, ctx.msg, ctx.attempt)
        self.scheduler.schedule(0.0, self._push, dup)

    def _retry(self, ctx: DeliveryCtx, reason: str):
        if ctx.attempt >= self.max_delivery_attempts:
            self.metrics.inc(f"sub.{self.name}.dead_lettered")
            self.metrics.log("dead_letter", sub=self.name,
                             id=ctx.msg.message_id, reason=reason)
            if self.dlq is not None:
                self.dlq.publish(ctx.msg.data,
                                 {**ctx.msg.attributes, "dlq_reason": reason})
            return
        backoff = min(self.min_backoff * 2 ** (ctx.attempt - 1),
                      self.max_backoff)
        self.metrics.log("retry", sub=self.name, id=ctx.msg.message_id,
                         attempt=ctx.attempt, backoff=backoff, reason=reason)
        self.scheduler.schedule(
            backoff, lambda: self._enqueue(ctx.msg, ctx.attempt + 1)
        )

    # ---- introspection -----------------------------------------------------
    def stats(self) -> dict:
        with self._lock:
            return {
                "backlog": len(self.backlog),
                "outstanding": len(self.outstanding),
                "acked": len(self.acked),
            }
