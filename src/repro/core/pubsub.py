"""Topic-based publish/subscribe broker with push delivery.

Implements the delivery semantics the paper relies on (and that our
fault-tolerance claims rest on):

* **at-least-once** — a message leaves the subscription only on explicit ack;
  no ack within ``ack_deadline`` ⇒ redelivery with exponential backoff,
* **dead-lettering** — after ``max_delivery_attempts`` the message is
  published to the DLQ topic instead of retried forever,
* **push flow control** — at most ``max_outstanding`` in-flight deliveries
  per subscription; excess messages queue in the backlog,
* **ordering keys** — messages sharing a key are delivered one-at-a-time in
  publish order (per-key serialization). A nacked/expired keyed message
  keeps its key reserved across the retry backoff (later messages cannot
  overtake it); the key is released — and the per-key backlog drained — on
  ack and on dead-letter, so a poison message stalls its key only until it
  dead-letters, never forever,
* **hedging** (straggler mitigation, beyond the paper's GCP defaults) — an
  optional duplicate delivery fires if no ack lands within ``hedge_after``;
  consumers are idempotent so duplicates are harmless. Whichever delivery
  acks first settles the message; a duplicate's nack is recorded but never
  touches the original delivery's outstanding entry, deadline timer, or
  retry budget,
* **budget-exempt nacks** (backpressure) — ``ctx.nack(reason,
  consume_budget=False)`` requeues the message after ``min_backoff``
  *without* incrementing the delivery attempt, so an overloaded consumer
  shedding load (HTTP-429-style) can push work back indefinitely without
  ever dead-lettering it; ordered messages keep their key reserved across
  the requeue,
* **fault injection** — an optional :class:`DeliveryFaults` schedule on a
  subscription deterministically drops, delays, or duplicates individual
  deliveries (the redelivery/dedup machinery above is what the fleet's
  fault-tolerance tests exercise through it).

The push endpoint is any callable ``endpoint(message, ctx)``; it reports
completion via ``ctx.ack()`` / ``ctx.nack()`` (asynchronously is fine).
"""
from __future__ import annotations

import dataclasses
import itertools
import random
from collections import Counter, defaultdict, deque
from typing import Callable

from repro.analysis.lockdep import TrackedLock, check_callback
from repro.analysis.racedep import tracked_state
from repro.core import tracing
from repro.core.metrics import Metrics

__all__ = ["Message", "Topic", "Subscription", "DeliveryCtx",
           "DeliveryFaults"]

_msg_ids = itertools.count(1)


@dataclasses.dataclass
class Message:
    data: dict
    attributes: dict = dataclasses.field(default_factory=dict)
    ordering_key: str | None = None
    message_id: int = dataclasses.field(default_factory=lambda: next(_msg_ids))
    publish_time: float = 0.0


class Topic:
    def __init__(self, name: str, scheduler, metrics: Metrics | None = None):
        self.name = name
        self.scheduler = scheduler
        self.metrics = metrics or Metrics(scheduler)
        self.subscriptions: list[Subscription] = []

    def subscribe(self, sub: "Subscription"):
        self.subscriptions.append(sub)

    def publish(self, data: dict, attributes: dict | None = None,
                ordering_key: str | None = None) -> Message:
        msg = Message(data=data, attributes=attributes or {},
                      ordering_key=ordering_key,
                      publish_time=self.scheduler.now())
        if tracing.current() is not None:
            # parent priority: the publishing handler's ambient span, else
            # trace context already on the attributes (a DLQ republish
            # carries the original message's context), else this publish
            # ROOTS a new trace — the landing bucket's ambient-less
            # OBJECT_FINALIZE publish is where a slide's trace begins
            sp = tracing.start_span(
                f"topic.{self.name}.publish",
                parent=tracing.current_span(),
                parent_ctx=tracing.extract(msg.attributes),
                message_id=msg.message_id,
                object=(data or {}).get("name"))
            tracing.inject(msg.attributes, sp)
            tracing.end_span(sp)
        self.metrics.inc(f"topic.{self.name}.published")
        self.metrics.log("publish", topic=self.name, id=msg.message_id)
        for sub in self.subscriptions:
            sub._enqueue(msg)
        return msg


class DeliveryFaults:
    """Deterministic delivery-fault schedule for a :class:`Subscription`.

    Two modes, both reproducible:

    * **scripted** — :meth:`drop` / :meth:`delay` / :meth:`duplicate` rules
      matched against the message (a ``str`` matches as a substring of the
      payload's ``name`` or the ordering key; a callable is a predicate
      over the :class:`Message`) and the delivery attempt. Each rule fires
      at most ``times`` times, so a dropped message's redelivery eventually
      gets through.
    * **seeded-random** — :meth:`random` draws each delivery's fate from a
      ``random.Random(seed)``; the same seed under ``SimScheduler`` yields
      the same interleaving (the property tests' arrival-trace fuzzing).

    A *dropped* delivery never reaches the endpoint: its context stays
    outstanding until the ack deadline expires, exercising redelivery (and
    ordered-key retention). A *delayed* delivery arrives late — possibly
    after the deadline already redelivered it, exercising consumer-side
    dedup. A *duplicated* delivery pushes the same context twice; first
    settlement wins.
    """

    def __init__(self):
        self._rules: list[dict] = []
        self._rng: random.Random | None = None
        self._p = {"drop": 0.0, "duplicate": 0.0, "delay": 0.0}
        self._max_delay = 0.0
        self.injected = Counter()  # action -> times fired

    # ---- scripted rules --------------------------------------------------
    def _add(self, action: str, match, *, attempts, times, by=0.0, lag=0.0):
        self._rules.append({"action": action, "match": match,
                            "attempts": tuple(attempts), "times": times,
                            "by": by, "lag": lag})
        return self

    def drop(self, match, *, attempts=(1,), times=1):
        """Swallow matching deliveries (ack-deadline expiry redelivers)."""
        return self._add("drop", match, attempts=attempts, times=times)

    def delay(self, match, by: float, *, attempts=(1,), times=1):
        """Deliver matching messages ``by`` seconds late."""
        return self._add("delay", match, attempts=attempts, times=times,
                         by=by)

    def duplicate(self, match, *, lag: float = 0.0, attempts=(1,), times=1):
        """Push matching deliveries twice (second copy ``lag`` s later)."""
        return self._add("duplicate", match, attempts=attempts, times=times,
                         lag=lag)

    @classmethod
    def random(cls, seed: int, *, p_drop: float = 0.1,
               p_duplicate: float = 0.1, p_delay: float = 0.2,
               max_delay: float = 30.0) -> "DeliveryFaults":
        f = cls()
        f._rng = random.Random(seed)
        f._p = {"drop": p_drop, "duplicate": p_duplicate, "delay": p_delay}
        f._max_delay = max_delay
        return f

    # ---- the subscription's hook -----------------------------------------
    @staticmethod
    def _matches(match, msg: Message) -> bool:
        if callable(match):
            return bool(match(msg))
        hay = str((msg.data or {}).get("name", "")) + "\0" + \
            str(msg.ordering_key or "")
        return str(match) in hay

    def plan(self, msg: Message, attempt: int):
        """→ ``(action, deliver_delay, duplicate_lag | None)``."""
        if self._rng is not None:
            r = self._rng.random()
            if r < self._p["drop"]:
                self.injected["drop"] += 1
                return ("drop", 0.0, None)
            r -= self._p["drop"]
            if r < self._p["duplicate"]:
                self.injected["duplicate"] += 1
                return ("deliver", 0.0,
                        self._rng.uniform(0.0, self._max_delay))
            r -= self._p["duplicate"]
            if r < self._p["delay"]:
                self.injected["delay"] += 1
                return ("deliver",
                        self._rng.uniform(0.0, self._max_delay), None)
            return ("deliver", 0.0, None)
        for rule in self._rules:
            if rule["times"] <= 0 or attempt not in rule["attempts"] \
                    or not self._matches(rule["match"], msg):
                continue
            rule["times"] -= 1
            self.injected[rule["action"]] += 1
            if rule["action"] == "drop":
                return ("drop", 0.0, None)
            if rule["action"] == "delay":
                return ("deliver", rule["by"], None)
            return ("deliver", 0.0, rule["lag"])  # duplicate
        return ("deliver", 0.0, None)


class DeliveryCtx:
    """Ack handle given to push endpoints.

    Settlement (ack / nack / deadline expiry) is atomic under the owning
    subscription's lock, so concurrent real-mode workers racing a deadline
    timer resolve to exactly one outcome.

    A hedged duplicate carries ``hedge_of`` (the original delivery). It
    settles *itself* only: its ack wins the race by acking the original
    (which owns the outstanding entry and timers), and its nack is recorded
    but deliberately touches nothing — the original is still in flight with
    its own deadline and retry budget, so a failed duplicate must not pop
    the original's outstanding entry or double-schedule a retry.
    """

    def __init__(self, sub: "Subscription", msg: Message, attempt: int,
                 hedge_of: "DeliveryCtx | None" = None):
        self.sub, self.msg, self.attempt = sub, msg, attempt
        self.hedge_of = hedge_of
        self.done = False
        self.deadline_handle = None
        self.hedge_handle = None
        self.span = None  # delivery-attempt span (None when disarmed)

    def ack(self):
        if not self.sub._settle(self):
            return
        if self.hedge_of is not None:
            self.sub._on_hedge_ack(self)
        else:
            self.sub._on_ack(self)

    def nack(self, reason: str = "", *, consume_budget: bool = True):
        """Reject the delivery. ``consume_budget=False`` is the
        backpressure path: the message is requeued after ``min_backoff``
        with the *same* attempt number, so a load-shedding consumer can
        push back forever without the message ever dead-lettering."""
        if not self.sub._settle(self):
            return
        if self.hedge_of is not None:
            self.sub._on_hedge_nack(self, reason or "nack")
        else:
            self.sub._on_nack(self, reason or "nack",
                              consume_budget=consume_budget)


@tracked_state("backlog", "outstanding", "acked", "_ordered_busy",
               "_ordered_backlog")
class Subscription:
    def __init__(
        self,
        topic: Topic,
        name: str,
        endpoint: Callable[[Message, DeliveryCtx], None],
        *,
        ack_deadline: float = 600.0,
        max_delivery_attempts: int = 5,
        min_backoff: float = 10.0,
        max_backoff: float = 600.0,
        max_outstanding: int = 1000,
        hedge_after: float | None = None,
        dlq: Topic | None = None,
        faults: DeliveryFaults | None = None,
    ):
        self.topic = topic
        self.name = name
        self.endpoint = endpoint
        self.scheduler = topic.scheduler
        self.metrics = topic.metrics
        self.ack_deadline = ack_deadline
        self.max_delivery_attempts = max_delivery_attempts
        self.min_backoff, self.max_backoff = min_backoff, max_backoff
        self.max_outstanding = max_outstanding
        self.hedge_after = hedge_after
        self.dlq = dlq
        self.faults = faults
        self.backlog: deque[tuple[Message, int]] = deque()
        self.outstanding: dict[int, DeliveryCtx] = {}
        self.acked: set[int] = set()
        self._ordered_busy: set[str] = set()
        self._ordered_backlog: dict[str, deque] = defaultdict(deque)
        # guards backlog/outstanding/acked; endpoints are always invoked
        # through the scheduler (never under this lock), so concurrent
        # real-mode workers acking in parallel cannot corrupt the pump —
        # lockdep's check_callback in _push enforces exactly that
        self._lock = TrackedLock(f"Subscription[{name}]._lock",
                                 reentrant=True)
        topic.subscribe(self)

    def _settle(self, ctx: DeliveryCtx) -> bool:
        """Atomically claim a delivery's completion; False if already done."""
        with self._lock:
            if ctx.done:
                return False
            ctx.done = True
            return True

    # ---- intake ----------------------------------------------------------
    def _enqueue(self, msg: Message, attempt: int = 1, *,
                 holds_key: bool = False):
        """Queue a delivery. ``holds_key=True`` marks a retry of an ordered
        message that *already owns* its busy key (kept reserved across the
        backoff so later messages with the key cannot overtake it); a
        normal enqueue against a busy key parks in the per-key backlog."""
        with self._lock:
            key = msg.ordering_key
            if key is not None:
                if holds_key:
                    self._ordered_busy.add(key)
                elif key in self._ordered_busy:
                    self._ordered_backlog[key].append((msg, attempt))
                    return
                else:
                    self._ordered_busy.add(key)
            self.backlog.append((msg, attempt))
            self._pump()

    def _pump(self):
        # lock held
        while self.backlog and len(self.outstanding) < self.max_outstanding:
            msg, attempt = self.backlog.popleft()
            self._deliver(msg, attempt)

    # ---- delivery --------------------------------------------------------
    def _deliver(self, msg: Message, attempt: int):
        # lock held
        if msg.message_id in self.acked:  # duplicate of an acked message
            if msg.ordering_key is not None:
                # the duplicate acquired the key in _enqueue; dropping it
                # must not leave the key busy forever
                self._release_key(msg.ordering_key)
            return
        ctx = DeliveryCtx(self, msg, attempt)
        # every delivery attempt (retries included) gets its own span,
        # parented on the publish span riding the message attributes
        ctx.span = tracing.start_span(
            f"sub.{self.name}.deliver",
            parent_ctx=tracing.extract(msg.attributes),
            attempt=attempt, message_id=msg.message_id)
        self.outstanding[msg.message_id] = ctx
        self.metrics.inc(f"sub.{self.name}.deliveries")
        ctx.deadline_handle = self.scheduler.schedule(
            self.ack_deadline, self._on_deadline, ctx
        )
        if self.hedge_after is not None:
            ctx.hedge_handle = self.scheduler.schedule(
                self.hedge_after, self._on_hedge, ctx
            )
        delay, dup_lag = 0.0, None
        if self.faults is not None:
            action, delay, dup_lag = self.faults.plan(msg, attempt)
            if action == "drop":
                # swallowed: the ctx stays outstanding (ordered key held),
                # so the ack deadline expires and redelivers — exactly the
                # lost-HTTP-push failure mode the paper's retries cover
                self.metrics.inc(f"sub.{self.name}.fault_dropped")
                tracing.add_event(ctx.span, "fault.drop", attempt=attempt)
                return
            if delay:
                self.metrics.inc(f"sub.{self.name}.fault_delayed")
                tracing.add_event(ctx.span, "fault.delay", by=delay)
            if dup_lag is not None:
                # same ctx pushed twice: first settlement wins, consumers
                # must dedupe (idempotent store / fleet admission)
                self.metrics.inc(f"sub.{self.name}.fault_duplicated")
                tracing.add_event(ctx.span, "fault.duplicate", lag=dup_lag)
                self.scheduler.schedule(delay + dup_lag, self._push, ctx)
        self.scheduler.schedule(delay, self._push, ctx)

    def _push(self, ctx: DeliveryCtx):
        check_callback(f"sub.{self.name}.endpoint")
        try:
            # the delivery span is ambient while the endpoint runs, so
            # service admission / conversion / store spans parent under it
            with tracing.use_span(ctx.span):
                self.endpoint(ctx.msg, ctx)
        except Exception as e:  # endpoint crashed synchronously
            ctx.nack(f"exception: {e}")

    # ---- completion paths --------------------------------------------------
    def _release_key(self, key: str):
        """Free an ordering key and hand delivery to the next queued message.

        Called (lock held) on every settlement that ends this message's
        ownership of the key — ack, dead-letter, and acked-duplicate drop.
        A nack/deadline expiry that will be *retried* does not release: the
        retry keeps the key reserved (see ``_enqueue(holds_key=True)``) so
        per-key publish order survives the backoff.
        """
        self._ordered_busy.discard(key)
        backlog = self._ordered_backlog.get(key)
        if backlog:
            nxt, att = backlog.popleft()
            if not backlog:
                del self._ordered_backlog[key]
            self._enqueue(nxt, att)
        elif backlog is not None:
            del self._ordered_backlog[key]

    def _cleanup(self, ctx: DeliveryCtx, *, release_key: bool = True):
        with self._lock:
            self.outstanding.pop(ctx.msg.message_id, None)
            for h in (ctx.deadline_handle, ctx.hedge_handle):
                if h is not None:
                    h.cancel()
            key = ctx.msg.ordering_key
            if key is not None and release_key:
                self._release_key(key)
            self._pump()

    def _on_ack(self, ctx: DeliveryCtx):
        with self._lock:
            self.acked.add(ctx.msg.message_id)
        self.metrics.inc(f"sub.{self.name}.acks")
        # publish→ack latency is per-delivery hot-path telemetry: fold it
        # into the bounded histogram instead of an unbounded series
        self.metrics.observe(
            f"sub.{self.name}.latency",
            self.scheduler.now() - ctx.msg.publish_time,
        )
        tracing.end_span(ctx.span, status="acked")
        self._cleanup(ctx)

    def _will_retry(self, ctx: DeliveryCtx) -> bool:
        return ctx.attempt < self.max_delivery_attempts

    def _on_nack(self, ctx: DeliveryCtx, reason: str, *,
                 consume_budget: bool = True):
        self.metrics.inc(f"sub.{self.name}.nacks")
        if not consume_budget:
            # backpressure: requeue after min_backoff with the SAME attempt
            # number — shed work retries until admitted and can never
            # dead-letter. Ordered messages keep their key reserved.
            self.metrics.inc(f"sub.{self.name}.requeues")
            self.metrics.log("requeue", sub=self.name,
                             id=ctx.msg.message_id, reason=reason)
            tracing.add_event(ctx.span, "sub.requeue", reason=reason)
            tracing.end_span(ctx.span, status="requeued")
            self._cleanup(ctx, release_key=False)
            held = ctx.msg.ordering_key is not None
            self.scheduler.schedule(
                self.min_backoff,
                lambda: self._enqueue(ctx.msg, ctx.attempt, holds_key=held))
            return
        tracing.end_span(ctx.span, status="nacked", reason=reason)
        # a retried ordered message keeps its key reserved through the
        # backoff; only a dead-letter hands the key to the next message
        self._cleanup(ctx, release_key=not self._will_retry(ctx))
        self._retry(ctx, reason)

    def _on_deadline(self, ctx: DeliveryCtx):
        if not self._settle(ctx):
            return
        self.metrics.inc(f"sub.{self.name}.deadline_expired")
        tracing.end_span(ctx.span, status="deadline")
        self._cleanup(ctx, release_key=not self._will_retry(ctx))
        self._retry(ctx, "ack deadline expired")

    def _on_hedge(self, ctx: DeliveryCtx):
        """Straggler mitigation: fire a duplicate delivery, original stays."""
        with self._lock:
            if ctx.done or ctx.msg.message_id in self.acked:
                return
        self.metrics.inc(f"sub.{self.name}.hedged")
        # duplicate delivery outside the outstanding map (original still owns
        # it); hedge_of routes the duplicate's settlement (see DeliveryCtx)
        dup = DeliveryCtx(self, ctx.msg, ctx.attempt, hedge_of=ctx)
        # the hedge's span links back to the primary attempt (`hedge_of`)
        # and parents on the same publish span, so both race legs land in
        # one tree
        dup.span = tracing.start_span(
            f"sub.{self.name}.hedge",
            parent_ctx=tracing.extract(ctx.msg.attributes),
            attempt=ctx.attempt,
            hedge_of=ctx.span.span_id if ctx.span is not None else None)
        self.scheduler.schedule(0.0, self._push, dup)

    def _on_hedge_ack(self, dup: DeliveryCtx):
        """The duplicate finished first: settle the original delivery."""
        self.metrics.inc(f"sub.{self.name}.hedge_acks")
        tracing.end_span(dup.span, status="acked")
        dup.hedge_of.ack()  # no-op if the original already settled

    def _on_hedge_nack(self, dup: DeliveryCtx, reason: str):
        # deliberately nothing else: the original owns the outstanding
        # entry, deadline timer, and retry budget
        self.metrics.inc(f"sub.{self.name}.hedge_nacks")
        self.metrics.log("hedge_nack", sub=self.name,
                         id=dup.msg.message_id, reason=reason)
        tracing.end_span(dup.span, status="nacked", reason=reason)

    def _retry(self, ctx: DeliveryCtx, reason: str):
        if not self._will_retry(ctx):
            self.metrics.inc(f"sub.{self.name}.dead_lettered")
            self.metrics.log("dead_letter", sub=self.name,
                             id=ctx.msg.message_id, reason=reason)
            tracing.add_event(ctx.span, "sub.dead_letter", reason=reason)
            if self.dlq is not None:
                self.dlq.publish(ctx.msg.data,
                                 {**ctx.msg.attributes, "dlq_reason": reason})
            return
        backoff = min(self.min_backoff * 2 ** (ctx.attempt - 1),
                      self.max_backoff)
        self.metrics.log("retry", sub=self.name, id=ctx.msg.message_id,
                         attempt=ctx.attempt, backoff=backoff, reason=reason)
        tracing.add_event(ctx.span, "sub.retry", attempt=ctx.attempt,
                          backoff=backoff, reason=reason)
        held = ctx.msg.ordering_key is not None
        self.scheduler.schedule(
            backoff,
            lambda: self._enqueue(ctx.msg, ctx.attempt + 1, holds_key=held)
        )

    # ---- introspection -----------------------------------------------------
    def stats(self) -> dict:
        with self._lock:
            return {
                "backlog": len(self.backlog),
                "outstanding": len(self.outstanding),
                "acked": len(self.acked),
                "ordered_backlog": sum(
                    len(q) for q in self._ordered_backlog.values()),
            }
