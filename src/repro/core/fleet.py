"""Simulated multi-instance converter fleet — the paper's institutional
scale (Figs 2–3) as a first-class, continuously-asserted subsystem.

:class:`ConverterFleet` extends :class:`AutoscalingService` from "one global
queue drained by idle instances" to the shape the paper's Cloud-Run fleet
actually has:

* **per-instance work queues** — each instance owns a bounded local queue
  (``instance_queue_depth``, modelling push-endpoint buffering). The
  dispatcher fills the least-loaded ready instance; an instance works its
  own queue through its ``concurrency`` slots.
* **backlog-reactive scaling** — a periodic controller tick (deterministic
  under ``SimScheduler``) sizes the fleet to
  ``ceil(demand / concurrency)``, clamped to ``[min_instances,
  max_instances]``; scale-down stays with the idle-delay machinery, giving
  Figure 3's ramp → plateau → decay.
* **backpressure / load shedding** — past ``shed_backlog`` waiting requests
  (or ``shed_dlq_depth`` dead-lettered ones), new deliveries are *shed*:
  the push endpoint answers the 429-equivalent, which the broker turns
  into a budget-exempt requeue (``nack(consume_budget=False)``) — shed
  work retries until admitted and can never dead-letter, and work already
  admitted is never shed.
* **per-tenant quotas + fair scheduling** — at most ``tenant_quota``
  admitted requests per tenant (excess sheds the same way), and pending
  work is dispatched round-robin across tenants so one scanner's burst
  cannot starve another lab.
* **fault tolerance** — :meth:`kill_instance` requeues the victim's local
  queue *and* in-flight requests exactly once (to the head of their
  tenants' pending queues); the ack/ordering-key machinery upstream is
  untouched, so the slide still converts exactly once. Duplicate
  deliveries (broker hedging, injected faults, redelivery racing a slow
  ack) are deduplicated at admission by request key — a duplicate of an
  in-flight request just attaches its completion callback, a duplicate of
  a finished request completes immediately.

The fleet is API-compatible with ``AutoscalingService`` (``receive``,
``instance_count``, ``kill_instance``, ``stats``, the ``svc.{name}.*``
metrics), so ``ConversionPipeline`` swaps it in without rewiring.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Callable

from repro.analysis.lockdep import check_callback
from repro.analysis.racedep import tracked_state
from repro.core import tracing
from repro.core.autoscaler import AutoscalingService, Instance, _req_ids

__all__ = ["ConverterFleet", "FleetInstance"]


# deliberately NOT @tracked_state: the per-instance queue/running deques
# are fleet-private — every access (receive/_drain/_finish/_kill) holds the
# fleet's single _lock, so the detector could never pair them into a race,
# and the dispatch loop polls len(queue) thousands of times per tick (the
# disarmed-overhead gate in fleet_bench would blow its 10% budget on
# structures with no unlocked second accessor). Cross-thread misuse of the
# fleet still surfaces through its tracked coordination surface
# (_pending/_admitted/_completed/instances on ConverterFleet below).
class FleetInstance(Instance):
    __slots__ = ("queue", "running")

    def __init__(self, iid: int, ready_at: float):
        super().__init__(iid, ready_at)
        self.queue: deque = deque()  # assigned, not yet serving
        self.running: list = []      # currently in a concurrency slot


@dataclasses.dataclass
class _FleetRequest:
    payload: object
    tenant: str
    key: object  # dedupe key, e.g. (object name, generation); None = no dedupe
    arrived: float
    dones: list = dataclasses.field(default_factory=list)
    req_id: int = dataclasses.field(default_factory=lambda: next(_req_ids))
    # explicit trace handoff (see autoscaler._Request): the request span
    # survives steals and kill-requeues; hspan is the current serve attempt
    span: object = None
    hspan: object = None

    def done(self, ok):
        # every delivery that attached to this request (original + deduped
        # duplicates) gets the completion; extra acks settle as no-ops
        for cb in self.dones:
            cb(ok)


def _default_tenant_of(payload) -> str:
    if isinstance(payload, dict):
        md = payload.get("metadata") or {}
        return md.get("tenant") or payload.get("tenant") or "default"
    return "default"


def _default_key_of(payload):
    if isinstance(payload, dict) and "name" in payload:
        return (payload["name"], payload.get("generation"))
    return None


@tracked_state("_pending", "_rr", "_tenant_load", "_admitted", "_completed")
class ConverterFleet(AutoscalingService):
    instance_cls = FleetInstance

    def __init__(
        self,
        name: str,
        scheduler,
        handler: Callable,
        *,
        instance_queue_depth: int = 2,
        control_interval: float = 2.0,
        shed_backlog: int | None = None,
        shed_dlq_depth: int | None = None,
        dlq_depth: Callable[[], int] | None = None,
        tenant_quota: int | None = None,
        tenant_of: Callable | None = None,
        key_of: Callable | None = None,
        **kw,
    ):
        # fleet state must exist before super().__init__: warm min_instances
        # schedule _instance_ready → _drain, which reads it (immediately on
        # a RealScheduler pool thread)
        self.instance_queue_depth = instance_queue_depth
        self.control_interval = control_interval
        self.shed_backlog = shed_backlog
        self.shed_dlq_depth = shed_dlq_depth
        self._dlq_depth = dlq_depth
        self.tenant_quota = tenant_quota
        self._tenant_of = tenant_of or _default_tenant_of
        self._key_of = key_of or _default_key_of
        self._pending: dict[str, deque] = {}   # tenant -> FIFO of requests
        self._rr: deque[str] = deque()         # tenant round-robin rotation
        self._tenant_load: dict[str, int] = {}  # admitted & unfinished
        self._admitted: dict = {}              # key -> in-flight request
        self._completed: set = set()           # keys that finished ok
        self._tick_pending = False
        super().__init__(name, scheduler, handler, **kw)

    # ---- admission ---------------------------------------------------------
    def receive(self, payload, done: Callable, *, tenant: str | None = None,
                key=None):
        tenant = tenant or self._tenant_of(payload)
        if key is None:
            key = self._key_of(payload)
        self.metrics.inc(f"svc.{self.name}.requests")
        verdict = None
        with self._lock:
            if key is not None and key in self._completed:
                # redelivery/duplicate of finished work: the study is
                # already durably stored (idempotent writes), just ack
                self.metrics.inc(f"svc.{self.name}.duplicates")
                # annotate the *delivery* span (ambient): this attempt
                # resolved against already-finished work
                tracing.add_event(None, "fleet.duplicate", outcome="done")
                verdict = "done"
            elif key is not None and key in self._admitted:
                # duplicate of in-flight work: ride the existing request
                primary = self._admitted[key]
                primary.dones.append(done)
                self.metrics.inc(f"svc.{self.name}.duplicates")
                tracing.add_event(None, "fleet.duplicate",
                                  outcome="attached", req_id=primary.req_id)
                return
            else:
                reason = self._shed_reason(tenant)
                if reason is not None:
                    self.metrics.log("shed", svc=self.name, tenant=tenant,
                                     reason=reason)
                    tracing.add_event(None, "fleet.shed", tenant=tenant,
                                      reason=reason)
                    verdict = "shed"
            if verdict is None:
                req = _FleetRequest(payload=payload, tenant=tenant, key=key,
                                    arrived=self.scheduler.now(),
                                    dones=[done])
                req.span = tracing.start_span(
                    f"svc.{self.name}.request",
                    req_id=req.req_id, tenant=tenant)
                self._admit(req)
                self._drain()
                self._kick_controller()
                return
        # completion callbacks always run outside the lock (they re-enter
        # the broker, which may re-enter receive)
        check_callback(f"svc.{self.name}.done")
        done(True if verdict == "done" else "shed")

    def _admit(self, req: _FleetRequest):
        # lock held
        if req.tenant not in self._pending:
            self._pending[req.tenant] = deque()
            self._rr.append(req.tenant)
        self._pending[req.tenant].append(req)
        self._tenant_load[req.tenant] = \
            self._tenant_load.get(req.tenant, 0) + 1
        self._record_tenant(req.tenant)
        if req.key is not None:
            self._admitted[req.key] = req

    def _shed_reason(self, tenant: str) -> str | None:
        # lock held
        waiting = self._waiting()
        if self.shed_backlog is not None and waiting >= self.shed_backlog:
            self.metrics.inc(f"svc.{self.name}.shed")
            return f"backlog {waiting} >= shed_backlog {self.shed_backlog}"
        if self.shed_dlq_depth is not None and self._dlq_depth is not None \
                and self._dlq_depth() >= self.shed_dlq_depth:
            self.metrics.inc(f"svc.{self.name}.shed")
            return (f"dlq depth {self._dlq_depth()} >= "
                    f"shed_dlq_depth {self.shed_dlq_depth}")
        if self.tenant_quota is not None and \
                self._tenant_load.get(tenant, 0) >= self.tenant_quota:
            self.metrics.inc(f"svc.{self.name}.shed")
            self.metrics.inc(f"svc.{self.name}.shed_quota")
            return (f"tenant {tenant!r} at quota {self.tenant_quota}")
        return None

    def _record_tenant(self, tenant: str):
        self.metrics.record(f"svc.{self.name}.tenant.{tenant}.load",
                            self._tenant_load.get(tenant, 0))

    # ---- dispatch ----------------------------------------------------------
    def _waiting(self) -> int:
        # lock held: admitted but not yet in a concurrency slot
        return sum(len(q) for q in self._pending.values()) + \
            sum(len(i.queue) for i in self.instances.values() if not i.dead)

    def backlog(self) -> int:
        with self._lock:
            return self._waiting()

    def _ready_instances(self) -> list[FleetInstance]:
        # lock held; sorted by iid for a deterministic sim
        return sorted((i for i in self.instances.values()
                       if not i.dead and i.state in ("idle", "busy")),
                      key=lambda i: i.iid)

    def _pick_target(self, ready=None) -> FleetInstance | None:
        # lock held: least-loaded ready instance with queue room
        best, best_load = None, None
        for inst in self._ready_instances() if ready is None else ready:
            load = inst.active + len(inst.queue)
            if load >= self.concurrency + self.instance_queue_depth:
                continue
            if best is None or load < best_load:
                best, best_load = inst, load
        return best

    def _next_fair(self) -> _FleetRequest | None:
        # lock held: round-robin across tenants with pending work
        for _ in range(len(self._rr)):
            tenant = self._rr[0]
            self._rr.rotate(-1)
            q = self._pending.get(tenant)
            if q:
                return q.popleft()
        return None

    def _drain(self):
        # lock held. Ready-set membership (alive + idle/busy) is stable for
        # the whole drain — serving only flips active counts — so sort once
        ready = self._ready_instances()
        # 1) promote local queues into free concurrency slots
        for inst in ready:
            while inst.queue and inst.active < self.concurrency:
                self._serve(inst, inst.queue.popleft())
        # 2) fair-assign pending work to per-instance queues
        while True:
            inst = self._pick_target(ready)
            if inst is None:
                break
            req = self._next_fair()
            if req is None:
                break
            if inst.active < self.concurrency:
                self._serve(inst, req)
            else:
                inst.queue.append(req)
        # 3) work stealing: an instance with a free concurrency slot and an
        # empty local queue takes the head of the longest local queue —
        # capacity that became ready after a burst was buffered (or an
        # instance that finished early) relieves the loaded instances
        # instead of idling next to their head-of-line backlog
        while True:
            free = [i for i in ready
                    if i.active < self.concurrency and not i.queue]
            donors = [i for i in ready if i.queue]
            if not free or not donors:
                return
            donor = max(donors, key=lambda i: (len(i.queue), -i.iid))
            stolen = donor.queue.popleft()
            tracing.add_event(stolen.span, "fleet.steal",
                              src=donor.iid, dst=free[0].iid)
            self._serve(free[0], stolen)

    def _serve(self, inst: FleetInstance, req: _FleetRequest):
        inst.running.append(req)
        super()._serve(inst, req)

    def _finish(self, inst: FleetInstance, req: _FleetRequest, ok: bool):
        with self._lock:
            if not inst.dead:
                # a dead instance's requests were already requeued by
                # _kill; their accounting transfers to the requeued run
                try:
                    inst.running.remove(req)
                except ValueError:
                    pass
                self._tenant_load[req.tenant] = \
                    max(0, self._tenant_load.get(req.tenant, 1) - 1)
                self._record_tenant(req.tenant)
                if req.key is not None:
                    self._admitted.pop(req.key, None)
                    if ok:
                        self._completed.add(req.key)
        super()._finish(inst, req, ok)

    def _maybe_scale_up(self):
        # the controller tick owns scaling; base receive() is not used
        pass

    # ---- controller --------------------------------------------------------
    def _kick_controller(self):
        # lock held
        if self._tick_pending:
            return
        self._tick_pending = True
        self.scheduler.schedule(0.0, self._control_tick)

    def _control_tick(self):
        with self._lock:
            self._tick_pending = False
            waiting = self._waiting()
            demand = waiting + sum(
                i.active for i in self.instances.values() if not i.dead)
            alive = [i for i in self.instances.values()
                     if i.state != "stopped"]
            desired = min(self.max_instances,
                          max(self.min_instances,
                              math.ceil(demand / max(1, self.concurrency))))
            for _ in range(desired - len(alive)):
                self._start_instance()
            self.metrics.record(f"svc.{self.name}.backlog", waiting)
            self._drain()
            # keep ticking while there is anything to react to; a later
            # receive() re-kicks an idle controller (lets SimScheduler.run
            # reach quiescence instead of ticking forever)
            if self._waiting() > 0 or any(
                    i.state == "starting" for i in self.instances.values()):
                self._tick_pending = True
                self.scheduler.schedule(self.control_interval,
                                        self._control_tick)

    # ---- fault injection ---------------------------------------------------
    def _kill(self, inst: FleetInstance):
        # lock held (via kill_instance). Requeue the victim's local queue
        # and in-flight requests exactly once, at the head of their
        # tenants' pending queues — admission accounting (quota, dedupe
        # key) stays with the request, so nothing is lost or duplicated.
        orphans = list(inst.running) + list(inst.queue)
        inst.running.clear()
        inst.queue.clear()
        super()._kill(inst)
        for req in reversed(orphans):
            # the serve attempt dies with the instance; the request span
            # stays open and ends when the requeued run completes
            tracing.end_span(req.hspan, status="killed")
            tracing.add_event(req.span, "fleet.kill_requeue",
                              instance=inst.iid)
            if req.tenant not in self._pending:
                self._pending[req.tenant] = deque()
                self._rr.append(req.tenant)
            self._pending[req.tenant].appendleft(req)
            self.metrics.inc(f"svc.{self.name}.requeued")
        if orphans:
            self._drain()
            self._kick_controller()

    # ---- introspection -----------------------------------------------------
    def tenant_loads(self) -> dict[str, int]:
        with self._lock:
            return {t: n for t, n in self._tenant_load.items() if n}

    def stats(self) -> dict:
        with self._lock:
            return {
                "instances": len([i for i in self.instances.values()
                                  if i.state != "stopped"]),
                "waiting": self._waiting(),
                "active": sum(i.active for i in self.instances.values()
                              if not i.dead),
                "cold_starts": self.cold_starts,
                "shed": int(self.metrics.get(f"svc.{self.name}.shed")),
                "requeued": int(
                    self.metrics.get(f"svc.{self.name}.requeued")),
                "duplicates": int(
                    self.metrics.get(f"svc.{self.name}.duplicates")),
                "tenants": {t: n for t, n in self._tenant_load.items() if n},
            }
