"""Execution substrate for the event-driven pipeline.

Every component (object store, pub/sub broker, autoscaling service) is written
against the ``Scheduler`` interface, so the same wiring runs in two modes:

* ``SimScheduler``  — deterministic discrete-event simulation. Time is
  virtual; ``schedule`` pushes onto a heap and ``run`` drains it. This is how
  the Figure 2/3 experiments model institutional-scale batches (hundreds of
  containers) on a one-core host, with service times *calibrated from real
  measured conversions*.
* ``RealScheduler`` — wall-clock execution on a thread pool. Used by the
  end-to-end examples and the fault-tolerance tests that kill real workers.
"""
from __future__ import annotations

import heapq
import itertools
import random
import threading
import time
from typing import Callable

from repro.analysis import racedep
from repro.analysis.lockdep import TrackedLock

__all__ = ["SimScheduler", "RealScheduler", "Handle", "wall_time",
           "wall_sleep", "monotonic"]


def wall_time() -> float:
    """The single sanctioned wall-clock read (epoch seconds).

    Everything inside the event-driven spine must use its scheduler's
    ``now()`` so SimScheduler runs stay deterministic; CLI launchers and
    checkpoint stamps that genuinely want wall time route through here
    (the ``wall-clock`` lint rule allows this module only).
    """
    return time.time()


def wall_sleep(seconds: float) -> None:
    """Sanctioned wall-clock sleep — real-scheduler polls in tests only.

    Never call this from code that can run under ``SimScheduler``; use
    ``scheduler.schedule(delay, fn)`` instead.
    """
    time.sleep(seconds)


def monotonic() -> float:
    """The sanctioned monotonic read, for interval timing that genuinely
    wants wall time (batch-window deadlines under ``RealScheduler``,
    test timeouts). Spine code measuring virtual time must use its
    scheduler's ``now()``; the ``wall-clock`` lint rule rejects raw
    ``time.monotonic()``/``time.perf_counter()`` outside this module and
    ``benchmarks/``.
    """
    return time.monotonic()


class Handle:
    """Cancellation token for a scheduled callback."""

    __slots__ = ("cancelled", "_on_cancel")

    def __init__(self):
        self.cancelled = False
        self._on_cancel = None

    def cancel(self):
        self.cancelled = True
        cb, self._on_cancel = self._on_cancel, None
        if cb is not None:
            cb()


class SimScheduler:
    """Deterministic discrete-event scheduler.

    With ``seed=None`` (the default), equal-timestamp events fire in strict
    FIFO submission order — bit-for-bit the historical behaviour. With an
    integer ``seed``, each event draws a random tie-break key at schedule
    time, so equal-timestamp events fire in a seeded *permutation* of
    submission order: a legal-but-different schedule for the same program.
    ``repro.analysis.schedules.explore`` re-runs a scenario across many
    seeds to hunt order-dependent bugs; ``trace`` records what actually
    fired (for the failure artifact), and re-running with the same seed
    replays the identical schedule.
    """

    def __init__(self, start: float = 0.0, seed: int | None = None,
                 record_trace: bool = False):
        self._now = start
        self._heap: list = []
        self._seq = itertools.count()
        self.seed = seed
        self._rng = None if seed is None else random.Random(seed)
        self.trace: list | None = [] if record_trace else None

    def now(self) -> float:
        return self._now

    def schedule(self, delay: float, fn: Callable, *args) -> Handle:
        h = Handle()
        tie = self._rng.random() if self._rng is not None else 0.0
        heapq.heappush(self._heap, (self._now + max(delay, 0.0), tie,
                                    next(self._seq), fn, args, h))
        return h

    def run(self, until: float | None = None, max_events: int = 10_000_000):
        """Drain events (deterministically) until the heap empties, ``until``
        passes, or ``max_events`` fire. Returns the number of events fired."""
        fired = 0
        trace = self.trace
        while self._heap and fired < max_events:
            t, _, seq, fn, args, h = self._heap[0]
            if until is not None and t > until:
                break
            heapq.heappop(self._heap)
            self._now = max(self._now, t)
            if not h.cancelled:
                if trace is not None:
                    trace.append((seq, round(t, 9),
                                  getattr(fn, "__qualname__",
                                          getattr(fn, "__name__", repr(fn)))))
                fn(*args)
                fired += 1
        if until is not None:
            self._now = max(self._now, until)
        return fired

    def idle(self) -> bool:
        return not self._heap


class RealScheduler:
    """Wall-clock scheduler: timers + a worker pool.

    ``schedule(0, fn)`` submits to the pool immediately; positive delays go
    through a timer thread. ``run`` blocks until quiescent (no pending timers,
    no in-flight work) or ``until`` (relative seconds) elapses.
    """

    def __init__(self, workers: int = 32):
        import concurrent.futures as cf

        self._t0 = time.monotonic()
        self._pool = cf.ThreadPoolExecutor(max_workers=workers)
        self._lock = TrackedLock("RealScheduler._lock")
        self._inflight = 0
        self._quiet = threading.Condition(self._lock)
        self._timers: set = set()

    def now(self) -> float:
        return time.monotonic() - self._t0

    def _done(self):
        with self._lock:
            self._inflight -= 1
            if self._inflight == 0:
                self._quiet.notify_all()

    def _submit(self, fn, args, h: Handle, tok=None):
        def wrapped():
            # the pool thread inherits the submitter's happens-before
            # frontier: everything the submitter did before schedule()
            # is ordered before this event
            racedep.join_point(tok)
            try:
                if not h.cancelled:
                    fn(*args)
            finally:
                self._done()

        try:
            self._pool.submit(wrapped)
        except RuntimeError:  # scheduled after shutdown: drop the event
            self._done()

    def schedule(self, delay: float, fn: Callable, *args) -> Handle:
        h = Handle()
        tok = racedep.fork_point()
        with self._lock:
            self._inflight += 1
        if delay <= 0:
            self._submit(fn, args, h, tok)
        else:
            settled = [False]  # fire/cancel exclusion

            def fire():
                with self._lock:
                    if settled[0]:
                        return
                    settled[0] = True
                    self._timers.discard(t)
                self._submit(fn, args, h, tok)
                self._done()

            def on_cancel():
                # a cancelled timer must release both its slots immediately,
                # or run() blocks until every ack-deadline timer expires
                with self._lock:
                    if settled[0]:
                        return
                    settled[0] = True
                    self._timers.discard(t)
                t.cancel()
                self._done()  # the timer slot
                self._done()  # the (never-run) work slot

            with self._lock:
                self._inflight += 1  # the timer itself
            t = threading.Timer(delay, fire)
            t.daemon = True
            h._on_cancel = on_cancel
            with self._lock:
                self._timers.add(t)
            t.start()
        return h

    def run(self, until: float | None = None, max_events: int = 0):
        deadline = None if until is None else time.monotonic() + until
        with self._quiet:
            while self._inflight > 0:
                timeout = None
                if deadline is not None:
                    timeout = deadline - time.monotonic()
                    if timeout <= 0:
                        break
                self._quiet.wait(timeout=timeout if timeout else 0.25)
        return 0

    def idle(self) -> bool:
        with self._lock:
            return self._inflight == 0

    def shutdown(self):
        for t in list(self._timers):
            t.cancel()
        self._pool.shutdown(wait=False)
