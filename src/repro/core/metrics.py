"""Shared metrics/logging — the paper's "all services log to one location,
monitored through a single dashboard".

Thread-safety (PR 8 lockdep audit): every mutation — ``inc``'s
read-modify-write on the counters dict, ``record``/``log`` appends — runs
under one :class:`TrackedLock`, because pool threads (fleet instances,
subscription settlements, store subscribers) all hit one shared ``Metrics``
concurrently; an unguarded ``counters[name] += v`` loses increments under
that interleaving. Readers either snapshot under the lock
(``timeseries``/``summary``) or go through :meth:`get`, which takes the
lock for the same reason. The lock is a leaf: nothing is called while it
is held, so it can never participate in an ordering cycle.
"""
from __future__ import annotations

import math
from collections import defaultdict

from repro.analysis.lockdep import TrackedLock
from repro.analysis.racedep import tracked_state
from repro.core.clock import monotonic

__all__ = ["Histogram", "Metrics"]


class Histogram:
    """Log-bucketed value histogram: O(1) memory per distinct magnitude.

    Buckets are geometric with ratio ``2**0.25`` (~19% width), so p50/p95/
    p99 come back with bounded relative error while hot paths (per-delivery
    latency, per-request queue wait) stop appending to unbounded ``series``
    lists. Exact count/sum/min/max are kept alongside; percentiles report
    the bucket upper bound clamped into [min, max]. Values ``<= 0`` (sim
    queue waits are often exactly 0.0) land in a dedicated zero bucket.

    Not self-locking: instances live inside ``Metrics.histograms`` and are
    only touched under ``Metrics._lock``.
    """

    __slots__ = ("counts", "n", "total", "lo", "hi", "zeros")
    LOG2_WIDTH = 0.25  # bucket boundaries at 2**(k/4)

    def __init__(self):
        self.counts: dict[int, int] = {}
        self.n = 0
        self.total = 0.0
        self.lo = math.inf
        self.hi = -math.inf
        self.zeros = 0

    def observe(self, value: float):
        self.n += 1
        self.total += value
        if value < self.lo:
            self.lo = value
        if value > self.hi:
            self.hi = value
        if value <= 0.0:
            self.zeros += 1
        else:
            b = math.floor(math.log2(value) / self.LOG2_WIDTH)
            self.counts[b] = self.counts.get(b, 0) + 1

    def percentile(self, q: float) -> float:
        if self.n == 0:
            return 0.0
        rank = q * self.n
        seen = self.zeros
        if seen >= rank:
            return min(self.lo, 0.0)
        for b in sorted(self.counts):
            seen += self.counts[b]
            if seen >= rank:
                upper = 2.0 ** ((b + 1) * self.LOG2_WIDTH)
                return max(self.lo, min(self.hi, upper))
        return self.hi

    def snapshot(self) -> dict:
        if self.n == 0:
            return {"count": 0, "sum": 0.0, "mean": 0.0, "min": 0.0,
                    "max": 0.0, "p50": 0.0, "p95": 0.0, "p99": 0.0}
        return {"count": self.n, "sum": self.total,
                "mean": self.total / self.n, "min": self.lo, "max": self.hi,
                "p50": self.percentile(0.50), "p95": self.percentile(0.95),
                "p99": self.percentile(0.99)}


@tracked_state("counters", "series", "events", "histograms")
class Metrics:
    def __init__(self, scheduler=None):
        self._sched = scheduler
        self._lock = TrackedLock("Metrics._lock")
        self.counters: dict[str, float] = defaultdict(float)
        self.series: dict[str, list[tuple[float, float]]] = defaultdict(list)
        self.events: list[tuple[float, str, dict]] = []
        self.histograms: dict[str, Histogram] = {}

    def _now(self) -> float:
        # real-mode (no scheduler) falls back to the sanctioned monotonic
        # clock — returning 0.0 collapsed every record()/log() timestamp
        return self._sched.now() if self._sched else monotonic()

    def inc(self, name: str, value: float = 1.0):
        with self._lock:
            self.counters[name] += value

    def get(self, name: str, default: float = 0.0) -> float:
        """Read one counter under the lock (no defaultdict insertion)."""
        with self._lock:
            return self.counters.get(name, default)

    def record(self, name: str, value: float):
        """Append a (t, value) sample to a time series."""
        with self._lock:
            self.series[name].append((self._now(), value))

    def log(self, kind: str, **fields):
        with self._lock:
            self.events.append((self._now(), kind, fields))

    def observe(self, name: str, value: float):
        """Fold a sample into the named log-bucket histogram (the bounded
        replacement for hot-path ``record`` series)."""
        with self._lock:
            hist = self.histograms.get(name)
            if hist is None:
                hist = self.histograms[name] = Histogram()
            hist.observe(value)

    def histogram(self, name: str) -> dict:
        """Snapshot (count/sum/mean/min/max/p50/p95/p99) of one histogram."""
        with self._lock:
            hist = self.histograms.get(name)
            return hist.snapshot() if hist is not None else \
                Histogram().snapshot()

    def timeseries(self, name: str) -> list[tuple[float, float]]:
        with self._lock:
            return list(self.series[name])

    def summary(self) -> dict:
        with self._lock:
            return {"counters": dict(self.counters),
                    "series": {k: len(v) for k, v in self.series.items()},
                    "events": len(self.events),
                    "histograms": {k: h.snapshot()
                                   for k, h in self.histograms.items()}}
