"""Shared metrics/logging — the paper's "all services log to one location,
monitored through a single dashboard"."""
from __future__ import annotations

import threading
from collections import defaultdict

__all__ = ["Metrics"]


class Metrics:
    def __init__(self, scheduler=None):
        self._sched = scheduler
        self._lock = threading.Lock()
        self.counters: dict[str, float] = defaultdict(float)
        self.series: dict[str, list[tuple[float, float]]] = defaultdict(list)
        self.events: list[tuple[float, str, dict]] = []

    def _now(self) -> float:
        return self._sched.now() if self._sched else 0.0

    def inc(self, name: str, value: float = 1.0):
        with self._lock:
            self.counters[name] += value

    def record(self, name: str, value: float):
        """Append a (t, value) sample to a time series."""
        with self._lock:
            self.series[name].append((self._now(), value))

    def log(self, kind: str, **fields):
        with self._lock:
            self.events.append((self._now(), kind, fields))

    def timeseries(self, name: str) -> list[tuple[float, float]]:
        with self._lock:
            return list(self.series[name])

    def summary(self) -> dict:
        with self._lock:
            return {"counters": dict(self.counters),
                    "series": {k: len(v) for k, v in self.series.items()},
                    "events": len(self.events)}
