"""Shared metrics/logging — the paper's "all services log to one location,
monitored through a single dashboard".

Thread-safety (PR 8 lockdep audit): every mutation — ``inc``'s
read-modify-write on the counters dict, ``record``/``log`` appends — runs
under one :class:`TrackedLock`, because pool threads (fleet instances,
subscription settlements, store subscribers) all hit one shared ``Metrics``
concurrently; an unguarded ``counters[name] += v`` loses increments under
that interleaving. Readers either snapshot under the lock
(``timeseries``/``summary``) or go through :meth:`get`, which takes the
lock for the same reason. The lock is a leaf: nothing is called while it
is held, so it can never participate in an ordering cycle.
"""
from __future__ import annotations

from collections import defaultdict

from repro.analysis.lockdep import TrackedLock
from repro.analysis.racedep import tracked_state

__all__ = ["Metrics"]


@tracked_state("counters", "series", "events")
class Metrics:
    def __init__(self, scheduler=None):
        self._sched = scheduler
        self._lock = TrackedLock("Metrics._lock")
        self.counters: dict[str, float] = defaultdict(float)
        self.series: dict[str, list[tuple[float, float]]] = defaultdict(list)
        self.events: list[tuple[float, str, dict]] = []

    def _now(self) -> float:
        return self._sched.now() if self._sched else 0.0

    def inc(self, name: str, value: float = 1.0):
        with self._lock:
            self.counters[name] += value

    def get(self, name: str, default: float = 0.0) -> float:
        """Read one counter under the lock (no defaultdict insertion)."""
        with self._lock:
            return self.counters.get(name, default)

    def record(self, name: str, value: float):
        """Append a (t, value) sample to a time series."""
        with self._lock:
            self.series[name].append((self._now(), value))

    def log(self, kind: str, **fields):
        with self._lock:
            self.events.append((self._now(), kind, fields))

    def timeseries(self, name: str) -> list[tuple[float, float]]:
        with self._lock:
            return list(self.series[name])

    def summary(self) -> dict:
        with self._lock:
            return {"counters": dict(self.counters),
                    "series": {k: len(v) for k, v in self.series.items()},
                    "events": len(self.events)}
