"""Span-based distributed tracing for the event spine.

One slide's journey — landing-bucket ``OBJECT_FINALIZE`` → topic publish →
every delivery attempt (retries, hedges, budget-exempt requeues, DLQ) →
fleet admission/queue-wait/steal/kill-requeue → conversion stages → sharded
STOW → validation/inference/export fan-out — lands as ONE span tree, even
across instance kills and duplicate deliveries. Trace context rides
``Message.attributes`` (traceparent-style ``trace_id``/``span_id``): the
publisher injects its span ids into the message, the subscription extracts
them when it creates a delivery span, and everything that runs inside a
delivery or a service handler inherits an *ambient* span via a thread-local
stack, so nested instrumentation parents correctly without threading span
objects through every call signature.

Cost contract (same as lockdep/racedep): the module is DISARMED by default
and every instrumentation entry point bails after a single module-global
read (``_TRACER is None``), so the production fast path pays one load +
branch per site. Arming is explicit — :func:`arm`/:func:`disarm` or the
:class:`capture` context manager (tests, benchmarks, the dashboard smoke
batch, schedule exploration). The fleet benchmark gates the disarmed
overhead at <10% (``tracing_overhead`` in ``BENCH_fleet.json``).

Determinism: span/trace ids come from a per-tracer ``itertools.count`` (no
``random``, no wall-clock ids), and a tracer armed with ``now=sched.now``
under :class:`~repro.core.clock.SimScheduler` produces bit-stable span
timings across runs — schedule-exploration failure artifacts therefore
ship reproducible traces.

Ambient context is intentionally NOT propagated across
``scheduler.schedule`` boundaries (a thread-local can't be trusted across
an event-loop hop); cross-boundary handoff is explicit — the delivery
context carries its span, service requests carry theirs — which is exactly
the places where the trace must survive retries and instance kills.
"""
from __future__ import annotations

import itertools
import threading

from repro.analysis.lockdep import TrackedLock
from repro.core.clock import monotonic

__all__ = [
    "Span", "Tracer", "arm", "disarm", "capture", "current",
    "start_span", "end_span", "add_event", "span", "use_span",
    "current_span", "inject", "extract",
]

# the single module-global read on the disarmed fast path
_TRACER: "Tracer | None" = None

_AMBIENT = threading.local()  # .stack: list[Span] per thread


class Span:
    """One timed operation. ``end is None`` while open; ``events`` is a
    list of ``(t, name, attrs)`` point annotations; ``attrs`` may carry a
    ``hedge_of`` link to the primary delivery's span id."""

    __slots__ = ("trace_id", "span_id", "parent_id", "name",
                 "start", "end", "status", "attrs", "events")

    def __init__(self, trace_id, span_id, parent_id, name, start, attrs):
        self.trace_id = trace_id
        self.span_id = span_id
        self.parent_id = parent_id
        self.name = name
        self.start = start
        self.end: float | None = None
        self.status = "open"
        self.attrs: dict = attrs
        self.events: list[tuple[float, str, dict]] = []

    def duration(self) -> float | None:
        return None if self.end is None else self.end - self.start

    def to_dict(self) -> dict:
        return {
            "trace_id": self.trace_id, "span_id": self.span_id,
            "parent_id": self.parent_id, "name": self.name,
            "start": self.start, "end": self.end, "status": self.status,
            "attrs": dict(self.attrs),
            "events": [{"t": t, "name": n, "attrs": dict(a)}
                       for t, n, a in self.events],
        }

    def __repr__(self):  # pragma: no cover - debugging aid
        return (f"Span({self.name!r}, {self.span_id}, "
                f"parent={self.parent_id}, status={self.status!r})")


class Tracer:
    """Span store. The lock is a leaf (nothing is called while held) —
    safe to take under broker/service locks, same discipline as
    ``Metrics._lock``."""

    def __init__(self, now=None):
        self._now = now if now is not None else monotonic
        self._lock = TrackedLock("Tracer._lock")
        self._ids = itertools.count(1)
        self.spans: list[Span] = []

    def now(self) -> float:
        return self._now()

    # ---- lifecycle -------------------------------------------------------
    def start(self, name: str, *, parent: Span | None = None,
              parent_ctx: tuple[str, str] | None = None,
              attrs: dict | None = None) -> Span:
        t = self._now()
        with self._lock:
            n = next(self._ids)
            sid = f"s{n:05d}"
            if parent is not None:
                trace_id, parent_id = parent.trace_id, parent.span_id
            elif parent_ctx is not None:
                trace_id, parent_id = parent_ctx
            else:
                trace_id, parent_id = f"t{n:05d}", None
            sp = Span(trace_id, sid, parent_id, name, t, attrs or {})
            self.spans.append(sp)
        return sp

    def finish(self, sp: Span, status: str, attrs: dict | None = None):
        t = self._now()
        with self._lock:
            if sp.end is None:  # idempotent: first settlement wins
                sp.end = t
                sp.status = status
            if attrs:
                sp.attrs.update(attrs)

    def event(self, sp: Span, name: str, attrs: dict | None = None):
        t = self._now()
        with self._lock:
            sp.events.append((t, name, attrs or {}))

    # ---- accessors -------------------------------------------------------
    def traces(self) -> dict[str, list[Span]]:
        """Spans grouped by trace id, in creation order."""
        with self._lock:
            spans = list(self.spans)
        out: dict[str, list[Span]] = {}
        for sp in spans:
            out.setdefault(sp.trace_id, []).append(sp)
        return out

    def spans_named(self, name: str) -> list[Span]:
        with self._lock:
            return [sp for sp in self.spans if sp.name == name]

    def export(self) -> list[dict]:
        with self._lock:
            return [sp.to_dict() for sp in self.spans]


# ---- arming --------------------------------------------------------------
def arm(now=None) -> Tracer:
    """Install a fresh tracer; ``now`` overrides the clock (pass
    ``sched.now`` for deterministic sim-time spans)."""
    global _TRACER
    if _TRACER is not None:
        raise RuntimeError("tracing already armed")
    _TRACER = Tracer(now=now)
    return _TRACER


def disarm() -> Tracer | None:
    """Remove the installed tracer and return it (with its spans)."""
    global _TRACER
    tr, _TRACER = _TRACER, None
    return tr


def current() -> Tracer | None:
    return _TRACER


class capture:
    """``with tracing.capture(now=sched.now) as tr:`` — arm a fresh tracer
    for the block, restoring whatever was armed before on exit (exceptions
    propagate; the captured spans stay readable on ``tr``)."""

    def __init__(self, now=None):
        self.tracer = Tracer(now=now)
        self._prev: Tracer | None = None

    def __enter__(self) -> Tracer:
        global _TRACER
        self._prev = _TRACER
        _TRACER = self.tracer
        return self.tracer

    def __exit__(self, *exc):
        global _TRACER
        _TRACER = self._prev
        return False


# ---- ambient span stack --------------------------------------------------
def current_span() -> Span | None:
    if _TRACER is None:
        return None
    st = getattr(_AMBIENT, "stack", None)
    return st[-1] if st else None


class _NullCtx:
    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NULL = _NullCtx()


class _UseCtx:
    __slots__ = ("_span",)

    def __init__(self, sp: Span):
        self._span = sp

    def __enter__(self) -> Span:
        st = getattr(_AMBIENT, "stack", None)
        if st is None:
            st = _AMBIENT.stack = []
        st.append(self._span)
        return self._span

    def __exit__(self, *exc):
        _AMBIENT.stack.pop()
        return False


class _SpanCtx(_UseCtx):
    """Lifecycle + ambient: ends the span on exit, status ``error`` if the
    block raised."""
    __slots__ = ()

    def __exit__(self, etype, exc, tb):
        _AMBIENT.stack.pop()
        tr = _TRACER
        if tr is not None:
            tr.finish(self._span, "error" if etype is not None else "ok",
                      {"error": repr(exc)} if etype is not None else None)
        return False


def use_span(sp: Span | None):
    """Make ``sp`` the ambient parent for the block (no lifecycle)."""
    if _TRACER is None or sp is None:
        return _NULL
    return _UseCtx(sp)


def span(name: str, **attrs):
    """Start a span, make it ambient for the block, end it on exit."""
    tr = _TRACER
    if tr is None:
        return _NULL
    st = getattr(_AMBIENT, "stack", None)
    parent = st[-1] if st else None
    return _SpanCtx(tr.start(name, parent=parent, attrs=attrs))


# ---- instrumentation entry points ---------------------------------------
def start_span(name: str, *, parent: Span | None = None,
               parent_ctx: tuple[str, str] | None = None,
               **attrs) -> Span | None:
    """Open a span. Parent resolution: explicit ``parent`` span, else
    extracted ``parent_ctx`` (from message attributes), else the ambient
    span, else a new trace root."""
    tr = _TRACER
    if tr is None:
        return None
    if parent is None and parent_ctx is None:
        st = getattr(_AMBIENT, "stack", None)
        if st:
            parent = st[-1]
    return tr.start(name, parent=parent, parent_ctx=parent_ctx, attrs=attrs)


def end_span(sp: Span | None, *, status: str = "ok", **attrs):
    tr = _TRACER
    if tr is None or sp is None:
        return
    tr.finish(sp, status, attrs or None)


def add_event(sp: Span | None, name: str, **attrs):
    """Point annotation on ``sp`` (or on the ambient span when ``sp`` is
    None); dropped silently when there is no span to attach to."""
    tr = _TRACER
    if tr is None:
        return
    if sp is None:
        st = getattr(_AMBIENT, "stack", None)
        if not st:
            return
        sp = st[-1]
    tr.event(sp, name, attrs or None)


def inject(attributes: dict, sp: Span | None = None):
    """Write trace context into pub/sub message attributes."""
    tr = _TRACER
    if tr is None:
        return
    if sp is None:
        st = getattr(_AMBIENT, "stack", None)
        if not st:
            return
        sp = st[-1]
    attributes["trace_id"] = sp.trace_id
    attributes["span_id"] = sp.span_id


def extract(attributes: dict | None) -> tuple[str, str] | None:
    """Read trace context from message attributes → ``(trace_id,
    span_id)`` parent ref, or None."""
    if _TRACER is None or not attributes:
        return None
    tid = attributes.get("trace_id")
    sid = attributes.get("span_id")
    if tid is None or sid is None:
        return None
    return (tid, sid)
