"""Object storage with creation notifications and lifecycle tiers.

The bucket is the pipeline's landing zone: every finalized write emits an
``OBJECT_FINALIZE`` notification to the configured pub/sub topic — the
paper's storage→event→topic wiring. Writes are content-addressed
(generation = hash), which makes downstream conversion idempotent: a retried
or hedged conversion writing identical bytes is a no-op, so at-least-once
delivery composes into effectively-once conversion.

Lifecycle rules move objects between STANDARD → NEARLINE → COLDLINE →
ARCHIVE by age (the paper's cost-tiering) without changing their content or
identity.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Callable

from repro.analysis.lockdep import TrackedLock
from repro.core.metrics import Metrics
from repro.core.pubsub import Topic

__all__ = ["ObjectStore", "Bucket", "Object", "LifecycleRule", "CLASSES"]

CLASSES = ("STANDARD", "NEARLINE", "COLDLINE", "ARCHIVE")


@dataclasses.dataclass
class Object:
    key: str
    data: bytes
    generation: str
    created: float
    updated: float
    storage_class: str = "STANDARD"
    metadata: dict = dataclasses.field(default_factory=dict)

    @property
    def size(self) -> int:
        return len(self.data)


@dataclasses.dataclass(frozen=True)
class LifecycleRule:
    age: float  # seconds since creation
    to_class: str


class Bucket:
    def __init__(self, name: str, scheduler, metrics: Metrics):
        self.name = name
        self.scheduler = scheduler
        self.metrics = metrics
        self._objects: dict[str, Object] = {}
        self._lock = TrackedLock(f"Bucket[{name}]._lock")
        # (topic, events, prefix, ordered)
        self._notify: list[tuple[Topic, str, str, bool]] = []
        self.lifecycle: list[LifecycleRule] = []

    # ---- notification config ---------------------------------------------
    def add_notification(self, topic: Topic, event_types: str = "OBJECT_FINALIZE",
                         prefix: str = "", *, ordered: bool = False):
        """``ordered=True`` keys notifications by object key, so successive
        events for the same object (re-uploads racing a slow conversion)
        deliver one-at-a-time in publish order through the broker."""
        self._notify.append((topic, event_types, prefix, ordered))

    def _emit(self, event_type: str, obj: Object):
        payload = {
            "eventType": event_type,
            "bucket": self.name,
            "name": obj.key,
            "generation": obj.generation,
            "size": obj.size,
            "timeCreated": obj.created,
            "storageClass": obj.storage_class,
            "metadata": dict(obj.metadata),
        }
        for topic, types, prefix, ordered in self._notify:
            if event_type in types and obj.key.startswith(prefix):
                topic.publish(payload, attributes={"eventType": event_type},
                              ordering_key=obj.key if ordered else None)

    # ---- object ops --------------------------------------------------------
    def put(self, key: str, data: bytes, metadata: dict | None = None,
            if_generation_match: str | None = None) -> Object:
        gen = hashlib.sha256(data).hexdigest()[:16]
        now = self.scheduler.now()
        with self._lock:
            prev = self._objects.get(key)
            if prev is not None and prev.generation == gen:
                self.metrics.inc(f"bucket.{self.name}.idempotent_skips")
                return prev  # identical content: idempotent, no re-notify
            if if_generation_match is not None and prev is not None \
                    and prev.generation != if_generation_match:
                raise ValueError(f"generation mismatch on {key}")
            obj = Object(key=key, data=data, generation=gen, created=now,
                         updated=now, metadata=metadata or {})
            self._objects[key] = obj
        self.metrics.inc(f"bucket.{self.name}.writes")
        self.metrics.inc(f"bucket.{self.name}.bytes", len(data))
        self._emit("OBJECT_FINALIZE", obj)
        return obj

    def get(self, key: str) -> Object:
        with self._lock:
            obj = self._objects.get(key)
        if obj is None:
            raise KeyError(f"gs://{self.name}/{key} not found")
        self.metrics.inc(f"bucket.{self.name}.reads")
        return obj

    def exists(self, key: str) -> bool:
        with self._lock:
            return key in self._objects

    def delete(self, key: str):
        with self._lock:
            obj = self._objects.pop(key, None)
        if obj is not None:
            self._emit("OBJECT_DELETE", obj)

    def list(self, prefix: str = "") -> list[str]:
        with self._lock:
            return sorted(k for k in self._objects if k.startswith(prefix))

    # ---- lifecycle -----------------------------------------------------------
    def add_lifecycle_rule(self, rule: LifecycleRule):
        assert rule.to_class in CLASSES
        self.lifecycle.append(rule)

    def apply_lifecycle(self):
        """Run lifecycle transitions as of 'now' (cron-style sweep)."""
        now = self.scheduler.now()
        moved = 0
        with self._lock:
            for obj in self._objects.values():
                age = now - obj.created
                target = obj.storage_class
                for rule in sorted(self.lifecycle, key=lambda r: r.age):
                    if age >= rule.age:
                        target = rule.to_class
                if target != obj.storage_class:
                    obj.storage_class = target
                    moved += 1
        if moved:
            self.metrics.inc(f"bucket.{self.name}.lifecycle_moves", moved)
        return moved


class ObjectStore:
    """A project's buckets + shared scheduler/metrics."""

    def __init__(self, scheduler, metrics: Metrics | None = None):
        self.scheduler = scheduler
        self.metrics = metrics or Metrics(scheduler)
        self.buckets: dict[str, Bucket] = {}

    def bucket(self, name: str) -> Bucket:
        if name not in self.buckets:
            self.buckets[name] = Bucket(name, self.scheduler, self.metrics)
        return self.buckets[name]
