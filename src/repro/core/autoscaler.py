"""Cloud-Run-style autoscaling container service.

Models (and in RealScheduler mode, actually executes) the paper's serverless
conversion backend:

* instances scale **0 → max_instances** on demand and back to zero,
* each new instance pays a **cold start** before it can serve,
* an instance handles ``concurrency`` requests at once (paper: 1),
* idle instances stop after ``scale_down_delay`` (Figure 3's slow decay),
* ``min_instances`` keeps warm capacity (the paper's cold-start mitigation,
  with its idle-cost trade-off),
* optional per-instance failure injection for the fault-tolerance tests.

The service exposes ``receive(request, done)`` — the push subscription's
endpoint calls it; ``done(ok)`` fires when the request finishes (the HTTP 200
of the paper). Work is supplied by a ``handler``:

* sim mode — ``handler(request) -> float`` returns the service time and the
  completion is scheduled (deterministic discrete-event execution),
* real mode — ``handler(request) -> None`` does the actual work (e.g. runs
  the JAX WSI→DICOM conversion) and its wall time is the service time.

**Real-mode concurrency**: every accepted request is dispatched to the
scheduler's worker pool, so one instance really does run up to
``concurrency`` handler calls in parallel threads (the converter's heavy
regions — transform dispatch, numpy entropy coding, zlib — release the
GIL). All service state (instance table, request queue, active counts) is
guarded by one re-entrant lock; real-work handlers always run outside it
(sim-mode service-time models are called inline — sim execution is
single-threaded), and ``done`` callbacks are invoked outside it too, so
the pub/sub layer can re-enter ``receive`` without lock-ordering hazards.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Callable

from repro.analysis.lockdep import TrackedLock, check_callback
from repro.core import tracing
from repro.core.metrics import Metrics

__all__ = ["AutoscalingService", "Instance"]

_req_ids = itertools.count(1)


@dataclasses.dataclass
class _Request:
    payload: object
    done: Callable[[bool], None]
    arrived: float
    req_id: int = dataclasses.field(default_factory=lambda: next(_req_ids))
    # trace handoff across scheduler hops: the request span (admission →
    # completion) and the per-serve handler span; thread-local ambience
    # cannot cross the event loop, so requests carry their spans explicitly
    span: object = None
    hspan: object = None


class Instance:
    __slots__ = ("iid", "state", "ready_at", "idle_since", "active", "dead")

    def __init__(self, iid: int, ready_at: float):
        self.iid = iid
        self.state = "starting"  # starting | idle | busy | stopped
        self.ready_at = ready_at
        self.idle_since = ready_at
        self.active = 0
        self.dead = False


# deliberately NOT @tracked_state: queue/instances are service-private —
# every access holds self._lock (receive/_drain/_finish/kill/ticks), so
# tracked accesses could never pair into a race, and the controller scans
# instances.values() thousands of times per run (the disarmed-overhead
# gate in fleet_bench budgets proxying for structures that actually cross
# a lock boundary: pubsub, metrics, pipeline and store maps, and the
# fleet's admission surface)
class AutoscalingService:
    #: Instance subclass to spawn — the fleet overrides this with an
    #: instance type that carries its own local work queue
    instance_cls = Instance

    def __init__(
        self,
        name: str,
        scheduler,
        handler: Callable,
        *,
        max_instances: int = 100,
        min_instances: int = 0,
        concurrency: int = 1,
        cold_start: float = 10.0,
        scale_down_delay: float = 60.0,
        metrics: Metrics | None = None,
        real_work: bool = False,
    ):
        self.name = name
        self.scheduler = scheduler
        self.handler = handler
        self.max_instances = max_instances
        self.min_instances = min_instances
        self.concurrency = concurrency
        self.cold_start = cold_start
        self.scale_down_delay = scale_down_delay
        self.metrics = metrics or Metrics(scheduler)
        self.real_work = real_work
        self.instances: dict[int, Instance] = {}
        self.queue: deque[_Request] = deque()
        self._iid = itertools.count(1)
        self._lock = TrackedLock(f"AutoscalingService[{name}]._lock",
                                 reentrant=True)
        self.cold_starts = 0
        with self._lock:
            for _ in range(min_instances):
                self._start_instance(warm=True)

    # ---- instance lifecycle ------------------------------------------------
    def _start_instance(self, warm: bool = False) -> Instance:
        # lock held
        iid = next(self._iid)
        delay = 0.0 if warm else self.cold_start
        inst = self.instance_cls(iid, self.scheduler.now() + delay)
        self.instances[iid] = inst
        if not warm:
            self.cold_starts += 1
            self.metrics.inc(f"svc.{self.name}.cold_starts")
        self._record_count()
        self.scheduler.schedule(delay, self._instance_ready, inst)
        return inst

    def _instance_ready(self, inst: Instance):
        with self._lock:
            if inst.state != "starting" or inst.dead:
                return
            inst.state = "idle"
            inst.idle_since = self.scheduler.now()
            self._drain()
            self._schedule_scale_down(inst)

    def _schedule_scale_down(self, inst: Instance):
        self.scheduler.schedule(self.scale_down_delay + 1e-9,
                                self._maybe_stop, inst)

    def _maybe_stop(self, inst: Instance):
        with self._lock:
            alive = [i for i in self.instances.values()
                     if i.state in ("starting", "idle", "busy")]
            if (
                inst.state == "idle"
                and self.scheduler.now() - inst.idle_since
                >= self.scale_down_delay
                and len(alive) > self.min_instances
            ):
                inst.state = "stopped"
                del self.instances[inst.iid]
                self.metrics.inc(f"svc.{self.name}.stopped")
                self._record_count()
            elif inst.state == "idle":
                self._schedule_scale_down(inst)

    def kill_instance(self, iid: int | None = None):
        """Fault injection: abruptly kill an instance (in-flight work lost)."""
        with self._lock:
            pool = [i for i in self.instances.values()
                    if i.state != "stopped"]
            if not pool:
                return None
            inst = self.instances.get(iid) if iid else pool[-1]
            if inst is None:
                return None
            self._kill(inst)
            return inst.iid

    def _kill(self, inst: Instance):
        # lock held; overridable — the fleet requeues the victim's queued
        # and in-flight work instead of losing it to the ack deadline
        inst.dead = True
        inst.state = "stopped"
        self.instances.pop(inst.iid, None)
        self.metrics.inc(f"svc.{self.name}.killed")
        self._record_count()

    def _record_count(self):
        self.metrics.record(
            f"svc.{self.name}.instances",
            len([i for i in self.instances.values() if i.state != "stopped"]),
        )

    # ---- request path --------------------------------------------------------
    def receive(self, payload, done: Callable[[bool], None]):
        req = _Request(payload, done, self.scheduler.now())
        # parented on the ambient delivery span (receive runs inside the
        # push endpoint)
        req.span = tracing.start_span(f"svc.{self.name}.request",
                                      req_id=req.req_id)
        self.metrics.inc(f"svc.{self.name}.requests")
        with self._lock:
            self.queue.append(req)
            self._drain()
            self._maybe_scale_up()

    def _maybe_scale_up(self):
        # lock held
        alive = [i for i in self.instances.values() if i.state != "stopped"]
        capacity = sum(
            self.concurrency - i.active for i in alive if not i.dead
        )
        need = len(self.queue) - capacity
        while need > 0 and len(alive) < self.max_instances:
            self._start_instance()
            alive = [i for i in self.instances.values()
                     if i.state != "stopped"]
            need -= self.concurrency

    def _drain(self):
        # lock held
        while self.queue:
            inst = self._pick_idle()
            if inst is None:
                return
            req = self.queue.popleft()
            self._serve(inst, req)

    def _pick_idle(self) -> Instance | None:
        # lock held
        best = None
        for i in self.instances.values():
            if i.state in ("idle", "busy") and not i.dead \
                    and i.active < self.concurrency:
                if best is None or i.active < best.active:
                    best = i
        return best

    def _serve(self, inst: Instance, req: _Request):
        # lock held. A real-work handler never runs here (it goes to the
        # pool via _run_real); the sim-mode handler is a service-time model
        # called inline under the lock, which is safe because sim execution
        # is single-threaded and the model must not call back into the
        # service.
        inst.active += 1
        inst.state = "busy"
        wait = self.scheduler.now() - req.arrived
        # per-request hot path: histogram, not an unbounded series
        self.metrics.observe(f"svc.{self.name}.queue_wait", wait)
        tracing.add_event(req.span, "svc.serve", instance=inst.iid,
                          queue_wait=wait)
        req.hspan = tracing.start_span(f"svc.{self.name}.handle",
                                       parent=req.span, instance=inst.iid)
        if self.real_work:
            # pool thread: up to `concurrency` of these run in parallel
            self.scheduler.schedule(0.0, self._run_real, inst, req)
        else:
            try:
                duration = float(self.handler(req.payload))
            except Exception:
                # sim-mode failure model: the request fails immediately
                # (done(False) → nack → broker retry/DLQ path), mirroring
                # the real-mode _run_real exception path
                self.scheduler.schedule(0.0, self._finish, inst, req, False)
            else:
                self.scheduler.schedule(duration, self._finish, inst, req,
                                        True)

    def _run_real(self, inst: Instance, req: _Request):
        # real-work handlers must run lock-free (PR 2's invariant; the
        # sim-mode service-time model is the one sanctioned exception)
        check_callback(f"svc.{self.name}.handler")
        try:
            # handler runs with the serve span ambient, so conversion-stage
            # spans nest under svc.<name>.handle
            with tracing.use_span(req.hspan):
                self.handler(req.payload)
            ok = True
        except Exception:
            ok = False
        self._finish(inst, req, ok)

    def _finish(self, inst: Instance, req: _Request, ok: bool):
        with self._lock:
            if inst.dead:
                return  # killed mid-flight: no ack → pub/sub redelivers
            inst.active -= 1
            if inst.active == 0:
                inst.state = "idle"
                inst.idle_since = self.scheduler.now()
                self._schedule_scale_down(inst)
            self.metrics.inc(f"svc.{self.name}.completed")
            latency = self.scheduler.now() - req.arrived
            # dual-recorded: the series carries completion *timestamps*
            # (Figure 2/3 read them), the histogram the p50/p95/p99
            self.metrics.record(f"svc.{self.name}.latency", latency)
            self.metrics.observe(f"svc.{self.name}.latency", latency)
        status = "ok" if ok else "error"
        tracing.end_span(req.hspan, status=status)
        tracing.end_span(req.span, status=status)
        # ack/nack outside the lock: it may re-enter receive() via the
        # subscription's redelivery pump
        check_callback(f"svc.{self.name}.done")
        req.done(ok)
        with self._lock:
            self._drain()

    # ---- introspection ---------------------------------------------------------
    def backlog(self) -> int:
        """Requests accepted but not yet being served."""
        with self._lock:
            return len(self.queue)

    def instance_count(self) -> int:
        with self._lock:
            return len([i for i in self.instances.values()
                        if i.state != "stopped"])

    def stats(self) -> dict:
        with self._lock:
            return {
                "instances": self.instance_count(),
                "queued": len(self.queue),
                "cold_starts": self.cold_starts,
            }
