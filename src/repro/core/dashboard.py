"""The paper's "single dashboard": one report for a run's metrics + traces.

``build_report`` turns a :class:`~repro.core.metrics.Metrics` bag and an
optional :class:`~repro.core.tracing.Tracer` into one JSON-serializable
dict — counters, latency-histogram percentiles, fault/chaos tallies, and a
per-slide trace summary with **critical-path attribution**: how much of
each slide's end-to-end time was queue/transit, compute, or store I/O.
``render_text`` prints it for terminals; ``python -m repro.core.dashboard
--smoke`` runs a small instrumented real-conversion batch (faults + an
instance kill included) and writes ``dashboard.json`` plus a sample trace
under ``--out`` — the CI artifact.

Attribution model
-----------------
Spans are categorized by name (``convert.*``/``*.handle`` → compute,
``stow.*``/``export.*``/``pipeline.store``/``pipeline.fetch`` → store,
``*.deliver``/``*.hedge``/``*.request`` → queue). The trace window [first
span start, last span end] is swept over the elementary intervals induced
by categorized span boundaries; each interval is attributed to the
*deepest* covering categorized span (a STOW span inside a service handler
counts as store, not compute), and intervals covered by nothing — retry
backoffs, requeue waits, broker scheduling — fall to queue. The three
buckets therefore sum to the trace duration *exactly*; the benchmark gate
only allows 5% slack for float accumulation.
"""
from __future__ import annotations

import argparse
import json
import os

__all__ = ["build_report", "render_text", "critical_path",
           "trace_summary", "trace_problems"]

_QUEUE_SUFFIXES = (".deliver", ".hedge", ".request")
_STORE_NAMES = ("pipeline.store", "pipeline.fetch")


def _category(name: str) -> str | None:
    if name.startswith("convert.") or name.endswith(".handle") \
            or name == "pipeline.convert":
        return "compute"
    if name.startswith(("stow.", "export.")) or name in _STORE_NAMES:
        return "store"
    if name.endswith(_QUEUE_SUFFIXES):
        return "queue"
    return None  # publish markers and other envelopes: no attribution


def _as_dicts(spans) -> list[dict]:
    return [s if isinstance(s, dict) else s.to_dict() for s in spans]


def _window(spans: list[dict]) -> tuple[float, float]:
    t0 = min(s["start"] for s in spans)
    t1 = max(s["end"] if s["end"] is not None else s["start"] for s in spans)
    return t0, t1


def _depths(spans: list[dict]) -> dict[str, int]:
    by_id = {s["span_id"]: s for s in spans}
    depth: dict[str, int] = {}

    def walk(sid: str) -> int:
        if sid in depth:
            return depth[sid]
        parent = by_id[sid]["parent_id"]
        d = 0 if parent is None or parent not in by_id else walk(parent) + 1
        depth[sid] = d
        return d

    for s in spans:
        walk(s["span_id"])
    return depth


def critical_path(spans) -> dict[str, float]:
    """Queue/compute/store attribution for ONE trace's spans; the buckets
    sum to the trace window exactly (uncovered time → queue)."""
    spans = _as_dicts(spans)
    if not spans:
        return {"queue": 0.0, "compute": 0.0, "store": 0.0}
    t0, t1 = _window(spans)
    depth = _depths(spans)
    cat: list[tuple[float, float, int, str]] = []
    for s in spans:
        c = _category(s["name"])
        if c is None:
            continue
        end = s["end"] if s["end"] is not None else t1
        lo, hi = max(s["start"], t0), min(end, t1)
        if hi > lo:
            cat.append((lo, hi, depth[s["span_id"]], c))
    out = {"queue": 0.0, "compute": 0.0, "store": 0.0}
    bounds = sorted({t0, t1, *(b for lo, hi, _, _ in cat for b in (lo, hi))})
    for lo, hi in zip(bounds, bounds[1:]):
        covering = [(d, c) for slo, shi, d, c in cat
                    if slo <= lo and shi >= hi]
        # deepest categorized span wins; gaps (backoffs, broker
        # scheduling) are wait time
        out[max(covering)[1] if covering else "queue"] += hi - lo
    return out


def trace_problems(spans) -> list[str]:
    """Span-tree integrity check for one trace: exactly one root, every
    parent resolves inside the trace. Empty list == healthy."""
    spans = _as_dicts(spans)
    problems = []
    roots = [s for s in spans if s["parent_id"] is None]
    if len(roots) != 1:
        problems.append(f"{len(roots)} roots (want exactly 1): "
                        f"{[s['name'] for s in roots]}")
    ids = {s["span_id"] for s in spans}
    for s in spans:
        if s["parent_id"] is not None and s["parent_id"] not in ids:
            problems.append(
                f"orphan span {s['name']} ({s['span_id']}): parent "
                f"{s['parent_id']} not in trace")
    return problems


def trace_summary(trace_id: str, spans) -> dict:
    spans = _as_dicts(spans)
    t0, t1 = _window(spans)
    roots = [s for s in spans if s["parent_id"] is None]
    slide = roots[0]["attrs"].get("object") if roots else None
    return {
        "trace_id": trace_id,
        "slide": slide,
        "duration": t1 - t0,
        "n_spans": len(spans),
        "n_events": sum(len(s["events"]) for s in spans),
        "attribution": critical_path(spans),
        "problems": trace_problems(spans),
    }


def _fault_counters(counters: dict) -> dict:
    keep = ("fault_", ".killed", ".requeued", ".requeues", ".shed",
            ".dead_lettered", ".deadline_expired", ".hedged",
            ".duplicates")
    return {k: v for k, v in sorted(counters.items())
            if any(t in k for t in keep) and v}


def build_report(metrics, tracer=None, *, title: str = "run") -> dict:
    summary = metrics.summary()
    report = {
        "title": title,
        "counters": dict(sorted(summary["counters"].items())),
        "histograms": dict(sorted(summary["histograms"].items())),
        "faults": _fault_counters(summary["counters"]),
    }
    if tracer is not None:
        report["traces"] = [trace_summary(tid, spans)
                            for tid, spans in sorted(tracer.traces().items())]
    return report


def _fmt_s(v: float) -> str:
    return f"{v:.3f}s" if v < 100 else f"{v:.1f}s"


def render_text(report: dict) -> str:
    lines = [f"== dashboard: {report['title']} =="]
    hists = report.get("histograms") or {}
    if hists:
        lines.append("-- latency histograms --")
        w = max(len(k) for k in hists)
        for k, h in hists.items():
            lines.append(
                f"  {k:<{w}}  n={h['count']:<6d} p50={_fmt_s(h['p50'])} "
                f"p95={_fmt_s(h['p95'])} p99={_fmt_s(h['p99'])} "
                f"max={_fmt_s(h['max'])}")
    traces = report.get("traces")
    if traces:
        lines.append("-- per-slide critical path (queue / compute / store) --")
        for t in traces:
            a, dur = t["attribution"], t["duration"]
            def pct(x):
                return f"{100.0 * x / dur:.0f}%" if dur else "-"
            lines.append(
                f"  {t['slide'] or t['trace_id']:<24} "
                f"total={_fmt_s(dur)}  "
                f"queue={_fmt_s(a['queue'])} ({pct(a['queue'])})  "
                f"compute={_fmt_s(a['compute'])} ({pct(a['compute'])})  "
                f"store={_fmt_s(a['store'])} ({pct(a['store'])})  "
                f"spans={t['n_spans']}")
            for p in t["problems"]:
                lines.append(f"    !! {p}")
    faults = report.get("faults") or {}
    if faults:
        lines.append("-- injected chaos / failure handling --")
        w = max(len(k) for k in faults)
        for k, v in faults.items():
            lines.append(f"  {k:<{w}}  {v:g}")
    counters = report.get("counters") or {}
    lines.append(f"-- counters ({len(counters)}) --")
    w = max((len(k) for k in counters), default=0)
    for k, v in counters.items():
        lines.append(f"  {k:<{w}}  {v:g}")
    return "\n".join(lines)


# ---- the instrumented smoke batch (CI artifact) ---------------------------
def _smoke(out_dir: str, n_slides: int, side: int) -> dict:
    # lazy imports: simulation-only users of repro.core never pay for jax
    import hashlib

    from repro.core import tracing
    from repro.core.clock import RealScheduler
    from repro.core.pipeline import ConversionPipeline
    from repro.core.pubsub import DeliveryFaults
    from repro.wsi import SyntheticScanner
    from repro.wsi.convert import ConvertOptions, convert_wsi_to_dicom

    def convert(data, meta):
        h = hashlib.sha256(meta["slide_id"].encode()).hexdigest()
        uids = ["2.25." + str(int(h[:24], 16)),
                "2.25." + str(int(h[24:48], 16))]
        opt = ConvertOptions(manifest={"uids": json.dumps(uids)})
        return convert_wsi_to_dicom(data, meta, options=opt)

    scanner = SyntheticScanner(seed=7)
    slides = {f"scans/s{i}.psv": scanner.scan(side, side, 256)
              for i in range(n_slides)}
    meta = {k: {"slide_id": k} for k in slides}
    # real-execution chaos: a dropped first delivery (redelivers on ack
    # deadline) plus a duplicated one (dedupes at fleet admission)
    faults = (DeliveryFaults()
              .drop("s0", attempts=(1,))
              .duplicate("s1", lag=0.1))
    sched = RealScheduler()
    try:
        with tracing.capture(now=sched.now) as tracer:
            pipe = ConversionPipeline(
                sched, convert=convert, cold_start=0.05, max_instances=4,
                ack_deadline=3.0, min_backoff=0.2, fleet={},
                ordered_ingest=True, store_shards=2, auto_export=True,
                delivery_faults=faults)
            sched.schedule(0.2, pipe.service.kill_instance)
            pipe.run_batch(slides, meta, timeout=180.0)
            sched.run(until=60.0)  # drain the store/validate/export fan-out
    finally:
        sched.shutdown()

    report = build_report(pipe.metrics, tracer, title="smoke batch")
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "dashboard.json"), "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    # sample trace: the full span tree of the first slide's journey
    first = sorted(tracer.traces().items())[0]
    with open(os.path.join(out_dir, "trace-sample.json"), "w") as f:
        json.dump({"trace_id": first[0],
                   "spans": [s.to_dict() for s in first[1]]},
                  f, indent=2, sort_keys=True)
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="run the instrumented smoke batch")
    ap.add_argument("--out", default="artifacts",
                    help="artifact directory (dashboard.json, "
                         "trace-sample.json)")
    ap.add_argument("--slides", type=int, default=2)
    ap.add_argument("--side", type=int, default=256)
    args = ap.parse_args(argv)
    if not args.smoke:
        ap.error("nothing to do (pass --smoke)")
    report = _smoke(args.out, args.slides, args.side)
    print(render_text(report))
    problems = [p for t in report["traces"] for p in t["problems"]]
    if problems:
        print(f"TRACE INTEGRITY FAILED: {problems}")
        return 1
    print(f"\nwrote {args.out}/dashboard.json and "
          f"{args.out}/trace-sample.json")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
