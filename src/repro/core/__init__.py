"""The paper's primary contribution: event-driven cloud infrastructure.

Object storage with creation notifications, a topic-based pub/sub broker with
at-least-once push delivery (ack deadlines, retries, DLQ, hedging), a
Cloud-Run-style autoscaling worker service (0→N→0, cold starts, concurrency),
and the Figure-1 conversion pipeline wiring — all runnable deterministically
under a discrete-event scheduler or on real threads.

Observability rides the same spine: :mod:`repro.core.tracing` threads one
span tree per slide through every pub/sub, fleet, conversion, and store
hop (disarmed by default, one global read per instrumentation point);
:mod:`repro.core.metrics` adds log-bucketed latency histograms; and
:mod:`repro.core.dashboard` folds both into the single report.
"""
from repro.core import dashboard, tracing  # noqa: F401
from repro.core.autoscaler import AutoscalingService  # noqa: F401
from repro.core.clock import RealScheduler, SimScheduler  # noqa: F401
from repro.core.fleet import ConverterFleet  # noqa: F401
from repro.core.metrics import Metrics  # noqa: F401
from repro.core.pipeline import ConversionPipeline  # noqa: F401
from repro.core.pubsub import (DeliveryCtx, DeliveryFaults, Message,  # noqa: F401
                               Subscription, Topic)
from repro.core.storage import Bucket, LifecycleRule, Object, ObjectStore  # noqa: F401
