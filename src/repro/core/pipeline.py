"""The paper's Figure-1 wiring: landing bucket → notification → pub/sub topic
→ push subscription → autoscaling conversion service → DICOM store →
downstream subscribers (validation, ML inference).

``ConversionPipeline`` assembles the microservices; the actual per-image work
is injected (`convert` callable for real execution, `service_time` model for
discrete-event simulation), so the same wiring backs:

* the real end-to-end example (synthetic SVS slides through the JAX converter
  into DICOM Part-10 bytes in the DICOM-store bucket),
* the Figure 2/3 simulations at institutional scale,
* the fault-tolerance tests (killed workers, redelivery, idempotent writes).

In real mode (``convert`` supplied + ``RealScheduler``) the service executes
up to ``concurrency`` conversions per instance **in parallel** on the
scheduler's worker pool — the converter is thread-safe and its heavy host
stages release the GIL — so a multi-slide batch overlaps downloads,
transform dispatches, and entropy coding across slides.

The Figure-1 final arrow is event-driven like the first one: the converted
study tar's ``OBJECT_FINALIZE`` in the dicom bucket pushes an ingest
subscription that unpacks the archive into the enterprise
``DicomStoreService`` (idempotent STOW under canonical instance keys),
whose own ``dicom-instance-stored`` topic fans out to the attached
validation and mock ML-inference subscribers.

The pipeline's third hop runs the other direction — retrieval: an
``export-request`` topic (its own push subscription + DLQ, symmetric with
ingestion) drives the ``ExportService``, which reads a stored study back
through QIDO/WADO and writes a deterministic tiled-TIFF pyramid into the
``derived`` bucket, where existing open-source analysis tooling (or this
very pipeline's TIFF sniffer, full circle) can consume it. Requests come
from ``request_export()`` or, with ``auto_export=True``, from every
``dicom-instance-stored`` event.
"""
from __future__ import annotations

import hashlib
import threading
from collections import Counter
from typing import Callable

from repro.analysis.lockdep import TrackedLock
from repro.analysis.racedep import tracked_state
from repro.core import clock, tracing
from repro.core.autoscaler import AutoscalingService
from repro.core.fleet import ConverterFleet
from repro.core.metrics import Metrics
from repro.core.pubsub import DeliveryCtx, Message, Subscription, Topic
from repro.core.storage import LifecycleRule, ObjectStore

__all__ = ["ConversionPipeline", "derive_out_key"]


def derive_out_key(key: str) -> str:
    """Landing key → DICOM-store study key, stripping only a trailing
    extension of the *basename* — dots in directory components
    (``scans.v1/slide``) and extensionless or dotfile basenames survive
    unmangled. Note ``a.svs`` and ``a.tiff`` still map to the same base
    key; ``ConversionPipeline._work`` disambiguates such collisions with a
    per-source suffix."""
    head, _, base = key.rpartition("/")
    stem = base.rsplit(".", 1)[0] or base
    return f"{head}/{stem}.dcm" if head else f"{stem}.dcm"


@tracked_state("converted", "_conversions", "_errors", "dead_lettered",
               "_out_claims", "export_dead_lettered")
class ConversionPipeline:
    def __init__(
        self,
        scheduler,
        *,
        convert: Callable[[bytes, dict], bytes] | None = None,
        service_time: Callable[[dict], float] | float = 60.0,
        max_instances: int = 100,
        min_instances: int = 0,
        concurrency: int = 1,
        cold_start: float = 10.0,
        scale_down_delay: float = 120.0,
        ack_deadline: float = 600.0,
        max_delivery_attempts: int = 5,
        min_backoff: float = 10.0,
        max_backoff: float = 600.0,
        hedge_after: float | None = None,
        landing_bucket: str = "wsi-landing",
        dicom_bucket: str = "dicom-store",
        instance_bucket: str = "dicom-instances",
        quarantine_bucket: str = "dicom-dlq",
        derived_bucket: str = "wsi-derived",
        subscribers: bool = True,
        auto_export: bool = False,
        lifecycle_cold_after: float = 30 * 24 * 3600.0,
        lifecycle_archive_after: float = 365 * 24 * 3600.0,
        fleet: dict | None = None,
        store_shards: int = 1,
        ordered_ingest: bool = False,
        delivery_faults=None,
    ):
        self.scheduler = scheduler
        self.metrics = Metrics(scheduler)
        self.store = ObjectStore(scheduler, self.metrics)
        self.convert = convert
        self.service_time = service_time

        # --- storage & ingestion service --------------------------------
        self.landing = self.store.bucket(landing_bucket)
        self.dicom = self.store.bucket(dicom_bucket)
        self.landing.add_lifecycle_rule(
            LifecycleRule(lifecycle_cold_after, "COLDLINE"))
        self.landing.add_lifecycle_rule(
            LifecycleRule(lifecycle_archive_after, "ARCHIVE"))

        # --- pub/sub messaging service -----------------------------------
        self.topic = Topic("wsi-dicom-conversion", scheduler, self.metrics)
        self.dlq = Topic("wsi-dicom-conversion-dlq", scheduler, self.metrics)
        self.landing.add_notification(self.topic, "OBJECT_FINALIZE",
                                      ordered=ordered_ingest)

        # --- containerized conversion web application ---------------------
        # `fleet` switches the backend from the single AutoscalingService to
        # the multi-instance ConverterFleet (per-instance queues, controller
        # scaling, tenant fairness, load shedding); its dict carries the
        # fleet-only knobs (instance_queue_depth, tenant_quota, shed_*, ...)
        common = dict(
            max_instances=max_instances, min_instances=min_instances,
            concurrency=concurrency, cold_start=cold_start,
            scale_down_delay=scale_down_delay, metrics=self.metrics,
            real_work=convert is not None,
        )
        if fleet is not None:
            self.service = ConverterFleet(
                "wsi2dcm", scheduler, self._work,
                dlq_depth=lambda: len(self.dead_lettered),
                **common, **fleet)
        else:
            self.service = AutoscalingService(
                "wsi2dcm", scheduler, self._work, **common)
        self.subscription = Subscription(
            self.topic, "wsi2dcm-push", self._endpoint,
            ack_deadline=ack_deadline,
            max_delivery_attempts=max_delivery_attempts,
            min_backoff=min_backoff, max_backoff=max_backoff,
            hedge_after=hedge_after, dlq=self.dlq,
            faults=delivery_faults,
        )
        self.converted: list[str] = []
        self._conversions: list[tuple[str, str]] = []  # (source, out key)
        self._converted_lock = TrackedLock("ConversionPipeline._converted_lock")
        # wakes run_batch on every conversion or dead-letter (no busy-poll)
        self._batch_cond = threading.Condition(self._converted_lock)
        self._errors: dict[str, str] = {}  # source key -> last failure
        self.dead_lettered: list[tuple[dict, str]] = []  # (event, dlq_reason)
        # serializes out-key claims
        self._out_lock = TrackedLock("ConversionPipeline._out_lock")
        self._out_claims: dict[str, str] = {}  # out key -> source key
        # permanent-failure visibility: a sink on the conversion DLQ records
        # the poisoned event + reason so run_batch can fail fast instead of
        # spinning out its timeout
        self.dlq_sink = Subscription(self.dlq, "wsi2dcm-dlq-sink",
                                     self._dlq_endpoint)

        # --- enterprise DICOM store + downstream subscribers ----------------
        # (the Figure-1 final arrow, itself event-driven: study tar lands in
        # the dicom bucket → OBJECT_FINALIZE → ingest subscription → STOW →
        # instance-stored topic → validation / ML fan-out)
        from repro.wsi.store_service import (DicomStoreService,
                                             ShardedDicomStore)

        if store_shards > 1:
            # study-UID-hash sharding across bucket partitions; the shards
            # share one dicom-instance-stored topic so downstream
            # subscribers attach exactly as they do to a single store
            self.instances = None
            self.store_service = ShardedDicomStore(
                self.store, scheduler, self.metrics,
                n_shards=store_shards, bucket_prefix=instance_bucket)
        else:
            self.instances = self.store.bucket(instance_bucket)
            self.store_service = DicomStoreService(
                self.instances, scheduler, self.metrics)
        self.store_topic = Topic("dicom-study-finalize", scheduler,
                                 self.metrics)
        self.store_dlq = Topic("dicom-store-ingest-dlq", scheduler,
                               self.metrics)
        self.dicom.add_notification(self.store_topic, "OBJECT_FINALIZE")
        self.store_subscription = Subscription(
            self.store_topic, "dicom-store-ingest", self._store_endpoint,
            ack_deadline=ack_deadline,
            max_delivery_attempts=max_delivery_attempts, dlq=self.store_dlq,
        )
        self.validator = self.ml_subscriber = None
        if subscribers:
            from repro.wsi.subscribers import (InferenceSubscriber,
                                               ValidationService)

            self.quarantine = self.store.bucket(quarantine_bucket)
            self.validator = ValidationService(self.store_service,
                                               self.quarantine)
            self.ml_subscriber = InferenceSubscriber(self.store_service)

        # --- export / retrieval hop (study → derived tiled-TIFF pyramid) ---
        # the third event-driven hop, symmetric with ingestion: its own
        # request topic, push subscription, and DLQ (with a sink recording
        # dead-lettered exports + the pipeline.export.dead_lettered metric)
        from repro.wsi.export import ExportService

        self.derived = self.store.bucket(derived_bucket)
        self.export_topic = Topic("export-request", scheduler, self.metrics)
        self.export_dlq = Topic("export-request-dlq", scheduler,
                                self.metrics)
        self.export_service = ExportService(
            self.store_service, self.derived,
            request_topic=self.export_topic, dlq=self.export_dlq,
            ack_deadline=ack_deadline,
            max_delivery_attempts=max_delivery_attempts,
            min_backoff=min_backoff, max_backoff=max_backoff)
        self.export_dead_lettered: list[tuple[dict, str]] = []
        self.export_dlq_sink = Subscription(
            self.export_dlq, "dicom2tiff-dlq-sink", self._export_dlq_endpoint)
        self.auto_export_subscription = None
        if auto_export:
            self.auto_export_subscription = Subscription(
                self.store_service.topic, "auto-export-trigger",
                self._auto_export_endpoint)

    # ---- subscription push endpoint → service --------------------------
    def _endpoint(self, msg: Message, ctx: DeliveryCtx):
        def done(ok):
            if ok is True:
                ctx.ack()
                return
            if ok == "shed":
                # backpressure, not failure: a budget-exempt nack requeues
                # after min_backoff without consuming a delivery attempt,
                # so shed work can never dead-letter
                ctx.nack("load shed: converter fleet at capacity",
                         consume_budget=False)
                return
            with self._converted_lock:
                reason = self._errors.get(msg.data.get("name"),
                                          "conversion failed")
            ctx.nack(reason)

        self.service.receive(msg.data, done)

    # ---- conversion DLQ sink ---------------------------------------------
    def _dlq_endpoint(self, msg: Message, ctx: DeliveryCtx):
        with self._batch_cond:
            self.dead_lettered.append(
                (msg.data, msg.attributes.get("dlq_reason", "")))
            # the failure is now settled: drop the recorded error so a
            # later re-ingest of the same key can't report a stale reason
            self._errors.pop(msg.data.get("name"), None)
            self._batch_cond.notify_all()
        ctx.ack()

    # ---- export hop -----------------------------------------------------
    def request_export(self, study_uid: str) -> Message:
        """Ask the export service for a derived tiled-TIFF pyramid."""
        return self.export_topic.publish({"study_uid": study_uid})

    def _auto_export_endpoint(self, msg: Message, ctx: DeliveryCtx):
        # every stored instance re-requests its study's export; the export
        # is deterministic and the derived bucket content-addressed, so the
        # extra requests collapse into idempotent no-ops
        self.request_export(msg.data["study_uid"])
        ctx.ack()

    def _export_dlq_endpoint(self, msg: Message, ctx: DeliveryCtx):
        with self._converted_lock:
            self.export_dead_lettered.append(
                (msg.data, msg.attributes.get("dlq_reason", "")))
        self.metrics.inc("pipeline.export.dead_lettered")
        ctx.ack()

    # ---- dicom bucket → enterprise store ingest -------------------------
    def _store_endpoint(self, msg: Message, ctx: DeliveryCtx):
        try:
            archive = self.dicom.get(msg.data["name"]).data
            self.store_service.store_study_archive(msg.data["name"], archive)
        except Exception as exc:  # corrupt archive / racing delete → DLQ path
            ctx.nack(f"store ingest failed: {exc}")
        else:
            ctx.ack()

    # ---- the worker ------------------------------------------------------
    def _store_study(self, source_key: str, generation: str,
                     dcm_bytes: bytes) -> str:
        """Write a converted study under a collision-safe output key.

        The base key strips only the basename's trailing extension
        (``derive_out_key``), so distinct sources that share a stem
        (``a.svs`` vs ``a.tiff``) contend for the same base key. The first
        source keeps it; any other source gets a stable per-source suffix.
        A redelivered or re-uploaded source always maps back to its own
        key (idempotent re-conversion), never onto another source's study.
        Claims are recorded in an in-memory map under a short lock — only
        the decision is serialized; the (expensive, content-hashing,
        notification-fanning) bucket put runs outside it.
        """
        base = out_key = derive_out_key(source_key)
        with self._out_lock:
            owner = self._out_claims.get(base)
            if owner is None and self.dicom.exists(base):
                # pre-existing study from before this process claimed it
                owner = self.dicom.get(base).metadata.get("source_key")
            if owner not in (None, source_key):
                self.metrics.inc("pipeline.out_key_collisions")
                digest = hashlib.sha256(source_key.encode()).hexdigest()[:8]
                out_key = f"{base[:-len('.dcm')]}-{digest}.dcm"
            self._out_claims[out_key] = source_key
        self.dicom.put(out_key, dcm_bytes,
                       metadata={"source_generation": generation,
                                 "source_key": source_key})
        return out_key

    def _work(self, event: dict):
        if self.convert is None:  # simulation: return the service time
            st = self.service_time
            return st(event) if callable(st) else float(st)
        # real mode: download → sniff → convert → upload (idempotent,
        # content-addressed). One deployment serves a mixed landing bucket:
        # the container format is resolved from the object's magic bytes
        # (never the key), so .psv/.tiff/.svs slides all route through the
        # same converter; unknown containers fail with the actionable sniff
        # error, which becomes the nack reason and, after the retry budget,
        # the dead-letter's dlq_reason.
        # imported lazily (like the store service) so simulation-only use of
        # repro.core never pays the repro.wsi/jax import
        from repro.wsi.formats import sniff

        try:
            with tracing.span("pipeline.fetch", key=event["name"]):
                obj = self.landing.get(event["name"])
                fmt = sniff(obj.data)
            self.metrics.inc(f"pipeline.format.{fmt}")
            meta = dict(obj.metadata)
            meta.setdefault("format", fmt)
            with tracing.span("pipeline.convert", key=event["name"],
                              format=fmt):
                dcm_bytes = self.convert(obj.data, meta)
        except Exception as exc:
            with self._converted_lock:
                self._errors[event["name"]] = \
                    f"{type(exc).__name__}: {exc}"
            raise
        with tracing.span("pipeline.store", key=event["name"]):
            out_key = self._store_study(event["name"], obj.generation,
                                        dcm_bytes)
        with self._batch_cond:
            self._errors.pop(event["name"], None)
            self.converted.append(out_key)
            self._conversions.append((event["name"], out_key))
            self._batch_cond.notify_all()
        return None

    # ---- ingestion --------------------------------------------------------
    def ingest(self, key: str, data: bytes, metadata: dict | None = None):
        """A scanner drops a slide into the landing zone."""
        return self.landing.put(key, data, metadata)

    def run_batch(self, slides: dict[str, bytes],
                  metadata: dict[str, dict] | None = None, *,
                  timeout: float = 600.0) -> dict[str, bytes]:
        """Real-mode batch driver: ingest every slide, wait for the studies.

        Blocks (wall clock — use with ``RealScheduler``) until every
        slide's study tar is durably in the DICOM store, then returns
        ``{landing key: study tar bytes}``. Completion is judged by
        *successful* conversions recorded per source key
        (``self._conversions``), not the service's completion metric,
        which also counts failed attempts that the subscription will
        still redeliver. The wait is a condition variable signalled by
        every finished conversion and every dead-letter — no busy-poll.

        Fails fast on permanent failures: the moment a batch slide is
        dead-lettered (retry budget exhausted), raises ``RuntimeError``
        carrying the ``dlq_reason`` instead of spinning out the timeout.
        Raises ``ValueError`` up front if two batch inputs derive the
        same output key (``a.svs`` + ``a.tiff``), and ``TimeoutError``
        if the batch does not finish within ``timeout`` seconds.
        """
        dupes = sorted(k for k, n in
                       Counter(map(derive_out_key, slides)).items() if n > 1)
        if dupes:
            raise ValueError(
                "batch inputs collide on output keys "
                f"{dupes} — rename the conflicting slides")
        # only conversions / dead-letters recorded after this call started
        # count, so a reused pipeline can't satisfy a new batch with stale
        # studies (or fail it on an old batch's poison slide)
        with self._converted_lock:
            start = len(self._conversions)
            dead_start = len(self.dead_lettered)
        for key, data in slides.items():
            meta = (metadata or {}).get(key, {"slide_id": key})
            self.ingest(key, data, meta)
        deadline = clock.monotonic() + timeout
        with self._batch_cond:
            while True:
                done = dict(self._conversions[start:])
                if all(k in done for k in slides):
                    break
                for event, reason in self.dead_lettered[dead_start:]:
                    if event.get("name") in slides:
                        raise RuntimeError(
                            f"slide {event['name']!r} dead-lettered: "
                            f"{reason}")
                remaining = deadline - clock.monotonic()
                if remaining <= 0:
                    raise TimeoutError(
                        f"batch conversion incomplete after {timeout}s "
                        f"({len(set(done) & set(slides))}/{len(slides)} "
                        "studies stored)")
                self._batch_cond.wait(timeout=remaining)
        return {k: self.dicom.get(done[k]).data for k in slides}

    # ---- reporting -------------------------------------------------------
    def instance_series(self):
        return self.metrics.timeseries("svc.wsi2dcm.instances")

    def done_count(self) -> int:
        return int(self.metrics.get("svc.wsi2dcm.completed"))
