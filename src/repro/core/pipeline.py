"""The paper's Figure-1 wiring: landing bucket → notification → pub/sub topic
→ push subscription → autoscaling conversion service → DICOM store.

``ConversionPipeline`` assembles the microservices; the actual per-image work
is injected (`convert` callable for real execution, `service_time` model for
discrete-event simulation), so the same wiring backs:

* the real end-to-end example (synthetic SVS slides through the JAX converter
  into DICOM Part-10 bytes in the DICOM-store bucket),
* the Figure 2/3 simulations at institutional scale,
* the fault-tolerance tests (killed workers, redelivery, idempotent writes).

In real mode (``convert`` supplied + ``RealScheduler``) the service executes
up to ``concurrency`` conversions per instance **in parallel** on the
scheduler's worker pool — the converter is thread-safe and its heavy host
stages release the GIL — so a multi-slide batch overlaps downloads,
transform dispatches, and entropy coding across slides.
"""
from __future__ import annotations

import threading
import time
from typing import Callable

from repro.core.autoscaler import AutoscalingService
from repro.core.metrics import Metrics
from repro.core.pubsub import DeliveryCtx, Message, Subscription, Topic
from repro.core.storage import LifecycleRule, ObjectStore

__all__ = ["ConversionPipeline"]


class ConversionPipeline:
    def __init__(
        self,
        scheduler,
        *,
        convert: Callable[[bytes, dict], bytes] | None = None,
        service_time: Callable[[dict], float] | float = 60.0,
        max_instances: int = 100,
        min_instances: int = 0,
        concurrency: int = 1,
        cold_start: float = 10.0,
        scale_down_delay: float = 120.0,
        ack_deadline: float = 600.0,
        max_delivery_attempts: int = 5,
        hedge_after: float | None = None,
        landing_bucket: str = "wsi-landing",
        dicom_bucket: str = "dicom-store",
        lifecycle_cold_after: float = 30 * 24 * 3600.0,
        lifecycle_archive_after: float = 365 * 24 * 3600.0,
    ):
        self.scheduler = scheduler
        self.metrics = Metrics(scheduler)
        self.store = ObjectStore(scheduler, self.metrics)
        self.convert = convert
        self.service_time = service_time

        # --- storage & ingestion service --------------------------------
        self.landing = self.store.bucket(landing_bucket)
        self.dicom = self.store.bucket(dicom_bucket)
        self.landing.add_lifecycle_rule(
            LifecycleRule(lifecycle_cold_after, "COLDLINE"))
        self.landing.add_lifecycle_rule(
            LifecycleRule(lifecycle_archive_after, "ARCHIVE"))

        # --- pub/sub messaging service -----------------------------------
        self.topic = Topic("wsi-dicom-conversion", scheduler, self.metrics)
        self.dlq = Topic("wsi-dicom-conversion-dlq", scheduler, self.metrics)
        self.landing.add_notification(self.topic, "OBJECT_FINALIZE")

        # --- containerized conversion web application ---------------------
        self.service = AutoscalingService(
            "wsi2dcm", scheduler, self._work,
            max_instances=max_instances, min_instances=min_instances,
            concurrency=concurrency, cold_start=cold_start,
            scale_down_delay=scale_down_delay, metrics=self.metrics,
            real_work=convert is not None,
        )
        self.subscription = Subscription(
            self.topic, "wsi2dcm-push", self._endpoint,
            ack_deadline=ack_deadline,
            max_delivery_attempts=max_delivery_attempts,
            hedge_after=hedge_after, dlq=self.dlq,
        )
        self.converted: list[str] = []
        self._converted_lock = threading.Lock()

    # ---- subscription push endpoint → service --------------------------
    def _endpoint(self, msg: Message, ctx: DeliveryCtx):
        self.service.receive(msg.data, lambda ok: ctx.ack() if ok else
                             ctx.nack("conversion failed"))

    # ---- the worker ------------------------------------------------------
    def _work(self, event: dict):
        if self.convert is None:  # simulation: return the service time
            st = self.service_time
            return st(event) if callable(st) else float(st)
        # real mode: download → convert → upload (idempotent, content-addressed)
        obj = self.landing.get(event["name"])
        dcm_bytes = self.convert(obj.data, dict(obj.metadata))
        out_key = event["name"].rsplit(".", 1)[0] + ".dcm"
        self.dicom.put(out_key, dcm_bytes,
                       metadata={"source_generation": obj.generation})
        with self._converted_lock:
            self.converted.append(out_key)
        return None

    # ---- ingestion --------------------------------------------------------
    def ingest(self, key: str, data: bytes, metadata: dict | None = None):
        """A scanner drops a slide into the landing zone."""
        return self.landing.put(key, data, metadata)

    def run_batch(self, slides: dict[str, bytes],
                  metadata: dict[str, dict] | None = None, *,
                  timeout: float = 600.0,
                  poll: float = 0.002) -> dict[str, bytes]:
        """Real-mode batch driver: ingest every slide, wait for the studies.

        Blocks (wall clock — use with ``RealScheduler``) until every
        slide's study tar is durably in the DICOM store, then returns
        ``{landing key: study tar bytes}``. Completion is judged by
        *successful* conversions (``self.converted``), not the service's
        completion metric, which also counts failed attempts that the
        subscription will still redeliver. Raises ``TimeoutError`` if the
        batch does not finish within ``timeout`` seconds.
        """
        out_keys = {k: k.rsplit(".", 1)[0] + ".dcm" for k in slides}
        # only conversions recorded after this call started count, so a
        # reused pipeline can't satisfy a new batch with stale studies
        with self._converted_lock:
            start = len(self.converted)
        for key, data in slides.items():
            meta = (metadata or {}).get(key, {"slide_id": key})
            self.ingest(key, data, meta)
        done: set[str] = set()
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            with self._converted_lock:
                done = set(self.converted[start:])
            if all(v in done for v in out_keys.values()):
                return {k: self.dicom.get(v).data
                        for k, v in out_keys.items()}
            time.sleep(poll)
        raise TimeoutError(
            f"batch conversion incomplete after {timeout}s "
            f"({len(done & set(out_keys.values()))}/{len(out_keys)} "
            "studies stored)")

    # ---- reporting -------------------------------------------------------
    def instance_series(self):
        return self.metrics.timeseries("svc.wsi2dcm.instances")

    def done_count(self) -> int:
        return int(self.metrics.counters.get("svc.wsi2dcm.completed", 0))
