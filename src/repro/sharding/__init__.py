"""Logical-axis sharding policy.

One greedy, divisibility-aware policy maps logical axis names to mesh axes for
*both* parameters and activations:

* ``batch``  → ``('pod','data')`` (hierarchical data parallel)
* ``vocab`` / ``mlp`` / ``tp`` / ``heads`` → ``'model'`` (tensor parallel)
* ``kvseq`` → ``'model'`` (context-parallel KV caches for decode)
* ``embed`` → ``'data'`` (FSDP / ZeRO-3 weight sharding — only claims 'data'
  when no batch dim already did, so the same rule serves weights and
  activations)
* ``seq`` / ``head_dim`` → ``'model'`` *fallbacks*, used when a tensor has no
  dim that can claim the model axis (e.g. gemma's 8 q-heads on a 16-way model
  axis fall back to sequence sharding for activations and head_dim sharding
  for weights).

Each mesh axis is claimed at most once per tensor and only when it divides the
dim size, so every arch in the zoo lowers on the same production mesh without
per-arch special cases.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.params import ParamDef, tree_defs

__all__ = [
    "spec_for",
    "param_specs",
    "named_sharding",
    "set_mesh",
    "current_mesh",
    "constrain",
    "batch_axes",
]

# logical axis -> ordered candidate mesh-axis tuples
CANDIDATES: dict[str, list[tuple[str, ...]]] = {
    "batch": [("pod", "data"), ("data",)],
    "vocab": [("model",)],
    "mlp": [("model",)],
    "tp": [("model",)],
    "heads": [("model",)],
    "kvseq": [("model",)],
    "embed": [("data",)],
    "seq": [("model",)],
    "head_dim": [("model",)],
}

# greedy claim order; earlier wins a contested mesh axis
PRIORITY = [
    "batch",
    "vocab",
    "mlp",
    "tp",
    "heads",
    "kvseq",
    "embed",
    "seq",
    "head_dim",
]

_MESH: contextvars.ContextVar[Mesh | None] = contextvars.ContextVar(
    "repro_mesh", default=None
)


def current_mesh() -> Mesh | None:
    return _MESH.get()


@contextlib.contextmanager
def set_mesh(mesh: Mesh | None):
    tok = _MESH.set(mesh)
    try:
        yield mesh
    finally:
        _MESH.reset(tok)


def _axis_sizes(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    """Mesh axes the global batch is sharded over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def spec_for(
    shape: Sequence[int], logical: Sequence[str], mesh: Mesh,
    policy: str = "train",
) -> P:
    """Resolve one tensor's logical axes to a PartitionSpec.

    ``policy="serve_replicated"`` drops the 'embed'→data FSDP rule: at decode
    the batch dim already owns 'data', so embed-sharded weights force a
    per-token weight all-gather. Replicating weights across 'data' (keeping
    TP over 'model') removes that collective entirely — used whenever the
    TP-sharded weights fit the HBM budget (weight-stationary serving).
    """
    sizes = _axis_sizes(mesh)
    assigned: dict[int, tuple[str, ...]] = {}
    used: set[str] = set()
    order = sorted(
        range(len(shape)),
        key=lambda i: PRIORITY.index(logical[i]) if logical[i] in PRIORITY else 99,
    )
    for i in order:
        name = logical[i]
        if policy == "serve_replicated" and name == "embed":
            continue
        for cand in CANDIDATES.get(name, []):
            axes = tuple(a for a in cand if a in sizes)
            if not axes or any(a in used for a in axes):
                continue
            total = 1
            for a in axes:
                total *= sizes[a]
            if total > 1 and shape[i] % total == 0:
                assigned[i] = axes
                used.update(axes)
                break
    parts = []
    for i in range(len(shape)):
        ax = assigned.get(i)
        parts.append(ax if ax and len(ax) > 1 else (ax[0] if ax else None))
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def named_sharding(shape, logical, mesh: Mesh, policy: str = "train") -> NamedSharding:
    return NamedSharding(mesh, spec_for(shape, logical, mesh, policy))


def param_specs(defs, mesh: Mesh, policy: str = "train"):
    """NamedSharding tree mirroring a ParamDef tree."""
    return jax.tree_util.tree_map(
        lambda d: named_sharding(d.shape, d.logical, mesh, policy),
        defs,
        is_leaf=lambda x: isinstance(x, ParamDef),
    )


def constrain(x, *logical: str):
    """with_sharding_constraint by logical axes; no-op outside a mesh context."""
    mesh = current_mesh()
    if mesh is None:
        return x
    if len(logical) != x.ndim:
        raise ValueError(f"rank mismatch: {logical} vs {x.shape}")
    return jax.lax.with_sharding_constraint(
        x, named_sharding(x.shape, logical, mesh)
    )
