"""Fused RWKV6 wkv chunk kernel (Pallas TPU).

The roofline analysis (EXPERIMENTS.md §Roofline) showed the pure-XLA chunked
wkv materializing its O(q²·K) intra-chunk decay products in HBM — on TPU the
whole chunk update fits VMEM. This kernel fuses one chunk's worth of the
Finch recurrence per grid step:

  grid = (B, H, S/Q) with the chunk axis sequential ("arbitrary"): the
  (K, V) recurrent state lives in a VMEM scratch that persists across the
  chunk axis; each step loads (Q, K) r/k/v/logw tiles, computes the
  boundary-factored intra-chunk + carried-state terms entirely in registers/
  VMEM, writes the (Q, K) output tile, and updates the state in place.

Math is identical to ``repro.models.rwkv6.wkv_chunked`` (same stability
construction: every cross-position decay is exp(Δ) with Δ ≤ 0); the oracle
is ``wkv_sequential``. Validated in interpret mode on CPU.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["wkv_chunk_pallas"]


def _kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, state_ref, *,
            sub: int, nc: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[0, :, 0, :].astype(jnp.float32)  # (Q, K)
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    lw = lw_ref[0, :, 0, :].astype(jnp.float32)
    u = u_ref[0, :]  # (K,)
    state = state_ref[...]  # (K, V)

    Q, K = r.shape
    ns = Q // sub
    L = jnp.cumsum(lw, axis=0)  # inclusive log decay
    Lex = L - lw
    Lend = L[-1]

    # inter-chunk: carried state
    out = (r * jnp.exp(Lex)) @ state  # (Q, V)

    # cross-sub-block, boundary factored (all exponents <= 0)
    Lb = jnp.concatenate(
        [jnp.zeros((1, K), jnp.float32), L[sub - 1 :: sub][: ns - 1]], axis=0
    )  # (ns, K)
    rg = r.reshape(ns, sub, K)
    Lexg = Lex.reshape(ns, sub, K)
    r2 = rg * jnp.exp(jnp.minimum(Lexg - Lb[:, None], 0.0))
    k2 = k[None] * jnp.exp(jnp.minimum(Lb[:, None] - L[None], 0.0))  # (ns,Q,K)
    smask = jax.lax.broadcasted_iota(jnp.int32, (ns, Q), 1) < (
        jax.lax.broadcasted_iota(jnp.int32, (ns, Q), 0) * sub
    )
    att_x = jnp.einsum("jtk,jsk->jts", r2, k2,
                       preferred_element_type=jnp.float32)
    att_x = att_x * smask[:, None, :]
    out = out + jnp.einsum("jts,sv->jtv", att_x, v,
                           preferred_element_type=jnp.float32).reshape(Q, K)

    # diagonal sub-blocks: exact log-space difference
    kg = k.reshape(ns, sub, K)
    vg = v.reshape(ns, sub, K)
    Lg = L.reshape(ns, sub, K)
    ldiff = jnp.minimum(Lexg[:, :, None] - Lg[:, None], 0.0)  # (ns,t,s,K)
    tri = (jax.lax.broadcasted_iota(jnp.int32, (sub, sub), 0)
           > jax.lax.broadcasted_iota(jnp.int32, (sub, sub), 1))
    att_d = jnp.einsum("jtk,jsk,jtsk->jts", rg, kg,
                       jnp.where(tri[None, :, :, None], jnp.exp(ldiff), 0.0),
                       preferred_element_type=jnp.float32)
    out_d = jnp.einsum("jts,jsv->jtv", att_d, vg,
                       preferred_element_type=jnp.float32)
    out_u = (rg * u[None, None] * kg).sum(-1, keepdims=True) * vg
    out = out + (out_d + out_u).reshape(Q, K)

    # state update
    kdec = k * jnp.exp(jnp.minimum(Lend[None] - L, 0.0))
    state_ref[...] = state * jnp.exp(Lend)[:, None] + kdec.T @ v
    o_ref[0, :, 0, :] = out


def wkv_chunk_pallas(r, k, v, logw, u, *, chunk: int = 64, sub: int = 16,
                     interpret: bool = True):
    """Fused chunked wkv. r/k/v/logw: (B, S, H, K) fp32; u: (H, K).

    S % chunk == 0. Returns out (B, S, H, K) fp32 (zero initial state).
    """
    B, S, H, K = r.shape
    assert S % chunk == 0 and chunk % sub == 0, (S, chunk, sub)
    nc = S // chunk
    grid = (B, H, nc)
    spec = pl.BlockSpec((1, chunk, 1, K), lambda b, h, c: (b, c, h, 0))
    u_spec = pl.BlockSpec((1, K), lambda b, h, c: (h, 0))
    return pl.pallas_call(
        partial(_kernel, sub=sub, nc=nc),
        grid=grid,
        in_specs=[spec, spec, spec, spec, u_spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((B, S, H, K), jnp.float32),
        scratch_shapes=[pltpu.VMEM((K, K), jnp.float32)],
        interpret=interpret,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ) if not interpret else None,
    )(r, k, v, logw, u)
