"""Pallas TPU kernels for the conversion hot spots (+ jnp oracles).

``<name>.py`` holds the ``pl.pallas_call`` + BlockSpec tiling, ``ops.py`` the
jit'd public wrappers, ``ref.py`` the pure-jnp ground truth.
"""
from repro.kernels.ops import (  # noqa: F401
    dct8x8_quant,
    downsample2x2,
    idct8x8_dequant,
    jpeg_inverse,
    jpeg_transform,
    rgb2ycbcr,
)
from repro.kernels.wkv_chunk import wkv_chunk_pallas  # noqa: F401
