"""Fused RGB→YCbCr + JPEG level shift as a Pallas TPU kernel.

Purely elementwise across the channel dim → VPU work. Blocks are
(3, 8, 128)-shaped VMEM tiles (8×128 = one VREG tile per channel); the grid
walks the (H/8, W/128) plane. The three output planes are produced in one
pass over the input — the fusion the CPU converter gets from SIMD loops.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import ycbcr_polynomials

__all__ = ["rgb2ycbcr_pallas"]

_BH, _BW = 8, 128


def _kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)  # (3, BH, BW)
    y, cb, cr = ycbcr_polynomials(x[0], x[1], x[2])
    o_ref[0, :, :] = y
    o_ref[1, :, :] = cb
    o_ref[2, :, :] = cr


def rgb2ycbcr_pallas(img, *, interpret: bool = True):
    """img: (3, H, W) uint8/float, H % 8 == 0, W % 128 == 0 → (3, H, W) f32."""
    C, H, W = img.shape
    assert C == 3 and H % _BH == 0 and W % _BW == 0, img.shape
    grid = (H // _BH, W // _BW)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((3, _BH, _BW), lambda i, j: (0, i, j))],
        out_specs=pl.BlockSpec((3, _BH, _BW), lambda i, j: (0, i, j)),
        out_shape=jax.ShapeDtypeStruct((3, H, W), jnp.float32),
        interpret=interpret,
    )(img)
