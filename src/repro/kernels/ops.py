"""Public jit'd wrappers over the Pallas kernels.

On the CPU container the kernels run in ``interpret=True`` (the kernel body
executes in Python, validating the BlockSpec tiling); on a real TPU set
``REPRO_PALLAS_COMPILE=1`` to lower them natively. ``impl="ref"`` falls back
to the pure-jnp oracles (used for differential testing and odd shapes).
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.dct8x8_quant import dct8x8_quant_pallas
from repro.kernels.downsample2x2 import downsample2x2_pallas
from repro.kernels.rgb2ycbcr import rgb2ycbcr_pallas

__all__ = ["rgb2ycbcr", "downsample2x2", "dct8x8_quant", "idct8x8_dequant"]


def _interpret() -> bool:
    return os.environ.get("REPRO_PALLAS_COMPILE", "0") != "1"


def _aligned(n: int, m: int) -> bool:
    return n % m == 0


@partial(jax.jit, static_argnames=("impl",))
def rgb2ycbcr(img, impl: str = "auto"):
    """(3, H, W) → (3, H, W) f32 level-shifted YCbCr."""
    if impl == "ref" or (impl == "auto" and not (
            _aligned(img.shape[1], 8) and _aligned(img.shape[2], 128))):
        return ref.rgb2ycbcr_ref(img)
    return rgb2ycbcr_pallas(img, interpret=_interpret())


@partial(jax.jit, static_argnames=("impl",))
def downsample2x2(img, impl: str = "auto"):
    """(C, H, W) → (C, H//2, W//2) f32 box-filtered."""
    if impl == "ref" or (impl == "auto" and not (
            _aligned(img.shape[1], 16) and _aligned(img.shape[2], 256))):
        return ref.downsample2x2_ref(img)
    return downsample2x2_pallas(img, interpret=_interpret())


@partial(jax.jit, static_argnames=("impl",))
def dct8x8_quant(plane, qtable, impl: str = "auto"):
    """(H, W) f32 → (H, W) i32 quantized DCT coefficients."""
    if impl == "ref" or (impl == "auto" and not (
            _aligned(plane.shape[0], 8) and _aligned(plane.shape[1], 128))):
        return ref.dct8x8_quant_ref(plane, qtable)
    return dct8x8_quant_pallas(plane, qtable, interpret=_interpret())


@jax.jit
def idct8x8_dequant(coef, qtable):
    """Decoder-side inverse (jnp only; used by tests and the JPEG decoder)."""
    return ref.idct8x8_dequant_ref(coef, qtable)
