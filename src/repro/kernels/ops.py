"""Public jit'd wrappers over the Pallas kernels.

``impl`` selects the backend per call:

- ``"auto"`` (default) — the fastest *correct* implementation for this
  environment: on a real TPU (``REPRO_PALLAS_COMPILE=1``) the Pallas kernel
  lowered natively; otherwise the pure-jnp oracle. Unaligned shapes always
  fall back to the oracle.
- ``"ref"`` — the pure-jnp oracle, unconditionally.
- ``"pallas"`` — the Pallas kernel, unconditionally; in this environment
  that means ``interpret=True`` (the kernel body executes in Python,
  validating the BlockSpec tiling). Used by the differential tests.

Interpret mode is a correctness harness, not an execution path — it is
orders of magnitude slower than the oracle and must never be what ``auto``
picks. Keeping every ``auto`` caller on one backend per environment also
preserves the byte-identity contract between the batched and per-tile JPEG
paths (DESIGN.md, "Bit-exactness contract"): expression-identical float
math compiled through *different* machinery (plain XLA vs the interpreter)
can differ in the last ULP and flip a round-at-half quantization.
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.dct8x8_quant import dct8x8_quant_pallas
from repro.kernels.downsample2x2 import downsample2x2_pallas
from repro.kernels.jpeg_inverse import jpeg_inverse_pallas
from repro.kernels.jpeg_transform import jpeg_transform_pallas
from repro.kernels.rgb2ycbcr import rgb2ycbcr_pallas

__all__ = ["rgb2ycbcr", "downsample2x2", "dct8x8_quant", "idct8x8_dequant",
           "jpeg_transform", "jpeg_inverse"]


def _interpret() -> bool:
    return os.environ.get("REPRO_PALLAS_COMPILE", "0") != "1"


def _aligned(n: int, m: int) -> bool:
    return n % m == 0


def _dispatch(impl: str, aligned: bool, pallas_fn, ref_fn):
    """The shared impl policy (see module docstring)."""
    if impl not in ("auto", "ref", "pallas"):
        raise ValueError(f"impl must be 'auto', 'ref' or 'pallas': {impl!r}")
    if impl == "pallas":
        return pallas_fn(interpret=_interpret())
    if impl == "ref" or not aligned or _interpret():
        return ref_fn()
    return pallas_fn(interpret=False)


@partial(jax.jit, static_argnames=("impl",))
def rgb2ycbcr(img, impl: str = "auto"):
    """(3, H, W) → (3, H, W) f32 level-shifted YCbCr."""
    return _dispatch(
        impl, _aligned(img.shape[1], 8) and _aligned(img.shape[2], 128),
        partial(rgb2ycbcr_pallas, img),
        lambda: ref.rgb2ycbcr_ref(img))


@partial(jax.jit, static_argnames=("impl",))
def downsample2x2(img, impl: str = "auto"):
    """(C, H, W) → (C, H//2, W//2) f32 box-filtered."""
    return _dispatch(
        impl, _aligned(img.shape[1], 16) and _aligned(img.shape[2], 256),
        partial(downsample2x2_pallas, img),
        lambda: ref.downsample2x2_ref(img))


@partial(jax.jit, static_argnames=("impl",))
def dct8x8_quant(plane, qtable, impl: str = "auto"):
    """(H, W) f32 → (H, W) i32 quantized DCT coefficients."""
    return _dispatch(
        impl, _aligned(plane.shape[0], 8) and _aligned(plane.shape[1], 128),
        partial(dct8x8_quant_pallas, plane, qtable),
        lambda: ref.dct8x8_quant_ref(plane, qtable))


@partial(jax.jit, static_argnames=("impl",))
def jpeg_transform(tiles, qluma=None, qchroma=None, impl: str = "auto"):
    """(N, 3, T, T) RGB tiles → (N, 3, T, T) i32 quantized YCbCr DCT coefs.

    The whole-level batched dispatch: one kernel launch transform-codes every
    tile of a pyramid level (fused rgb2ycbcr + per-channel dct8x8_quant).
    """
    qluma = jnp.asarray(ref.JPEG_LUMA_Q) if qluma is None else qluma
    qchroma = jnp.asarray(ref.JPEG_CHROMA_Q) if qchroma is None else qchroma
    return _dispatch(
        impl, _aligned(tiles.shape[2], 8) and _aligned(tiles.shape[3], 128),
        partial(jpeg_transform_pallas, tiles, qluma, qchroma),
        lambda: ref.jpeg_transform_ref(tiles, qluma, qchroma))


@partial(jax.jit, static_argnames=("impl",))
def jpeg_inverse(coef, qluma=None, qchroma=None, impl: str = "auto"):
    """(N, 3, T, T) i32 quantized YCbCr DCT coefs → (N, 3, T, T) u8 RGB.

    The whole-level batched inverse dispatch: one kernel launch
    decode-transforms every tile of a stored pyramid level (fused dequant +
    per-channel iDCT + YCbCr→RGB + round/clip) — the device half of the
    export path's JPEG decoder.
    """
    qluma = jnp.asarray(ref.JPEG_LUMA_Q) if qluma is None else qluma
    qchroma = jnp.asarray(ref.JPEG_CHROMA_Q) if qchroma is None else qchroma
    return _dispatch(
        impl, _aligned(coef.shape[2], 8) and _aligned(coef.shape[3], 128),
        lambda **kw: jpeg_inverse_pallas(
            coef, qluma, qchroma, **kw).astype(jnp.uint8),
        lambda: ref.jpeg_inverse_ref(coef, qluma, qchroma))


@jax.jit
def idct8x8_dequant(coef, qtable):
    """Decoder-side inverse (jnp only; used by tests and the JPEG decoder)."""
    return ref.idct8x8_dequant_ref(coef, qtable)
