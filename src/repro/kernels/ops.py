"""Public wrappers over the Pallas kernels: impl dispatch, batch sharding,
size-bucketed jit.

``impl`` selects the backend per call:

- ``"auto"`` (default) — the fastest *correct* implementation for this
  environment: on a real TPU (``REPRO_PALLAS_COMPILE=1``) the Pallas kernel
  lowered natively; otherwise the pure-jnp oracle. Non-lane-aligned shapes
  run the kernel through an explicit pad-to-aligned + slice path on TPU and
  fall back to the oracle on CPU.
- ``"ref"`` — the pure-jnp oracle, unconditionally.
- ``"pallas"`` — the Pallas kernel, unconditionally; in this environment
  that means ``interpret=True`` (the kernel body executes in Python,
  validating the BlockSpec tiling). Unaligned shapes take the padded path
  (pad + kernel + slice), used by the ragged-shape differential tests.

Interpret mode is a correctness harness, not an execution path — it is
orders of magnitude slower than the oracle and must never be what ``auto``
picks. Keeping every ``auto`` caller on one backend per environment also
preserves the byte-identity contract between the batched and per-tile JPEG
paths (DESIGN.md, "Bit-exactness contract"): expression-identical float
math compiled through *different* machinery (plain XLA vs the interpreter)
can differ in the last ULP and flip a round-at-half quantization.

**Mesh sharding + bucketing** (DESIGN.md, "Kernel roofline & sharding"):
the whole-level batched kernels ``jpeg_transform``/``jpeg_inverse`` carry
an (N, 3, T, T) batch whose leading dimension is embarrassingly parallel —
every tile's transform is independent. Calls from op-by-op (non-traced)
code pad N up to the next power of two (so the jit cache holds a handful
of bucketed executables instead of one per level geometry — the
small-batch recompile fix), lay the batch out over the ambient mesh's
``data`` axis with ``jax.sharding.NamedSharding``, and slice the result
back; calls from inside an enclosing trace (the fused pyramid chain in
``wsi/convert.py``) keep their static shapes and get a
``with_sharding_constraint`` instead. Pad tiles are all-zero and sliced
away, and the per-tile math is batch-size independent (asserted by tests),
so sharded, bucketed and single-device dispatches all produce bit-identical
tiles. The ambient mesh defaults to ``make_local_mesh()`` over every
visible device; ``use_mesh`` scopes an explicit one.
"""
from __future__ import annotations

import os
import threading
from contextlib import contextmanager
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.kernels import ref
from repro.kernels.dct8x8_quant import dct8x8_quant_pallas
from repro.kernels.downsample2x2 import downsample2x2_pallas
from repro.kernels.jpeg_inverse import jpeg_inverse_pallas
from repro.kernels.jpeg_transform import jpeg_transform_pallas
from repro.kernels.rgb2ycbcr import rgb2ycbcr_pallas

__all__ = ["rgb2ycbcr", "downsample2x2", "dct8x8_quant", "idct8x8_dequant",
           "jpeg_transform", "jpeg_inverse", "default_mesh", "use_mesh",
           "data_sharding"]


def _interpret() -> bool:
    return os.environ.get("REPRO_PALLAS_COMPILE", "0") != "1"


def _aligned(n: int, m: int) -> bool:
    return n % m == 0


# --------------------------------------------------------------------------
# mesh context: which devices whole-level batches are laid out over
# --------------------------------------------------------------------------
_MESH_TLS = threading.local()


def default_mesh():
    """The ambient mesh for whole-level batch sharding.

    Defaults (per thread, built lazily so importing this module never
    touches jax device state) to ``make_local_mesh()`` — every visible
    device on a ``("data",)`` axis. On the single-device CPU container
    that is a 1-device mesh and sharding degenerates to replication;
    under ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (the
    multi-device tests) or on a real slice, level batches split N ways.
    """
    mesh = getattr(_MESH_TLS, "mesh", None)
    if mesh is None:
        from repro.launch.mesh import make_local_mesh
        mesh = make_local_mesh()
        _MESH_TLS.mesh = mesh
    return mesh


@contextmanager
def use_mesh(mesh):
    """Scope the ambient mesh (thread-local) for batched kernel dispatch."""
    prev = getattr(_MESH_TLS, "mesh", None)
    _MESH_TLS.mesh = mesh
    try:
        yield mesh
    finally:
        _MESH_TLS.mesh = prev


def data_sharding(n: int, mesh=None) -> NamedSharding:
    """Sharding for a leading batch of ``n``: split over ``data`` when it
    divides evenly, replicated otherwise (a level batch that does not
    divide must still produce identical bytes, just without the speedup)."""
    mesh = default_mesh() if mesh is None else mesh
    ndev = int(mesh.devices.size)
    spec = P("data") if ndev > 1 and n > 0 and n % ndev == 0 else P()
    return NamedSharding(mesh, spec)


def _bucket(n: int) -> int:
    """Smallest power of two ≥ n — the jit-cache key for level batch sizes,
    so arbitrary pyramid geometries reuse a handful of executables."""
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def _batched_call(x, core, *args):
    """Shared batch policy for the (N, 3, T, T) kernels.

    Traced operands (the fused pyramid chain) keep their static shape and
    get a sharding constraint; concrete operands are bucket-padded to the
    next power of two, laid out over the mesh's data axis, dispatched, and
    sliced back. Pad tiles are zeros; per-tile math is batch-independent
    (tested), so the sliced result is bit-identical to the unpadded call.
    """
    if isinstance(x, jax.core.Tracer):
        n = x.shape[0]
        sh = data_sharding(n)
        if sh.spec:  # only constrain when actually split over devices
            x = jax.lax.with_sharding_constraint(x, sh)
        return core(x, *args)
    x = jnp.asarray(x)
    n = x.shape[0]
    if n == 0:
        return core(x, *args)
    nb = _bucket(n)
    if nb != n:
        x = jnp.concatenate(
            [x, jnp.zeros((nb - n,) + x.shape[1:], x.dtype)])
    x = jax.device_put(x, data_sharding(nb))
    out = core(x, *args)
    return out[:n] if nb != n else out


def _dispatch(impl: str, aligned: bool, pallas_fn, ref_fn, padded_fn=None):
    """The shared impl policy (see module docstring)."""
    if impl not in ("auto", "ref", "pallas"):
        raise ValueError(f"impl must be 'auto', 'ref' or 'pallas': {impl!r}")
    if impl == "pallas":
        if aligned or padded_fn is None:
            return pallas_fn(interpret=_interpret())
        return padded_fn(interpret=_interpret())
    if impl == "ref" or _interpret():
        return ref_fn()
    if aligned:
        return pallas_fn(interpret=False)
    if padded_fn is None:
        return ref_fn()
    return padded_fn(interpret=False)


def _pad_hw(x, mh: int, mw: int):
    """Zero-pad the two trailing axes up to (mh, mw) multiples."""
    H, W = x.shape[-2], x.shape[-1]
    ph, pw = -H % mh, -W % mw
    cfg = [(0, 0)] * (x.ndim - 2) + [(0, ph), (0, pw)]
    return jnp.pad(x, cfg)


@partial(jax.jit, static_argnames=("impl",))
def rgb2ycbcr(img, impl: str = "auto"):
    """(3, H, W) → (3, H, W) f32 level-shifted YCbCr."""
    H, W = img.shape[1], img.shape[2]
    return _dispatch(
        impl, _aligned(H, 8) and _aligned(W, 128),
        partial(rgb2ycbcr_pallas, img),
        lambda: ref.rgb2ycbcr_ref(img),
        # elementwise → padding is invisible to the retained region
        lambda **kw: rgb2ycbcr_pallas(_pad_hw(img, 8, 128),
                                      **kw)[:, :H, :W])


@partial(jax.jit, static_argnames=("impl",))
def downsample2x2(img, impl: str = "auto"):
    """(C, H, W) → (C, H//2, W//2) f32 box-filtered."""
    H, W = img.shape[1], img.shape[2]
    return _dispatch(
        impl, _aligned(H, 16) and _aligned(W, 256),
        partial(downsample2x2_pallas, img),
        lambda: ref.downsample2x2_ref(img),
        # 2×2 boxes are independent; the pad only fills boxes sliced away
        lambda **kw: downsample2x2_pallas(_pad_hw(img, 16, 256),
                                          **kw)[:, :H // 2, :W // 2])


@partial(jax.jit, static_argnames=("impl",))
def dct8x8_quant(plane, qtable, impl: str = "auto"):
    """(H, W) f32 → (H, W) i32 quantized DCT coefficients."""
    H, W = plane.shape
    return _dispatch(
        impl, _aligned(H, 8) and _aligned(W, 128),
        partial(dct8x8_quant_pallas, plane, qtable),
        lambda: ref.dct8x8_quant_ref(plane, qtable),
        # 8×8 blocks are independent; padding adds all-zero blocks only
        lambda **kw: dct8x8_quant_pallas(_pad_hw(plane, 8, 128), qtable,
                                         **kw)[:H, :W])


@partial(jax.jit, static_argnames=("impl",))
def _jpeg_transform_core(tiles, qluma, qchroma, impl: str = "auto"):
    H, W = tiles.shape[2], tiles.shape[3]
    return _dispatch(
        impl, _aligned(H, 8) and _aligned(W, 128),
        partial(jpeg_transform_pallas, tiles, qluma, qchroma),
        lambda: ref.jpeg_transform_ref(tiles, qluma, qchroma),
        lambda **kw: jpeg_transform_pallas(_pad_hw(tiles, 8, 128), qluma,
                                           qchroma, **kw)[:, :, :H, :W])


@partial(jax.jit, static_argnames=("impl",))
def _jpeg_inverse_core(coef, qluma, qchroma, impl: str = "auto"):
    H, W = coef.shape[2], coef.shape[3]
    return _dispatch(
        impl, _aligned(H, 8) and _aligned(W, 128),
        lambda **kw: jpeg_inverse_pallas(
            coef, qluma, qchroma, **kw).astype(jnp.uint8),
        lambda: ref.jpeg_inverse_ref(coef, qluma, qchroma),
        lambda **kw: jpeg_inverse_pallas(
            _pad_hw(coef, 8, 128), qluma, qchroma,
            **kw).astype(jnp.uint8)[:, :, :H, :W])


def jpeg_transform(tiles, qluma=None, qchroma=None, impl: str = "auto"):
    """(N, 3, T, T) RGB tiles → (N, 3, T, T) i32 quantized YCbCr DCT coefs.

    The whole-level batched dispatch: one kernel launch transform-codes
    every tile of a pyramid level (fused rgb2ycbcr + per-channel
    dct8x8_quant). The batch dimension is bucket-padded to a power of two
    and laid out over the ambient mesh's ``data`` axis (see module
    docstring) — bit-identical to the unsharded, unpadded call.
    """
    qluma = jnp.asarray(ref.JPEG_LUMA_Q) if qluma is None else qluma
    qchroma = jnp.asarray(ref.JPEG_CHROMA_Q) if qchroma is None else qchroma
    return _batched_call(
        tiles, lambda x, ql, qc: _jpeg_transform_core(x, ql, qc, impl),
        qluma, qchroma)


def jpeg_inverse(coef, qluma=None, qchroma=None, impl: str = "auto"):
    """(N, 3, T, T) i32 quantized YCbCr DCT coefs → (N, 3, T, T) u8 RGB.

    The whole-level batched inverse dispatch: one kernel launch
    decode-transforms every tile of a stored pyramid level (fused dequant +
    per-channel iDCT + YCbCr→RGB + round/clip) — the device half of the
    export path's JPEG decoder. Bucketed and mesh-sharded exactly like
    :func:`jpeg_transform`.
    """
    qluma = jnp.asarray(ref.JPEG_LUMA_Q) if qluma is None else qluma
    qchroma = jnp.asarray(ref.JPEG_CHROMA_Q) if qchroma is None else qchroma
    return _batched_call(
        coef, lambda x, ql, qc: _jpeg_inverse_core(x, ql, qc, impl),
        qluma, qchroma)


@jax.jit
def idct8x8_dequant(coef, qtable):
    """Decoder-side inverse (jnp only; used by tests and the JPEG decoder)."""
    return ref.idct8x8_dequant_ref(coef, qtable)
