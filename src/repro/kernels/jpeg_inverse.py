"""Whole-level fused inverse JPEG transform (dequant→iDCT→YCbCr→RGB) kernel.

The exact mirror of ``jpeg_transform.py``: one ``pallas_call`` inverts an
entire pyramid level — the input is an ``(N, 3, T, T)`` batch of int32
quantized YCbCr DCT coefficients (blocks in place, as the forward kernel
and the entropy decoder emit them) and the output the ``(N, 3, T, T)``
int32 RGB samples in [0, 255] — the whole device side of the JPEG decoder
in a single dispatch. This is the compute spine of the export subsystem
(DICOM study → tiled TIFF): decoding a stored level is one entropy-decode
pass on the host plus this one dispatch, versus 3 iDCT dispatches + a host
color conversion per tile on the per-tile path.

Grid: ``(N, T/8, T/128)``. Each step loads one (1, 3, 8, 128) VMEM block —
an 8×128 strip of all three coefficient channels of one tile (16 DCT
blocks side by side) — multiplies by the per-channel quantization tables
(riding along as a single resident (3, 8, 128) operand, exactly as in the
forward kernel), runs the batched 8×8 inverse DCT contractions on the MXU,
then applies the YCbCr→RGB polynomials + level unshift on the VPU and
rounds/clips to [0, 255].

Bit-exactness contract: the inverse contraction lives in
``ref.idct_dequant_blocks`` and the color polynomials in
``ref.ycbcr_inverse_polynomials`` — a single copy each, shared between
this kernel body and the jnp oracle (the contraction is two chained
fixed-order dots precisely so the association order cannot drift between
operand shapes), so the fused path produces the same RGB samples and the
batched and per-tile JPEG decode paths emit pixel-identical tiles.

The output is int32, not uint8: 8-bit outputs would need (32, 128)-tiled
blocks on real hardware, and the public wrapper (``ops.jpeg_inverse``)
casts to uint8 outside the kernel either way.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import (dct_matrix, idct_dequant_blocks,
                               ycbcr_inverse_polynomials)

__all__ = ["jpeg_inverse_pallas"]

_BH, _BW = 8, 128
_NB = _BW // 8  # DCT blocks per VMEM strip


def _kernel(c_ref, q_ref, dctm_ref, o_ref):
    C = dctm_ref[:, :8]  # the host-built DCT matrix (see ref docstring)
    planes = []
    for ci in range(3):
        xb = c_ref[0, ci].reshape(8, _NB, 8).transpose(1, 0, 2)  # (16, 8, 8)
        y = idct_dequant_blocks(
            xb, q_ref[ci].reshape(8, _NB, 8).transpose(1, 0, 2), C)
        planes.append(y.transpose(1, 0, 2).reshape(8, _BW))
    r, g, b = ycbcr_inverse_polynomials(*planes)
    for ci, chan in enumerate((r, g, b)):
        o_ref[0, ci] = jnp.clip(jnp.round(chan), 0, 255).astype(jnp.int32)


def jpeg_inverse_pallas(coef, qluma, qchroma, *, interpret: bool = True):
    """coef: (N, 3, H, W) int32 quantized coefficients; q*: (8, 8) tables.

    H % 8 == 0, W % 128 == 0. Returns (N, 3, H, W) int32 RGB samples in
    [0, 255] (cast to uint8 by the ``ops.jpeg_inverse`` wrapper) in one
    ``pallas_call``.
    """
    N, C, H, W = coef.shape
    assert C == 3 and H % _BH == 0 and W % _BW == 0, coef.shape
    qwide = jnp.stack([
        jnp.tile(jnp.asarray(q, jnp.float32), (1, _NB))
        for q in (qluma, qchroma, qchroma)
    ])  # (3, 8, 128): per-channel tables, resident across the grid
    # the DCT matrix rides along (8, 128)-tiled, sliced back to (8, 8) in
    # the kernel: the oracle uses the numpy-built matrix, and rebuilding it
    # in-kernel (iota→cos) drifts the last ULP — see idct_dequant_blocks
    dctm = jnp.tile(jnp.asarray(dct_matrix()), (1, _NB))
    grid = (N, H // _BH, W // _BW)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 3, _BH, _BW), lambda n, i, j: (n, 0, i, j)),
            pl.BlockSpec((3, _BH, _BW), lambda n, i, j: (0, 0, 0)),
            pl.BlockSpec((_BH, _BW), lambda n, i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 3, _BH, _BW), lambda n, i, j: (n, 0, i, j)),
        out_shape=jax.ShapeDtypeStruct((N, 3, H, W), jnp.int32),
        interpret=interpret,
    )(coef, qwide, dctm)
