"""Whole-level fused JPEG transform (RGB→YCbCr→8×8 DCT→quant) Pallas kernel.

One ``pallas_call`` transform-codes an entire pyramid level: the input is a
``(N, 3, T, T)`` batch of RGB tiles and the output the ``(N, 3, T, T)`` int32
quantized YCbCr DCT coefficients — the whole device side of the JPEG encoder
in a single dispatch, versus the 4 per-tile dispatches of the unfused path
(``rgb2ycbcr`` + 3× ``dct8x8_quant``). For an L-tile level that is a 4L→1
dispatch reduction (see DESIGN.md, "Whole-level batched dispatch").

Grid: ``(N, T/8, T/128)``. Each step loads one (1, 3, 8, 128) VMEM block —
an 8×128 strip of all three channels of one tile (8×128 = one VREG tile per
channel, 16 DCT blocks side by side) — converts to level-shifted YCbCr on
the VPU, then runs the per-channel batched 8×8 DCT contractions on the MXU
and fuses the divide-by-Q rounding. Both quantization tables ride along as a
single (3, 8, 128) operand (luma, chroma, chroma — each Q tiled 16× along
the lane dim) mapped to block (0, 0, 0) so they stay resident in VMEM across
the whole grid.

Bit-exactness contract: the per-channel math is expression-identical to the
unfused ``rgb2ycbcr`` / ``dct8x8_quant`` kernels (same (16, 8, 8) einsum
shape, shared ``ref.ycbcr_polynomials``), so the fused path produces the
same int32 coefficients — the batched and per-tile JPEG byte streams match
exactly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.dct8x8_quant import _dct_mat
from repro.kernels.ref import ycbcr_polynomials

__all__ = ["jpeg_transform_pallas"]

_BH, _BW = 8, 128
_NB = _BW // 8  # DCT blocks per VMEM strip


def _kernel(x_ref, q_ref, o_ref):
    x = x_ref[0].astype(jnp.float32)  # (3, 8, 128)
    y, cb, cr = ycbcr_polynomials(x[0], x[1], x[2])
    C = _dct_mat()
    for ci, plane in enumerate((y, cb, cr)):
        xb = plane.reshape(8, _NB, 8).transpose(1, 0, 2)  # (16, 8, 8)
        yc = jnp.einsum("ij,bjk,lk->bil", C, xb, C,
                        preferred_element_type=jnp.float32)
        q = q_ref[ci].reshape(8, _NB, 8).transpose(1, 0, 2)
        out = jnp.round(yc / q)
        o_ref[0, ci] = out.transpose(1, 0, 2).reshape(8, _BW).astype(jnp.int32)


def jpeg_transform_pallas(tiles, qluma, qchroma, *, interpret: bool = True):
    """tiles: (N, 3, H, W) uint8/float RGB; q*: (8, 8) tables.

    H % 8 == 0, W % 128 == 0. Returns (N, 3, H, W) int32 quantized YCbCr
    DCT coefficients (blocks in place) in one ``pallas_call``.
    """
    N, C, H, W = tiles.shape
    assert C == 3 and H % _BH == 0 and W % _BW == 0, tiles.shape
    qwide = jnp.stack([
        jnp.tile(jnp.asarray(q, jnp.float32), (1, _NB))
        for q in (qluma, qchroma, qchroma)
    ])  # (3, 8, 128): per-channel tables, resident across the grid
    grid = (N, H // _BH, W // _BW)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 3, _BH, _BW), lambda n, i, j: (n, 0, i, j)),
            pl.BlockSpec((3, _BH, _BW), lambda n, i, j: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 3, _BH, _BW), lambda n, i, j: (n, 0, i, j)),
        out_shape=jax.ShapeDtypeStruct((N, 3, H, W), jnp.int32),
        interpret=interpret,
    )(tiles, qwide)
