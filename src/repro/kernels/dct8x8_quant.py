"""Fused 8×8 blockwise DCT-II + quantization as a Pallas TPU kernel.

The JPEG transform stage, re-blocked for the MXU: the separable 2-D DCT is
two 8×8 constant-matrix contractions. Each grid step loads an (8, 128) VMEM
block (= 16 DCT blocks side by side), reshapes to (16, 8, 8), and runs

    Y = C · X · Cᵀ   →   einsum over the batched 16-block axis (MXU dots)

then fuses the divide-by-Q rounding. The quant table rides along as a second
(8, 128)-tiled operand (Q repeated 16×) so everything stays in VMEM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

__all__ = ["dct8x8_quant_pallas"]

_BH, _BW = 8, 128
_NB = _BW // 8  # DCT blocks per VMEM block


def _dct_mat():
    """Orthonormal 8×8 DCT-II matrix, built in-kernel (iota → cos) so the
    kernel captures no host constants."""
    m = jax.lax.broadcasted_iota(jnp.float32, (8, 8), 0)  # row index k
    n = jax.lax.broadcasted_iota(jnp.float32, (8, 8), 1)  # col index
    C = jnp.cos((2.0 * n + 1.0) * m * (jnp.pi / 16.0)) * jnp.sqrt(2.0 / 8.0)
    scale = jnp.where(m == 0, 1.0 / jnp.sqrt(2.0), 1.0)
    return C * scale


def _kernel(x_ref, q_ref, o_ref):
    C = _dct_mat()
    x = x_ref[...].astype(jnp.float32)  # (8, 128)
    xb = x.reshape(8, _NB, 8).transpose(1, 0, 2)  # (16, 8, 8)
    y = jnp.einsum("ij,bjk,lk->bil", C, xb, C,
                   preferred_element_type=jnp.float32)
    q = q_ref[...].reshape(8, _NB, 8).transpose(1, 0, 2)
    out = jnp.round(y / q)
    o_ref[...] = out.transpose(1, 0, 2).reshape(8, _BW).astype(jnp.int32)


def dct8x8_quant_pallas(plane, qtable, *, interpret: bool = True):
    """plane: (H, W) float32 level-shifted; qtable: (8, 8).

    H % 8 == 0, W % 128 == 0. Returns (H, W) int32 quantized coefficients.
    """
    H, W = plane.shape
    assert H % _BH == 0 and W % _BW == 0, plane.shape
    qwide = jnp.tile(jnp.asarray(qtable, jnp.float32), (1, _NB))
    grid = (H // _BH, W // _BW)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((_BH, _BW), lambda i, j: (i, j)),
            pl.BlockSpec((_BH, _BW), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((_BH, _BW), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((H, W), jnp.int32),
        interpret=interpret,
    )(plane, qwide)
