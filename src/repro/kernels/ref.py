"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "rgb2ycbcr_ref", "downsample2x2_ref", "dct8x8_quant_ref",
    "idct8x8_dequant_ref", "jpeg_transform_ref", "jpeg_inverse_ref",
    "idct_dequant_blocks", "ycbcr_polynomials", "ycbcr_inverse_polynomials",
    "dct_matrix", "JPEG_LUMA_Q", "JPEG_CHROMA_Q",
]

# ITU-T81 Annex K quantization tables (quality 50)
JPEG_LUMA_Q = np.array([
    [16, 11, 10, 16, 24, 40, 51, 61],
    [12, 12, 14, 19, 26, 58, 60, 55],
    [14, 13, 16, 24, 40, 57, 69, 56],
    [14, 17, 22, 29, 51, 87, 80, 62],
    [18, 22, 37, 56, 68, 109, 103, 77],
    [24, 35, 55, 64, 81, 104, 113, 92],
    [49, 64, 78, 87, 103, 121, 120, 101],
    [72, 92, 95, 98, 112, 100, 103, 99],
], np.float32)

JPEG_CHROMA_Q = np.array([
    [17, 18, 24, 47, 99, 99, 99, 99],
    [18, 21, 26, 66, 99, 99, 99, 99],
    [24, 26, 56, 99, 99, 99, 99, 99],
    [47, 66, 99, 99, 99, 99, 99, 99],
    [99, 99, 99, 99, 99, 99, 99, 99],
    [99, 99, 99, 99, 99, 99, 99, 99],
    [99, 99, 99, 99, 99, 99, 99, 99],
    [99, 99, 99, 99, 99, 99, 99, 99],
], np.float32)


def dct_matrix() -> np.ndarray:
    """Orthonormal 8×8 DCT-II matrix C (DCT: C·X·Cᵀ)."""
    k = np.arange(8)
    C = np.cos((2 * k[None, :] + 1) * k[:, None] * np.pi / 16)
    C *= np.sqrt(2.0 / 8.0)
    C[0] *= 1.0 / np.sqrt(2.0)
    return C.astype(np.float32)


def ycbcr_polynomials(r, g, b):
    """The single copy of the level-shifted JPEG YCbCr polynomials.

    Every consumer — the Pallas kernel bodies (``rgb2ycbcr_pallas``,
    ``jpeg_transform_pallas``) and this module's oracle — must call this
    instead of restating the expressions: the batched/per-tile byte-identity
    contract needs bit-identical floats, and a reassociated term in one
    copy can drift the last ULP and flip a round-at-half quantization.
    """
    y = 0.299 * r + 0.587 * g + 0.114 * b - 128.0
    cb = -0.168736 * r - 0.331264 * g + 0.5 * b
    cr = 0.5 * r - 0.418688 * g - 0.081312 * b
    return y, cb, cr


def ycbcr_inverse_polynomials(y, cb, cr):
    """The single copy of the inverse (level-unshifted) YCbCr→RGB polynomials.

    The exact mirror of :func:`ycbcr_polynomials` and under the same
    contract: the Pallas inverse kernel body and the jnp oracle must call
    this one copy, because the batched/per-tile **decoder** pixel-identity
    contract (``decode_tiles_batch`` ≡ ``decode_tile`` loop) needs
    bit-identical floats before the final round/clip to uint8.
    """
    y = y + 128.0
    r = y + 1.402 * cr
    g = y - 0.344136 * cb - 0.714136 * cr
    b = y + 1.772 * cb
    return r, g, b


def rgb2ycbcr_ref(img):
    """BT.601 full-range RGB→YCbCr with JPEG level shift on Y only after
    shift convention: returns float32 planes in [-128, 127].

    img: (3, H, W) uint8/float  →  (3, H, W) float32 (Y, Cb, Cr), level-shifted
    (Y−128, Cb−128→centered, Cr centered).
    """
    r, g, b = (img[i].astype(jnp.float32) for i in range(3))
    return jnp.stack(list(ycbcr_polynomials(r, g, b)))


def downsample2x2_ref(img):
    """2×2 box filter, stride 2. img: (C, H, W) → (C, H//2, W//2) float32."""
    x = img.astype(jnp.float32)
    C, H, W = x.shape
    x = x[:, : H - H % 2, : W - W % 2]
    return 0.25 * (x[:, 0::2, 0::2] + x[:, 1::2, 0::2]
                   + x[:, 0::2, 1::2] + x[:, 1::2, 1::2])


def dct8x8_quant_ref(plane, qtable):
    """Blockwise 8×8 DCT-II + quantization (round(X̂/Q)).

    plane: (H, W) float32 level-shifted; qtable: (8, 8).
    Returns int32 coefficients, same (H, W) layout (blocks in place).
    """
    H, W = plane.shape
    assert H % 8 == 0 and W % 8 == 0
    C = jnp.asarray(dct_matrix())
    x = plane.astype(jnp.float32).reshape(H // 8, 8, W // 8, 8)
    x = x.transpose(0, 2, 1, 3)  # (bh, bw, 8, 8)
    y = jnp.einsum("ij,bcjk,lk->bcil", C, x, C)
    q = jnp.round(y / qtable[None, None]).astype(jnp.int32)
    return q.transpose(0, 2, 1, 3).reshape(H, W)


def jpeg_transform_ref(tiles, qluma=None, qchroma=None):
    """Oracle for the fused whole-level JPEG transform kernel.

    tiles: (N, 3, H, W) RGB → (N, 3, H, W) int32 quantized YCbCr DCT
    coefficients (rgb2ycbcr_ref ∘ dct8x8_quant_ref per channel, batched).
    """
    qluma = JPEG_LUMA_Q if qluma is None else qluma
    qchroma = JPEG_CHROMA_Q if qchroma is None else qchroma
    ycc = jax.vmap(rgb2ycbcr_ref)(tiles)  # (N, 3, H, W) f32 level-shifted
    qs = (qluma, qchroma, qchroma)
    planes = [
        jax.vmap(lambda p, q=jnp.asarray(qs[c]): dct8x8_quant_ref(p, q))(
            ycc[:, c]
        )
        for c in range(3)
    ]
    return jnp.stack(planes, axis=1)


def idct8x8_dequant_ref(coef, qtable):
    """Inverse of ``dct8x8_quant_ref`` (decoder path / PSNR tests)."""
    H, W = coef.shape
    C = jnp.asarray(dct_matrix())
    x = coef.astype(jnp.float32).reshape(H // 8, 8, W // 8, 8)
    x = x.transpose(0, 2, 1, 3) * qtable[None, None]
    y = jnp.einsum("ji,bcjk,kl->bcil", C, x, C)  # Cᵀ·X·C
    return y.transpose(0, 2, 1, 3).reshape(H, W)


def idct_dequant_blocks(xb, qtable, C=None):
    """(…, 8, 8) quantized coefficient blocks → (…, 8, 8) spatial samples.

    The single copy of the inverse-transform contraction, shared by the
    fused Pallas kernel body (``jpeg_inverse_pallas``) and the batched
    oracle below — the decoder-side twin of ``ycbcr_polynomials``'s
    contract, with two extra bit-exactness guards the forward path's
    quantization rounding forgives but a pixel round does not:

    * the iDCT is **two chained fixed-order contractions** (Cᵀ·X, then ·C)
      rather than one triple einsum — a triple einsum lets the backend pick
      the association order per operand shape, and the two orders differ in
      the last ULPs;
    * the kernel passes the host-built ``dct_matrix()`` in as an operand
      (``C``) instead of rebuilding it in-kernel with iota→cos — XLA's
      float32 cosine differs from numpy's in the last ULP.
    """
    if C is None:
        C = jnp.asarray(dct_matrix())
    x = xb.astype(jnp.float32) * qtable
    t = jnp.einsum("ji,...jk->...ik", C, x)
    return jnp.einsum("...ik,kl->...il", t, C)


def jpeg_inverse_ref(coef, qluma=None, qchroma=None):
    """Oracle for the fused whole-level inverse JPEG transform kernel.

    coef: (N, 3, H, W) int quantized YCbCr DCT coefficients (blocks in
    place) → (N, 3, H, W) uint8 RGB (idct_dequant_blocks per channel +
    ycbcr_inverse_polynomials + round/clip, batched) — the inverse of
    :func:`jpeg_transform_ref` up to quantization loss.
    """
    qluma = JPEG_LUMA_Q if qluma is None else qluma
    qchroma = JPEG_CHROMA_Q if qchroma is None else qchroma
    N, _, H, W = coef.shape
    qs = (qluma, qchroma, qchroma)
    planes = []
    for c in range(3):
        x = (coef[:, c].reshape(N, H // 8, 8, W // 8, 8)
             .transpose(0, 1, 3, 2, 4))
        y = idct_dequant_blocks(x, jnp.asarray(qs[c]))
        planes.append(y.transpose(0, 1, 3, 2, 4).reshape(N, H, W))
    r, g, b = ycbcr_inverse_polynomials(*planes)
    rgb = jnp.stack([r, g, b], axis=1)
    return jnp.clip(jnp.round(rgb), 0, 255).astype(jnp.uint8)
