"""Stride-2 2×2 box-filter pyramid downsample as a Pallas TPU kernel.

Builds every WSI pyramid level. Channel-planar layout: each grid step loads a
(1, 16, 256) input VMEM block and writes the (1, 8, 128) mean-pooled output
block (8×128 = one VREG tile), so both sides stay hardware-aligned and the
reduction is register-local (strided adds on the VPU — no gather).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["downsample2x2_pallas"]

_BH, _BW = 8, 128  # output block


def _kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)  # (1, 2·BH, 2·BW)
    o_ref[...] = 0.25 * (
        x[:, 0::2, 0::2] + x[:, 1::2, 0::2] + x[:, 0::2, 1::2] + x[:, 1::2, 1::2]
    )


def downsample2x2_pallas(img, *, interpret: bool = True):
    """img: (C, H, W); H % 16 == 0, W % 256 == 0 → (C, H//2, W//2) float32."""
    C, H, W = img.shape
    assert H % (2 * _BH) == 0 and W % (2 * _BW) == 0, img.shape
    grid = (C, H // (2 * _BH), W // (2 * _BW))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, 2 * _BH, 2 * _BW), lambda c, i, j: (c, i, j))],
        out_specs=pl.BlockSpec((1, _BH, _BW), lambda c, i, j: (c, i, j)),
        out_shape=jax.ShapeDtypeStruct((C, H // 2, W // 2), jnp.float32),
        interpret=interpret,
    )(img)
