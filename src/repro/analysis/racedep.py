"""Hybrid lockset + vector-clock happens-before data-race detector.

PRs 2, 4, and 7 each fixed real interleaving bugs (hedge settlement,
callback ordering, fleet shed/settle races) by inspection, and lockdep
(PR 8) only checks lock *ordering* — not whether shared state is guarded
at all. This module turns "no data races, under any legal schedule" into a
machine-checked property, with the same disarmed-fast-path / ``arm()`` /
``capture()`` contract as :mod:`repro.analysis.lockdep`:

* :class:`Shared` wraps one shared mutable structure (a dict, deque, list,
  set…). Every method that reads or mutates the underlying object records
  an access — ``(thread, source site, lockset, vector-clock epoch)`` —
  when a detector is armed; disarmed, each operation costs one
  module-global read plus the delegation call.
* :func:`tracked_state` is the class decorator that keeps a class's
  declared attributes wrapped: any assignment to a tracked name (in
  ``__init__`` or a later rebinding, e.g. ``rebuild_index`` swapping the
  whole index) is transparently replaced by a :class:`Shared` proxy.
* :class:`RaceDep` is the detector. Happens-before edges come from

  - ``TrackedLock`` acquire/release (and ``Condition`` wait/notify, which
    run through the lock's ``_release_save``/``_acquire_restore``),
  - scheduler fork/join — ``RealScheduler`` captures the submitting
    thread's clock at ``schedule()`` and the pool/timer thread joins it
    before running the event (``SimScheduler`` is single-threaded, so
    program order already covers it),
  - thread fork/join through :func:`spawn`, the tree's only sanctioned
    way to start a thread (the ``bare-thread`` lint rule),
  - pub/sub deliver→settle, which rides the two edges above: deliveries
    are scheduler events and settlements run under the subscription lock.

  A write racing a read or write from another thread is reported when the
  two accesses' locksets are **disjoint** (Eraser) *and* their clocks are
  **unordered** (no happens-before path): either condition alone marks
  benign patterns (lock-free handoff through the scheduler, reads under a
  different-but-consistent guard) as races. Reports carry both source
  sites.

Granularity is the wrapped structure: mutating an inner object fished out
of a tracked dict (``studies[uid].append(...)``) is attributed to the
``__getitem__`` read, not tracked per-element. Guard whole structures.

The detector's own mutable state is guarded by a *bare* ``threading.Lock``
on purpose — instrumenting the instrumentation would recurse; like
lockdep, this module is allowed one.
"""
from __future__ import annotations

import dataclasses
import sys
import threading
from typing import Callable

from repro.analysis import lockdep as _lockdep
from repro.analysis.lockdep import TrackedLock

__all__ = ["Shared", "tracked_state", "RaceDep", "RaceViolation", "arm",
           "disarm", "capture", "current", "spawn", "fork_point",
           "join_point", "set_instrumentation", "instrumentation_enabled"]

#: the armed detector, or None — one module-global read is the whole
#: disarmed fast path (gated <10% over uninstrumented in fleet_bench's
#: racedep_overhead section)
_DETECTOR: "RaceDep | None" = None

#: kill switch for the overhead benchmark's uninstrumented baseline:
#: when False, tracked_state assignments keep the raw structure (objects
#: constructed while disabled carry zero instrumentation)
_INSTRUMENT = True


def set_instrumentation(enabled: bool) -> bool:
    """Toggle wrapping of tracked attributes (benchmark baseline hook).

    Only affects objects constructed after the call; returns the previous
    setting."""
    global _INSTRUMENT
    prev, _INSTRUMENT = _INSTRUMENT, bool(enabled)
    return prev


def instrumentation_enabled() -> bool:
    return _INSTRUMENT


_OWN_FILE = __file__.rstrip("co")  # .pyc -> .py


def _site() -> str:
    """First caller frame outside this module, as ``file:line in fn``."""
    f = sys._getframe(1)
    for _ in range(8):
        if f is None:
            break
        if not f.f_code.co_filename.startswith(_OWN_FILE):
            return (f"{f.f_code.co_filename}:{f.f_lineno} "
                    f"in {f.f_code.co_name}")
        f = f.f_back
    return "<unknown>"


@dataclasses.dataclass
class RaceViolation:
    kind: str          # always "data-race"
    variable: str      # Shared name
    message: str
    first_site: str    # the earlier access
    second_site: str   # the access that exposed the race

    def __str__(self):
        return f"[{self.kind}] {self.message}"


# --------------------------------------------------------------------------
# the detector
# --------------------------------------------------------------------------
class _ThreadState:
    __slots__ = ("tid", "clock", "held")

    def __init__(self, tid: int):
        self.tid = tid
        self.clock = {tid: 1}  # vector clock, tid -> counter
        self.held: dict[int, int] = {}  # id(TrackedLock) -> recursion count


class _VarState:
    """Per-(detector, Shared) access history: one last-write epoch plus the
    last read per thread — the FastTrack-style minimum that still catches
    every write/write and read/write pair."""
    __slots__ = ("write", "reads")

    def __init__(self):
        self.write = None           # (tid, c, lockset, site)
        self.reads: dict = {}       # tid -> (c, lockset, site)


class RaceDep:
    """Lockset ∩ = ∅ AND clocks unordered ⇒ data race, both sites kept."""

    def __init__(self, *, max_violations: int = 50):
        self.max_violations = max_violations
        self.violations: list[RaceViolation] = []
        self._tls = threading.local()
        self._tids = iter(range(1, 1 << 30))
        # bare lock by design (see module docstring): the detector must
        # not instrument itself  # lint: allow(bare-lock)
        self._mu = threading.Lock()
        self._lock_clocks: dict[int, dict] = {}  # id(lock) -> clock
        self._reported: set = set()              # (var, siteA, siteB) dedupe
        self.accesses = 0

    # ---- per-thread state -------------------------------------------------
    def _state(self) -> _ThreadState:
        try:
            return self._tls.state
        except AttributeError:
            with self._mu:
                st = _ThreadState(next(self._tids))
            self._tls.state = st
            return st

    # ---- happens-before edges --------------------------------------------
    def _join_lock(self, st: _ThreadState, key: int):
        with self._mu:
            lc = self._lock_clocks.get(key)
            if lc:
                clock = st.clock
                for t, c in lc.items():
                    if clock.get(t, 0) < c:
                        clock[t] = c

    def _publish_lock(self, st: _ThreadState, key: int):
        with self._mu:
            lc = self._lock_clocks.setdefault(key, {})
            for t, c in st.clock.items():
                if lc.get(t, 0) < c:
                    lc[t] = c
        st.clock[st.tid] += 1

    def _on_lock_acquired(self, lock: TrackedLock):
        st = self._state()
        key = id(lock)
        n = st.held.get(key, 0)
        st.held[key] = n + 1
        if n == 0:  # outermost acquisition: join the lock's clock
            self._join_lock(st, key)

    def _on_lock_released(self, lock: TrackedLock):
        st = self._state()
        key = id(lock)
        n = st.held.get(key, 0)
        if n > 1:  # inner reentrant release: lock still held
            st.held[key] = n - 1
            return
        st.held.pop(key, None)
        self._publish_lock(st, key)

    def _on_wait_release(self, lock: TrackedLock) -> int | None:
        """Condition.wait fully released the lock (any recursion depth);
        returns the count to restore on wakeup."""
        st = self._state()
        count = st.held.pop(id(lock), None)
        self._publish_lock(st, id(lock))
        return count

    def _on_wait_acquire(self, lock: TrackedLock, count: int | None):
        st = self._state()
        st.held[id(lock)] = count if count else 1
        self._join_lock(st, id(lock))

    def fork(self) -> dict:
        """Snapshot the calling thread's clock (a message/submit token)."""
        st = self._state()
        snap = dict(st.clock)
        st.clock[st.tid] += 1
        return snap

    def join(self, token: dict):
        """Merge a fork token into the calling thread's clock."""
        st = self._state()
        clock = st.clock
        for t, c in token.items():
            if clock.get(t, 0) < c:
                clock[t] = c
        clock[st.tid] += 1

    # ---- the access check -------------------------------------------------
    def _access(self, shared: "Shared", is_write: bool):
        st = self._state()
        self.accesses += 1
        tid, clock = st.tid, st.clock
        lockset = frozenset(st.held)
        with self._mu:
            entry = shared._race
            if entry is None or entry[0] is not self:
                var = _VarState()
                shared._race = (self, var)
            else:
                var = entry[1]
            w = var.write
            if w is not None and w[0] != tid and clock.get(w[0], 0) < w[1] \
                    and not (w[2] & lockset):
                self._report(shared, w, is_write, "write")
            if is_write:
                for rt, r in var.reads.items():
                    if rt != tid and clock.get(rt, 0) < r[0] \
                            and not (r[1] & lockset):
                        self._report(shared, (rt,) + r, True, "read")
                var.write = (tid, clock[tid], lockset, _site())
                var.reads.clear()
            else:
                var.reads[tid] = (clock[tid], lockset, _site())

    def _report(self, shared: "Shared", prior, cur_is_write: bool,
                prior_kind: str):
        # self._mu held
        site = _site()
        prior_site = prior[3] if len(prior) > 3 else prior[2]
        key = (shared.name, prior_site, site)
        if key in self._reported or \
                len(self.violations) >= self.max_violations:
            return
        self._reported.add(key)
        cur_kind = "write" if cur_is_write else "read"
        v = RaceViolation(
            kind="data-race", variable=shared.name,
            first_site=prior_site, second_site=site,
            message=(f"data race on {shared.name!r}: {prior_kind} at "
                     f"{prior_site} races {cur_kind} at {site} "
                     "(disjoint locksets, unordered vector clocks)"))
        self.violations.append(v)

    def report(self) -> str:
        with self._mu:
            vs = list(self.violations)
        if not vs:
            return "racedep: no violations"
        return "racedep: %d violation(s)\n" % len(vs) + \
            "\n".join(f"  {v}" for v in vs)


# --------------------------------------------------------------------------
# module-level arming API (mirrors lockdep)
# --------------------------------------------------------------------------
def arm(**kw) -> RaceDep:
    """Install a fresh global detector; returns it. Nesting is rejected —
    use :func:`capture` to scope a detector inside an armed region."""
    global _DETECTOR
    if _DETECTOR is not None:
        raise RuntimeError("racedep already armed — use capture() to nest")
    _DETECTOR = RaceDep(**kw)
    _lockdep._RACE = _DETECTOR
    return _DETECTOR


def disarm() -> list[RaceViolation]:
    """Remove the global detector; returns its recorded violations."""
    global _DETECTOR
    det, _DETECTOR = _DETECTOR, None
    _lockdep._RACE = None
    return det.violations if det is not None else []


class capture:
    """``with capture() as det:`` — scope a detector, restoring whatever
    was armed before. Self-tests plant deliberate races inside one so the
    suite-wide detector never sees them."""

    def __init__(self, **kw):
        self._kw = kw
        self.detector: RaceDep | None = None

    def __enter__(self) -> RaceDep:
        global _DETECTOR
        self._prev = _DETECTOR
        self.detector = _DETECTOR = RaceDep(**self._kw)
        _lockdep._RACE = self.detector
        return self.detector

    def __exit__(self, *exc):
        global _DETECTOR
        _DETECTOR = self._prev
        _lockdep._RACE = self._prev
        return False


def current() -> RaceDep | None:
    return _DETECTOR


def fork_point() -> dict | None:
    """Clock snapshot for work handed to another thread (scheduler submit,
    thread spawn). Returns ``None`` disarmed — pass it to
    :func:`join_point` unconditionally."""
    det = _DETECTOR
    return det.fork() if det is not None else None


def join_point(token: dict | None):
    """Join a :func:`fork_point` token on the thread that runs the work."""
    det = _DETECTOR
    if det is not None and token is not None:
        det.join(token)


# --------------------------------------------------------------------------
# sanctioned thread spawn (the bare-thread lint rule's escape hatch)
# --------------------------------------------------------------------------
class TrackedThread(threading.Thread):
    """``threading.Thread`` with fork/join happens-before edges: the child
    starts with the spawner's clock, and ``join()`` merges the child's
    final clock back into the joiner."""

    def __init__(self, target: Callable, args=(), kwargs=None, *,
                 name=None, daemon=None):
        super().__init__(name=name, daemon=daemon)
        self._rd_target = target
        self._rd_args = args
        self._rd_kwargs = kwargs or {}
        self._rd_token = fork_point()
        self._rd_final: dict | None = None

    def run(self):
        join_point(self._rd_token)
        try:
            self._rd_target(*self._rd_args, **self._rd_kwargs)
        finally:
            self._rd_final = fork_point()

    def join(self, timeout=None):
        super().join(timeout)
        if not self.is_alive():
            join_point(self._rd_final)


def spawn(target: Callable, *args, name: str | None = None,
          daemon: bool = True, start: bool = True, **kwargs) -> TrackedThread:
    """Start (or with ``start=False``, just build) a :class:`TrackedThread`.

    The tree's only sanctioned way to create a thread outside
    ``analysis/`` and ``core/clock.py`` — the ``bare-thread`` lint rule
    rejects raw ``threading.Thread(...)`` so racedep/lockdep always see
    thread identity and the fork/join edges."""
    t = TrackedThread(target, args, kwargs, name=name, daemon=daemon)
    if start:
        t.start()
    return t


# --------------------------------------------------------------------------
# the instrumentation layer
# --------------------------------------------------------------------------
#: methods that mutate their receiver — recorded as writes; every other
#: proxied method (get/keys/values/items/count/index/copy/…) is a read
_WRITE_METHODS = frozenset({
    "append", "appendleft", "extend", "extendleft", "insert", "add",
    "update", "setdefault", "pop", "popleft", "popitem", "remove",
    "discard", "clear", "rotate", "move_to_end", "sort", "reverse",
})


class Shared:
    """Recording proxy around one shared mutable structure.

    Supports the dict/list/deque/set surface the spine uses: dunder access
    (``len``/``iter``/``in``/``[]``/``==``/``bool``) plus named methods,
    classified read-or-write by :data:`_WRITE_METHODS`. Unknown attributes
    delegate unrecorded (e.g. ``maxlen``). The wrapped object is reachable
    as ``_obj`` for code that must bypass recording (none in-tree).
    """

    __slots__ = ("_obj", "name", "_race", "__dict__", "__weakref__")

    def __init__(self, obj, name: str = "shared"):
        object.__setattr__(self, "_obj", obj)
        object.__setattr__(self, "name", name)
        object.__setattr__(self, "_race", None)

    # ---- dunder reads ----------------------------------------------------
    def __len__(self):
        det = _DETECTOR
        if det is not None:
            det._access(self, False)
        return len(self._obj)

    def __bool__(self):
        det = _DETECTOR
        if det is not None:
            det._access(self, False)
        return bool(self._obj)

    def __iter__(self):
        det = _DETECTOR
        if det is not None:
            det._access(self, False)
        return iter(self._obj)

    def __contains__(self, item):
        det = _DETECTOR
        if det is not None:
            det._access(self, False)
        return item in self._obj

    def __getitem__(self, key):
        det = _DETECTOR
        if det is not None:
            det._access(self, False)
        return self._obj[key]

    def __eq__(self, other):
        det = _DETECTOR
        if det is not None:
            det._access(self, False)
        if isinstance(other, Shared):
            other = other._obj
        return self._obj == other

    def __ne__(self, other):
        return not self.__eq__(other)

    def __hash__(self):
        return hash(self._obj)  # raises for mutables, same as unwrapped

    def __repr__(self):
        return f"Shared({self.name!r}, {self._obj!r})"

    # ---- dunder writes ---------------------------------------------------
    def __setitem__(self, key, value):
        det = _DETECTOR
        if det is not None:
            det._access(self, True)
        self._obj[key] = value

    def __delitem__(self, key):
        det = _DETECTOR
        if det is not None:
            det._access(self, True)
        del self._obj[key]

    # ---- named methods ---------------------------------------------------
    def __getattr__(self, attr):
        # only reached on the FIRST lookup of each method per instance: the
        # recording wrapper is cached in the instance __dict__, so every
        # later lookup is a plain attribute hit and a call costs one
        # module-global read (the disarmed-overhead budget depends on this)
        bound = getattr(object.__getattribute__(self, "_obj"), attr)
        if not callable(bound):
            return bound
        is_write = attr in _WRITE_METHODS

        def recording(*a, **kw):
            det = _DETECTOR
            if det is not None:
                det._access(self, is_write)
            return bound(*a, **kw)

        recording.__name__ = attr
        self.__dict__[attr] = recording
        return recording


def tracked_state(*names: str):
    """Class decorator: every assignment to a listed attribute wraps the
    value in a :class:`Shared` proxy named ``Class.attr`` — covering both
    ``__init__`` and later whole-structure rebindings. With instrumentation
    disabled (:func:`set_instrumentation`), assignments pass through raw
    (the overhead benchmark's uninstrumented baseline).
    """
    tracked = frozenset(names)

    def deco(cls):
        prev_setattr = cls.__setattr__
        label = cls.__name__

        def __setattr__(self, name, value):
            if name in tracked and _INSTRUMENT \
                    and not isinstance(value, Shared):
                value = Shared(value, f"{label}.{name}")
            prev_setattr(self, name, value)

        cls.__setattr__ = __setattr__
        existing = getattr(cls, "_tracked_state", frozenset())
        cls._tracked_state = frozenset(existing | tracked)
        return cls

    return deco
