"""Runtime lockdep: instrumented locks + a lock-order race detector.

PRs 2, 4, and 7 each fixed real lock/ordering bugs by inspection
(ordering-key wedges, hedge settlement races, handlers invoked under a
lock). This module makes those invariants *checked* properties:

* :class:`TrackedLock` is a drop-in for ``threading.Lock`` /
  ``threading.RLock`` (``reentrant=True``) that also works as the lock of
  a ``threading.Condition`` (it implements the ``_release_save`` /
  ``_acquire_restore`` / ``_is_owned`` protocol, with the bookkeeping
  following the wait's release/re-acquire). With no detector armed, the
  per-operation overhead is one module-global read.
* :class:`LockDep` is the detector. While armed (:func:`arm` /
  :func:`capture`) it maintains, per thread, the stack of held tracked
  locks and a global directed acquisition graph ("A was held while B was
  acquired"). It reports:

  - **lock-order-inversion** — adding an edge A→B when B already reaches A
    closes a cycle: two threads can interleave into a deadlock even if
    this run did not. Reported with both acquisition sites.
  - **callback-under-lock** — infrastructure that invokes user callbacks
    (push endpoints, ``done`` completions, real-work handlers) calls
    :func:`check_callback` first; if the calling thread holds any tracked
    lock, that's the re-entrancy hazard PR 2 fixed by hand in
    ``AutoscalingService`` and ``Subscription._settle``.
  - **held-too-long** — a lock held longer than ``max_hold`` wall seconds
    (condition waits release the lock, so they never count).
  - **acquired-in-jit** — a lock acquired while a jax trace is active:
    the guard runs at trace time only and silently protects nothing in
    the compiled execution.

The detector's own mutable state is guarded by a *bare* ``threading.Lock``
on purpose — instrumenting the instrumentation would recurse. This module
is the single place the lint pass allows one.
"""
from __future__ import annotations

import dataclasses
import sys
import threading
import time
import traceback

__all__ = ["TrackedLock", "LockDep", "Violation", "arm", "disarm",
           "capture", "check_callback", "current"]

#: the armed detector, or None. Read once per lock operation — keeping the
#: disarmed fast path to a single global load is what makes TrackedLock a
#: zero-cost default (see the overhead gate in benchmarks/fleet_bench.py).
_DETECTOR: "LockDep | None" = None

#: the armed race detector, or None — set by :mod:`repro.analysis.racedep`
#: (arm/disarm/capture) so TrackedLock emits happens-before edges without
#: this module importing racedep (imports flow racedep -> lockdep only)
_RACE = None


def _site(skip: int = 2) -> str:
    """Caller's source site, a few frames up, for violation reports."""
    frames = traceback.extract_stack(limit=skip + 6)[:-skip]
    own = __file__.rstrip("co")  # .pyc -> .py
    frames = [f for f in frames if not f.filename.startswith(own)]
    if not frames:
        return "<unknown>"
    f = frames[-1]
    return f"{f.filename}:{f.lineno} in {f.name}"


_TRACE_CLEAN = None  # jax.core.trace_state_clean, resolved once jax exists


def _in_jit_trace() -> bool:
    """True while jax is tracing (jit/pmap/scan…). Never imports jax."""
    global _TRACE_CLEAN
    fn = _TRACE_CLEAN
    if fn is None:
        jax = sys.modules.get("jax")
        if jax is None:
            return False
        try:
            fn = _TRACE_CLEAN = jax.core.trace_state_clean
        except AttributeError:
            return False
    try:
        return not fn()
    except Exception:
        return False


@dataclasses.dataclass
class Violation:
    kind: str      # inversion | callback-under-lock | held-too-long | ...
    message: str
    thread: str
    site: str

    def __str__(self):
        return f"[{self.kind}] {self.message} (thread {self.thread}, " \
               f"at {self.site})"


class TrackedLock:
    """Instrumented mutual exclusion — the project's only sanctioned lock.

    ``reentrant=False`` wraps ``threading.Lock``, ``reentrant=True`` wraps
    ``threading.RLock``. ``name`` labels the lock in reports; it defaults
    to the construction site (``module:line``), so per-instance locks of
    one class share a name but remain distinct graph nodes (cycles are
    detected per instance — N shard locks taken one at a time never
    alias).
    """

    __slots__ = ("_lock", "_reentrant", "name")

    def __init__(self, name: str | None = None, *, reentrant: bool = False):
        self._lock = threading.RLock() if reentrant else threading.Lock()
        self._reentrant = reentrant
        if name is None:
            f = sys._getframe(1)
            name = f"{f.f_globals.get('__name__', '?')}:{f.f_lineno}"
        self.name = name

    # ---- core lock protocol ----------------------------------------------
    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        got = self._lock.acquire(blocking, timeout)
        if got:
            det = _DETECTOR
            if det is not None:
                det._on_acquired(self)
            r = _RACE
            if r is not None:
                r._on_lock_acquired(self)
        return got

    def release(self):
        det = _DETECTOR
        if det is not None:
            det._on_released(self)
        r = _RACE
        if r is not None:
            r._on_lock_released(self)
        self._lock.release()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False

    def locked(self) -> bool:
        if self._reentrant:
            # RLock has no .locked() before 3.13. Owned by us → held; else
            # probe non-blocking (a probe from the owner would falsely
            # succeed, hence the ownership check first).
            if self._lock._is_owned():
                return True
            if self._lock.acquire(False):
                self._lock.release()
                return False
            return True
        return self._lock.locked()

    def __repr__(self):
        kind = "RLock" if self._reentrant else "Lock"
        return f"<TrackedLock({kind}) {self.name!r}>"

    # ---- threading.Condition protocol ------------------------------------
    # Condition(lock) wires wait() through these; the bookkeeping must
    # follow the wait's full release (held time stops) and re-acquisition
    # (a fresh acquisition: order edges are recorded again).
    def _release_save(self):
        det = _DETECTOR
        count = det._on_wait_release(self) if det is not None else None
        r = _RACE
        rcount = r._on_wait_release(self) if r is not None else None
        if self._reentrant:
            inner = self._lock._release_save()
        else:
            self._lock.release()
            inner = None
        return (inner, count, rcount)

    def _acquire_restore(self, state):
        inner, count, rcount = state
        if self._reentrant:
            self._lock._acquire_restore(inner)
        else:
            self._lock.acquire()
        det = _DETECTOR
        if det is not None:
            det._on_wait_acquire(self, count)
        r = _RACE
        if r is not None:
            r._on_wait_acquire(self, rcount)

    def _is_owned(self) -> bool:
        if self._reentrant:
            return self._lock._is_owned()
        # stdlib fallback semantics for non-reentrant locks: "owned" means
        # "held by someone" — a raw probe, no detector bookkeeping
        if self._lock.acquire(False):
            self._lock.release()
            return False
        return True


class LockDep:
    """The detector: per-thread held stacks + a global acquisition graph."""

    def __init__(self, *, max_hold: float | None = 30.0,
                 check_jit: bool = True):
        self.max_hold = max_hold
        self.check_jit = check_jit
        self.violations: list[Violation] = []
        self._tls = threading.local()
        # bare lock by design (see module docstring): the detector must
        # not instrument itself  # lint: allow(bare-lock)
        self._mu = threading.Lock()
        self._adj: dict[int, set[int]] = {}        # edge a -> {b}
        self._names: dict[int, str] = {}           # node id -> lock name
        self._edge_sites: dict[tuple[int, int], str] = {}
        self.edges_recorded = 0

    # ---- per-thread held stack -------------------------------------------
    def _held(self) -> list:
        try:
            return self._tls.held
        except AttributeError:
            h = self._tls.held = []  # entries: [lock, t_acquired, count]
            return h

    def held_locks(self) -> list[TrackedLock]:
        """Tracked locks the *calling thread* currently holds."""
        return [e[0] for e in self._held()]

    # ---- event hooks (called from TrackedLock) ---------------------------
    def _on_acquired(self, lock: TrackedLock):
        held = self._held()
        for e in held:
            if e[0] is lock:       # re-entrant re-acquisition: no new edge
                e[2] += 1
                return
        if self.check_jit and _in_jit_trace():
            self._violation(
                "acquired-in-jit",
                f"lock {lock.name!r} acquired inside a jax trace — the "
                "guard runs at trace time only and protects nothing in "
                "the compiled execution")
        for e in held:
            self._add_edge(e[0], lock)
        # hold-time accounting wants real elapsed time even under
        # SimScheduler  # lint: allow(wall-clock)
        held.append([lock, time.monotonic(), 1])

    def _on_released(self, lock: TrackedLock):
        held = self._held()
        for i in range(len(held) - 1, -1, -1):
            e = held[i]
            if e[0] is lock:
                e[2] -= 1
                if e[2] == 0:
                    self._check_hold_time(lock, e[1])
                    del held[i]
                return
        # released a lock acquired before arming: nothing to unwind

    def _on_wait_release(self, lock: TrackedLock) -> int | None:
        """Condition.wait released the lock fully; returns the recursion
        count to restore (None if this detector never saw the acquire)."""
        held = self._held()
        for i in range(len(held) - 1, -1, -1):
            e = held[i]
            if e[0] is lock:
                self._check_hold_time(lock, e[1])
                count = e[2]
                del held[i]
                return count
        return None

    def _on_wait_acquire(self, lock: TrackedLock, count: int | None):
        if count is None:
            return  # armed mid-wait: we never saw the release
        held = self._held()
        for e in held:
            if e[0] is lock:
                e[2] += count
                return
        for e in held:
            self._add_edge(e[0], lock)
        held.append([lock, time.monotonic(), count])  # lint: allow(wall-clock)

    def _check_hold_time(self, lock: TrackedLock, t0: float):
        if self.max_hold is None:
            return
        dt = time.monotonic() - t0  # lint: allow(wall-clock)
        if dt > self.max_hold:
            self._violation(
                "held-too-long",
                f"lock {lock.name!r} held for {dt:.3f}s "
                f"(max_hold={self.max_hold}s)")

    # ---- the acquisition graph -------------------------------------------
    def _add_edge(self, a: TrackedLock, b: TrackedLock):
        ka, kb = id(a), id(b)
        with self._mu:
            succ = self._adj.setdefault(ka, set())
            if kb in succ:
                return
            self._names[ka] = a.name
            self._names[kb] = b.name
            site = _site()
            # closing edge a->b while b already reaches a = an inversion:
            # some other chain acquired these locks in the opposite order
            path = self._path(kb, ka)
            succ.add(kb)
            self._edge_sites[(ka, kb)] = site
            self.edges_recorded += 1
            if path is not None:
                names = [self._names[n] for n in [ka, kb] + path[1:]]
                sites = [site] + [
                    self._edge_sites.get((u, v), "?")
                    for u, v in zip([kb] + path[1:], path[1:])]
        if path is not None:
            self._violation(
                "inversion",
                "lock-order-inversion cycle: "
                + " -> ".join(names)
                + " | edge sites: " + " ; ".join(sites))

    def _path(self, src: int, dst: int) -> list[int] | None:
        """Node path src..dst in the edge graph (DFS), else None.
        Caller holds self._mu."""
        if src == dst:
            return [src]
        stack, parent = [src], {src: None}
        while stack:
            u = stack.pop()
            for v in self._adj.get(u, ()):
                if v in parent:
                    continue
                parent[v] = u
                if v == dst:
                    path, node = [], v
                    while node is not None:
                        path.append(node)
                        node = parent[node]
                    return path[::-1]
                stack.append(v)
        return None

    # ---- violations -------------------------------------------------------
    def _violation(self, kind: str, message: str):
        v = Violation(kind=kind, message=message,
                      thread=threading.current_thread().name, site=_site())
        with self._mu:
            self.violations.append(v)

    def report(self) -> str:
        with self._mu:
            vs = list(self.violations)
        if not vs:
            return "lockdep: no violations"
        return "lockdep: %d violation(s)\n" % len(vs) + \
            "\n".join(f"  {v}" for v in vs)


# --------------------------------------------------------------------------
# module-level arming API
# --------------------------------------------------------------------------
def arm(**kw) -> LockDep:
    """Install a fresh global detector; returns it. Nesting is not allowed
    (use :func:`capture` to scope a detector inside an armed region)."""
    global _DETECTOR
    if _DETECTOR is not None:
        raise RuntimeError("lockdep already armed — use capture() to nest")
    _DETECTOR = LockDep(**kw)
    return _DETECTOR


def disarm() -> list[Violation]:
    """Remove the global detector; returns its recorded violations."""
    global _DETECTOR
    det, _DETECTOR = _DETECTOR, None
    return det.violations if det is not None else []


class capture:
    """``with capture() as det:`` — scope a detector, restoring whatever
    was armed before. Self-tests plant deliberate violations inside one so
    the suite-wide detector never sees them."""

    def __init__(self, **kw):
        self._kw = kw
        self.detector: LockDep | None = None

    def __enter__(self) -> LockDep:
        global _DETECTOR
        self._prev = _DETECTOR
        self.detector = _DETECTOR = LockDep(**self._kw)
        return self.detector

    def __exit__(self, *exc):
        global _DETECTOR
        _DETECTOR = self._prev
        return False


def current() -> LockDep | None:
    return _DETECTOR


def check_callback(label: str):
    """Invariant check at every infrastructure→user-callback boundary:
    push endpoints, real-work handlers, and ``done`` completions must run
    with **no** tracked lock held (PR 2's hand-established rule, now
    machine-checked). Call right before invoking the callback."""
    det = _DETECTOR
    if det is None:
        return
    held = det.held_locks()
    if held:
        det._violation(
            "callback-under-lock",
            f"callback {label!r} invoked while holding "
            + ", ".join(repr(lk.name) for lk in held))
