"""Project-specific AST lint rules (``make lint``, the CI ``lint`` job).

The event-driven spine's correctness rests on conventions a generic linter
cannot know; each rule below turns one of them into a checked property:

==================== =====================================================
rule id              invariant
==================== =====================================================
bare-lock            no ``threading.Lock()``/``RLock()`` outside
                     ``analysis/`` — every lock must be a
                     ``TrackedLock`` so lockdep sees it
wall-clock           no ``time.time()``/``time.sleep()``/
                     ``time.monotonic()``/``time.perf_counter()`` outside
                     ``core/clock.py`` and ``benchmarks/`` — wall-clock
                     reads break SimScheduler determinism; use the
                     scheduler's ``now()`` or ``core.clock.wall_time``/
                     ``wall_sleep``/``monotonic``
bare-thread          no ``threading.Thread(...)``/``threading.Timer(...)``
                     outside ``analysis/`` and ``core/clock.py`` — spawns
                     go through ``repro.analysis.racedep.spawn`` so
                     racedep/lockdep see thread identity and the
                     fork/join happens-before edges
unseeded-random      no ``random``/``np.random`` use without an explicit
                     seed (module-global RNG state is run-order
                     dependent): ``random.Random(seed)``,
                     ``np.random.default_rng(seed)`` or
                     ``jax.random.PRNGKey(seed)`` only
direct-pallas        no ``pallas_call`` outside ``kernels/`` — every
                     kernel entry routes through ``ops._dispatch`` /
                     ``ops._batched_call`` (impl policy, bucketing,
                     mesh sharding live there exactly once)
counter-name         first argument of ``metrics.inc``/``metrics.record``/
                     ``metrics.observe`` must be dotted
                     ``segment.segment`` lowercase names (f-string
                     placeholders allowed inside segments)
span-name            names given to ``tracing.span``/``start_span`` and
                     ``add_event`` follow the same dotted-lowercase
                     contract as counters, so the dashboard's
                     name-prefix attribution rules stay total
jit-global-mutation  no mutation of module-level state inside a
                     ``jax.jit``-traced function — it runs at trace time
                     only and silently stops happening once cached
==================== =====================================================

Suppression: append ``# lint: allow(<rule-id>)`` (comma-separated ids) to
the offending line, or put it on the line directly above, with a comment
justifying the exemption. See DESIGN.md "Static analysis & lockdep" for
how to add a rule.
"""
from __future__ import annotations

import argparse
import ast
import re
import sys
from pathlib import Path

__all__ = ["lint_file", "lint_paths", "Finding", "RULES"]

RULES = {
    "bare-lock": "threading.Lock/RLock outside analysis/ (use TrackedLock)",
    "bare-thread": "threading.Thread/Timer outside analysis/ and "
                   "core/clock.py (use racedep.spawn)",
    "wall-clock": "time.time()/sleep()/monotonic()/perf_counter() outside "
                  "core/clock.py and benchmarks/",
    "unseeded-random": "random/np.random use without an explicit seed",
    "direct-pallas": "pallas_call referenced outside kernels/",
    "counter-name": "metrics counter not in dotted segment.segment form",
    "span-name": "tracing span/event name not in dotted segment.segment "
                 "form",
    "jit-global-mutation": "module-level state mutated inside jax.jit",
}

_ALLOW_RE = re.compile(r"lint:\s*allow\(([^)]*)\)")

#: functions on the stdlib ``random`` module that use the hidden global RNG
_RANDOM_GLOBAL_FNS = {
    "random", "randint", "randrange", "choice", "choices", "shuffle",
    "sample", "uniform", "gauss", "normalvariate", "betavariate",
    "expovariate", "triangular", "getrandbits", "randbytes", "seed",
}
#: legacy ``np.random`` functions that use the hidden global RandomState
_NP_RANDOM_GLOBAL_FNS = {
    "seed", "random", "rand", "randn", "randint", "random_sample",
    "normal", "uniform", "choice", "shuffle", "permutation", "standard_normal",
}
_MUTATING_METHODS = {
    "append", "extend", "insert", "add", "update", "setdefault", "pop",
    "popitem", "remove", "discard", "clear", "appendleft",
}
_COUNTER_SEG_RE = re.compile(r"[a-z0-9_\x00]+\Z")


class Finding:
    __slots__ = ("path", "line", "rule", "message")

    def __init__(self, path: str, line: int, rule: str, message: str):
        self.path, self.line = path, line
        self.rule, self.message = rule, message

    def __str__(self):
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"

    def __repr__(self):
        return f"Finding({self})"


def _dotted(node: ast.AST) -> str:
    """``a.b.c`` attribute chain as a string ('' if not a plain chain)."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _static_text(node: ast.AST) -> str | None:
    """Literal / f-string first arg as text, interpolations as ``\\x00``."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    if isinstance(node, ast.JoinedStr):
        out = []
        for part in node.values:
            if isinstance(part, ast.Constant):
                out.append(str(part.value))
            else:
                out.append("\x00")
        return "".join(out)
    return None


def _is_jit_decorated(fn: ast.AST) -> bool:
    """@jax.jit / @jit / @partial(jax.jit, ...) / @jax.jit(...)."""
    for dec in getattr(fn, "decorator_list", []):
        target = dec
        if isinstance(dec, ast.Call):
            name = _dotted(dec.func)
            if name in ("functools.partial", "partial") and dec.args:
                target = dec.args[0]
            else:
                target = dec.func
        name = _dotted(target)
        if name in ("jax.jit", "jit") or name.endswith(".jit"):
            return True
    return False


class _Linter(ast.NodeVisitor):
    def __init__(self, path: Path, tree: ast.Module, rel: str):
        self.path = path
        self.rel = rel.replace("\\", "/")
        self.findings: list[Finding] = []
        self._jit_depth = 0
        # module-level bindings (for jit-global-mutation): names assigned
        # at the module's top level
        self.module_names: set[str] = set()
        for stmt in tree.body:
            for tgt in getattr(stmt, "targets", []) or \
                    ([stmt.target] if isinstance(
                        stmt, (ast.AnnAssign, ast.AugAssign)) else []):
                if isinstance(tgt, ast.Name):
                    self.module_names.add(tgt.id)

    # ---- helpers ----------------------------------------------------------
    def _report(self, node: ast.AST, rule: str, message: str):
        self.findings.append(
            Finding(str(self.path), getattr(node, "lineno", 0), rule,
                    message))

    def _in(self, *parts: str) -> bool:
        return any(p in self.rel for p in parts)

    # ---- visitors ----------------------------------------------------------
    def visit_Call(self, node: ast.Call):
        name = _dotted(node.func)
        tail = name.rsplit(".", 1)[-1] if name else ""

        # bare-lock -------------------------------------------------------
        if name in ("threading.Lock", "threading.RLock", "Lock", "RLock") \
                and tail in ("Lock", "RLock") \
                and not self._in("/analysis/"):
            if name.startswith("threading.") or name in ("Lock", "RLock"):
                self._report(
                    node, "bare-lock",
                    f"{name}() — use repro.analysis.lockdep.TrackedLock"
                    f"{'(reentrant=True)' if tail == 'RLock' else ''} so "
                    "lockdep can see it")

        # bare-thread -----------------------------------------------------
        if name in ("threading.Thread", "threading.Timer") \
                and not self._in("/analysis/") \
                and not self.rel.endswith("core/clock.py"):
            self._report(
                node, "bare-thread",
                f"{name}() — spawn through repro.analysis.racedep.spawn "
                "(or schedule on a RealScheduler) so racedep/lockdep see "
                "thread identity and fork/join ordering")

        # wall-clock ------------------------------------------------------
        if name in ("time.time", "time.sleep", "time.monotonic",
                    "time.perf_counter") \
                and not self.rel.endswith("core/clock.py") \
                and not self._in("/benchmarks/"):
            sanctioned = {"time": "wall_time", "sleep": "wall_sleep",
                          "monotonic": "monotonic",
                          "perf_counter": "monotonic"}[tail]
            self._report(
                node, "wall-clock",
                f"{name}() breaks SimScheduler determinism — use the "
                f"scheduler's now()/schedule(), or core.clock."
                f"{sanctioned}() for sanctioned wall-clock use")

        # unseeded-random -------------------------------------------------
        self._check_random(node, name, tail)

        # counter-name / span-name: one dotted-lowercase naming contract --
        if isinstance(node.func, ast.Attribute):
            attr = node.func.attr
            if attr in ("inc", "record", "observe") and node.args:
                self._check_dotted(node, node.args[0], "counter-name",
                                   "counter")
            elif attr == "start_span" and node.args:
                self._check_dotted(node, node.args[0], "span-name", "span")
            elif attr == "span" and node.args \
                    and name.endswith("tracing.span"):
                self._check_dotted(node, node.args[0], "span-name", "span")
            elif attr == "add_event" and len(node.args) >= 2:
                self._check_dotted(node, node.args[1], "span-name",
                                   "span event")

        self.generic_visit(node)

    def _check_dotted(self, node: ast.Call, arg: ast.AST, rule: str,
                      kind: str):
        text = _static_text(arg)
        if text is None:
            return
        segs = text.split(".")
        if len(segs) < 2 or not all(
                s and _COUNTER_SEG_RE.match(s) for s in segs):
            self._report(
                node, rule,
                f"{kind} {text.replace(chr(0), '{…}')!r} must be "
                "dotted lowercase segment.segment form")

    def _check_random(self, node: ast.Call, name: str, tail: str):
        if name in ("random.Random",) and not node.args:
            self._report(node, "unseeded-random",
                         "random.Random() without a seed argument")
        elif name.startswith("random.") and tail in _RANDOM_GLOBAL_FNS \
                and name.count(".") == 1:
            self._report(
                node, "unseeded-random",
                f"{name}() uses the hidden module-global RNG — construct "
                "random.Random(seed) explicitly")
        elif name.endswith("random.default_rng") and not node.args:
            self._report(node, "unseeded-random",
                         "default_rng() without a seed argument")
        elif (name.startswith("np.random.") or
              name.startswith("numpy.random.")) \
                and tail in _NP_RANDOM_GLOBAL_FNS:
            self._report(
                node, "unseeded-random",
                f"{name}() uses numpy's global RandomState — use "
                "np.random.default_rng(seed)")

    def visit_Attribute(self, node: ast.Attribute):
        if node.attr == "pallas_call" and not self._in("/kernels/"):
            self._report(
                node, "direct-pallas",
                "pallas_call outside kernels/ — route kernel entries "
                "through kernels.ops (_dispatch/_batched_call own the "
                "impl policy, bucketing, and mesh sharding)")
        self.generic_visit(node)

    def visit_Name(self, node: ast.Name):
        if node.id == "pallas_call" and not self._in("/kernels/"):
            self._report(
                node, "direct-pallas",
                "pallas_call outside kernels/ — route kernel entries "
                "through kernels.ops (_dispatch/_batched_call)")
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom):
        if not self._in("/kernels/"):
            for alias in node.names:
                if alias.name == "pallas_call":
                    self._report(
                        node, "direct-pallas",
                        "importing pallas_call outside kernels/")
        self.generic_visit(node)

    # ---- jit-global-mutation ----------------------------------------------
    def _visit_function(self, node):
        jitted = _is_jit_decorated(node)
        if jitted:
            self._jit_depth += 1
        self.generic_visit(node)
        if jitted:
            self._jit_depth -= 1

    visit_FunctionDef = _visit_function
    visit_AsyncFunctionDef = _visit_function

    def visit_Global(self, node: ast.Global):
        if self._jit_depth:
            self._report(
                node, "jit-global-mutation",
                f"global {', '.join(node.names)} inside a jit-traced "
                "function — the mutation happens at trace time only and "
                "stops happening once the trace is cached")
        self.generic_visit(node)

    def _root_name(self, node: ast.AST) -> str | None:
        while isinstance(node, (ast.Attribute, ast.Subscript)):
            node = node.value
        return node.id if isinstance(node, ast.Name) else None

    def _check_jit_store(self, target: ast.AST, node: ast.AST):
        if isinstance(target, (ast.Subscript, ast.Attribute)):
            root = self._root_name(target)
            if root in self.module_names:
                self._report(
                    node, "jit-global-mutation",
                    f"module-level {root!r} mutated inside a jit-traced "
                    "function — trace-time side effect, silently dropped "
                    "on cached executions")

    def visit_Assign(self, node: ast.Assign):
        if self._jit_depth:
            for tgt in node.targets:
                self._check_jit_store(tgt, node)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign):
        if self._jit_depth:
            self._check_jit_store(node.target, node)
        self.generic_visit(node)

    def visit_Expr(self, node: ast.Expr):
        # CACHE.update(...) / CACHE.append(...) on a module-level name
        if self._jit_depth and isinstance(node.value, ast.Call) \
                and isinstance(node.value.func, ast.Attribute) \
                and node.value.func.attr in _MUTATING_METHODS:
            root = self._root_name(node.value.func.value)
            if root in self.module_names:
                self._report(
                    node, "jit-global-mutation",
                    f"module-level {root!r}.{node.value.func.attr}() "
                    "inside a jit-traced function — trace-time side "
                    "effect, silently dropped on cached executions")
        self.generic_visit(node)


def _allowed(lines: list[str], finding: Finding) -> bool:
    """``# lint: allow(rule)`` on the finding's line or the line above."""
    for ln in (finding.line, finding.line - 1):
        if 1 <= ln <= len(lines):
            m = _ALLOW_RE.search(lines[ln - 1])
            if m and finding.rule in \
                    {s.strip() for s in m.group(1).split(",")}:
                return True
    return False


def lint_file(path: Path, root: Path | None = None) -> list[Finding]:
    src = path.read_text(encoding="utf-8")
    rel = str(path.resolve())
    if root is not None:
        try:
            rel = str(path.resolve().relative_to(root.resolve()))
        except ValueError:
            pass
    try:
        tree = ast.parse(src, filename=str(path))
    except SyntaxError as exc:
        return [Finding(str(path), exc.lineno or 0, "syntax",
                        f"unparseable: {exc.msg}")]
    linter = _Linter(path, tree, "/" + rel)
    linter.visit(tree)
    lines = src.splitlines()
    return [f for f in linter.findings if not _allowed(lines, f)]


def lint_paths(paths: list[Path], root: Path | None = None) -> list[Finding]:
    files: list[Path] = []
    for p in paths:
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        elif p.suffix == ".py":
            files.append(p)
    findings: list[Finding] = []
    for f in files:
        findings.extend(lint_file(f, root=root))
    return findings


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.analysis.lint",
        description="project lint rules (see module docstring)")
    ap.add_argument("paths", nargs="*", default=["src", "tests",
                                                 "benchmarks"],
                    help="files or directories to lint")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)
    if args.list_rules:
        for rid, desc in RULES.items():
            print(f"{rid:22s} {desc}")
        return 0
    root = Path.cwd()
    findings = lint_paths([Path(p) for p in args.paths], root=root)
    for f in findings:
        print(f)
    n_files = len({f.path for f in findings})
    if findings:
        print(f"lint: {len(findings)} finding(s) in {n_files} file(s)")
        return 1
    print("lint: clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
