"""Machine-checked concurrency + determinism invariants.

Four legs (DESIGN.md, "Static analysis & lockdep" and "Race detection &
schedule exploration"):

* :mod:`repro.analysis.lockdep` — runtime lock-order instrumentation.
  Every lock in the event-driven spine is a :class:`~repro.analysis
  .lockdep.TrackedLock`; arming a detector records the per-thread
  acquisition graph and flags lock-order-inversion cycles, callbacks
  invoked under a lock, held-too-long anomalies, and locks acquired
  inside a jax trace. The tier-1 test suite runs fully armed
  (``tests/conftest.py``).
* :mod:`repro.analysis.racedep` — hybrid lockset + vector-clock data-race
  detector over the spine's shared structures (``Shared`` proxies planted
  by ``@tracked_state``). Happens-before edges come from TrackedLock
  acquire/release, condition wait/notify, scheduler pool submit/join, and
  thread spawn/join; a race is an unordered access pair with disjoint
  locksets. The tier-1 suite also runs with racedep armed.
* :mod:`repro.analysis.schedules` — systematic schedule exploration:
  seeded tie-breaking over equal-timestamp SimScheduler events, trace
  record/replay, and an ``explore()`` harness asserting exactly-once
  settlement, cross-schedule byte-identical output, and zero races
  (``make race`` / the CI ``race`` job). Failures dump a replayable
  seed+trace artifact.
* :mod:`repro.analysis.lint` — AST lint pass with project-specific rules
  (``make lint`` / the CI ``lint`` job): no bare ``threading.Lock``, no
  bare ``threading.Thread`` (use ``racedep.spawn``), no wall-clock or
  monotonic reads outside ``core/clock.py``, no unseeded randomness, no
  ``pallas_call`` outside ``kernels/``, dotted counter names, no
  module-state mutation inside jit-traced functions.
"""
from repro.analysis.lockdep import (LockDep, TrackedLock, Violation, arm,
                                    capture, check_callback, current, disarm)
from repro.analysis.racedep import (RaceDep, RaceViolation, Shared, spawn,
                                    tracked_state)

__all__ = ["LockDep", "TrackedLock", "Violation", "arm", "disarm",
           "capture", "check_callback", "current",
           "RaceDep", "RaceViolation", "Shared", "spawn", "tracked_state",
           "ExplorationFailure", "explore", "replay"]

_SCHEDULES_EXPORTS = ("ExplorationFailure", "explore", "replay")


def __getattr__(name):
    # lazy: schedules is also a `python -m` entry point, and importing it
    # here eagerly would trip runpy's already-in-sys.modules warning
    if name in _SCHEDULES_EXPORTS:
        from repro.analysis import schedules
        return getattr(schedules, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
