"""Machine-checked concurrency + determinism invariants.

Two legs (DESIGN.md, "Static analysis & lockdep"):

* :mod:`repro.analysis.lockdep` — runtime lock-order instrumentation.
  Every lock in the event-driven spine is a :class:`~repro.analysis
  .lockdep.TrackedLock`; arming a detector records the per-thread
  acquisition graph and flags lock-order-inversion cycles, callbacks
  invoked under a lock, held-too-long anomalies, and locks acquired
  inside a jax trace. The tier-1 test suite runs fully armed
  (``tests/conftest.py``).
* :mod:`repro.analysis.lint` — AST lint pass with project-specific rules
  (``make lint`` / the CI ``lint`` job): no bare ``threading.Lock``, no
  wall-clock reads outside ``core/clock.py``, no unseeded randomness, no
  ``pallas_call`` outside ``kernels/``, dotted counter names, no
  module-state mutation inside jit-traced functions.
"""
from repro.analysis.lockdep import (LockDep, TrackedLock, Violation, arm,
                                    capture, check_callback, current, disarm)

__all__ = ["LockDep", "TrackedLock", "Violation", "arm", "disarm",
           "capture", "check_callback", "current"]
