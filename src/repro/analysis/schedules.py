"""Systematic schedule exploration for the event-driven spine.

``SimScheduler`` is deterministic, which makes tests reproducible — and
blind: one seed exercises exactly one of the many legal orders of
equal-timestamp events (pub/sub deliveries, ack timers, autoscaler ticks
all landing on the same virtual instant). The bugs PRs 2/4/7 fixed lived
precisely in those orderings. This module turns the scheduler's
determinism into a *search*:

* ``SimScheduler(seed=N)`` draws a per-event tie-break key, so each seed
  runs a different legal permutation of equal-timestamp events — same
  program, different schedule, still fully reproducible from the seed.
* :func:`explore` re-runs a scenario under many seeds with racedep armed,
  asserting the scenario's own invariants (every slide settles exactly
  once — the scenarios assert it), cross-seed result identity (study tars
  byte-identical regardless of schedule), and zero data-race reports.
* On failure it writes ``artifacts/schedule-<scenario>-seed<N>.json`` —
  seed, schedule trace, exception — and prints the one-line replay
  command; :func:`replay` re-runs exactly that schedule under a debugger.

Run the exploration tier from the CLI (this is what ``make race`` does)::

    python -m repro.analysis.schedules --explore realbytes --seeds 20
    python -m repro.analysis.schedules --replay artifacts/schedule-....json
"""
from __future__ import annotations

import hashlib
import importlib
import json
import os
from typing import Callable

from repro.analysis import racedep
from repro.core import tracing

__all__ = ["explore", "replay", "ExplorationFailure", "ExplorationReport",
           "sim_fleet_scenario", "realbytes_fleet_scenario", "SCENARIOS"]


class ExplorationFailure(AssertionError):
    """A scenario broke an invariant under some seeded schedule. Carries
    the seed and the artifact path so harnesses can point straight at the
    repro."""

    def __init__(self, message: str, *, seed, artifact: str | None):
        super().__init__(message)
        self.seed = seed
        self.artifact = artifact


class ExplorationReport:
    """Outcome of a clean :func:`explore` run."""

    def __init__(self, scenario: str, seeds: list, accesses: int):
        self.scenario = scenario
        self.seeds = seeds
        self.accesses = accesses

    def __repr__(self):
        return (f"<ExplorationReport {self.scenario}: {len(self.seeds)} "
                f"schedules clean, {self.accesses} tracked accesses>")


def _scenario_path(fn: Callable) -> str:
    mod = fn.__module__
    if mod == "__main__" and fn.__name__ in globals():
        # `python -m repro.analysis.schedules` defines this module as
        # __main__; record the importable name so --replay resolves it
        # from any process
        mod = "repro.analysis.schedules"
    return f"{mod}:{fn.__qualname__}"


def _resolve(path: str) -> Callable:
    mod, _, name = path.partition(":")
    fn = importlib.import_module(mod)
    for part in name.split("."):
        fn = getattr(fn, part)
    return fn


def _digest(result) -> str:
    """Stable fingerprint of a scenario result for cross-seed comparison
    (dict of bytes → per-key sha256; anything else → repr hash)."""
    h = hashlib.sha256()
    if isinstance(result, dict):
        for k in sorted(result):
            v = result[k]
            h.update(str(k).encode())
            h.update(v if isinstance(v, (bytes, bytearray))
                     else repr(v).encode())
    else:
        h.update(repr(result).encode())
    return h.hexdigest()


def _dump_artifact(artifacts_dir: str, scenario: Callable, seed, sched,
                   error: str, tracer=None) -> str:
    os.makedirs(artifacts_dir, exist_ok=True)
    name = scenario.__name__.replace("_", "-")
    path = os.path.join(artifacts_dir,
                        f"schedule-{name}-seed{seed}.json")
    trace = list(getattr(sched, "trace", None) or [])
    spath = _scenario_path(scenario)
    replay_cmd = (f"python -m repro.analysis.schedules --replay {path}")
    with open(path, "w") as f:
        json.dump({
            "scenario": spath,
            "seed": seed,
            "error": error,
            "events_fired": len(trace),
            "replay": replay_cmd,
            "trace": [[seq, t, fn] for seq, t, fn in trace],
            # the failing run's full span trees: which slide's journey
            # wedged, and at which hop, without re-running anything
            "spans": tracer.export() if tracer is not None else [],
        }, f, indent=1)
    print(f"schedule exploration FAILED (seed={seed}): {error}")
    print(f"artifact: {path}")
    print(f"replay:   {replay_cmd}")
    return path


def _run_one(scenario: Callable, seed):
    """One scenario run under one seed with racedep scoped around it.
    Returns (result, scheduler, violations)."""
    from repro.core.clock import SimScheduler

    sched = SimScheduler(seed=seed, record_trace=True)
    with racedep.capture() as det, tracing.capture(now=sched.now):
        result = scenario(sched)
    return result, sched, det


def explore(scenario: Callable, seeds: int = 20, *,
            artifacts_dir: str = "artifacts",
            base_seed: int = 1) -> ExplorationReport:
    """Run ``scenario(sched)`` under the legacy FIFO schedule plus
    ``seeds`` seeded permutations, asserting on every run:

    * the scenario's internal invariants hold (scenarios ``assert`` that
      every slide settles exactly once, nothing dead-letters, …),
    * racedep records **zero** data races,
    * the result is byte-identical across all schedules.

    On the first violated invariant, dumps seed + schedule trace under
    ``artifacts_dir`` and raises :class:`ExplorationFailure` naming the
    one-line replay command.
    """
    from repro.core.clock import SimScheduler

    seed_list = [None] + [base_seed + i for i in range(seeds)]
    reference = None
    accesses = 0
    for seed in seed_list:
        sched = SimScheduler(seed=seed, record_trace=True)
        tracer = None
        try:
            # traced on the sim clock: a failure artifact carries the span
            # trees alongside the schedule trace
            with racedep.capture() as det, \
                    tracing.capture(now=sched.now) as tracer:
                result = scenario(sched)
            accesses += det.accesses
            if det.violations:
                raise AssertionError(
                    f"{len(det.violations)} data race(s): "
                    + "; ".join(str(v) for v in det.violations))
            digest = _digest(result)
            if reference is None:
                reference = digest
            elif digest != reference:
                raise AssertionError(
                    f"result diverged across schedules: digest {digest} "
                    f"!= reference {reference} (schedule-dependent bytes)")
        except Exception as e:  # noqa: BLE001 — every failure becomes a repro
            artifact = _dump_artifact(artifacts_dir, scenario, seed, sched,
                                      f"{type(e).__name__}: {e}", tracer)
            raise ExplorationFailure(
                f"scenario {scenario.__name__!r} failed under seed {seed}: "
                f"{e}", seed=seed, artifact=artifact) from e
    return ExplorationReport(_scenario_path(scenario), seed_list, accesses)


def replay(artifact_path: str):
    """Re-run the exact schedule recorded in a failure artifact (same
    scenario, same seed — the seed fully determines the schedule) and
    return the scenario result. Raises whatever the original run raised."""
    with open(artifact_path) as f:
        art = json.load(f)
    scenario = _resolve(art["scenario"])
    result, sched, det = _run_one(scenario, art["seed"])
    if det.violations:
        raise AssertionError(
            f"{len(det.violations)} data race(s): "
            + "; ".join(str(v) for v in det.violations))
    return result


# --------------------------------------------------------------------------
# scenarios (module-level so artifacts can name them importably)
# --------------------------------------------------------------------------
def _pinned_convert():
    """Real WSI→DICOM conversion with UIDs pinned per slide id, so every
    schedule (and the serial baseline) mints byte-identical studies."""
    from repro.wsi.convert import ConvertOptions, convert_wsi_to_dicom

    def uids(slide_id: str) -> list[str]:
        h = hashlib.sha256(slide_id.encode()).hexdigest()
        return ["2.25." + str(int(h[:24], 16)),
                "2.25." + str(int(h[24:48], 16))]

    def convert(data: bytes, meta: dict) -> bytes:
        opt = ConvertOptions(
            manifest={"uids": json.dumps(uids(meta["slide_id"]))})
        return convert_wsi_to_dicom(data, meta, options=opt)

    return convert


def _fleet_run(sched, slides: dict, meta: dict, convert,
               check_writes: bool = True) -> dict:
    """Drive a faulted two-tenant fleet over ``slides`` on ``sched`` and
    assert the exactly-once invariants; returns {landing key: tar bytes}."""
    from repro.core import ConversionPipeline, DeliveryFaults
    from repro.core.pipeline import derive_out_key

    # "s1." not "s1": the substring match must not alias s10/s11
    names = [k.rsplit("/", 1)[-1].split(".")[0] + "." for k in slides]
    faults = DeliveryFaults()
    if len(names) >= 3:
        faults = (DeliveryFaults()
                  .drop(names[0], attempts=(1,))
                  .duplicate(names[1], lag=1.0)
                  .delay(names[2], by=200.0))
    pipe = ConversionPipeline(
        sched, convert=convert, cold_start=10.0, max_instances=4,
        ack_deadline=120.0, min_backoff=5.0,
        fleet=dict(instance_queue_depth=2), ordered_ingest=True,
        store_shards=2, delivery_faults=faults)
    for k, d in slides.items():
        pipe.ingest(k, d, meta[k])
    sched.schedule(5.0, pipe.service.kill_instance)
    sched.run()

    # every slide settles exactly once: nothing dead-letters, one study
    # per slide, one store write per slide (a double conversion would
    # show up as an extra write even though re-STOW is idempotent)
    assert pipe.dead_lettered == [], \
        f"dead-lettered under exploration: {pipe.dead_lettered}"
    out_keys = pipe.dicom.list()
    assert len(out_keys) == len(slides), \
        f"{len(out_keys)} studies for {len(slides)} slides"
    if check_writes:
        writes = int(pipe.metrics.get("bucket.dicom-store.writes"))
        assert writes == len(slides), \
            f"{writes} writes for {len(slides)} slides (double convert?)"
    return {k: pipe.dicom.get(derive_out_key(k)).data for k in slides}


def sim_fleet_scenario(sched) -> dict:
    """Fast exploration scenario: the full faulted fleet spine over tiny
    real slides with a stand-in converter — exercises every pub/sub,
    fleet, autoscaler, and store interleaving without real pixel work."""
    from repro.wsi import SyntheticScanner

    def convert(data: bytes, meta: dict) -> bytes:
        return b"study:" + meta["slide_id"].encode() + b":" + \
            hashlib.sha256(data).digest()

    scanner = SyntheticScanner(seed=23)
    slides = {f"scans/s{i}.psv": scanner.scan(64, 64, 32)
              for i in range(12)}
    tenants = ("lab-a", "lab-b")
    meta = {k: {"slide_id": k, "tenant": tenants[i % 2]}
            for i, k in enumerate(slides)}
    return _fleet_run(sched, slides, meta, convert)


def realbytes_fleet_scenario(sched) -> dict:
    """The acceptance scenario: real synthetic slides through the real
    converter under a faulted fleet. Checks byte-identity against a
    serial no-infrastructure baseline *within* the run; :func:`explore`
    additionally checks identity across schedules."""
    from repro.wsi import SyntheticScanner
    from repro.wsi.formats import sniff

    scanner = SyntheticScanner(seed=11)
    slides = {f"scans/s{i}.psv": scanner.scan(512, 512, 256)
              for i in range(4)}
    tenants = ("lab-a", "lab-b")
    meta = {k: {"slide_id": k, "tenant": tenants[i % 2]}
            for i, k in enumerate(slides)}
    convert = _pinned_convert()

    baseline = {}
    for k, d in slides.items():
        m = dict(meta[k])
        m.setdefault("format", sniff(d))
        baseline[k] = convert(d, m)

    tars = _fleet_run(sched, slides, meta, convert)
    for k in slides:
        assert tars[k] == baseline[k], \
            f"fleet study tar differs from serial baseline for {k}"
    return tars


SCENARIOS = {
    "sim": sim_fleet_scenario,
    "realbytes": realbytes_fleet_scenario,
}


def main(argv: list[str] | None = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        description="seeded schedule exploration / replay")
    ap.add_argument("--explore", choices=sorted(SCENARIOS),
                    help="scenario to explore")
    ap.add_argument("--seeds", type=int, default=20)
    ap.add_argument("--artifacts", default="artifacts")
    ap.add_argument("--replay", metavar="ARTIFACT.json",
                    help="re-run the schedule recorded in a failure artifact")
    args = ap.parse_args(argv)
    if args.replay:
        replay(args.replay)
        print(f"replay of {args.replay}: scenario completed cleanly")
        return 0
    if not args.explore:
        ap.error("one of --explore/--replay is required")
    report = explore(SCENARIOS[args.explore], seeds=args.seeds,
                     artifacts_dir=args.artifacts)
    print(f"{report!r}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
