"""Error-feedback int8 gradient compression.

At 1000+ node scale the inter-pod (DCN / slow-link) all-reduce of fp32/bf16
gradients dominates step time; quantizing the reduced payload to int8 with a
per-tensor scale cuts that traffic 4× (vs fp32). Plain quantization biases the
update, so we carry the quantization residual forward (error feedback, as in
1-bit Adam / EF-SGD): the compressed gradient stream converges to the true one.

Inside a single jit/GSPMD program the all-reduce is implicit, so the
quantize→dequantize pair models exactly the payload that would cross the slow
link; the ``compressed_psum`` variant is the explicit shard_map form used by
the elastic (non-SPMD) trainer and the unit tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "int8_quantize",
    "int8_dequantize",
    "ef_init",
    "ef_compress",
    "compressed_psum",
]


def int8_quantize(x):
    """Per-tensor symmetric int8. Returns (q, scale)."""
    x = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def ef_init(params):
    """Zero error-feedback residual tree (fp32)."""
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )


def ef_compress(grads, ef):
    """Quantize (grads + residual); return (dequantized grads, new residual)."""

    def one(g, e):
        tot = g.astype(jnp.float32) + e
        q, s = int8_quantize(tot)
        deq = int8_dequantize(q, s)
        return deq.astype(g.dtype), tot - deq

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(ef)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_e = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    return new_g, new_e


def compressed_psum(x, axis_name: str):
    """int8-payload psum across ``axis_name`` (for use under shard_map).

    Each participant quantizes its shard; the int8 payloads are summed in int32
    (exact), then dequantized with the max scale. This is the explicit form of
    what ``ef_compress`` models inside a single SPMD program.
    """
    q, s = int8_quantize(x)
    s_max = jax.lax.pmax(s, axis_name)
    # requantize against the shared scale so the integer sum is meaningful
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s_max), -127, 127)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * s_max
