"""Distributed-communication helpers: gradient compression, collective utils."""
from repro.comms.compress import (  # noqa: F401
    ef_init,
    ef_compress,
    int8_dequantize,
    int8_quantize,
)
