"""Config dataclasses for architectures and input shapes.

One ``ModelConfig`` per assigned architecture lives in
``repro/configs/<id>.py``; the shared shape grid lives here.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    mlp_type: str = "swiglu"  # swiglu | geglu | relu2 | gelu
    norm_eps: float = 1e-5
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0
    tie_embeddings: bool = False
    parallel_block: bool = False  # command-r style joint attn+FFN residual
    embed_scale: bool = False  # gemma multiplies embeddings by sqrt(d_model)
    attn_bias: bool = False
    sliding_window: int = 0  # 0 = full attention

    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    capacity_factor: float = 1.25

    # SSM (Mamba2 / zamba2 hybrid)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_head_dim: int = 64
    shared_attn_every: int = 0  # zamba2: shared attn+MLP block cadence

    # RWKV6
    rwkv: bool = False
    rwkv_lora_dim: int = 32
    rwkv_decay_lora_dim: int = 64

    # cross-attention (vlm / audio conditioning)
    cross_attn_every: int = 0  # every Nth layer has cross-attn (vlm)
    cross_attn_all_layers: bool = False  # musicgen: every layer cross-attends
    n_cross_tokens: int = 0  # stub modality frontend token count

    # numerics / runtime
    dtype: Any = jnp.bfloat16
    loss_chunk: int = 512  # sequence chunking for the softmax-xent head
    attn_chunk: int = 1024  # KV-block size for blocked attention
    scan_layers: bool = True
    remat: str = "nothing"  # nothing | dots | none
    kv_cache_dtype: str = "bf16"  # bf16 | int8 (quantized serving KV cache)

    source: str = ""  # citation tag from the assignment table

    # ---- derived helpers -------------------------------------------------
    @property
    def d_inner(self) -> int:  # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    @property
    def attn_free(self) -> bool:
        return self.rwkv

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode at 500k context without O(S) full-attn KV scoring?"""
        return self.rwkv or self.family in ("ssm", "hybrid") or self.sliding_window > 0

    def supports_shape(self, shape: "ShapeConfig") -> bool:
        if shape.name == "long_500k":
            return self.sub_quadratic
        return True

    def reduced(self) -> "ModelConfig":
        """Smoke-test scale config of the same family (runs on 1 CPU)."""
        kv = min(self.num_kv_heads, 2) if self.num_kv_heads else 0
        heads = 4 if self.num_heads else 0
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=4 if (self.shared_attn_every or self.cross_attn_every) else 2,
            d_model=64,
            num_heads=heads,
            num_kv_heads=kv if self.num_kv_heads > 1 else min(self.num_kv_heads, 1),
            head_dim=16,
            d_ff=128,
            vocab_size=256,
            num_experts=4 if self.num_experts else 0,
            num_experts_per_tok=min(self.num_experts_per_tok, 2),
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            shared_attn_every=2 if self.shared_attn_every else 0,
            cross_attn_every=2 if self.cross_attn_every else 0,
            n_cross_tokens=8 if self.n_cross_tokens else 0,
            rwkv_lora_dim=8,
            rwkv_decay_lora_dim=8,
            sliding_window=min(self.sliding_window, 32) if self.sliding_window else 0,
            attn_chunk=32,
            loss_chunk=32,
            dtype=jnp.float32,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
