"""zamba2-1.2b — 38L d_model=2048 (Mamba2) + shared attn block, vocab=32000.

Mamba2 (SSD, ssm_state=64) backbone; one weight-shared attention+MLP block
(32H GQA kv=32, d_ff=8192) interleaved every 6 Mamba layers.
[arXiv:2411.15242; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32_000,
    ssm_state=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_head_dim=64,
    shared_attn_every=6,
    rope_theta=10_000.0,
    source="arXiv:2411.15242; hf",
)
