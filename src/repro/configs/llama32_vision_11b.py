"""llama-3.2-vision-11b — 40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256.

Text decoder with cross-attention image layers every 5th layer; the vision
tower is a stub supplying precomputed patch embeddings via input_specs().
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128_256,
    mlp_type="swiglu",
    cross_attn_every=5,
    n_cross_tokens=1600,
    rope_theta=500_000.0,
    source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
)
