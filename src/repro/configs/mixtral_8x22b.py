"""mixtral-8x22b — 56L d_model=6144 48H (GQA kv=8) d_ff=16384, MoE 8e top-2.

Sliding-window attention (4096) per assignment; vocab=32768.
[arXiv:2401.04088; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32_768,
    mlp_type="swiglu",
    num_experts=8,
    num_experts_per_tok=2,
    sliding_window=4096,
    rope_theta=1_000_000.0,
    source="arXiv:2401.04088; hf",
)
