"""phi4-mini-3.8b — 32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064.

RoPE (partial fraction 0.75), SwiGLU, GQA, tied embeddings.
[arXiv:2412.08905; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=200_064,
    mlp_type="swiglu",
    tie_embeddings=True,
    rope_fraction=0.75,
    rope_theta=10_000.0,
    source="arXiv:2412.08905; hf",
)
