"""rwkv6-3b (Finch) — 32L d_model=2560, attention-free, d_ff=8960 vocab=65536.

RWKV6 time-mix with data-dependent decay (per-channel), token-shift ddlerp,
squared-ReLU channel-mix.  [arXiv:2404.05892; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=40,
    num_kv_heads=0,
    head_dim=64,
    d_ff=8960,
    vocab_size=65_536,
    rwkv=True,
    source="arXiv:2404.05892; hf",
)
