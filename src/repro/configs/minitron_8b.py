"""minitron-8b — 32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000.

Width-pruned Nemotron-4: squared-ReLU (non-gated) MLP, partial RoPE.
[arXiv:2407.14679; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=256_000,
    mlp_type="relu2",
    rope_fraction=0.5,
    rope_theta=10_000.0,
    source="arXiv:2407.14679; hf",
)
