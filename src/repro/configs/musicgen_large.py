"""musicgen-large — 48L d_model=2048 32H (kv=32) d_ff=8192 vocab=2048.

Decoder-only over EnCodec tokens; GELU MLP; cross-attention to precomputed
text-conditioning embeddings in every layer (frontend stubbed per assignment).
[arXiv:2306.05284; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    mlp_type="gelu",
    cross_attn_all_layers=True,
    n_cross_tokens=64,
    rope_theta=10_000.0,
    source="arXiv:2306.05284; hf",
)
