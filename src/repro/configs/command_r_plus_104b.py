"""command-r-plus-104b — 64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000.

Parallel attn+FFN residual block, no biases, tied embeddings.
[hf:CohereForAI/c4ai-command-r-v01; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    num_layers=64,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    head_dim=128,
    d_ff=33792,
    vocab_size=256_000,
    mlp_type="swiglu",
    parallel_block=True,
    tie_embeddings=True,
    rope_theta=75_000_000.0,
    source="hf:CohereForAI/c4ai-command-r-plus; unverified",
)
