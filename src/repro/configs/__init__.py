"""Architecture config registry.

``get_config(name)`` returns the full published config; ``--arch <id>`` in the
launchers resolves through here.  Each arch module exports ``CONFIG``.
"""
from __future__ import annotations

import importlib

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig

_ARCHS = {
    "gemma-2b": "gemma_2b",
    "minitron-8b": "minitron_8b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "command-r-plus-104b": "command_r_plus_104b",
    "musicgen-large": "musicgen_large",
    "llama-3.2-vision-11b": "llama32_vision_11b",
    "zamba2-1.2b": "zamba2_1_2b",
    "mixtral-8x7b": "mixtral_8x7b",
    "mixtral-8x22b": "mixtral_8x22b",
    "rwkv6-3b": "rwkv6_3b",
}


def list_archs() -> list[str]:
    return list(_ARCHS)


def get_config(name: str) -> ModelConfig:
    """Resolve an arch id; ``+`` suffixes select runtime variants:
    ``<arch>+kv8`` = int8-quantized serving KV cache."""
    import dataclasses

    parts = name.split("+")
    name, mods = parts[0], parts[1:]
    if name.endswith("-smoke"):
        cfg = get_config(name[: -len("-smoke")]).reduced()
    else:
        if name not in _ARCHS:
            raise KeyError(f"unknown arch {name!r}; available: {sorted(_ARCHS)}")
        mod = importlib.import_module(f"repro.configs.{_ARCHS[name]}")
        cfg = mod.CONFIG
    for m in mods:
        if m == "kv8":
            cfg = dataclasses.replace(cfg, kv_cache_dtype="int8",
                                      name=cfg.name + "+kv8")
        elif m.startswith("ac"):  # attention KV-chunk override, e.g. +ac512
            cfg = dataclasses.replace(cfg, attn_chunk=int(m[2:]),
                                      name=cfg.name + "+" + m)
        else:
            raise KeyError(f"unknown variant {m!r}")
    return cfg


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


__all__ = ["get_config", "get_shape", "list_archs", "ModelConfig", "ShapeConfig", "SHAPES"]
