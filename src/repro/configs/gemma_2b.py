"""gemma-2b — 18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000.

GeGLU MLP, head_dim=256, multi-query attention, tied embeddings scaled by
sqrt(d_model).  [arXiv:2403.08295; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256_000,
    mlp_type="geglu",
    tie_embeddings=True,
    embed_scale=True,
    rope_theta=10_000.0,
    norm_eps=1e-6,
    source="arXiv:2403.08295; hf",
)
