"""Production training launcher.

    python -m repro.launch.train --arch <id> [--smoke] [--steps N]
        [--batch B] [--seq S] [--microbatches K] [--compress]
        [--ckpt DIR] [--resume]

On a real TPU fleet this runs under ``jax.distributed.initialize()`` with the
production mesh; on this container use ``--smoke`` (reduced config, local
mesh). The loop is the deployable shape: sharded state, event-driven shard
queue, async checkpoints, restore-on-start.
"""
import argparse
import sys

from repro.core.clock import wall_time
from pathlib import Path


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--multi-pod", action="store_true",
                    help="build the 2×16×16 production mesh (real fleet)")
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp

    from repro import sharding as shd
    from repro.configs import get_config
    from repro.data import TokenDataset
    from repro.launch.mesh import make_local_mesh, make_production_mesh
    from repro.train import (TrainConfig, init_train_state, make_train_step,
                             state_shardings)
    from repro.train.checkpoint import (AsyncCheckpointer, latest_step,
                                        restore_checkpoint)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    tc = TrainConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 2),
                     total_steps=args.steps,
                     microbatches=args.microbatches,
                     compress="int8_ef" if args.compress else "none")
    mesh = (make_production_mesh(multi_pod=args.multi_pod)
            if not args.smoke else make_local_mesh())
    print(f"arch={cfg.name} mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    with shd.set_mesh(mesh):
        step_fn = jax.jit(
            make_train_step(cfg, tc),
            in_shardings=(state_shardings(cfg, tc, mesh), None),
            out_shardings=(state_shardings(cfg, tc, mesh), None),
            donate_argnums=(0,),
        )
        state = init_train_state(cfg, tc, jax.random.PRNGKey(0))
        start = 0
        ck = AsyncCheckpointer(args.ckpt, keep=3) if args.ckpt else None
        if args.resume and args.ckpt and latest_step(args.ckpt) is not None:
            abstract = jax.tree_util.tree_map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
            state, start = restore_checkpoint(
                args.ckpt, abstract, state_shardings(cfg, tc, mesh))
            print(f"resumed from step {start}")

        ds = TokenDataset(cfg.vocab_size, args.seq, seed=0)
        t0 = wall_time()
        m = {}
        for i in range(start, args.steps):
            batch = {k: jnp.asarray(v)
                     for k, v in ds.shard_batch(i, args.batch).items()}
            if cfg.family in ("vlm", "audio"):
                batch["cond"] = jnp.zeros(
                    (args.batch, cfg.n_cross_tokens, cfg.d_model), cfg.dtype)
            state, m = step_fn(state, batch)
            if (i + 1) % 10 == 0:
                print(f"step {i+1:5d} loss {float(m['loss']):.4f} "
                      f"({(wall_time()-t0)/(i-start+1):.2f}s/step)")
            if ck and (i + 1) % args.ckpt_every == 0:
                ck.save(i + 1, state)
        if ck:
            ck.save(args.steps, state)
            ck.wait()
    print(f"finished at loss {float(m.get('loss', float('nan'))):.4f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
