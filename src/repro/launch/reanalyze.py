"""Re-derive roofline terms for existing dry-run cells from their saved HLO
(no recompilation) after a byte/collective-model change.

    python -m repro.launch.reanalyze [--dir artifacts/dryrun]

Cells without a saved ``.hlo.txt.gz`` are listed for recompilation.
"""
import argparse
import gzip
import json
from pathlib import Path


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=None)
    args = ap.parse_args()

    from repro.roofline import analyze_hlo, derive_terms

    base = Path(args.dir) if args.dir else (
        Path(__file__).resolve().parents[3] / "artifacts" / "dryrun")
    missing = []
    updated = 0
    for jf in sorted(base.glob("*.json")):
        d = json.loads(jf.read_text())
        if not d.get("ok"):
            continue
        hf = jf.with_suffix("").with_suffix("")  # strip .json
        hf = base / (jf.stem + ".hlo.txt.gz")
        if not hf.exists():
            missing.append(jf.stem)
            continue
        hlo = gzip.open(hf, "rt").read()
        hm = analyze_hlo(hlo)
        flops_dev = max(d.get("cost_analysis_flops", 0.0), hm["flops"])
        bytes_dev = max(0.0, hm["bytes"])
        terms = derive_terms(
            flops_per_device=flops_dev,
            bytes_per_device=bytes_dev,
            collective_bytes_per_device=hm["collective_bytes"],
            chips=d["chips"],
            model_flops_total=d["model_flops"],
        )
        d["collectives"] = {"total": hm["collective_bytes"],
                            "by_kind": hm["by_kind"], "loops": hm["loops"]}
        d["flops_per_device"] = flops_dev
        d["bytes_per_device"] = bytes_dev
        d["hlo_walk_flops"] = hm["flops"]
        d["hlo_walk_bytes"] = hm["bytes"]
        d.update({k: v for k, v in terms.items() if k != "chips"})
        jf.write_text(json.dumps(d, indent=2, default=float))
        updated += 1
    print(f"updated {updated} cells from saved HLO")
    if missing:
        print(f"{len(missing)} cells lack saved HLO (recompile these):")
        for m in missing:
            print("  ", m)
    return 0


if __name__ == "__main__":
    main()
