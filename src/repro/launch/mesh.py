"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state; the dry-run sets XLA_FLAGS before first jax init.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def _axis_types_kwargs(n: int) -> dict:
    # jax < 0.5 has neither jax.sharding.AxisType nor the axis_types kwarg
    # on jax.make_mesh; Auto is the default there, so omitting it is exact.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n}


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips when ``multi_pod``."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_types_kwargs(len(axes)))


def make_local_mesh():
    """Whatever devices exist, as a (data,) mesh — smoke tests / examples."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",), **_axis_types_kwargs(1))
