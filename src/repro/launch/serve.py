"""Serving launcher: event-driven continuous-batching engine.

    python -m repro.launch.serve --arch <id> [--smoke] [--requests N] [--kv8]

The production shape: a request topic feeds engine replicas (each the
analogue of one autoscaled container); this launcher runs one replica with
a synthetic request stream and reports throughput + batching efficiency.
"""
import argparse
import sys

from repro.core.clock import wall_time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--kv8", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args(argv)

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.core import SimScheduler, Subscription, Topic
    from repro.models import model as M
    from repro.serve.engine import ContinuousBatchingEngine, PubSubFrontend

    name = args.arch + ("-smoke" if args.smoke else "") + \
        ("+kv8" if args.kv8 else "")
    cfg = get_config(name)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    sched = SimScheduler()
    req, resp = Topic("requests", sched), Topic("responses", sched)
    out = []
    Subscription(resp, "client", lambda m, c: (out.append(m.data), c.ack()))
    engine = ContinuousBatchingEngine(cfg, params, batch_size=args.slots,
                                      max_len=args.max_len)
    PubSubFrontend(engine, req, resp)

    rng = np.random.default_rng(0)
    t0 = wall_time()
    for i in range(args.requests):
        req.publish({"request_id": i,
                     "prompt": rng.integers(0, cfg.vocab_size,
                                            size=4 + i % 7).tolist(),
                     "max_new_tokens": args.max_new})
    sched.run(until=0.0)
    engine.run_until_drained()
    sched.run()
    dt = wall_time() - t0
    toks = sum(len(r["tokens"]) for r in out)
    print(f"{len(out)}/{args.requests} responses, {toks} tokens, "
          f"{toks/dt:.1f} tok/s, {toks/max(engine.steps,1):.2f} tokens/tick")
    return 0 if len(out) == args.requests else 1


if __name__ == "__main__":
    sys.exit(main())
