import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this jits the real step function (train_step for train shapes,
prefill/decode serving steps otherwise) against ShapeDtypeStruct inputs on the
production mesh, compiles it, and records:

* ``memory_analysis()``  — per-device bytes (proves the cell fits HBM),
* ``cost_analysis()``    — per-device FLOPs / bytes accessed,
* HLO-parsed collective link traffic (loop-aware),
* the derived three-term roofline (see repro.roofline).

One JSON artifact per cell lands in ``artifacts/dryrun``; ``--all`` sweeps
every cell in its own subprocess (compilation memory is returned to the OS
between cells), skipping cells whose artifact already exists.

Usage:
    python -m repro.launch.dryrun --one <arch> <shape> <single|multi>
    python -m repro.launch.dryrun --all [--mesh single|multi|both] [--force]
"""
import argparse
import gzip
import json
import sys

from repro.core.clock import wall_time
from pathlib import Path

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

# gradient-accumulation microbatches for the biggest trainers (activation fit)
TRAIN_MICROBATCHES = {
    "command-r-plus-104b": 8,
    "command-r-plus-104b+ac512": 4,  # smaller attn chunks free the HBM for mb=4
    "mixtral-8x22b": 4,
    "mixtral-8x7b": 2,
    "zamba2-1.2b": 2,
}


def cell_name(arch: str, shape: str, mesh: str) -> str:
    return f"{arch}__{shape}__{mesh}"


def _analytic_flops(cfg, shape, n_params: int, n_active: int) -> dict:
    """Assignment MODEL_FLOPS (6·N·D train / 2·N·D inference) + attention extra."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        tokens, mult = B * S, 6
    elif shape.kind == "prefill":
        tokens, mult = B * S, 2
    else:
        tokens, mult = B, 2
    model = float(mult) * n_active * tokens
    # analytic attention math (info only; 0 for attention-free paths)
    attn = 0.0
    H, hd, L = cfg.num_heads, cfg.head_dim, cfg.num_layers
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        W = min(S, cfg.sliding_window) if cfg.sliding_window else S
        if shape.kind == "decode":
            attn = 4.0 * B * L * H * hd * W * (mult / 2)
        else:
            eff = (W if cfg.sliding_window else S / 2)
            attn = 4.0 * B * S * L * H * hd * eff * (mult / 2)
    return {"model_flops": model, "attn_flops_analytic": attn}


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             save_hlo: bool = True) -> dict:
    import jax

    from repro import sharding as shd
    from repro.configs import get_config, get_shape
    from repro.launch.mesh import make_production_mesh
    from repro.models import model as M
    from repro.models.params import abstractify
    from repro.roofline import analyze_hlo, derive_terms
    from repro.serve import steps as sv
    from repro.train import (TrainConfig, abstract_train_state,
                             batch_defs, batch_shardings, make_train_step,
                             state_shardings)

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                 "kind": shape.kind, "ok": False}
    if not cfg.supports_shape(shape):
        rec.update(skipped=True, reason="full-attention arch at 500k decode "
                   "(sub-quadratic path required; see DESIGN.md)")
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = int(mesh.devices.size)
    B, S = shape.global_batch, shape.seq_len
    n_params = M.param_count(cfg)
    n_active = M.active_param_count(cfg)

    t0 = wall_time()
    with shd.set_mesh(mesh):
        if shape.kind == "train":
            tc = TrainConfig(microbatches=TRAIN_MICROBATCHES.get(arch, 1))
            fn = make_train_step(cfg, tc)
            args = (abstract_train_state(cfg, tc),
                    abstractify(batch_defs(cfg, B, S)))
            in_sh = (state_shardings(cfg, tc, mesh),
                     batch_shardings(cfg, B, S, mesh))
            out_sh = (in_sh[0], None)
        elif shape.kind == "prefill":
            fn = sv.make_prefill_step(cfg, max_len=S)
            params = M.abstract_params(cfg)
            inp = abstractify(sv.prefill_input_defs(cfg, B, S))
            in_defs = sv.prefill_input_defs(cfg, B, S)
            psh = shd.param_specs(M.model_defs(cfg), mesh)
            ish = shd.param_specs(in_defs, mesh)
            if cfg.family in ("vlm", "audio"):
                args = (params, inp["tokens"], inp["cond"])
                in_sh = (psh, ish["tokens"], ish["cond"])
            else:
                args = (params, inp["tokens"])
                in_sh = (psh, ish["tokens"])
            out_sh = None
        else:  # decode
            fn = sv.make_decode_step(cfg)
            params = M.abstract_params(cfg)
            cache = M.abstract_cache(cfg, B, S)
            inp = abstractify(sv.decode_input_defs(cfg, B))
            args = (params, cache, inp["token"], inp["pos"])
            dsh = shd.param_specs(sv.decode_input_defs(cfg, B), mesh)
            # weight-stationary serving: replicate weights over 'data' when
            # the TP shard fits the budget → no per-token FSDP all-gather
            model_ax = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
            tp_shard_bytes = 2.0 * n_params / model_ax
            # 4 GB budget: the CPU proxy carries an extra f32 weight copy, so
            # replication costs ~3× the bf16 shard; MoE expert stacks blow
            # past it (mixtral: measured 18.7 GB — refuted, see §Perf)
            policy = ("serve_replicated" if tp_shard_bytes <= 4e9 else "train")
            rec["weight_policy"] = policy
            in_sh = (shd.param_specs(M.model_defs(cfg), mesh, policy),
                     shd.param_specs(M.cache_defs(cfg, B, S), mesh),
                     dsh["token"], dsh["pos"])
            out_sh = None

        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
        lowered = jitted.lower(*args)
        t_lower = wall_time() - t0
        compiled = lowered.compile()
        t_compile = wall_time() - t0 - t_lower

    cost = compiled.cost_analysis() or {}
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "code_bytes": ma.generated_code_size_in_bytes,
        }
    except Exception as e:  # pragma: no cover
        mem = {"error": str(e)}
    hlo = compiled.as_text()
    hm = analyze_hlo(hlo)
    col = {"total": hm["collective_bytes"], "by_kind": hm["by_kind"],
           "loops": hm["loops"]}

    # cost_analysis counts while bodies once; the HLO walk is loop-aware.
    flops_dev = max(float(cost.get("flops", 0.0)), hm["flops"])
    bytes_dev = max(float(cost.get("bytes accessed", 0.0)), hm["bytes"])
    analytic = _analytic_flops(cfg, shape, n_params, n_active)
    terms = derive_terms(
        flops_per_device=flops_dev,
        bytes_per_device=bytes_dev,
        collective_bytes_per_device=col["total"],
        chips=chips,
        model_flops_total=analytic["model_flops"],
    )
    terms["cost_analysis_flops"] = float(cost.get("flops", 0.0))
    terms["cost_analysis_bytes"] = float(cost.get("bytes accessed", 0.0))
    terms["hlo_walk_flops"] = hm["flops"]
    terms["hlo_walk_bytes"] = hm["bytes"]
    arg_b = mem.get("argument_bytes", 0) or 0
    tmp_b = mem.get("temp_bytes", 0) or 0
    out_b = mem.get("output_bytes", 0) or 0
    rec.update(
        ok=True, n_params=n_params, n_active=n_active,
        flops_per_device=flops_dev, bytes_per_device=bytes_dev,
        collectives=col, memory=mem,
        hbm_per_device=arg_b + tmp_b,
        hbm_per_device_undonated=arg_b + tmp_b + out_b,
        fits_hbm=bool(arg_b + tmp_b < 16e9),
        **analytic, **terms,
        lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
    )
    if save_hlo:
        ARTIFACTS.mkdir(parents=True, exist_ok=True)
        with gzip.open(
            ARTIFACTS / (cell_name(arch, shape_name, mesh_kind) + ".hlo.txt.gz"),
            "wt",
        ) as f:
            f.write(hlo)
    return rec


def all_cells(mesh_filter: str) -> list[tuple[str, str, str]]:
    from repro.configs import SHAPES, get_config, list_archs

    meshes = {"single": ["single"], "multi": ["multi"],
              "both": ["single", "multi"]}[mesh_filter]
    cells = [
        (arch, shape, mesh)
        for mesh in meshes
        for arch in list_archs()
        for shape in SHAPES
    ]
    # cheap cells first: decode < prefill < train, then by d_model·layers
    def key(c):
        arch, shape, mesh = c
        cfg = get_config(arch)
        kind_rank = {"decode": 0, "prefill": 1, "train": 2}[SHAPES[shape].kind]
        return (mesh == "multi", kind_rank,
                cfg.d_model * cfg.num_layers * (cfg.num_experts or 1))
    return sorted(cells, key=key)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--one", nargs=3, metavar=("ARCH", "SHAPE", "MESH"))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--timeout", type=int, default=3600)
    ap.add_argument("--no-hlo", action="store_true")
    args = ap.parse_args()
    ARTIFACTS.mkdir(parents=True, exist_ok=True)

    if args.one:
        arch, shape, mesh = args.one
        rec = run_cell(arch, shape, mesh, save_hlo=not args.no_hlo)
        out = ARTIFACTS / (cell_name(arch, shape, mesh) + ".json")
        out.write_text(json.dumps(rec, indent=2, default=float))
        status = ("SKIP" if rec.get("skipped")
                  else "OK" if rec.get("ok") else "FAIL")
        print(f"[{status}] {arch} {shape} {mesh} "
              f"compile={rec.get('compile_s', '-')}s "
              f"dominant={rec.get('dominant', '-')}")
        return 0 if status != "FAIL" else 1

    if args.all:
        import subprocess

        cells = all_cells(args.mesh)
        if args.arch:
            cells = [c for c in cells if c[0] == args.arch]
        if args.shape:
            cells = [c for c in cells if c[1] == args.shape]
        failures = []
        for arch, shape, mesh in cells:
            out = ARTIFACTS / (cell_name(arch, shape, mesh) + ".json")
            if out.exists() and not args.force:
                prev = json.loads(out.read_text())
                if prev.get("ok") or prev.get("skipped"):
                    continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--one", arch, shape, mesh]
            if args.no_hlo:
                cmd.append("--no-hlo")
            t0 = wall_time()
            try:
                r = subprocess.run(cmd, timeout=args.timeout,
                                   capture_output=True, text=True)
                if r.returncode != 0 and not out.exists():
                    out.write_text(json.dumps({
                        "arch": arch, "shape": shape, "mesh": mesh,
                        "ok": False, "error": (r.stderr or "")[-4000:],
                    }, indent=2))
                if r.returncode != 0:
                    failures.append((arch, shape, mesh))
                    print(f"[FAIL {wall_time()-t0:6.0f}s] {arch} {shape} {mesh}")
                    print((r.stderr or "")[-1500:])
                else:
                    print(r.stdout.strip())
            except subprocess.TimeoutExpired:
                failures.append((arch, shape, mesh))
                out.write_text(json.dumps({
                    "arch": arch, "shape": shape, "mesh": mesh,
                    "ok": False, "error": f"timeout {args.timeout}s",
                }, indent=2))
                print(f"[TIMEOUT] {arch} {shape} {mesh}")
            sys.stdout.flush()
        print(f"done; {len(failures)} failures: {failures}")
        return 1 if failures else 0

    ap.print_help()
    return 2


if __name__ == "__main__":
    sys.exit(main())
