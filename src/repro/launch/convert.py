"""Conversion-service launcher: the paper's pipeline as a long-running worker.

    python -m repro.launch.convert [--slides N] [--size PIXELS]
        [--max-instances K] [--hedge SECONDS]

Stands up the full event chain (landing bucket → topic → push subscription →
autoscaled converters → DICOM store) on the real-threaded scheduler and
pushes N synthetic proprietary-format slides through it.
"""
import argparse
import sys

from repro.core.clock import wall_time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--slides", type=int, default=3)
    ap.add_argument("--size", type=int, default=512)
    ap.add_argument("--max-instances", type=int, default=2)
    ap.add_argument("--hedge", type=float, default=None)
    args = ap.parse_args(argv)

    from repro.core import ConversionPipeline, RealScheduler
    from repro.wsi import SyntheticScanner, convert_wsi_to_dicom

    sched = RealScheduler(workers=max(args.max_instances * 2, 4))
    pipe = ConversionPipeline(
        sched,
        convert=lambda data, meta: convert_wsi_to_dicom(data, meta),
        max_instances=args.max_instances, cold_start=0.0,
        hedge_after=args.hedge, scale_down_delay=2.0,
    )
    scanner = SyntheticScanner(seed=1)
    t0 = wall_time()
    for i in range(args.slides):
        pipe.ingest(f"slides/s{i:03d}.psv",
                    scanner.scan(args.size, args.size, 256),
                    {"slide_id": f"S{i:03d}"})
    sched.run(until=600.0)
    dt = wall_time() - t0
    ok = pipe.done_count() == args.slides
    print(f"{pipe.done_count()}/{args.slides} converted in {dt:.1f}s; "
          f"DICOM store: {pipe.dicom.list()}")
    for k, v in sorted(pipe.metrics.summary()["counters"].items()):
        print(f"  {k} = {v:g}")
    sched.shutdown()
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
